"""int8 gradient all-reduce with error feedback (DP strategy).

In-theme distributed-optimization trick: the data-parallel gradient
all-reduce is quantized to int8 with a per-tensor shared scale and an
error-feedback buffer (residual accumulation), cutting DP sync bytes 4x
vs f32 at negligible quality cost. Implemented with shard_map so the
collective is explicit:

  scale  = pmax(max|g + e|) / 127          (consensus scale)
  codes  = round((g + e)/scale)  in int8
  g_hat  = psum(codes) * scale / n_shards
  e_new  = (g + e) - codes * scale          (local residual)

Only wired for the dp strategy — TP/FSDP gradients are reduce-scattered
by GSPMD inside the backward pass where a custom collective would need
an HLO rewrite (documented trade-off in DESIGN.md).
"""
from __future__ import annotations

from functools import partial
from typing import Any

import inspect

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from . import adam

# jax >= 0.6 exposes jax.shard_map (check_vma=); 0.4.x has the
# experimental module (check_rep=). Resolve both once here.
_shard_map = getattr(jax, "shard_map", None)
if _shard_map is None:
    from jax.experimental.shard_map import shard_map as _shard_map
_CHECK_KW = ("check_vma" if "check_vma" in
             inspect.signature(_shard_map).parameters else "check_rep")

Params = Any


def _compress_psum(g: jax.Array, e: jax.Array, axis: str):
    ge = g.astype(jnp.float32) + e
    n = jax.lax.psum(1, axis)
    amax = jax.lax.pmax(jnp.max(jnp.abs(ge)), axis)
    scale = jnp.maximum(amax / 127.0, 1e-12)
    codes = jnp.clip(jnp.round(ge / scale), -127, 127)
    summed = jax.lax.psum(codes, axis)  # <= 127 * n, exact in f32 for n < 2^16
    g_hat = summed * scale / n
    e_new = ge - codes * scale
    return g_hat.astype(g.dtype), e_new


def init_error(params: Params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def make_dp_train_step(model, mesh: Mesh, acfg: adam.AdamConfig,
                       remat: str = "dots", axis: str = "data"):
    """shard_map train step: batch over ``axis``, params replicated,
    int8+error-feedback gradient reduction."""

    def step(params, opt_state, err, batch):
        def inner(params, opt_state, err, batch):
            loss, grads = jax.value_and_grad(
                lambda p: model.loss(p, batch, remat=remat))(params)
            out = jax.tree.map(partial(_compress_psum, axis=axis), grads, err)
            g_hat = jax.tree.map(lambda t: t[0], out,
                                 is_leaf=lambda x: isinstance(x, tuple))
            err2 = jax.tree.map(lambda t: t[1], out,
                                is_leaf=lambda x: isinstance(x, tuple))
            params2, opt2 = adam.update(acfg, g_hat, opt_state, params)
            loss = jax.lax.pmean(loss, axis)
            return params2, opt2, err2, loss

        rep = P()
        return _shard_map(
            inner, mesh=mesh,
            in_specs=(rep, rep, rep, P(axis)),
            out_specs=(rep, rep, rep, rep),
            **{_CHECK_KW: False},
        )(params, opt_state, err, batch)

    return jax.jit(step, donate_argnums=(0, 1, 2))
