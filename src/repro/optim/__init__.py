from . import adam  # noqa: F401
from .adam import AdamConfig, cosine_schedule  # noqa: F401
