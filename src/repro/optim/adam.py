"""Adam/AdamW built on raw pytrees (no optax in this environment).

Used by (a) the BRECQ reconstruction inner loop (paper: Adam, lr 1e-3 on
rounding logits, 4e-5 on activation step sizes) and (b) the pretraining
driver. Supports per-leaf learning-rate trees and ZeRO-friendly state
layout (states mirror the param tree exactly, so the same PartitionSpecs
apply).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Union

import jax
import jax.numpy as jnp

Params = Any


@dataclasses.dataclass(frozen=True)
class AdamConfig:
    lr: Union[float, Callable] = 1e-3
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0
    grad_clip: Optional[float] = None


def init(params: Params) -> dict:
    zeros = lambda: jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
    return {"m": zeros(), "v": zeros(), "count": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def update(cfg: AdamConfig, grads: Params, state: dict, params: Params,
           lr_tree: Optional[Params] = None) -> tuple[Params, dict]:
    """Returns (new_params, new_state). ``lr_tree`` optionally scales the
    learning rate per leaf (BRECQ uses different lrs for v vs act scales)."""
    count = state["count"] + 1
    if cfg.grad_clip is not None:
        gn = global_norm(grads)
        scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gn, 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)
    lr = cfg.lr(count) if callable(cfg.lr) else cfg.lr
    b1c = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** count.astype(jnp.float32)

    def upd(g, m, v, p, lr_leaf):
        g32 = g.astype(jnp.float32)
        m = cfg.b1 * m + (1 - cfg.b1) * g32
        v = cfg.b2 * v + (1 - cfg.b2) * g32 * g32
        step = lr * lr_leaf * (m / b1c) / (jnp.sqrt(v / b2c) + cfg.eps)
        if cfg.weight_decay:
            step = step + lr * lr_leaf * cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - step).astype(p.dtype), m, v

    if lr_tree is None:
        lr_tree = jax.tree.map(lambda _: 1.0, params)
    flat = jax.tree.map(upd, grads, state["m"], state["v"], params, lr_tree)
    new_params = jax.tree.map(lambda t: t[0], flat, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], flat, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], flat, is_leaf=lambda x: isinstance(x, tuple))
    return new_params, {"m": new_m, "v": new_v, "count": count}


def cosine_schedule(base_lr: float, warmup: int, total: int, min_frac: float = 0.1):
    def lr(count):
        c = count.astype(jnp.float32)
        warm = c / jnp.maximum(warmup, 1)
        t = jnp.clip((c - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
        cos = min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
        return base_lr * jnp.where(c < warmup, warm, cos)

    return lr
