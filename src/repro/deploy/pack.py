"""Leaf-level packed-int weight format + the calibration-free RTN path.

This is the deployment half of the quantizer: integer codes packed into
int8 containers along the reduction axis (``pack_int`` layout,
offset-binary) plus per-(group, out-channel) f32 scales. A packed linear
node in a params tree is

    {"w": int8 (..., K * bits / 8, N), "qscale": f32 (..., G, N), ...}

where ``G = K / group_size`` (``G == 1`` for per-channel / per-tensor
scales). Bits and group are *inferred from shapes* at the use site
(``K`` is known from the activation), so the node needs no static
metadata and slices cleanly through ``lax.scan`` over stacked layers.

Container promotion: codes quantized at ``b`` bits may be stored in a
wider container (e.g. 2-bit codes in a 4-bit field, or unpacked int8)
without changing their dequantized values — the unpack subtracts the
container's own offset. This is how mixed-precision layers share one
stacked leaf, and how a reduction dim not divisible by the packing
factor falls back to an int8 container instead of failing.

Everything here is functional and jit/eval_shape-safe (shape-driven
decisions only): ``launch/steps.py`` traces :func:`quantize_tree` to
build abstract serving params.
"""
from __future__ import annotations

import hashlib
import zlib
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.quantizer import pack_int, unpack_int

Array = jax.Array
Params = Any

# param-tree keys that must stay FP even though they hold a linear
# weight: the MoE router is read directly (no quant hook) by design.
SKIP_KEYS = ("router",)
# leaves under these top-level keys quantize at 8 bits regardless of the
# requested width (the paper keeps first/last layers 8-bit).
EIGHT_BIT_ROOTS = ("embed", "head")


def container_bits(bits: int, k: int) -> int:
    """Container width for ``bits``-wide codes over a K-row reduction dim.

    Sub-byte packing needs the field width to divide a byte (2/4-bit —
    the shape-based bits inference at the use site can only distinguish
    whole values-per-byte factors, so 3/5/6/7-bit codes store unpacked)
    and ``K`` divisible by the values-per-byte factor; otherwise the
    codes stay in an int8 container (values unchanged).
    """
    if bits >= 8 or 8 % bits != 0:
        return 8
    return bits if k % (8 // bits) == 0 else 8


def pack_codes(codes: Array, k: int, bits: int) -> Array:
    """(…, K, N) int8 codes -> packed (…, K*cbits/8, N) container."""
    return pack_int(codes, container_bits(bits, k), axis=-2)


def code_layout(wp: Array, k: int) -> tuple[int, int]:
    """(container bits, values-per-byte) of a packed codes leaf.

    The single shape→layout inference shared by :func:`dequant_leaf` and
    the qmm tier dispatcher (``kernels.qmatmul.ops.from_node``): ``k``
    is the reduction dim known from the activation, ``wp`` stores
    ``k * bits / 8`` rows along axis -2. Raises ``ValueError`` when the
    row count cannot be a packed view of ``k`` — callers attach the
    node path.
    """
    rows = wp.shape[-2]
    if rows == 0 or k % rows:
        raise ValueError(
            f"{rows} packed rows do not divide the reduction dim K={k} "
            f"(codes shape {wp.shape})")
    per = k // rows
    if per not in (1, 2, 4):
        raise ValueError(
            f"{per} values/byte is not a packable container width "
            f"(codes shape {wp.shape}, K={k}); expected 1, 2 or 4")
    return 8 // per, per


def dequant_leaf(wp: Array, qscale: Array, k: int) -> Array:
    """Packed node -> f32 weights. ``k`` is the original reduction dim.

    wp: (…, K * cbits/8, N) int8; qscale: (…, G, N) f32 broadcastable
    against the leading dims. Bits and group size are inferred from the
    shapes (``per = K // rows``, ``group = K // G``). This is the
    *reference* leaf view — serving never calls it per step: 2-D nodes
    run the ``qmm`` decode/prefill tiers and stacked (E, …) expert nodes
    the grouped tier, both dequantizing tile-wise in-kernel.
    """
    bits, _ = code_layout(wp, k)
    codes = unpack_int(wp, bits, k, axis=-2).astype(jnp.float32)
    g_rows = qscale.shape[-2]
    n = codes.shape[-1]
    cg = codes.reshape(*codes.shape[:-2], g_rows, k // g_rows, n)
    w = cg * qscale[..., :, None, :]
    return w.reshape(*codes.shape)


def rtn_codes(w: Array, bits: int, group: Optional[int] = None
              ) -> tuple[Array, Array]:
    """Symmetric minmax RTN -> (unpacked int8 codes, scales) for one leaf.

    w: (…, K, N). Scales are per-(group, out-channel); ``group`` falls
    back to per-channel (one group spanning K) when it does not divide K.
    Returns codes (…, K, N) int8 in the ``bits``-wide range and qscale
    (…, G, N) f32. The mixed-precision stacking path (``deploy.budget``)
    consumes the unpacked codes so layers quantized at different widths
    can share one promoted container."""
    k, n = w.shape[-2], w.shape[-1]
    g = group if (group and k % group == 0) else k
    qmax = 2 ** (bits - 1) - 1
    wg = w.astype(jnp.float32).reshape(*w.shape[:-2], k // g, g, n)
    amax = jnp.max(jnp.abs(wg), axis=-2, keepdims=True)
    scale = jnp.maximum(amax / qmax, 1e-8)
    codes = jnp.clip(jnp.round(wg / scale), -(2 ** (bits - 1)), qmax)
    return codes.reshape(w.shape).astype(jnp.int8), scale.squeeze(-2)


def rtn_pack_leaf(w: Array, bits: int, group: Optional[int] = None
                  ) -> tuple[Array, Array]:
    """:func:`rtn_codes` + :func:`pack_codes`: (packed codes, scales).

    Returns packed (…, K*cbits/8, N) int8 and qscale (…, G, N) f32.
    """
    codes, scales = rtn_codes(w, bits, group)
    return pack_codes(codes, w.shape[-2], bits), scales


def _leaf_plan(node: dict, keypath: tuple, bits: int):
    """Packing decision for one dict node: ``('embed', 8)``,
    ``('linear', b)`` or ``None`` (pass through). The single predicate
    shared by :func:`quantize_tree` and :func:`rtn_bits_by_path` so the
    manifest walk can never drift from the packing walk. Already-packed
    nodes (``table_qscale`` / ``qscale`` present) are never re-quantized."""
    if "table" in node and "table_qscale" not in node:
        return ("embed", 8)
    if ("w" in node and "qscale" not in node
            and getattr(node["w"], "ndim", 0) >= 2
            and (not keypath or keypath[-1] not in SKIP_KEYS)):
        return ("linear", 8 if keypath and keypath[0] in EIGHT_BIT_ROOTS else bits)
    return None


def quantize_tree(params: Params, bits: int, group: Optional[int] = None
                  ) -> Params:
    """Calibration-free RTN packing of a whole params tree.

    Every linear node ``{"w": (…, K, N)}`` becomes a packed node
    ``{"w": int8, "qscale": f32}`` consumed by the models' packed-weight
    path; the embedding table becomes int8 with a per-channel
    ``table_qscale``. Embed/head stay 8-bit, the MoE router stays FP,
    1-D leaves (norms, biases, gates, convs) pass through untouched, and
    already-packed nodes are left alone (idempotent).

    Pure shape-driven jnp — safe under jit and ``jax.eval_shape`` (the
    launch layer traces it to derive abstract serving params).
    """

    def walk(node, keypath):
        if not isinstance(node, dict):
            return node
        plan = _leaf_plan(node, keypath, bits)
        if plan is None:
            return {k: walk(v, keypath + (k,)) for k, v in node.items()}
        kind, b = plan
        out = dict(node)
        if kind == "embed":
            out["table"], out["table_qscale"] = rtn_pack_leaf(node["table"], b, None)
        else:
            out["w"], out["qscale"] = rtn_pack_leaf(node["w"], b, group)
        return out

    return walk(params, ())


def tree_bytes(tree) -> int:
    """Physical bytes of every array leaf (int8 counts 1 byte/value)."""
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree))


# ---------------------------------------------------------------------------
# integrity: per-leaf checksums + content digest (artifact schema v2)
# ---------------------------------------------------------------------------


def leaf_crc32(arr) -> int:
    """crc32 over a leaf's dtype/shape header + raw bytes.

    The header is folded in so a leaf whose bytes happen to survive a
    reshape or dtype reinterpretation still fails verification."""
    a = np.ascontiguousarray(jax.device_get(arr))
    crc = zlib.crc32(f"{a.dtype.str}{a.shape}".encode())
    return zlib.crc32(a.tobytes(), crc) & 0xFFFFFFFF


def tree_checksums(tree) -> dict[str, int]:
    """Flat '/'-joined leaf path -> :func:`leaf_crc32`, in the same key
    layout the checkpoint layer stores (so a verifying load can compare
    against exactly what `arrays.npz` holds)."""
    out: dict[str, int] = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path)
        out[key] = leaf_crc32(leaf)
    return out


def content_digest(checksums: dict[str, int]) -> str:
    """Order-independent digest of the whole artifact's leaf checksums."""
    h = hashlib.sha256()
    for key in sorted(checksums):
        h.update(f"{key}:{checksums[key]}\n".encode())
    return h.hexdigest()


def rtn_bits_by_path(params: Params, bits: int) -> dict[str, int]:
    """'/'-joined path -> code bits for the leaves :func:`quantize_tree`
    would pack, from the *unquantized* tree (shape-only walk; same
    :func:`_leaf_plan` predicate as the packing walk)."""

    def walk(node, keypath, out):
        if not isinstance(node, dict):
            return
        plan = _leaf_plan(node, keypath, bits)
        if plan is not None:
            kind, b = plan
            suffix = ("table",) if kind == "embed" else ()
            out["/".join(keypath + suffix)] = b
            return
        for key, v in node.items():
            walk(v, keypath + (key,), out)

    out: dict[str, int] = {}
    walk(params, (), out)
    return out
