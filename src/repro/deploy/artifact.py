"""`QuantizedArtifact`: the canonical deployment output of calibration.

BRECQ's product is not a fake-quantized f32 tree — it is a packed
integer model that real hardware can serve. This module turns a
:class:`~repro.core.reconstruction.PTQResult` (or a calibration-free RTN
pass) into one object that every downstream consumer speaks:

* ``params`` — a params-shaped pytree where each quantized weight is a
  packed node ``{"w": int8 codes, "qscale": f32 scales}`` (layout in
  :mod:`.pack`); models execute these through the ``QuantHook``
  weight-provider protocol (``packed_matmul`` -> ``qmm``), so serving
  holds int codes in HBM, not a dequantized f32 copy.
* ``act_scales`` — path -> learned LSQ step size (empty for weight-only).
* ``manifest`` — JSON-serializable static description: arch, per-path
  code bits (mixed precision preserved), group size, activation bits.
* ``stats`` — deployment telemetry: ``pack_wall_s``, ``artifact_bytes``,
  ``fp_bytes``, per-path ``bits_histogram``.

``save()``/``load()`` go through :class:`repro.ckpt.CheckpointManager`
(atomic step directory, npz arrays + manifest.json), so artifacts ride
the same fault-tolerant storage as training checkpoints.

Export is exact: baked fake-quant weights in ``params_q`` lie on the
quantizer grid, so ``quantize_int`` recovers the integer codes
bit-perfectly and ``dequant(pack(codes)) == params_q`` leaf for leaf.
Mixed-precision stacked leaves are stored at the widest layer's
container (a narrow code in a wide container dequantizes unchanged —
see pack.py "container promotion").
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Optional

import jax
import jax.numpy as jnp

from ..ckpt.checkpoint import CheckpointManager, CheckpointReadError
from ..core.quantizer import quantize_int
from .pack import (content_digest, pack_codes, quantize_tree,
                   rtn_bits_by_path, tree_bytes, tree_checksums)

Array = jax.Array
Params = Any

ARTIFACT_VERSION = 1
# Manifest schema. v1 (implicit — manifests without the key) predates
# integrity checking; v2 adds per-leaf crc32 checksums + content digest,
# verified by default at load. Bump when the saved layout changes
# incompatibly.
ARTIFACT_SCHEMA_VERSION = 2
_ESC = "%2F"  # act-scale paths contain '/', which is the ckpt tree separator


class ArtifactError(RuntimeError):
    """Base for deployment-artifact failures (load/verify/serve)."""


class ArtifactSchemaError(ArtifactError):
    """The artifact's manifest schema is missing, older, or newer than
    this build understands."""


class ArtifactCorruptionError(ArtifactError):
    """The artifact's stored bytes do not match its manifest checksums
    (bit flip, truncation, partial write). Names the offending leaf when
    one can be identified."""

    def __init__(self, message: str, leaf: Optional[str] = None):
        super().__init__(message)
        self.leaf = leaf


class ArtifactMismatchError(ArtifactError):
    """A structurally valid artifact does not match the model it is
    being served with (arch/dims disagree, or packing did not shrink)."""


@dataclasses.dataclass
class QuantizedArtifact:
    """Packed-int deployment artifact. See module docstring."""

    params: Params
    act_scales: dict[str, Array]
    manifest: dict
    stats: dict = dataclasses.field(default_factory=dict)

    # -- accounting -----------------------------------------------------------

    def nbytes(self) -> int:
        return tree_bytes(self.params) + tree_bytes(self.act_scales)

    @property
    def a_bits(self) -> Optional[int]:
        return self.manifest.get("a_bits")

    def hook(self):
        """Serving hook: LSQ activation fake-quant when calibrated, else
        the default weight-provider (packed matmuls via ``qmm``)."""
        from ..core.hooks import ServeHook
        from ..models.common import NO_QUANT

        if self.act_scales and self.a_bits:
            return ServeHook(self.act_scales, self.a_bits)
        return NO_QUANT

    # -- persistence ----------------------------------------------------------

    def save(self, directory: str, step: int = 0) -> None:
        """Atomic save through the checkpoint layer (npz + manifest).

        The write goes to a temp step directory and is renamed into
        place only after `manifest.json` exists, so a preempted save can
        never be mistaken for a complete artifact. Before writing, the
        manifest is stamped with ``schema_version``, per-leaf crc32
        ``checksums`` and a ``content_digest`` — :meth:`load` verifies
        all three by default."""
        mgr = CheckpointManager(directory, keep=1)
        tree = {"params": self.params,
                "act_scales": {k.replace("/", _ESC): v
                               for k, v in self.act_scales.items()}}
        checksums = tree_checksums(tree)
        self.manifest["schema_version"] = ARTIFACT_SCHEMA_VERSION
        self.manifest["checksums"] = checksums
        self.manifest["content_digest"] = content_digest(checksums)
        mgr.save(step, tree, meta={"manifest": self.manifest,
                                   "stats": self.stats})

    @classmethod
    def load(cls, directory: str, step: Optional[int] = None, *,
             verify: bool = True) -> "QuantizedArtifact":
        """Load a saved artifact, verifying integrity by default.

        Verification (``verify=True``): the manifest schema version must
        match this build, every stored leaf must hash to its manifest
        crc32, and the leaf set itself must match the manifest's
        ``content_digest``. Failures raise :class:`ArtifactSchemaError`
        or :class:`ArtifactCorruptionError` (naming the offending leaf).
        ``verify=False`` (serve's ``--no-verify``) skips all checks and
        loads whatever bytes are on disk."""
        mgr = CheckpointManager(directory)
        step = mgr.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no artifact checkpoint in {directory}")
        meta = mgr.manifest(step)["meta"]
        manifest = meta.get("manifest", {})
        if verify:
            _check_schema(manifest, directory)
        try:
            tree = mgr.restore_nested(step, strict=verify)
        except CheckpointReadError as e:
            if e.member is not None:
                # the zip layer's member CRC caught the damage first —
                # still name the leaf, like our own checksum pass would
                raise ArtifactCorruptionError(
                    f"artifact {directory} step {step}: leaf {e.member!r} "
                    f"is truncated or bit-flipped on disk: {e}",
                    leaf=e.member) from e
            raise ArtifactCorruptionError(
                f"artifact {directory} step {step} is unreadable "
                f"(truncated or corrupt): {e}") from e
        if verify:
            _verify_checksums(tree, manifest, directory)
        acts = {k.replace(_ESC, "/"): v
                for k, v in tree.get("act_scales", {}).items()}
        return cls(params=tree["params"], act_scales=acts,
                   manifest=manifest, stats=meta.get("stats", {}))


def _check_schema(manifest: dict, directory: str) -> None:
    schema = manifest.get("schema_version")
    if schema is None:
        raise ArtifactSchemaError(
            f"artifact {directory} has no manifest schema_version (pre-v2 "
            f"artifact, saved without integrity checksums). Re-export and "
            f"save it with this build to upgrade, or pass verify=False "
            f"(serve: --no-verify) to load it unchecked.")
    if schema != ARTIFACT_SCHEMA_VERSION:
        raise ArtifactSchemaError(
            f"artifact {directory} has manifest schema_version={schema} but "
            f"this build reads schema_version={ARTIFACT_SCHEMA_VERSION}. "
            f"Re-export the artifact with this build, or pass verify=False "
            f"(serve: --no-verify) to load it unchecked.")


def _verify_checksums(tree, manifest: dict, directory: str) -> None:
    want: dict = manifest.get("checksums") or {}
    if not want:
        raise ArtifactSchemaError(
            f"artifact {directory} declares schema_version="
            f"{manifest.get('schema_version')} but carries no checksums — "
            f"manifest is corrupt or hand-edited; pass verify=False to "
            f"load it unchecked.")
    got = tree_checksums(tree)
    for key in sorted(want):
        if key not in got:
            raise ArtifactCorruptionError(
                f"artifact {directory}: leaf {key!r} listed in the manifest "
                f"is missing from arrays.npz", leaf=key)
    for key in sorted(got):
        if key not in want:
            raise ArtifactCorruptionError(
                f"artifact {directory}: stored leaf {key!r} is not listed "
                f"in the manifest checksums", leaf=key)
        if int(want[key]) != got[key]:
            raise ArtifactCorruptionError(
                f"artifact {directory}: checksum mismatch at leaf {key!r} "
                f"(manifest crc32={int(want[key])}, stored bytes crc32="
                f"{got[key]}) — the leaf was truncated or bit-flipped on "
                f"disk", leaf=key)
    digest = content_digest({k: int(v) for k, v in want.items()})
    if manifest.get("content_digest") != digest:
        raise ArtifactCorruptionError(
            f"artifact {directory}: manifest content_digest does not match "
            f"its own checksum table — the manifest was edited")


# ---------------------------------------------------------------------------
# export: PTQResult -> artifact
# ---------------------------------------------------------------------------


def export(model, result, *, a_bits: Optional[int] = None,
           kv_dtype: str = "int8", kv_page_size: int = 16) -> QuantizedArtifact:
    """Pack a calibrated :class:`PTQResult` into a :class:`QuantizedArtifact`.

    Args:
      model: the block-graph model the result was calibrated for (its
        config feeds the manifest).
      result: ``PTQResult`` from :func:`repro.core.quantize` — hardened
        AdaRound weights live in ``params_q``; ``qstates`` carries the
        per-path (QState, QConfig) incl. mixed-precision bit widths and
        the 8-bit embed/head.
      a_bits: activation bit-width matching ``result.act_scales``; taken
        from ``result.stats`` when calibration recorded it.
      kv_dtype / kv_page_size: serving-side KV cache policy recorded in
        the manifest — ``ServeEngine.from_artifact`` defaults to them.

    Returns:
      Artifact whose dequantized weights equal ``result.params_q``
      bit-for-bit (same hard rounding; f32 accumulation at serve time).
    """
    t0 = time.time()
    if a_bits is None:
        a_bits = result.stats.get("a_bits") if isinstance(result.stats, dict) else None
    params_q = result.params_q
    art = jax.tree.map(lambda x: x, params_q)  # fresh containers, shared leaves
    bits_by_path: dict[str, int] = {}
    group = None

    # group stacked per-layer paths ("body.3/sub0/attn/wq") by their leaf
    stacked: dict[tuple, dict[int, str]] = {}
    flat: list[str] = []
    for path, (st, qc) in result.qstates.items():
        bits_by_path[path] = qc.bits
        if qc.group_size is not None:
            group = qc.group_size
        parts = path.split("/")
        if "." in parts[0]:
            sname, ri = parts[0].rsplit(".", 1)
            stacked.setdefault((sname, *parts[1:]), {})[int(ri)] = path
        else:
            flat.append(path)

    for key, by_layer in stacked.items():
        node = art[key[0]]
        for k in key[1:]:
            node = node[k]
        w = node["w"]  # (n_layers, …, K, N) baked fake-quant values
        n = w.shape[0]
        missing = set(range(n)) - set(by_layer)
        if missing:
            raise ValueError(f"unquantized layers {sorted(missing)} in "
                             f"stacked leaf {'/'.join(key)}")
        cbits = max(result.qstates[by_layer[i]][1].bits for i in range(n))
        codes, scales = [], []
        for i in range(n):
            st, qc = result.qstates[by_layer[i]]
            codes.append(quantize_int(w[i], st, qc))  # exact on-grid recovery
            scales.append(_scale_rows(st.scale, w[i].ndim))
        node["w"] = pack_codes(jnp.stack(codes), w.shape[-2], cbits)
        node["qscale"] = jnp.stack(scales)

    for path in flat:
        st, qc = result.qstates[path]
        if path == "embed/table":
            table = params_q["embed"]["table"]
            art["embed"]["table"] = quantize_int(table, st, qc)
            art["embed"]["table_qscale"] = st.scale.reshape(1, table.shape[-1])
        elif path == "head/w":
            w = params_q["head"]["w"]
            art["head"]["w"] = pack_codes(quantize_int(w, st, qc),
                                          w.shape[-2], qc.bits)
            art["head"]["qscale"] = _scale_rows(st.scale, w.ndim)
        else:
            raise ValueError(f"unstacked quantized path {path!r}")

    cfg = model.cfg
    manifest = {
        "version": ARTIFACT_VERSION,
        "schema_version": ARTIFACT_SCHEMA_VERSION,
        "arch": cfg.name, "family": cfg.family,
        "n_layers": cfg.n_layers, "d_model": cfg.d_model, "vocab": cfg.vocab,
        "tie_embeddings": cfg.tie_embeddings,
        "w_group": group, "a_bits": a_bits,
        "kv_dtype": kv_dtype, "kv_page_size": kv_page_size,
        "bits_by_path": bits_by_path,
    }
    artifact = QuantizedArtifact(art, dict(result.act_scales), manifest)
    artifact.stats = _deploy_stats(artifact, tree_bytes(params_q),
                                   time.time() - t0, bits_by_path)
    return artifact


def _scale_rows(scale: Array, w_ndim: int) -> Array:
    """QState scale (keepdims layout) -> the node's (…, G, N) qscale."""
    if scale.ndim == w_ndim + 1:  # grouped: (…, G, 1, N)
        return jnp.squeeze(scale, axis=-2)
    return scale  # per-channel/tensor keepdims already (…, 1, N)-like


# ---------------------------------------------------------------------------
# RTN fast path: params -> artifact without calibration
# ---------------------------------------------------------------------------


def rtn_artifact(params: Params, bits: int, group: Optional[int] = None,
                 *, cfg=None, kv_dtype: str = "int8",
                 kv_page_size: int = 16) -> QuantizedArtifact:
    """Calibration-free artifact: :func:`quantize_tree` + manifest/stats.

    The phantom ``dist.deploy`` replacement for quick serving experiments
    (``launch/serve.py --quant``); accuracy is plain RTN — use
    :func:`export` on a calibrated result for BRECQ quality.
    """
    t0 = time.time()
    bits_by_path = rtn_bits_by_path(params, bits)
    packed = jax.jit(quantize_tree, static_argnums=(1, 2))(params, bits, group)
    jax.block_until_ready(jax.tree.leaves(packed))
    manifest = {
        "version": ARTIFACT_VERSION,
        "schema_version": ARTIFACT_SCHEMA_VERSION,
        "arch": getattr(cfg, "name", None), "family": getattr(cfg, "family", None),
        "n_layers": getattr(cfg, "n_layers", None),
        "d_model": getattr(cfg, "d_model", None),
        "vocab": getattr(cfg, "vocab", None),
        "tie_embeddings": getattr(cfg, "tie_embeddings", None),
        "w_group": group, "a_bits": None,
        "kv_dtype": kv_dtype, "kv_page_size": kv_page_size,
        "bits_by_path": bits_by_path,
    }
    artifact = QuantizedArtifact(packed, {}, manifest)
    artifact.stats = _deploy_stats(artifact, tree_bytes(params),
                                   time.time() - t0, bits_by_path)
    return artifact


def _deploy_stats(artifact: QuantizedArtifact, fp_bytes: int, wall_s: float,
                  bits_by_path: dict[str, int]) -> dict:
    hist: dict[str, int] = {}
    for b in bits_by_path.values():
        hist[str(b)] = hist.get(str(b), 0) + 1
    return {"pack_wall_s": wall_s, "artifact_bytes": artifact.nbytes(),
            "fp_bytes": fp_bytes, "bits_histogram": hist}
