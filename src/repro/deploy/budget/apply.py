"""Budget → servable artifact: the deployment half of `repro.deploy.budget`.

:func:`budget_artifact` is the one-call flow behind
``launch/serve.py --budget-bytes/--budget-decode-ms``:

    sens table ──┐
                 ├─ solve_budget ── assign ── rtn_mixed_artifact ── serve
    cost table ──┘

Storage coupling: model bodies store weights as ``lax.scan`` stacks —
one leaf per (sub, module, matrix) holding all layers — and a stacked
leaf ships at the *widest* layer's container (pack.py "container
promotion"). Splitting bits inside a stack therefore buys zero bytes and
zero kernel time; :func:`storage_groups` ties each stack's per-layer
paths so the solver only spends budget where the artifact can cash it.
Under those groups every per-(path, bits) cost table is exactly additive.

Bytes accounting: scales, embed/head, norms and fp leaves cost the same
regardless of the assignment, so the fixed overhead is computed once
from a cheapest-assignment probe pack and subtracted from the budget —
the solver then bounds exactly the artifact's variable code bytes, and
``artifact.nbytes() <= budget`` holds by construction (the smoke job
verifies it).

The calibrated route uses the same assignment: pass
``BudgetSolution.assign`` as ``ReconConfig.per_layer_bits`` and export
the result for BRECQ-quality weights under the same byte/latency bound;
:func:`rtn_mixed_artifact` is the calibration-free fast path.
"""
from __future__ import annotations

import time
from typing import Optional

import jax
import jax.numpy as jnp

from ...core.sensitivity import SensTable
from ..artifact import (ARTIFACT_SCHEMA_VERSION, ARTIFACT_VERSION,
                        QuantizedArtifact, _deploy_stats)
from ..pack import (EIGHT_BIT_ROOTS, _leaf_plan, pack_codes, rtn_codes,
                    rtn_pack_leaf, tree_bytes)
from .cost import CostTable, bytes_cost_table, measure_cost_table
from .solver import (BIT_CHOICES, BudgetInfeasibleError, BudgetSolution,
                     solve_budget)


def _split_layer(path: str) -> Optional[tuple[tuple, int]]:
    """'body.3/sub0/attn/wq' -> (('body','sub0','attn','wq'), 3); None
    for paths without a layer index (the per-layer ↔ storage-leaf naming
    convention of artifact.export / ReconConfig.per_layer_bits)."""
    parts = path.split("/")
    if "." not in parts[0]:
        return None
    sname, ri = parts[0].rsplit(".", 1)
    if not ri.isdigit():
        return None
    return (sname, *parts[1:]), int(ri)


def storage_groups(paths) -> dict[str, tuple]:
    """path -> storage-stack key: per-layer paths of one scanned leaf
    share a group (same int container on disk and in HBM); paths without
    a layer index are their own group."""
    out: dict[str, tuple] = {}
    for p in paths:
        split = _split_layer(p)
        out[p] = split[0] if split is not None else (p,)
    return out


def _stacked_linears(params, n_layers: Optional[int]):
    """Yield ``(keypath, w)`` for every scanned linear stack in a params
    tree — the mixed-precision assignment domain. The walk reuses
    :func:`~repro.deploy.pack._leaf_plan` (bits value irrelevant here) so
    it can never drift from what RTN packing actually quantizes;
    embed/head (pinned 8-bit) and the fp router are excluded by it."""

    def walk(node, keypath):
        if not isinstance(node, dict):
            return
        plan = _leaf_plan(node, keypath, 4)
        if plan is None:
            for key, v in node.items():
                yield from walk(v, keypath + (key,))
            return
        kind, _ = plan
        if kind != "linear" or (keypath and keypath[0] in EIGHT_BIT_ROOTS):
            return
        w = node["w"]
        if w.ndim >= 3 and (n_layers is None or w.shape[0] == n_layers):
            yield keypath, w

    yield from walk(params, ())


def weight_shapes(params, n_layers: Optional[int] = None) -> dict[str, tuple]:
    """Per-layer path -> weight shape for every scanned linear stack —
    the same domain/shape dict a measured :class:`SensTable` carries, so
    cost tables can be built without running a calibration."""
    shapes: dict[str, tuple] = {}
    for keypath, w in _stacked_linears(params, n_layers):
        for i in range(w.shape[0]):
            shapes["/".join((f"{keypath[0]}.{i}", *keypath[1:]))] = \
                tuple(w.shape[1:])
    return shapes


def _rtn_sq_err(w, bits: int, group: Optional[int]):
    """Per-layer Σ(w - RTN(w))² over a stacked leaf (L, …, K, N) — same
    scale/round/clip math as :func:`~repro.deploy.pack.rtn_codes`."""
    k, n = w.shape[-2], w.shape[-1]
    g = group if (group and k % group == 0) else k
    qmax = 2.0 ** (bits - 1) - 1
    wg = w.astype(jnp.float32).reshape(*w.shape[:-2], k // g, g, n)
    amax = jnp.max(jnp.abs(wg), axis=-2, keepdims=True)
    scale = jnp.maximum(amax / qmax, 1e-8)
    dq = jnp.clip(jnp.round(wg / scale), -(qmax + 1), qmax) * scale
    return jnp.sum(((wg - dq) ** 2).reshape(w.shape[0], -1), axis=1)


def weight_sens_table(params, n_layers: Optional[int] = None, *,
                      bit_choices=BIT_CHOICES,
                      group: Optional[int] = None) -> SensTable:
    """Calibration-free sensitivity proxy: per-layer RTN weight error.

    ``diag[(path, b)]`` is the summed squared round-to-nearest error of
    that layer's weights at ``b`` bits — no Fisher weighting, no block
    propagation, no interactions (``offdiag`` is empty). It is the
    zero-cost stand-in ``serve --budget-*`` uses when no measured table
    (:meth:`SensTable.load`) is supplied; the solver, groups and cost
    accounting are identical either way, only the loss numbers are
    cruder. Paths/shapes follow the per-layer convention of
    ``core.sensitivity.measure`` (``body.{i}/sub0/attn/wq``).
    """
    diag: dict[tuple[str, int], float] = {}
    block_of: dict[str, int] = {}
    shapes: dict[str, tuple] = {}
    for keypath, w in _stacked_linears(params, n_layers):
        errs = {b: jax.device_get(_rtn_sq_err(w, b, group))
                for b in bit_choices}
        for i in range(w.shape[0]):
            p = "/".join((f"{keypath[0]}.{i}", *keypath[1:]))
            shapes[p] = tuple(w.shape[1:])
            block_of[p] = i
            for b in bit_choices:
                diag[(p, b)] = float(errs[b][i])
    if not shapes:
        raise ValueError("params tree has no scanned linear stacks to "
                         "assign mixed precision over")
    return SensTable(diag=diag, offdiag={}, block_of=block_of, shapes=shapes)


def rtn_mixed_artifact(params, assign: dict[str, int], *,
                       group: Optional[int] = None, cfg=None,
                       default_bits: int = 2, kv_dtype: str = "int8",
                       kv_page_size: int = 16) -> QuantizedArtifact:
    """Calibration-free artifact with *per-layer* bits.

    The mixed-precision counterpart of :func:`~repro.deploy.rtn_artifact`:
    ``assign`` maps per-layer paths (``body.{i}/sub0/attn/wq``) to code
    bits; each scanned stack packs every layer's codes at its own width
    into the stack's widest container (the same promotion rule as the
    calibrated ``export``), embed/head stay 8-bit, the router stays fp.
    Quantizable leaves ``assign`` does not cover fall back to
    ``default_bits`` — keep it at the solver's cheapest choice so budget
    accounting stays exact.
    """
    t0 = time.time()
    stack_assign: dict[tuple, dict[int, int]] = {}
    for p, b in assign.items():
        split = _split_layer(p)
        if split is None:
            raise ValueError(f"assignment path {p!r} has no layer index "
                             f"('body.{{i}}/…' expected)")
        stack_assign.setdefault(split[0], {})[split[1]] = int(b)

    bits_by_path: dict[str, int] = {}
    matched: set[tuple] = set()

    def walk(node, keypath):
        if not isinstance(node, dict):
            return node
        plan = _leaf_plan(node, keypath, default_bits)
        if plan is None:
            return {k: walk(v, keypath + (k,)) for k, v in node.items()}
        kind, b = plan
        out = dict(node)
        if kind == "embed":
            out["table"], out["table_qscale"] = rtn_pack_leaf(
                node["table"], b, None)
            bits_by_path["/".join(keypath + ("table",))] = b
            return out
        w = node["w"]
        by_layer = stack_assign.get(keypath)
        if by_layer is None or w.ndim < 3:
            out["w"], out["qscale"] = rtn_pack_leaf(w, b, group)
            bits_by_path["/".join(keypath)] = b
            return out
        matched.add(keypath)
        layer_bits = [by_layer.get(i, default_bits) for i in range(w.shape[0])]
        codes, scales = [], []
        for i, lb in enumerate(layer_bits):
            c, s = rtn_codes(w[i], lb, group)
            codes.append(c)
            scales.append(s)
        # container promotion: the stack ships at the widest layer's width
        out["w"] = pack_codes(jnp.stack(codes), w.shape[-2], max(layer_bits))
        out["qscale"] = jnp.stack(scales)
        for i, lb in enumerate(layer_bits):
            bits_by_path["/".join((f"{keypath[0]}.{i}", *keypath[1:]))] = lb
        return out

    packed = walk(params, ())
    unmatched = set(stack_assign) - matched
    if unmatched:
        raise ValueError(
            f"assignment names storage stacks absent from the params tree: "
            f"{sorted('/'.join(k) for k in unmatched)}")
    manifest = {
        "version": ARTIFACT_VERSION,
        "schema_version": ARTIFACT_SCHEMA_VERSION,
        "arch": getattr(cfg, "name", None),
        "family": getattr(cfg, "family", None),
        "n_layers": getattr(cfg, "n_layers", None),
        "d_model": getattr(cfg, "d_model", None),
        "vocab": getattr(cfg, "vocab", None),
        "tie_embeddings": getattr(cfg, "tie_embeddings", None),
        "w_group": group, "a_bits": None,
        "kv_dtype": kv_dtype, "kv_page_size": kv_page_size,
        "bits_by_path": bits_by_path,
    }
    artifact = QuantizedArtifact(packed, {}, manifest)
    artifact.stats = _deploy_stats(artifact, tree_bytes(params),
                                   time.time() - t0, bits_by_path)
    return artifact


def budget_artifact(params, sens: SensTable, budget: float, *,
                    kind: str = "bytes", cfg=None,
                    group: Optional[int] = None, method: str = "exact",
                    bit_choices=BIT_CHOICES, m: int = 1,
                    cost_table: Optional[CostTable] = None,
                    kv_dtype: str = "int8", kv_page_size: int = 16
                    ) -> tuple[QuantizedArtifact, BudgetSolution, CostTable]:
    """Budget in, servable artifact out (the ``serve --budget-*`` core).

    Args:
      params: fp params tree of the model to deploy.
      sens: sensitivity table (measured, or :func:`weight_sens_table`).
      budget: ``kind='bytes'``: total artifact bytes (codes + scales +
        embed/head + fp leaves — what :meth:`QuantizedArtifact.nbytes`
        reports); ``kind='decode_ms'``: summed per-layer decode matmul
        time under the measured table (attention/norm time is
        assignment-independent and excluded).
      cost_table: override the default table (analytic bytes table, or a
        freshly measured ``decode_ms`` table at ``m`` rows).
      m: decode activation rows to time for ``kind='decode_ms'``.

    Returns:
      ``(artifact, solution, cost_table)``. The artifact manifest gains
      ``'budget'`` (solution + accounting) and — for measured tables —
      the per-backend ``'cost_tables'`` cache.
    """
    groups = storage_groups(sens.shapes)
    bmin = min(bit_choices)
    all_min = {p: bmin for p in sens.shapes}

    if kind == "bytes":
        table = cost_table or bytes_cost_table(sens.shapes, bit_choices)
        probe = rtn_mixed_artifact(params, all_min, group=group, cfg=cfg,
                                   default_bits=bmin)
        overhead = probe.nbytes() - table.assign_cost(all_min)
        try:
            sol = solve_budget(sens, table, budget - overhead, groups=groups,
                               bit_choices=bit_choices, method=method)
        except BudgetInfeasibleError:
            raise BudgetInfeasibleError(
                f"budget {budget:g} bytes leaves {budget - overhead:g} for "
                f"weight codes after {overhead:g} fixed bytes (scales, "
                f"embed/head, fp leaves) — below the all-{bmin}-bit floor "
                f"of {table.assign_cost(all_min):g}") from None
    elif kind == "decode_ms":
        table = cost_table or measure_cost_table(sens.shapes, m=m,
                                                 bit_choices=bit_choices)
        overhead = 0.0
        sol = solve_budget(sens, table, budget, groups=groups,
                           bit_choices=bit_choices, method=method)
    else:
        raise ValueError(f"unknown budget kind {kind!r} (bytes | decode_ms)")

    art = rtn_mixed_artifact(params, sol.assign, group=group, cfg=cfg,
                             default_bits=bmin, kv_dtype=kv_dtype,
                             kv_page_size=kv_page_size)
    info = sol.to_json()
    # the solution's own budget is the overhead-reduced solver bound;
    # report the user-facing artifact budget as 'budget'
    info.update({"overhead_bytes": overhead, "solver_budget": info["budget"],
                 "budget": budget, "artifact_bytes": art.nbytes()})
    art.manifest["budget"] = info
    if table.kind != "bytes":
        art.manifest.setdefault("cost_tables", {})[table.backend] = \
            table.to_json()
    if kind == "bytes" and art.nbytes() > budget:
        raise AssertionError(
            f"budget accounting drift: artifact is {art.nbytes()} bytes "
            f"against a {budget:g}-byte budget (overhead {overhead:g} + "
            f"solver cost {sol.cost:g})")
    return art, sol, table
