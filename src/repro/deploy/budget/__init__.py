"""Budgeted mixed-precision deployment (BRECQ Sec. 3.4, CalibTIP-style).

Give it a budget — artifact bytes or decode milliseconds — and it ships
the best servable artifact under it:

* :mod:`.solver` — exact constrained bit assignment over a sensitivity
  table (Pareto-merge DP, brute-force-verified), with a Lagrangian
  approximation and the genetic search as a cross-check baseline.
* :mod:`.cost` — per-(path, bits) cost tables: container-aware bytes,
  or measured per-layer qmm tier time (which doubles as the measured
  dispatch table replacing the ``DECODE_M_MAX`` heuristic).
* :mod:`.apply` — assignment → packed artifact: storage-stack groups,
  the calibration-free RTN proxy sensitivity, per-layer mixed RTN
  packing with container promotion, and the one-call
  :func:`budget_artifact` behind ``serve --budget-bytes/--budget-decode-ms``.

See ``docs/budget.md``.
"""
from .apply import (budget_artifact, rtn_mixed_artifact, storage_groups,
                    weight_sens_table, weight_shapes)
from .cost import (CostTable, bytes_cost_table, ensure_cost_table,
                   install_dispatch, measure_cost_table)
from .solver import (BudgetInfeasibleError, BudgetSolution, brute_force,
                     grouped_problem, solve_budget)

__all__ = [
    "BudgetInfeasibleError", "BudgetSolution", "CostTable",
    "brute_force", "budget_artifact", "bytes_cost_table",
    "ensure_cost_table", "grouped_problem", "install_dispatch",
    "measure_cost_table", "rtn_mixed_artifact", "solve_budget",
    "storage_groups", "weight_sens_table", "weight_shapes",
]
