"""Per-layer deployment cost tables: analytic bytes + measured kernel time.

The budget solver consumes a :class:`CostTable` — a per-(path, bits)
additive cost in one unit:

* ``bytes_cost_table`` — physical packed-code bytes, *container-aware*:
  a width that does not pack (W3, or K not divisible by the packing
  factor) is billed at its int8 container, exactly what
  ``deploy.pack.container_bits`` stores. The analytic FLOP/byte roofline
  of ``core.mixed_precision.TPUCostModel`` scores logical bits; this
  table scores what the artifact actually ships.

* ``measure_cost_table`` — wall-clock of each layer's *eligible qmm
  tiers* (``qgemv`` decode vs prefill GEMM for 2-D nodes at decode row
  counts, the grouped kernel for stacked expert nodes), timed AOT-
  compiled at the layer's real (K, N[, E]) shape and container bits on
  the current backend. The per-(path, bits) cost is the best tier's
  time; the winning tier doubles as a *measured dispatch table*
  (:func:`install_dispatch`) replacing the hard-coded
  ``DECODE_M_MAX`` heuristic that ``BENCH_serve.json`` already caught
  being wrong on CPU (``decode_ratio_tier_vs_legacy < 1``).

Measured tables are cached in the artifact manifest per backend
(:func:`ensure_cost_table`), so a served artifact re-times its layers at
most once per (backend, decode batch).
"""
from __future__ import annotations

import dataclasses
import json
import time
from typing import Optional, Sequence

import numpy as np

from ..pack import container_bits

# decode-region tiers a 2-D packed node can run; 3-D expert stacks only
# ever run the grouped kernel
_DENSE_TIERS = ("decode", "prefill")


@dataclasses.dataclass
class CostTable:
    """Additive per-(path, bits) deployment cost.

    Attributes:
      kind: cost unit — ``'bytes'`` or ``'decode_ms'``.
      backend: ``'analytic'`` or the jax backend that timed it.
      costs: (path, bits) -> cost in ``kind`` units.
      tiers: (path, bits) -> winning qmm tier (measured tables only).
      dispatch: ``"K,N,container_bits"`` -> winning decode-region tier
        (the measured dispatch table, JSON-key friendly).
      meta: provenance (decode rows ``m``, reps, unique shapes timed…).
    """

    kind: str
    backend: str
    costs: dict[tuple[str, int], float]
    tiers: dict[tuple[str, int], str] = dataclasses.field(default_factory=dict)
    dispatch: dict[str, str] = dataclasses.field(default_factory=dict)
    meta: dict = dataclasses.field(default_factory=dict)

    def cost(self, path: str, bits: int) -> float:
        try:
            return self.costs[(path, bits)]
        except KeyError:
            raise KeyError(
                f"cost table ({self.kind}, {self.backend}) has no entry for "
                f"({path!r}, {bits}); available bits for known paths: "
                f"{sorted({b for _, b in self.costs})}") from None

    def assign_cost(self, assign: dict[str, int]) -> float:
        """Total cost of an assignment — the solver/GA constraint value."""
        return sum(self.cost(p, b) for p, b in assign.items())

    # -- persistence (manifest / JSON file) -----------------------------------

    def to_json(self) -> dict:
        return {
            "kind": self.kind, "backend": self.backend,
            "costs": [[p, b, c] for (p, b), c in sorted(self.costs.items())],
            "tiers": [[p, b, t] for (p, b), t in sorted(self.tiers.items())],
            "dispatch": dict(self.dispatch), "meta": dict(self.meta),
        }

    @classmethod
    def from_json(cls, doc: dict) -> "CostTable":
        return cls(kind=doc["kind"], backend=doc["backend"],
                   costs={(p, int(b)): float(c) for p, b, c in doc["costs"]},
                   tiers={(p, int(b)): t for p, b, t in doc.get("tiers", [])},
                   dispatch=dict(doc.get("dispatch", {})),
                   meta=dict(doc.get("meta", {})))

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_json(), f, indent=1)

    @classmethod
    def load(cls, path: str) -> "CostTable":
        with open(path) as f:
            return cls.from_json(json.load(f))


def bytes_cost_table(shapes: dict[str, tuple],
                     bit_choices: Sequence[int] = (2, 4, 8)) -> CostTable:
    """Packed-code bytes per (path, bits), container-aware.

    ``shapes`` maps each path to its per-layer weight shape
    ``(…, K, N)`` (a ``SensTable.shapes`` dict). Scale/embed/norm bytes
    are assignment-independent and excluded — deployment flows account
    for them as a fixed overhead against the total artifact budget.
    """
    costs: dict[tuple[str, int], float] = {}
    for p, shape in shapes.items():
        *lead, k, n = shape
        e = int(np.prod(lead)) if lead else 1
        for b in bit_choices:
            costs[(p, b)] = e * k * n * container_bits(b, k) / 8.0
    return CostTable(kind="bytes", backend="analytic", costs=costs,
                     meta={"container_aware": True})


def _time_compiled(fn, x, *, inner: int = 8, reps: int = 3,
                   warmup: int = 1) -> float:
    """Best-of-``reps`` wall of ``inner`` back-to-back calls, in ms/call."""
    import jax

    for _ in range(warmup):
        jax.block_until_ready(fn(x))
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        for _ in range(inner):
            out = fn(x)
        jax.block_until_ready(out)
        best = min(best, (time.perf_counter() - t0) / inner)
    return best * 1e3


def measure_cost_table(shapes: dict[str, tuple], *, m: int = 1,
                       bit_choices: Sequence[int] = (2, 4, 8),
                       inner: int = 8, reps: int = 3,
                       seed: int = 0) -> CostTable:
    """Time every layer's eligible qmm tiers at its real shape and bits.

    Args:
      shapes: path -> per-layer weight shape: ``(K, N)`` dense (runs the
        decode/prefill tiers at ``m`` activation rows) or ``(E, K, N)``
        stacked experts (grouped tier, ``m`` rows per expert).
      m: decode-step activation rows (the serving batch).
      bit_choices: widths to cost; each is timed at its *container*
        width (a W3 or ragged-K layer runs — and is billed — as int8).
      inner/reps: timing loop shape (best-of-reps of inner calls).

    Returns:
      ``CostTable(kind='decode_ms')`` whose per-entry cost is the best
      eligible tier's ms/call and whose ``dispatch`` records the winner
      per (K, N, container) — feed it to :func:`install_dispatch`.

    Unique (shape, container) pairs are timed once and fanned out to all
    paths that share them, so the cost of measuring scales with the
    number of distinct layer geometries, not the depth.
    """
    import jax
    import jax.numpy as jnp

    from ...kernels.qmatmul import ops as qmm_ops
    from ...kernels.qmatmul.ops import QuantizedLinear, qmm

    rng = np.random.default_rng(seed)
    uniq: dict[tuple, dict] = {}  # (shape, cbits) -> {"ms": …, "tier": …}
    t0 = time.time()

    def timed(shape: tuple, cb: int) -> dict:
        key = (tuple(shape), cb)
        if key in uniq:
            return uniq[key]
        *lead, k, n = shape
        packed_shape = (*lead, k * cb // 8, n)
        packed = jnp.asarray(
            rng.integers(-128, 128, packed_shape), jnp.int8)
        scales = jnp.asarray(rng.uniform(0.01, 0.1, (*lead, 1, n)), jnp.float32)
        qw = QuantizedLinear(packed, scales, cb, k)
        if lead:  # stacked experts: only the grouped tier exists
            x = jnp.asarray(rng.normal(size=(lead[0], m, k)), jnp.float32)
            fc = jax.jit(lambda x: qmm(x, qw)).lower(x).compile()
            uniq[key] = {"ms": _time_compiled(fc, x, inner=inner, reps=reps),
                         "tier": "grouped"}
            return uniq[key]
        x = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
        per_tier: dict[str, float] = {}
        for tier in _DENSE_TIERS:
            if tier == "decode" and m > qmm_ops.DECODE_M_MAX:
                continue  # the gemv kernel is a skinny-M specialization
            try:
                qmm_ops.set_decode_tier(tier == "decode")
                fc = jax.jit(lambda x: qmm(x, qw)).lower(x).compile()
            finally:
                qmm_ops.set_decode_tier(None)
            per_tier[tier] = _time_compiled(fc, x, inner=inner, reps=reps)
        tier = min(per_tier, key=per_tier.get)
        uniq[key] = {"ms": per_tier[tier], "tier": tier,
                     "per_tier": per_tier, "k": k, "n": n}
        return uniq[key]

    costs: dict[tuple[str, int], float] = {}
    tiers: dict[tuple[str, int], str] = {}
    dispatch: dict[str, str] = {}
    for p, shape in shapes.items():
        k = shape[-2]
        for b in bit_choices:
            cb = container_bits(b, k)
            r = timed(tuple(shape), cb)
            costs[(p, b)] = r["ms"]
            tiers[(p, b)] = r["tier"]
            if "k" in r:  # dense: record the measured dispatch winner
                dispatch[f"{r['k']},{r['n']},{cb}"] = r["tier"]
    return CostTable(
        kind="decode_ms", backend=jax.default_backend(), costs=costs,
        tiers=tiers, dispatch=dispatch,
        meta={"m": m, "inner": inner, "reps": reps,
              "unique_shapes": len(uniq), "measure_wall_s":
              round(time.time() - t0, 3)})


def install_dispatch(table: Optional[CostTable]) -> None:
    """Install a measured table's tier winners as the qmm dispatch table.

    ``select_tier`` consults it for decode-shaped 2-D matmuls whenever
    the dispatch mode resolves to ``'measured'`` (automatic once a table
    is installed; ``REPRO_QMM_DISPATCH=heuristic`` opts out). ``None``
    clears the table.
    """
    from ...kernels.qmatmul import ops as qmm_ops

    if table is None:
        qmm_ops.set_dispatch_table(None)
        return
    parsed = {}
    for key, tier in table.dispatch.items():
        k, n, cb = (int(v) for v in key.split(","))
        parsed[(k, n, cb)] = tier
    qmm_ops.set_dispatch_table(parsed)


def ensure_cost_table(artifact, shapes: dict[str, tuple], *, m: int = 1,
                      bit_choices: Sequence[int] = (2, 4, 8),
                      inner: int = 8, reps: int = 3) -> CostTable:
    """Measured cost table for an artifact, cached in its manifest.

    Looks up ``manifest['cost_tables'][backend]``; a hit with matching
    decode rows ``m`` is returned without touching the kernels.
    Otherwise the layers are timed (:func:`measure_cost_table`) and the
    result is stamped into the manifest — re-``save()`` the artifact to
    persist the cache for the next load.
    """
    import jax

    backend = jax.default_backend()
    cached = (artifact.manifest.get("cost_tables") or {}).get(backend)
    if cached is not None and cached.get("meta", {}).get("m") == m:
        return CostTable.from_json(cached)
    table = measure_cost_table(shapes, m=m, bit_choices=bit_choices,
                               inner=inner, reps=reps)
    artifact.manifest.setdefault("cost_tables", {})[backend] = table.to_json()
    return table
