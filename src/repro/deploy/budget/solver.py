"""Exact budgeted mixed-precision solver (CalibTIP direction).

``solve_budget`` picks per-layer bit widths from a small choice set to
minimize the predicted task loss of a :class:`~repro.core.sensitivity.
SensTable` — the diagonal per-layer sensitivities plus the tabulated
2-bit intra-block pair interactions, i.e. exactly the objective
:func:`repro.core.mixed_precision.fitness` scores — subject to a budget
on any per-(path, bits) additive cost (:class:`.cost.CostTable`: model
bytes or measured decode latency).

Method (``method='exact'``): the interaction terms only couple paths
inside a block, so the assignment graph decomposes into small
*components* (connected via offdiag pairs and group ties). Each
component is enumerated exhaustively and reduced to its Pareto-optimal
(cost, loss) options; components are then combined by a Pareto-merge
dynamic program (pruning a dominated partial sum is safe because costs
and losses add). The optimum of the constrained problem lies on the
merged frontier, so the result is exact — verified against brute-force
enumeration by the hypothesis suite in ``tests/test_budget.py``. The
genetic search of ``core.mixed_precision`` is kept as a cross-check
baseline (it can never win; the bench guard asserts that).

``method='lagrange'`` is the fast approximate path for very large
instances: a bisection on the multiplier of ``loss + lam * cost`` that
returns the best feasible convex-hull point.

Groups: ``groups`` maps paths to a shared key; tied paths must take the
same bits. Deployment flows tie each storage stack (``lax.scan`` stacked
leaves share one int container, so per-layer splits inside a stack buy
no bytes and no latency — see ``docs/budget.md``).
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Hashable, Mapping, Optional, Sequence

from ...core.mixed_precision import BIT_CHOICES, fitness
from ...core.sensitivity import SensTable

# Largest per-component joint enumeration. Components are blocks (a
# handful of linears) or tied stacks; 3^12 is far beyond any real model.
MAX_COMPONENT_ENUM = 3 ** 12


class BudgetInfeasibleError(ValueError):
    """No assignment satisfies the budget (even the cheapest one)."""


@dataclasses.dataclass
class BudgetSolution:
    """Result of :func:`solve_budget`.

    ``assign`` maps every path of the sensitivity table's domain to its
    chosen bits; ``predicted_loss`` is the table objective
    (:func:`~repro.core.mixed_precision.fitness`) and ``cost`` the cost
    table's value of the assignment — both recomputed from ``assign`` so
    they can be compared directly against other searchers.
    """

    assign: dict[str, int]
    predicted_loss: float
    cost: float
    budget: float
    kind: str  # cost-table kind ("bytes" | "decode_ms" | ...)
    method: str
    n_frontier: int = 0  # Pareto points surviving the final merge

    def to_json(self) -> dict:
        hist: dict[str, int] = {}
        for b in self.assign.values():
            hist[str(b)] = hist.get(str(b), 0) + 1
        return {"predicted_loss": self.predicted_loss, "cost": self.cost,
                "budget": self.budget, "kind": self.kind,
                "method": self.method, "n_frontier": self.n_frontier,
                "bits_histogram": hist}


def _normalize_groups(paths: Sequence[str],
                      groups: Optional[Mapping[str, Hashable]]
                      ) -> dict[str, Hashable]:
    if groups is None:
        return {p: p for p in paths}
    missing = [p for p in paths if p not in groups]
    if missing:
        raise KeyError(f"groups is missing {len(missing)} paths, e.g. "
                       f"{missing[0]!r}")
    return {p: groups[p] for p in paths}


def _components(paths: Sequence[str], group_of: Mapping[str, Hashable],
                pairs: Sequence[tuple[str, str]]) -> list[list[Hashable]]:
    """Connected components over *groups*: offdiag pairs couple the two
    endpoint groups; tied paths are already one group."""
    parent: dict[Hashable, Hashable] = {group_of[p]: group_of[p] for p in paths}

    def find(x):
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    for p1, p2 in pairs:
        r1, r2 = find(group_of[p1]), find(group_of[p2])
        if r1 != r2:
            parent[r2] = r1
    comps: dict[Hashable, list[Hashable]] = {}
    for g in dict.fromkeys(group_of[p] for p in paths):  # stable order
        comps.setdefault(find(g), []).append(g)
    return list(comps.values())


def _pareto(options: list[tuple[float, float, tuple]]
            ) -> list[tuple[float, float, tuple]]:
    """Prune (cost, loss, choice) to the Pareto set: ascending cost,
    strictly descending loss."""
    options.sort(key=lambda o: (o[0], o[1]))
    out: list[tuple[float, float, tuple]] = []
    best = float("inf")
    for c, l, choice in options:
        if l < best:
            out.append((c, l, choice))
            best = l
    return out


def _component_options(comp: list[Hashable], members: Mapping[Hashable, list[str]],
                       group_of: Mapping[str, Hashable], sens: SensTable,
                       costs, bit_choices: Sequence[int]
                       ) -> list[tuple[float, float, tuple]]:
    """Enumerate one component's joint assignments -> Pareto options.

    Option choice payload is the per-group bits tuple (aligned with
    ``comp`` order).
    """
    n_joint = len(bit_choices) ** len(comp)
    if n_joint > MAX_COMPONENT_ENUM:
        raise ValueError(
            f"component of {len(comp)} coupled groups needs {n_joint} joint "
            f"evaluations (> {MAX_COMPONENT_ENUM}); tie more paths via "
            f"`groups` or use method='lagrange'")
    in_comp = {p for g in comp for p in members[g]}
    pairs = [(p1, p2, v) for (p1, p2), v in sens.offdiag.items()
             if p1 in in_comp and p2 in in_comp]
    options = []
    for bits_tuple in itertools.product(bit_choices, repeat=len(comp)):
        of = dict(zip(comp, bits_tuple))
        loss = 0.0
        cost = 0.0
        for g in comp:
            b = of[g]
            for p in members[g]:
                loss += sens.diag.get((p, b), 0.0)
                cost += costs(p, b)
        for p1, p2, v in pairs:
            if of[group_of[p1]] == 2 and of[group_of[p2]] == 2:
                loss += v
        options.append((cost, loss, bits_tuple))
    return _pareto(options)


def solve_budget(sens: SensTable, cost_table, budget: float, *,
                 groups: Optional[Mapping[str, Hashable]] = None,
                 bit_choices: Sequence[int] = BIT_CHOICES,
                 method: str = "exact") -> BudgetSolution:
    """Minimize predicted loss subject to ``cost(assign) <= budget``.

    Args:
      sens: sensitivity lookup table; its ``shapes`` keys define the
        assignment domain.
      cost_table: a :class:`.cost.CostTable` (or anything with a
        ``cost(path, bits)`` method and a ``kind`` attribute).
      budget: inclusive upper bound in the cost table's unit.
      groups: optional path -> key map; paths sharing a key are
        constrained to the same bits (storage stacks — see
        :func:`.apply.storage_groups`).
      bit_choices: candidate widths per path (default ``{2, 4, 8}``).
      method: ``'exact'`` (Pareto-merge DP, default) or ``'lagrange'``
        (approximate multiplier bisection for very large instances).

    Returns:
      :class:`BudgetSolution`; ``predicted_loss``/``cost`` are recomputed
      from the returned assignment via the shared
      :func:`~repro.core.mixed_precision.fitness` objective.

    Raises:
      BudgetInfeasibleError: when even the cheapest assignment exceeds
        the budget.
    """
    paths = sorted(sens.shapes)
    if not paths:
        raise ValueError("sensitivity table has an empty domain")
    group_of = _normalize_groups(paths, groups)
    members: dict[Hashable, list[str]] = {}
    for p in paths:
        members.setdefault(group_of[p], []).append(p)

    costs = cost_table.cost
    dom_pairs = [(p1, p2) for (p1, p2) in sens.offdiag
                 if p1 in group_of and p2 in group_of]
    comps = _components(paths, group_of, dom_pairs)
    per_comp = [_component_options(c, members, group_of, sens, costs,
                                   bit_choices) for c in comps]

    min_cost = sum(min(o[0] for o in opts) for opts in per_comp)
    if min_cost > budget:
        raise BudgetInfeasibleError(
            f"budget {budget:g} ({cost_table.kind}) is below the cheapest "
            f"feasible assignment ({min_cost:g})")

    if method == "lagrange":
        choice = _lagrange(per_comp, budget)
    elif method == "exact":
        choice = _pareto_merge(per_comp, budget)
    else:
        raise ValueError(f"unknown method {method!r} (exact | lagrange)")

    assign: dict[str, int] = {}
    n_frontier = choice.pop("n_frontier")
    for comp, bits_tuple in zip(comps, choice["bits"]):
        for g, b in zip(comp, bits_tuple):
            for p in members[g]:
                assign[p] = b
    loss = fitness(sens, assign)
    cost = sum(costs(p, b) for p, b in assign.items())
    return BudgetSolution(assign=assign, predicted_loss=loss, cost=cost,
                          budget=budget, kind=cost_table.kind, method=method,
                          n_frontier=n_frontier)


def _pareto_merge(per_comp: list[list[tuple[float, float, tuple]]],
                  budget: float) -> dict:
    """Exact DP: fold component Pareto sets into one frontier of sums."""
    # cheapest completion of components [i:] — lets the merge prune
    # partial sums that can no longer fit the budget
    min_tail = [0.0] * (len(per_comp) + 1)
    for i in range(len(per_comp) - 1, -1, -1):
        min_tail[i] = min_tail[i + 1] + min(o[0] for o in per_comp[i])

    frontier: list[tuple[float, float, tuple]] = [(0.0, 0.0, ())]
    for i, opts in enumerate(per_comp):
        merged = [(c0 + c, l0 + l, ch0 + (ch,))
                  for c0, l0, ch0 in frontier
                  for c, l, ch in opts
                  if c0 + c + min_tail[i + 1] <= budget]
        frontier = _pareto(merged)
    best = min(frontier, key=lambda o: o[1])
    return {"bits": best[2], "n_frontier": len(frontier)}


def _lagrange(per_comp: list[list[tuple[float, float, tuple]]],
              budget: float, iters: int = 64) -> dict:
    """Bisect the multiplier of ``loss + lam * cost``; keep the best
    feasible point seen. Returns a convex-hull point (approximate)."""

    def pick(lam: float):
        total_c = total_l = 0.0
        bits = []
        for opts in per_comp:
            c, l, ch = min(opts, key=lambda o: o[1] + lam * o[0])
            total_c += c
            total_l += l
            bits.append(ch)
        return total_c, total_l, tuple(bits)

    best = None
    lo, hi = 0.0, 1.0
    c, l, ch = pick(0.0)
    if c <= budget:
        return {"bits": ch, "n_frontier": 1}
    while pick(hi)[0] > budget:
        hi *= 2.0
        if hi > 1e18:
            break
    for _ in range(iters):
        lam = 0.5 * (lo + hi)
        c, l, ch = pick(lam)
        if c <= budget:
            if best is None or l < best[1]:
                best = (c, l, ch)
            hi = lam
        else:
            lo = lam
    if best is None:  # fall back to the cheapest assignment
        best = pick(hi)
    return {"bits": best[2], "n_frontier": 1}


def grouped_problem(sens: SensTable, cost_table, groups: Mapping[str, Hashable],
                    *, bit_choices: Sequence[int] = BIT_CHOICES):
    """Collapse (sens, cost) to one path per group — the search space
    tied paths actually span.

    Cross-checking searchers without group support (``genetic_search``)
    against a group-constrained :func:`solve_budget` run is only fair on
    the same space: an untied GA can report per-layer splits inside a
    storage stack that container promotion cannot ship, "beating" the
    solver with fictitious points. Returns ``(gsens, gcost, expand)``:
    group-level tables whose fitness/cost equal the full problem's under
    the tie (intra-group 2-bit pairs fold into the group's 2-bit
    diagonal), and ``expand`` mapping a group assignment back to
    per-path bits.
    """
    from .cost import CostTable

    paths = sorted(sens.shapes)
    group_of = _normalize_groups(paths, groups)
    members: dict[Hashable, list[str]] = {}
    for p in paths:
        members.setdefault(group_of[p], []).append(p)
    names = {g: g if isinstance(g, str) else "/".join(map(str, g))
             if isinstance(g, tuple) else str(g) for g in members}
    if len(set(names.values())) != len(names):
        raise ValueError("group keys collide after string rendering")

    gdiag: dict[tuple[str, int], float] = {}
    goff: dict[tuple[str, str], float] = {}
    for g, mem in members.items():
        for b in bit_choices:
            gdiag[(names[g], b)] = sum(sens.diag.get((p, b), 0.0)
                                       for p in mem)
    for (p1, p2), v in sens.offdiag.items():
        if p1 not in group_of or p2 not in group_of:
            continue
        g1, g2 = group_of[p1], group_of[p2]
        if g1 == g2:
            if 2 in bit_choices:
                gdiag[(names[g1], 2)] += v
        else:
            key = (names[g1], names[g2]) if names[g1] < names[g2] \
                else (names[g2], names[g1])
            goff[key] = goff.get(key, 0.0) + v
    gsens = SensTable(
        diag=gdiag, offdiag=goff,
        block_of={names[g]: min(sens.block_of.get(p, 0) for p in mem)
                  for g, mem in members.items()},
        shapes={names[g]: (len(mem),) + tuple(sens.shapes[mem[0]])
                for g, mem in members.items()})
    gcost = CostTable(
        kind=cost_table.kind,
        backend=getattr(cost_table, "backend", "derived"),
        costs={(names[g], b): sum(cost_table.cost(p, b) for p in mem)
               for g, mem in members.items() for b in bit_choices})

    def expand(gassign: Mapping[str, int]) -> dict[str, int]:
        return {p: gassign[names[group_of[p]]] for p in paths}

    return gsens, gcost, expand


def brute_force(sens: SensTable, cost_table, budget: float, *,
                groups: Optional[Mapping[str, Hashable]] = None,
                bit_choices: Sequence[int] = BIT_CHOICES,
                max_enum: int = MAX_COMPONENT_ENUM) -> BudgetSolution:
    """Full enumeration oracle for :func:`solve_budget` (tests only)."""
    paths = sorted(sens.shapes)
    group_of = _normalize_groups(paths, groups)
    gkeys = list(dict.fromkeys(group_of[p] for p in paths))
    if len(bit_choices) ** len(gkeys) > max_enum:
        raise ValueError(f"brute force over {len(gkeys)} groups is too large")
    best = None
    for bits_tuple in itertools.product(bit_choices, repeat=len(gkeys)):
        of = dict(zip(gkeys, bits_tuple))
        assign = {p: of[group_of[p]] for p in paths}
        cost = sum(cost_table.cost(p, b) for p, b in assign.items())
        if cost > budget:
            continue
        loss = fitness(sens, assign)
        if best is None or loss < best.predicted_loss:
            best = BudgetSolution(assign=assign, predicted_loss=loss,
                                  cost=cost, budget=budget,
                                  kind=cost_table.kind, method="brute")
    if best is None:
        raise BudgetInfeasibleError(
            f"budget {budget:g} ({cost_table.kind}) admits no assignment")
    return best
