"""Packed-int deployment: one artifact format from `quantize()` to serving.

Public API:
  * :class:`QuantizedArtifact` — packed codes + scales + manifest.
  * :func:`export` — PTQResult -> artifact (exact, mixed-precision aware).
  * :func:`rtn_artifact` / :func:`quantize_tree` — calibration-free RTN
    fast path (``quantize_tree`` is the traceable tree transform).
  * :func:`dequant_leaf` / :func:`tree_bytes` — leaf helpers used by the
    models' packed-weight path and the launch layer.
  * Integrity: :data:`ARTIFACT_SCHEMA_VERSION`, :func:`leaf_crc32` /
    :func:`tree_checksums` / :func:`content_digest`, and the typed load
    errors (:class:`ArtifactError` base; schema / corruption / mismatch).
  * :mod:`.budget` — budgeted mixed precision: :func:`solve_budget` over
    measured/bytes cost tables, :func:`budget_artifact` (budget in,
    servable artifact out), measured qmm dispatch (``docs/budget.md``).
"""
from .artifact import (ARTIFACT_SCHEMA_VERSION,  # noqa: F401
                       ArtifactCorruptionError, ArtifactError,
                       ArtifactMismatchError, ArtifactSchemaError,
                       QuantizedArtifact, export, rtn_artifact)
from .budget import (budget_artifact, rtn_mixed_artifact,  # noqa: F401
                     solve_budget)
from .pack import (code_layout, container_bits, content_digest,  # noqa: F401
                   dequant_leaf, leaf_crc32, pack_codes, quantize_tree,
                   rtn_bits_by_path, rtn_codes, rtn_pack_leaf, tree_bytes,
                   tree_checksums)
