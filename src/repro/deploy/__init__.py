"""Packed-int deployment: one artifact format from `quantize()` to serving.

Public API:
  * :class:`QuantizedArtifact` — packed codes + scales + manifest.
  * :func:`export` — PTQResult -> artifact (exact, mixed-precision aware).
  * :func:`rtn_artifact` / :func:`quantize_tree` — calibration-free RTN
    fast path (``quantize_tree`` is the traceable tree transform).
  * :func:`dequant_leaf` / :func:`tree_bytes` — leaf helpers used by the
    models' packed-weight path and the launch layer.
"""
from .artifact import QuantizedArtifact, export, rtn_artifact  # noqa: F401
from .pack import (code_layout, container_bits, dequant_leaf,  # noqa: F401
                   pack_codes, quantize_tree, rtn_bits_by_path, rtn_pack_leaf,
                   tree_bytes)
