"""Step builders: jitted train / prefill / decode with explicit shardings.

Mixed precision policy: f32 master params, bf16 compute (cast inside the
loss so gradients land back in f32). Buffers are donated (params + opt
state on train; cache on serve) so steady-state memory is one copy.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs.base import ArchConfig, ShapeSpec
from .. import deploy
from ..dist.sharding import Plan
from ..optim import adam
from . import specs as specs_mod

Array = jax.Array


def cast_float(tree, dtype=jnp.bfloat16):
    return jax.tree.map(
        lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x,
        tree)


@dataclasses.dataclass
class Lowerable:
    """A step function + the abstract args and shardings to lower it with."""

    fn: Any
    args: tuple  # ShapeDtypeStructs
    in_shardings: tuple
    out_shardings: Any
    donate_argnums: tuple = ()

    def jit(self):
        return jax.jit(self.fn, in_shardings=self.in_shardings,
                       out_shardings=self.out_shardings,
                       donate_argnums=self.donate_argnums)

    def lower(self):
        return self.jit().lower(*self.args)


# ---------------------------------------------------------------------------
# train
# ---------------------------------------------------------------------------


def act_shard_fn(plan: Plan, global_batch: int, seq_len: int = 0):
    """Pin activation shardings: (B,S,d) hidden states and (B,E,C,d) MoE
    dispatch tensors (experts over "model" when the batch doesn't use it).

    For tp/fsdp the block-boundary hidden state is additionally sequence-
    sharded over "model" (Megatron-style sequence parallelism): remat-
    saved layer inputs then live model_size-times more sharded, and the
    gather back to full sequence merges with the TP all-gather the
    attention layer needs anyway."""
    bspec = plan.batch_spec(global_batch, 3, seq_axis=1, seq_len=0)
    b_axes = plan.batch_axes(global_batch)
    e_axis = None if "model" in b_axes else "model"
    seq_axis = None
    if (getattr(plan, "seq_parallel", False)
            and plan.strategy in ("tp", "fsdp") and e_axis == "model"
            and seq_len and seq_len % (plan.mesh.shape["model"] * 128) == 0):
        seq_axis = "model"

    def shard(x, kind="tokens"):
        if kind == "expert_major":  # (B, E, ...) MoE routing/dispatch
            spec = P(bspec[0], e_axis, *([None] * (x.ndim - 2)))
        elif x.ndim == 3:
            sa = seq_axis if x.shape[1] == seq_len else None
            spec = P(bspec[0], sa, None)
        else:
            spec = P(bspec[0], *([None] * (x.ndim - 1)))
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(plan.mesh, spec))

    return shard


def make_train_step(model, plan: Plan, shape: ShapeSpec,
                    acfg: Optional[adam.AdamConfig] = None,
                    remat: str = "dots", aux_weight: float = 0.01) -> Lowerable:
    acfg = acfg or adam.AdamConfig(lr=3e-4, grad_clip=1.0)
    shard = act_shard_fn(plan, shape.global_batch, shape.seq_len)

    def train_step(params, opt_state, batch):
        def loss_fn(p):
            return model.loss(cast_float(p), batch, remat=remat,
                              aux_weight=aux_weight, act_shard=shard)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        params2, opt_state2 = adam.update(acfg, grads, opt_state, params)
        metrics = {"loss": loss, "gnorm": adam.global_norm(grads)}
        return params2, opt_state2, metrics

    params_sds = specs_mod.params_specs(model)
    opt_sds = jax.eval_shape(adam.init, params_sds)
    batch_sds = specs_mod.input_specs(model.cfg, shape)

    p_sh = plan.params_sharding(params_sds)
    o_sh = {"m": plan.opt_sharding(opt_sds["m"]),
            "v": plan.opt_sharding(opt_sds["v"]),
            "count": NamedSharding(plan.mesh, P())}
    b_sh = plan.batch_sharding(batch_sds, shape.global_batch, shard_seq=True)
    rep = NamedSharding(plan.mesh, P())
    return Lowerable(
        fn=train_step,
        args=(params_sds, opt_sds, batch_sds),
        in_shardings=(p_sh, o_sh, b_sh),
        out_shardings=(p_sh, o_sh, {"loss": rep, "gnorm": rep}),
        donate_argnums=(0, 1),
    )


# ---------------------------------------------------------------------------
# serve: prefill / decode
# ---------------------------------------------------------------------------


def _serve_params(model, quant_bits: Optional[int], group: Optional[int]):
    """Abstract serving params: bf16, or packed-int deployment format."""
    params_sds = specs_mod.params_specs(model)
    if quant_bits is None:
        return jax.eval_shape(partial(cast_float, dtype=jnp.bfloat16), params_sds)
    return jax.eval_shape(
        lambda p: deploy.quantize_tree(p, quant_bits, group), params_sds)


def make_prefill_step(model, plan: Plan, shape: ShapeSpec,
                      quant_bits: Optional[int] = None,
                      group: Optional[int] = None,
                      remat: str = "dots") -> Lowerable:
    shard = act_shard_fn(plan, shape.global_batch, shape.seq_len)

    def prefill_step(params, batch, cache):
        logits, cache = model.prefill(params, batch, cache, remat=remat,
                                      act_shard=shard)
        return logits, cache

    params_sds = _serve_params(model, quant_bits, group)
    batch_sds = specs_mod.input_specs(model.cfg, shape)
    cache_sds = jax.eval_shape(
        lambda: model.init_cache(shape.global_batch, shape.seq_len, jnp.bfloat16))

    p_sh = plan.params_sharding(params_sds)
    b_sh = plan.batch_sharding(batch_sds, shape.global_batch, shard_seq=True)
    c_sh = plan.cache_sharding(cache_sds, shape.global_batch)
    return Lowerable(
        fn=prefill_step,
        args=(params_sds, batch_sds, cache_sds),
        in_shardings=(p_sh, b_sh, c_sh),
        out_shardings=(None, c_sh),
        donate_argnums=(2,),
    )


def cache_shard_fn(plan: Plan, global_batch: int):
    """Per-layer cache constraint inside the decode scan: the stacked
    cache spec minus its leading (layer) dim."""

    bspec = plan.batch_spec(global_batch, 2)

    def shard(x, leaf):
        if leaf == "q":  # decode query: batch-sharded, replicated elsewhere
            spec = P(bspec[0], *([None] * (x.ndim - 1)))
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(plan.mesh, spec))
        if leaf == "scores":  # (B,K,G,S): follow the cache's seq sharding
            sa = "model" if x.shape[-1] % plan.mesh.shape["model"] == 0 else None
            spec = P(bspec[0], *([None] * (x.ndim - 2)), sa)
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(plan.mesh, spec))

        class _K:  # fake path keys for the rule engine
            def __init__(self, key):
                self.key = key

        spec = plan.cache_spec((_K("stack"), _K(leaf)),
                               (1, *x.shape), global_batch)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(plan.mesh, P(*spec[1:])))

    return shard


def make_decode_step(model, plan: Plan, shape: ShapeSpec,
                     quant_bits: Optional[int] = None,
                     group: Optional[int] = None) -> Lowerable:
    shard = act_shard_fn(plan, shape.global_batch)
    cshard = cache_shard_fn(plan, shape.global_batch)

    def decode_step(params, tokens, cache, pos):
        logits, cache = model.decode_step(params, tokens, cache, pos,
                                          act_shard=shard,
                                          extras={"cache_shard": cshard})
        return logits, cache

    params_sds = _serve_params(model, quant_bits, group)
    B = shape.global_batch
    tok_sds = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    pos_sds = jax.ShapeDtypeStruct((B,), jnp.int32)
    cache_sds = jax.eval_shape(
        lambda: model.init_cache(B, shape.seq_len, jnp.bfloat16))

    p_sh = plan.params_sharding(params_sds)
    c_sh = plan.cache_sharding(cache_sds, B)
    bspec = plan.batch_spec(B, 2)
    tok_sh = NamedSharding(plan.mesh, bspec)
    pos_sh = NamedSharding(plan.mesh, P(bspec[0]))
    return Lowerable(
        fn=decode_step,
        args=(params_sds, tok_sds, cache_sds, pos_sds),
        in_shardings=(p_sh, tok_sh, c_sh, pos_sh),
        out_shardings=(None, c_sh),
        donate_argnums=(2,),
    )


def make_step(kind: str, model, plan: Plan, shape: ShapeSpec, **kw) -> Lowerable:
    if kind == "train":
        kw.pop("quant_bits", None)
        kw.pop("group", None)
        return make_train_step(model, plan, shape, **kw)
    if kind == "prefill":
        return make_prefill_step(model, plan, shape, **kw)
    if kind == "decode":
        kw.pop("remat", None)
        return make_decode_step(model, plan, shape, **kw)
    raise ValueError(kind)
