import os
if "--xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The lines above MUST stay first: jax locks the device count at first
init, and the production meshes need 512 placeholder devices. An
externally-set device count wins (the CI ``dryrun-smoke`` job and
``tests/test_dist.py`` run ``--reduced`` cells with 8 devices);
unrelated XLA_FLAGS are preserved, with the 512 default appended.

Per cell this emits artifacts/dryrun/<arch>_<shape>_<mesh>[_tag].json:
  * compiled.memory_analysis()  (proves per-chip fit)
  * compiled.cost_analysis()    (XLA's own flops/bytes; while-body-once)
  * while-aware HLO totals      (flops / bytes / collective bytes+counts)
  * roofline terms + bottleneck (analysis/roofline.py)

``--all`` runs every applicable cell in a subprocess each (compile-memory
isolation; one bad cell cannot take down the sweep).
"""

import argparse
import dataclasses
import json
import subprocess
import sys
import time
from pathlib import Path


def parse_args(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", type=str, default=None)
    p.add_argument("--shape", type=str, default=None)
    p.add_argument("--mesh", choices=["single", "multi"], default="single")
    p.add_argument("--reduced", action="store_true",
                   help="reduced arch + toy shape on a host mesh built from "
                        "whatever devices exist (CI smoke / tests)")
    p.add_argument("--strategy", choices=["auto", "dp", "tp", "fsdp", "zero3"],
                   default="auto")
    p.add_argument("--quant", type=int, default=None, choices=[2, 4, 8],
                   help="serve with packed int weights at this bit-width")
    p.add_argument("--group", type=int, default=None, help="weight group size")
    p.add_argument("--remat", default="full", choices=["none", "dots", "full"])
    p.add_argument("--moe-impl", default=None, choices=["dense", "capacity"])
    p.add_argument("--fsdp-axis", default="data")
    p.add_argument("--no-shard-experts", action="store_true")
    p.add_argument("--tag", default="", help="suffix for the artifact name")
    p.add_argument("--out", default="artifacts/dryrun")
    p.add_argument("--all", action="store_true", help="run every cell (subprocesses)")
    p.add_argument("--include-quant", action="store_true",
                   help="with --all: also run int8/int4 decode variants")
    p.add_argument("--timeout", type=int, default=1800)
    return p.parse_args(argv)


def run_cell(args) -> dict:
    import jax

    from ..analysis import roofline as rl
    from ..configs.base import SHAPES
    from ..dist.sharding import Plan, pick_strategy
    from ..models import get_model
    from . import steps as steps_mod
    from .mesh import make_host_mesh, make_production_mesh

    cfg, model = get_model(args.arch, reduced=args.reduced,
                           moe_impl=args.moe_impl)
    shape = SHAPES[args.shape]
    if args.reduced:
        shape = dataclasses.replace(shape,
                                    global_batch=min(shape.global_batch, 8),
                                    seq_len=min(shape.seq_len, 64))
        n_dev = len(jax.devices())
        mesh = make_host_mesh(model=2 if n_dev % 2 == 0 else 1)
    else:
        mesh = make_production_mesh(multi_pod=(args.mesh == "multi"))
    strategy = (pick_strategy(cfg, shape.kind) if args.strategy == "auto"
                else args.strategy)
    plan = Plan(mesh=mesh, strategy=strategy, cfg=cfg,
                fsdp_axis=args.fsdp_axis,
                shard_experts=not args.no_shard_experts)

    t0 = time.time()
    lowerable = steps_mod.make_step(
        shape.kind, model, plan, shape, quant_bits=args.quant,
        group=args.group, remat=args.remat)
    lowered = lowerable.lower()
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    ma = compiled.memory_analysis()
    mem = {k: getattr(ma, k) for k in (
        "argument_size_in_bytes", "output_size_in_bytes",
        "temp_size_in_bytes", "alias_size_in_bytes",
        "generated_code_size_in_bytes")} if ma is not None else {}
    ca = compiled.cost_analysis() or {}
    ca_small = {k: ca[k] for k in ("flops", "bytes accessed", "transcendentals")
                if k in ca}

    n_chips = mesh.devices.size
    hlo_text = compiled.as_text()
    roof, summ = rl.from_hlo(hlo_text, cfg, shape, n_chips,
                             w_bits=args.quant or 16)

    per_chip_hbm = (mem.get("argument_size_in_bytes", 0)
                    + mem.get("temp_size_in_bytes", 0)
                    + mem.get("output_size_in_bytes", 0)
                    - mem.get("alias_size_in_bytes", 0))
    # XLA:CPU legalizes bf16 dots to f32, materializing f32 twins of the
    # bf16 saved-activation stacks (TPU keeps bf16 natively). Subtract the
    # duplicated f32 stacks for a TPU-representative fit estimate; both
    # numbers are reported.
    cpu_excess = _bf16_dup_excess(hlo_text)
    per_chip_tpu = per_chip_hbm - cpu_excess
    out = {
        "arch": args.arch, "shape": args.shape, "mesh": args.mesh,
        "reduced": args.reduced,
        "n_chips": n_chips, "strategy": strategy, "kind": shape.kind,
        "quant": args.quant, "group": args.group, "remat": args.remat,
        "moe_impl": args.moe_impl, "tag": args.tag,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory_analysis": mem,
        "per_chip_bytes": per_chip_hbm,
        "cpu_f32_dup_bytes": cpu_excess,
        "per_chip_bytes_tpu_corrected": per_chip_tpu,
        "fits_16gb": bool(per_chip_tpu < 16e9) if mem else None,
        "cost_analysis": ca_small,
        "hlo": {
            "flops": summ.flops, "bytes": summ.bytes,
            "collective_bytes": summ.collective_bytes,
            "collective_counts": summ.collective_counts,
        },
        "roofline": roof.as_dict(),
    }
    return out


def _bf16_dup_excess(hlo_text: str) -> float:
    """Bytes of f32 activation buffers that have an identically-shaped
    bf16 twin (CPU bf16-dot legalization artifact; absent on TPU)."""
    import math
    import re as _re

    f32 = set()
    bf16 = set()
    for m in _re.finditer(r"(f32|bf16)\[([\d,]+)\]", hlo_text):
        (f32 if m.group(1) == "f32" else bf16).add(m.group(2))
    excess = 0.0
    for dims in f32 & bf16:
        n = math.prod(int(d) for d in dims.split(","))
        if n * 4 >= 256e6:  # only large activation stacks
            excess += n * 4.0
    return excess


def cell_list(include_quant: bool = False):
    from ..configs.base import applicable_shapes
    from ..models import ARCH_IDS, get_config

    cells = []
    for arch in ARCH_IDS:
        if arch == "brecq_lm_100m":
            continue  # paper model is exercised by benchmarks, not the 40-cell table
        cfg = get_config(arch)
        for shape in applicable_shapes(cfg):
            for mesh in ("single", "multi"):
                cells.append((arch, shape, mesh, None))
                if include_quant and shape in ("decode_32k", "long_500k") and mesh == "single":
                    cells.append((arch, shape, mesh, 4))
    return cells


def main():
    args = parse_args()
    outdir = Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)

    if args.all:
        cells = cell_list(args.include_quant)
        print(f"dry-run sweep: {len(cells)} cells")
        failures = []
        for arch, shape, mesh, quant in cells:
            tag = f"_w{quant}" if quant else ""
            name = f"{arch}_{shape}_{mesh}{tag}"
            path = outdir / f"{name}.json"
            if path.exists():
                print(f"[skip] {name} (cached)")
                continue
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", arch, "--shape", shape, "--mesh", mesh,
                   "--out", str(outdir)]
            if quant:
                cmd += ["--quant", str(quant), "--tag", f"w{quant}"]
            t0 = time.time()
            r = subprocess.run(cmd, capture_output=True, text=True,
                               timeout=args.timeout)
            ok = r.returncode == 0 and path.exists()
            print(f"[{'ok' if ok else 'FAIL'}] {name} ({time.time()-t0:.0f}s)")
            if not ok:
                failures.append(name)
                (outdir / f"{name}.err").write_text(r.stdout[-4000:] + "\n---\n"
                                                    + r.stderr[-8000:])
        print(f"done: {len(cells) - len(failures)}/{len(cells)} ok")
        if failures:
            print("failures:", failures)
            sys.exit(1)
        return

    assert args.arch and args.shape, "--arch and --shape required (or --all)"
    out = run_cell(args)
    tag = f"_{args.tag}" if args.tag else ""
    name = f"{args.arch}_{args.shape}_{args.mesh}{tag}.json"
    path = outdir / name
    path.write_text(json.dumps(out, indent=1, default=float))
    print(json.dumps({k: out[k] for k in
                      ("arch", "shape", "mesh", "strategy", "per_chip_bytes",
                       "fits_16gb", "compile_s")}, default=float))
    print("memory_analysis:", out["memory_analysis"])
    print("cost_analysis:", out["cost_analysis"])
    print("roofline:", json.dumps(out["roofline"], indent=1, default=float))
    print(f"wrote {path}")


if __name__ == "__main__":
    main()
