"""Production meshes.

A function, not a module constant: importing this module must never touch
jax device state (the dry-run sets XLA_FLAGS before first jax init).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Tiny mesh over whatever devices exist (tests / local runs)."""
    n = len(jax.devices())
    assert n % model == 0, (n, model)
    return jax.make_mesh((n // model, model), ("data", "model"))
