"""Fault-tolerant training driver.

Runs on whatever devices exist (the production meshes are exercised by
dryrun.py; this driver trains real models on the host — e.g. the paper's
brecq-lm-100m — and at pod scale the same code runs under multi-host jax
with the production mesh).

Fault tolerance: auto-resume from the newest complete checkpoint, async
checkpoint every N steps, SIGTERM-triggered flush, per-step watchdog,
deterministic data keyed by (seed, host, step) so restarts replay
exactly.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch brecq_lm_100m \
      --steps 300 --batch 16 --seq 128 --ckpt-dir artifacts/ckpt_100m
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp

from ..ckpt import CheckpointManager
from ..data import Corpus, CorpusConfig, arch_extras_fn, make_batches
from ..dist.sharding import Plan, pick_strategy
from ..models import get_model
from ..optim import adam
from ..optim.grad_compress import init_error, make_dp_train_step
from .mesh import make_host_mesh
from .watchdog import GracefulShutdown, StepWatchdog


def parse_args(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="brecq_lm_100m")
    p.add_argument("--reduced", action="store_true")
    p.add_argument("--steps", type=int, default=300)
    p.add_argument("--batch", type=int, default=16)
    p.add_argument("--seq", type=int, default=128)
    p.add_argument("--lr", type=float, default=3e-3)
    p.add_argument("--warmup", type=int, default=20)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--remat", default="dots")
    p.add_argument("--ckpt-dir", default=None)
    p.add_argument("--ckpt-every", type=int, default=50)
    p.add_argument("--grad-compress", choices=["none", "int8"], default="none")
    p.add_argument("--model-shard", type=int, default=1,
                   help="model-axis size of the host mesh")
    p.add_argument("--log-every", type=int, default=20)
    p.add_argument("--metrics-out", default=None)
    args = p.parse_args(argv)
    if args.grad_compress == "int8" and args.model_shard > 1:
        # the int8 all-reduce step is DP-only: it shard_maps over the
        # "data" axis and would silently ignore a model axis (see
        # optim/grad_compress.py for the documented trade-off)
        p.error("--grad-compress int8 is DP-only and ignores a model axis; "
                "use --model-shard 1 or --grad-compress none")
    return args


def main(argv=None):
    args = parse_args(argv)
    cfg, model = get_model(args.arch, reduced=args.reduced)
    corpus = Corpus(CorpusConfig(vocab=cfg.vocab))
    extras_fn = arch_extras_fn(cfg)
    host = getattr(jax, "process_index", lambda: 0)()

    acfg = adam.AdamConfig(
        lr=adam.cosine_schedule(args.lr, args.warmup, args.steps),
        grad_clip=1.0)
    mesh = make_host_mesh(model=args.model_shard)

    params = model.init(jax.random.PRNGKey(args.seed))
    opt_state = adam.init(params)
    err = init_error(params) if args.grad_compress == "int8" else None
    start_step = 0

    ckpt = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    if ckpt is not None and ckpt.latest_step() is not None:
        start_step = ckpt.latest_step()
        state = {"params": params, "opt": opt_state}
        restored = ckpt.restore(start_step, state)
        params, opt_state = restored["params"], restored["opt"]
        print(f"[resume] restored step {start_step} from {args.ckpt_dir}")

    if args.grad_compress == "int8":
        step_fn_c = make_dp_train_step(model, mesh, acfg, remat=args.remat)
    else:
        @jax.jit
        def step_fn(params, opt_state, batch):
            loss, grads = jax.value_and_grad(
                lambda p: model.loss(p, batch, remat=args.remat))(params)
            params, opt_state = adam.update(acfg, grads, opt_state, params)
            return params, opt_state, loss

    watchdog = StepWatchdog()
    shutdown = GracefulShutdown()
    losses = []
    last_step = start_step  # stays put when resuming at/after completion
    t_start = time.time()
    for step in range(start_step, args.steps):
        last_step = step + 1
        batch = make_batches(corpus, 1, args.batch, args.seq, seed=args.seed,
                             host=host, start_step=step, extras_fn=extras_fn)[0]
        watchdog.start()
        if args.grad_compress == "int8":
            params, opt_state, err, loss = step_fn_c(params, opt_state, err, batch)
        else:
            params, opt_state, loss = step_fn(params, opt_state, batch)
        loss = float(loss)
        watchdog.stop(step)
        losses.append(loss)
        if step % args.log_every == 0 or step == args.steps - 1:
            print(f"step {step:5d}  loss {loss:.4f}  "
                  f"({watchdog.mean or 0:.2f}s/step)")
        if ckpt is not None and ((step + 1) % args.ckpt_every == 0
                                 or shutdown.requested):
            ckpt.save_async(step + 1, {"params": params, "opt": opt_state},
                            meta={"loss": loss, "arch": args.arch})
        if shutdown.requested:
            print(f"[shutdown] checkpointed at step {step + 1}; exiting")
            break
    if ckpt is not None:
        ckpt.wait()
        if losses:  # no steps ran -> the restored checkpoint already covers it
            ckpt.save(min(args.steps, last_step),
                      {"params": params, "opt": opt_state},
                      meta={"loss": losses[-1], "arch": args.arch})
    wall = time.time() - t_start
    print(f"done: {len(losses)} steps in {wall:.0f}s, "
          f"final loss {losses[-1]:.4f}" if losses else "no steps run")
    if args.metrics_out:
        json_out = {"arch": args.arch, "steps": len(losses), "wall_s": wall,
                    "final_loss": losses[-1] if losses else None,
                    "stragglers": watchdog.stragglers}
        from pathlib import Path

        Path(args.metrics_out).write_text(json.dumps(json_out))
    return params


if __name__ == "__main__":
    main()
