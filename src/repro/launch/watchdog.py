"""Step-time watchdog: straggler detection + preemption-safe shutdown.

At pod scale, the scheduler restarts slow/failed workers; the framework's
job is to (a) notice abnormal step latency (EWMA z-score) and surface it,
(b) checkpoint promptly on SIGTERM/SIGINT so a preempted worker loses at
most one step. Both hooks live here and are consumed by launch/train.py.
"""
from __future__ import annotations

import math
import signal
import time
from typing import Callable, Optional


class StepWatchdog:
    def __init__(self, z_threshold: float = 4.0, alpha: float = 0.05,
                 warmup: int = 5, log: Callable[[str], None] = print,
                 label: str = "step"):
        self.z = z_threshold
        self.alpha = alpha
        self.warmup = warmup
        self.log = log
        self.label = label
        self.mean: Optional[float] = None
        self.var: float = 0.0
        self.n = 0
        self.stragglers = 0
        self._t0: Optional[float] = None

    def start(self):
        self._t0 = time.perf_counter()

    def stop(self, step: int) -> float:
        dt = time.perf_counter() - self._t0
        self.n += 1
        if self.mean is None:
            self.mean = dt
        else:
            if self.n > self.warmup:
                sd = math.sqrt(self.var) if self.var > 0 else self.mean * 0.1
                if dt > self.mean + self.z * sd:
                    self.stragglers += 1
                    self.log(f"[watchdog] {self.label} {step}: {dt:.2f}s "
                             f"(mean {self.mean:.2f}s +{self.z} sigma) — straggler")
            delta = dt - self.mean
            self.mean += self.alpha * delta
            self.var = (1 - self.alpha) * (self.var + self.alpha * delta * delta)
        return dt


class GracefulShutdown:
    """SIGTERM/SIGINT -> finish the current step/unit, checkpoint, exit.

    Consumed by launch/train.py (per training step) and by
    ``repro.core.quantize(workdir=...)`` (per reconstruction unit).
    Library callers that install the handlers temporarily must call
    :meth:`restore` (or use the instance as a context manager) so the
    process's previous SIGINT/SIGTERM behaviour comes back after the
    guarded section."""

    def __init__(self, install: bool = True):
        self.requested = False
        self._prev: dict[int, object] = {}
        if install:
            self.install()

    def install(self):
        for sig in (signal.SIGTERM, signal.SIGINT):
            try:
                self._prev[sig] = signal.signal(sig, self._handler)
            except ValueError:
                pass  # non-main thread (tests)

    def restore(self):
        for sig, prev in self._prev.items():
            try:
                signal.signal(sig, prev)
            except ValueError:
                pass
        self._prev = {}

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.restore()
        return False

    def _handler(self, signum, frame):
        self.requested = True
