"""Step-time watchdog: straggler detection + preemption-safe shutdown.

At pod scale, the scheduler restarts slow/failed workers; the framework's
job is to (a) notice abnormal step latency (EWMA z-score) and surface it,
(b) checkpoint promptly on SIGTERM/SIGINT so a preempted worker loses at
most one step. Both hooks live here and are consumed by launch/train.py.
"""
from __future__ import annotations

import math
import signal
import time
from typing import Callable, Optional


class StepWatchdog:
    def __init__(self, z_threshold: float = 4.0, alpha: float = 0.05,
                 warmup: int = 5, log: Callable[[str], None] = print):
        self.z = z_threshold
        self.alpha = alpha
        self.warmup = warmup
        self.log = log
        self.mean: Optional[float] = None
        self.var: float = 0.0
        self.n = 0
        self.stragglers = 0
        self._t0: Optional[float] = None

    def start(self):
        self._t0 = time.time()

    def stop(self, step: int) -> float:
        dt = time.time() - self._t0
        self.n += 1
        if self.mean is None:
            self.mean = dt
        else:
            if self.n > self.warmup:
                sd = math.sqrt(self.var) if self.var > 0 else self.mean * 0.1
                if dt > self.mean + self.z * sd:
                    self.stragglers += 1
                    self.log(f"[watchdog] step {step}: {dt:.2f}s "
                             f"(mean {self.mean:.2f}s +{self.z} sigma) — straggler")
            delta = dt - self.mean
            self.mean += self.alpha * delta
            self.var = (1 - self.alpha) * (self.var + self.alpha * delta * delta)
        return dt


class GracefulShutdown:
    """SIGTERM/SIGINT -> finish the current step, checkpoint, exit."""

    def __init__(self):
        self.requested = False
        for sig in (signal.SIGTERM, signal.SIGINT):
            try:
                signal.signal(sig, self._handler)
            except ValueError:
                pass  # non-main thread (tests)

    def _handler(self, signum, frame):
        self.requested = True
