"""Batched serving driver: prefill + decode with optional BRECQ weights.

Serves a (small, host-runnable) model with continuous batched requests:
  1. load FP or BRECQ-quantized params (packed-int deployment format),
  2. prefill the prompt batch, 3. decode N tokens with the jitted step,
  4. report tokens/s and (if quantized) the bytes saved.

The production-mesh serving path is exercised by dryrun.py decode cells;
this driver runs the same model code end-to-end on the host.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..data import Corpus, CorpusConfig
from ..dist import deploy
from ..models import get_model


def parse_args(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="brecq_lm_100m")
    p.add_argument("--reduced", action="store_true")
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--prompt-len", type=int, default=64)
    p.add_argument("--gen-len", type=int, default=32)
    p.add_argument("--quant", type=int, default=None, choices=[2, 4, 8])
    p.add_argument("--group", type=int, default=None)
    p.add_argument("--seed", type=int, default=0)
    return p.parse_args(argv)


def tree_bytes(t) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(t))


def main(argv=None, params=None):
    args = parse_args(argv)
    cfg, model = get_model(args.arch, reduced=args.reduced)
    if params is None:
        params = model.init(jax.random.PRNGKey(args.seed))
    fp_bytes = tree_bytes(params)
    if args.quant is not None:
        params = deploy.quantize_tree(params, args.quant, args.group)
        print(f"quantized W{args.quant}: {fp_bytes/1e6:.1f}MB -> "
              f"{tree_bytes(params)/1e6:.1f}MB")

    corpus = Corpus(CorpusConfig(vocab=cfg.vocab))
    prompts = jnp.asarray(corpus.sample(args.batch, args.prompt_len, seed=7))
    batch = {"tokens": prompts}
    if cfg.family == "vlm":
        rng = np.random.default_rng(0)
        batch["patches"] = jnp.asarray(
            rng.normal(size=(args.batch, cfg.n_patches, cfg.d_model)), jnp.float32)
    if cfg.enc_dec:
        rng = np.random.default_rng(0)
        batch["frames"] = jnp.asarray(
            rng.normal(size=(args.batch, args.prompt_len, cfg.d_model)), jnp.float32)

    max_len = args.prompt_len + args.gen_len
    cache = model.init_cache(args.batch, max_len, jnp.float32)

    t0 = time.time()
    prefill = jax.jit(lambda p, b, c: model.prefill(p, b, c, remat="none"))
    logits, cache = prefill(params, batch, cache)
    logits.block_until_ready()
    t_prefill = time.time() - t0

    decode = jax.jit(model.decode_step, donate_argnums=(2,))
    tok = jnp.argmax(logits, -1)[:, None]
    out_tokens = [tok]
    t0 = time.time()
    for i in range(args.gen_len - 1):
        pos = jnp.full((args.batch,), args.prompt_len + i, jnp.int32)
        logits, cache = decode(params, tok, cache, pos)
        tok = jnp.argmax(logits, -1)[:, None]
        out_tokens.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.time() - t0
    toks = args.batch * (args.gen_len - 1)
    print(f"prefill {args.batch}x{args.prompt_len} in {t_prefill:.2f}s; "
          f"decode {toks} tokens in {t_decode:.2f}s "
          f"({toks/max(t_decode,1e-9):.1f} tok/s)")
    gen = jnp.concatenate(out_tokens, axis=1)
    print("sample:", np.asarray(gen[0][:16]))
    return gen


if __name__ == "__main__":
    main()
