"""Batched serving driver: prefill + decode from a packed QuantizedArtifact.

Serves a (small, host-runnable) model with continuous batched requests:
  1. resolve weights — FP params, a saved :class:`QuantizedArtifact`
     (``--artifact DIR``), or a fresh RTN artifact (``--quant BITS``,
     which is saved and re-loaded so the served bytes are exactly what a
     deployment would ship),
  2. prefill the prompt batch, 3. decode N tokens with the jitted step,
  4. report artifact bytes vs FP, tokens/s packed-vs-fp (steady state —
     compile is AOT'd out of the timed loops) and which qmm tiers fired
     (decode steps dispatch to the ``qgemv`` fast path by shape).

Packed weights stay int8 codes in HBM end-to-end: every linear resolves
through the ``QuantHook.packed_matmul`` weight-provider (``qmm``), so the
resident bytes printed here are the real serving footprint. The
production-mesh serving path is exercised by dryrun.py decode cells; this
driver runs the same model code end-to-end on the host.
"""
from __future__ import annotations

import argparse
import copy
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..data import Corpus, CorpusConfig
from ..deploy import (ArtifactMismatchError, QuantizedArtifact, rtn_artifact,
                      tree_bytes)
from ..models import get_model


def parse_args(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="brecq_lm_100m")
    p.add_argument("--reduced", action="store_true")
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--prompt-len", type=int, default=64)
    p.add_argument("--gen-len", type=int, default=32)
    p.add_argument("--quant", type=int, default=None, choices=[2, 4, 8],
                   help="pack weights to this many bits (RTN artifact)")
    p.add_argument("--group", type=int, default=None)
    p.add_argument("--budget-bytes", type=float, default=None,
                   help="solve per-layer bits so the whole artifact fits "
                        "this many bytes, then serve it "
                        "(repro.deploy.budget)")
    p.add_argument("--budget-decode-ms", type=float, default=None,
                   help="solve per-layer bits so the summed measured "
                        "per-layer decode matmul time fits this many ms, "
                        "then serve it")
    p.add_argument("--sens", default=None,
                   help="SensTable JSON (core.sensitivity.SensTable.save) "
                        "for --budget-*; default: calibration-free RTN "
                        "weight-error proxy")
    p.add_argument("--dispatch", default="auto",
                   choices=["auto", "heuristic", "measured"],
                   help="qmm decode-shape tier dispatch: measured times "
                        "each eligible tier at the served shapes (cached "
                        "in the artifact manifest per backend) and routes "
                        "by the winners; heuristic keeps the M<=8 gemv "
                        "guess; auto = measured iff a table is installed")
    p.add_argument("--artifact", default=None,
                   help="serve from a saved QuantizedArtifact directory")
    p.add_argument("--save-artifact", default=None,
                   help="where --quant saves its artifact (default: tmpdir)")
    p.add_argument("--no-compare-fp", action="store_true",
                   help="skip the FP throughput reference pass")
    p.add_argument("--packed-backend", default="auto",
                   choices=["auto", "xla", "pallas"],
                   help="qmm execution path for packed weights (tiers are "
                        "still picked by shape)")
    p.add_argument("--no-verify", action="store_true",
                   help="skip artifact schema/checksum verification at load "
                        "(escape hatch for pre-v2 or known-good artifacts)")
    p.add_argument("--engine", action="store_true",
                   help="serve through the continuous-batching engine "
                        "(repro.serve_engine) instead of the fixed-batch "
                        "harness; --batch becomes the slot count")
    p.add_argument("--streams", type=int, default=None,
                   help="number of synthetic request streams for --engine "
                        "(staggered arrivals, mixed lengths; default: "
                        "2x the slot count)")
    p.add_argument("--kv-dtype", default=None,
                   choices=["int8", "float16", "bfloat16", "float32"],
                   help="engine KV pool dtype (default: artifact manifest "
                        "kv_dtype, else int8)")
    p.add_argument("--page-size", type=int, default=None,
                   help="engine KV page size in tokens (default: manifest "
                        "kv_page_size, else 16)")
    p.add_argument("--prefill-chunk", type=int, default=32,
                   help="engine prefill chunk length (tokens per tick)")
    p.add_argument("--num-pages", type=int, default=None,
                   help="engine KV pool size in pages incl. the sink "
                        "(default: worst-case sizing — every slot can hold "
                        "a full-length stream); set it below that to create "
                        "page pressure")
    p.add_argument("--overcommit", default="none",
                   choices=["none", "prompt"],
                   help="engine admission policy: 'none' reserves the "
                        "worst-case page need up front (reference); "
                        "'prompt' reserves only the prompt's pages plus a "
                        "small headroom and preempts the newest / lowest-"
                        "priority stream on pool exhaustion (bit-exact "
                        "re-prefill resume)")
    p.add_argument("--deadline-ticks", type=int, default=None,
                   help="per-request relative deadline for --engine: a "
                        "stream not finished within this many ticks of "
                        "submission moves to the terminal 'expired' state "
                        "and its pages are reclaimed")
    p.add_argument("--drain-on-sigterm", action="store_true",
                   help="install GracefulShutdown for the --engine loop: "
                        "SIGTERM/SIGINT stops admission, finishes in-flight "
                        "streams and reports per-request statuses instead "
                        "of killing them dead")
    p.add_argument("--seed", type=int, default=0)
    return p.parse_args(argv)


def _check_manifest(manifest: dict, cfg) -> None:
    """Fail fast (clearly) when a loaded artifact doesn't match the model
    built from --arch/--reduced, instead of an opaque shape error deep in
    prefill."""
    for field, got in (("arch", cfg.name), ("n_layers", cfg.n_layers),
                       ("d_model", cfg.d_model), ("vocab", cfg.vocab)):
        want = manifest.get(field)
        if want is not None and want != got:
            raise ArtifactMismatchError(
                f"artifact was exported for {field}={want!r} but the served "
                f"model has {field}={got!r} — pass the matching --arch/"
                f"--reduced flags (manifest: arch={manifest.get('arch')!r}, "
                f"n_layers={manifest.get('n_layers')}, "
                f"d_model={manifest.get('d_model')}, "
                f"vocab={manifest.get('vocab')})")


def _solve_budget_artifact(args, cfg, params):
    """--budget-bytes/--budget-decode-ms: sensitivity table (measured
    JSON via --sens, else the RTN weight-error proxy) -> exact solver ->
    packed mixed-precision artifact. Raises on an infeasible budget."""
    from ..core.sensitivity import SensTable
    from ..deploy.budget import budget_artifact, weight_sens_table

    if args.budget_bytes is not None and args.budget_decode_ms is not None:
        raise SystemExit("pass --budget-bytes or --budget-decode-ms, not both")
    if args.sens:
        sens = SensTable.load(args.sens)
    else:
        sens = weight_sens_table(params, cfg.n_layers, group=args.group)
    if args.budget_bytes is not None:
        kind, budget = "bytes", args.budget_bytes
    else:
        kind, budget = "decode_ms", args.budget_decode_ms
    art, sol, _ = budget_artifact(params, sens, budget, kind=kind, cfg=cfg,
                                  group=args.group,
                                  m=min(args.batch, 8) if kind != "bytes" else 1)
    if kind == "bytes" and art.nbytes() > budget:
        raise ArtifactMismatchError(
            f"budget solve produced a {art.nbytes()}-byte artifact over the "
            f"{budget:g}-byte budget")
    return art


def _setup_dispatch(args, cfg, params, artifact) -> None:
    """--dispatch: route decode-shaped qmm calls by measured tier
    winners. 'measured' times the served shapes now (reusing the
    artifact's per-backend manifest cache when present); 'heuristic'
    pins the env override so even an installed table is ignored."""
    import os

    if args.dispatch == "heuristic":
        os.environ["REPRO_QMM_DISPATCH"] = "heuristic"
        return
    if args.dispatch != "measured":
        return
    if artifact is None:
        raise SystemExit("--dispatch measured needs packed weights "
                         "(--artifact/--quant/--budget-*)")
    from ..deploy.budget import (ensure_cost_table, install_dispatch,
                                 weight_shapes)

    os.environ["REPRO_QMM_DISPATCH"] = "measured"
    table = ensure_cost_table(artifact, weight_shapes(params, cfg.n_layers),
                              m=min(args.batch, 8))
    install_dispatch(table)
    wins = {}
    for key, tier in table.dispatch.items():
        wins[tier] = wins.get(tier, 0) + 1
    print(f"[dispatch] measured tier winners on {table.backend} "
          f"(m={table.meta['m']}): {wins} over "
          f"{table.meta['unique_shapes']} shapes")


def run_prefill_decode(model, params, batch, *, batch_size: int,
                       prompt_len: int, gen_len: int, hook=None, tag="fp",
                       quiet=False):
    """One prefill + ``gen_len`` greedy decode steps with the jitted
    step; returns (gen tokens, stats). The single timing harness shared
    by this driver and ``benchmarks/table6_deploy.py``.

    Both programs are AOT-compiled (``lower().compile()``) before the
    clock starts, so ``t_prefill``/``t_decode`` are steady-state serving
    walls — compile time is reported separately as ``t_compile`` (it
    used to land inside the decode loop and dominate short packed runs).
    ``qmm_tiers`` records which packed execution tiers the two programs
    traced (all zero for FP params).
    """
    from ..kernels.qmatmul import ops as qmm_ops
    from ..models.common import NO_QUANT

    hook = hook or NO_QUANT
    cache = model.init_cache(batch_size, prompt_len + gen_len, jnp.float32)

    prefill = jax.jit(lambda p, b, c: model.prefill(p, b, c, hook, remat="none"))
    decode = jax.jit(
        lambda p, t, c, pos: model.decode_step(p, t, c, pos, hook),
        donate_argnums=(2,))
    tiers0 = dict(qmm_ops.TIER_COUNTS)
    t0 = time.time()
    prefill_c = prefill.lower(params, batch, cache).compile()
    tok0 = jnp.zeros((batch_size, 1), jnp.int32)
    pos0 = jnp.full((batch_size,), prompt_len, jnp.int32)
    decode_c = decode.lower(params, tok0, cache, pos0).compile()
    t_compile = time.time() - t0
    tiers = {k: qmm_ops.TIER_COUNTS[k] - tiers0[k] for k in tiers0}

    t0 = time.time()
    logits, cache = prefill_c(params, batch, cache)
    logits.block_until_ready()
    t_prefill = time.time() - t0

    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    out_tokens = [tok]
    t0 = time.time()
    for i in range(gen_len - 1):
        pos = jnp.full((batch_size,), prompt_len + i, jnp.int32)
        logits, cache = decode_c(params, tok, cache, pos)
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        out_tokens.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.time() - t0
    toks = batch_size * (gen_len - 1)
    tok_s = toks / max(t_decode, 1e-9)
    prefill_tok_s = batch_size * prompt_len / max(t_prefill, 1e-9)
    if not quiet:
        used = ",".join(f"{k}={v}" for k, v in tiers.items() if v) or "none"
        note = "" if qmm_ops.decode_tier_enabled() else " (decode tier off)"
        print(f"[{tag}] compile {t_compile:.2f}s; prefill {batch_size}x"
              f"{prompt_len} in {t_prefill:.2f}s ({prefill_tok_s:.0f} tok/s); "
              f"decode {toks} tokens in {t_decode:.2f}s ({tok_s:.1f} tok/s); "
              f"qmm tiers: {used}{note}")
    gen = jnp.concatenate(out_tokens, axis=1)
    return gen, {"t_prefill": t_prefill, "t_decode": t_decode,
                 "t_compile": t_compile, "tok_s": tok_s,
                 "prefill_tok_s": prefill_tok_s, "qmm_tiers": tiers,
                 "decode_tier_enabled": qmm_ops.decode_tier_enabled()}


def _run_once(model, params, batch, args, hook=None, tag="fp"):
    return run_prefill_decode(model, params, batch, batch_size=args.batch,
                              prompt_len=args.prompt_len,
                              gen_len=args.gen_len, hook=hook, tag=tag)


def main(argv=None, params=None):
    args = parse_args(argv)
    cfg, model = get_model(args.arch, reduced=args.reduced)
    if params is None:
        params = model.init(jax.random.PRNGKey(args.seed))
    fp_bytes = tree_bytes(params)

    artifact = None
    tmp_dir = None  # cleaned on exit when the user didn't ask to keep it
    try:
        if args.artifact:
            # verifying load: schema + per-leaf checksums, unless --no-verify
            artifact = QuantizedArtifact.load(args.artifact,
                                              verify=not args.no_verify)
            _check_manifest(artifact.manifest, cfg)
            print(f"loaded artifact {args.artifact}: "
                  f"{artifact.nbytes()/1e6:.1f}MB, manifest arch="
                  f"{artifact.manifest.get('arch')}")
        elif args.budget_bytes is not None or args.budget_decode_ms is not None:
            art = _solve_budget_artifact(args, cfg, params)
            if args.save_artifact:
                out_dir = args.save_artifact
            else:
                tmp_dir = tempfile.TemporaryDirectory(prefix="brecq_art_")
                out_dir = tmp_dir.name
            art.save(out_dir)
            artifact = QuantizedArtifact.load(out_dir,
                                              verify=not args.no_verify)
            info = artifact.manifest["budget"]
            print(f"[budget] {info['kind']} <= {info['budget']:g}: solved "
                  f"bits {info['bits_histogram']} predicted-loss "
                  f"{info['predicted_loss']:.4g}; artifact_bytes="
                  f"{artifact.nbytes()} -> {out_dir}")
        elif args.quant is not None:
            art = rtn_artifact(params, args.quant, args.group, cfg=cfg)
            if args.save_artifact:
                out_dir = args.save_artifact
            else:
                tmp_dir = tempfile.TemporaryDirectory(prefix="brecq_art_")
                out_dir = tmp_dir.name
            art.save(out_dir)
            # serve what was shipped, through the same verifying loader
            artifact = QuantizedArtifact.load(out_dir,
                                              verify=not args.no_verify)
            print(f"packed W{args.quant} artifact in "
                  f"{art.stats['pack_wall_s']:.2f}s -> {out_dir}")
        _setup_dispatch(args, cfg, params, artifact)
        return _serve(args, cfg, model, params, artifact, fp_bytes)
    finally:
        if tmp_dir is not None:
            tmp_dir.cleanup()


def _serve_engine(args, cfg, model, params, artifact, fp_bytes):
    """Continuous-batching mode: N synthetic streams with staggered
    arrivals and mixed prompt/gen lengths through the serve engine.
    The fixed-batch harness is the degenerate case (one arrival wave,
    uniform lengths)."""
    from ..serve_engine import EngineConfig, ServeEngine

    manifest = artifact.manifest if artifact is not None else {}
    kv_dtype = args.kv_dtype or manifest.get("kv_dtype") or "int8"
    page_size = args.page_size or int(manifest.get("kv_page_size") or 16)
    from ..launch.watchdog import GracefulShutdown

    num_slots = args.batch
    streams = args.streams or 2 * num_slots
    max_len = args.prompt_len + args.gen_len
    pages_per = -(-max_len // page_size)
    num_pages = args.num_pages or 1 + num_slots * pages_per
    ecfg = EngineConfig(
        num_slots=num_slots, page_size=page_size,
        num_pages=num_pages, max_len=max_len,
        prefill_chunk=min(args.prefill_chunk, max(args.prompt_len, 1)),
        kv_dtype=kv_dtype, overcommit=args.overcommit)
    hook = artifact.hook() if artifact is not None else None
    weights = artifact.params if artifact is not None else params
    from ..models.common import NO_QUANT
    eng = ServeEngine(model, weights, ecfg, quant=hook or NO_QUANT)
    t_compile = eng.compile()

    rng = np.random.default_rng(args.seed)
    corpus = Corpus(CorpusConfig(vocab=cfg.vocab))
    arrivals = sorted(int(a) for a in rng.integers(0, 4 * streams, streams))
    plens = rng.integers(max(args.prompt_len // 2, 1), args.prompt_len + 1,
                         streams)
    gens = rng.integers(max(args.gen_len // 2, 1), args.gen_len + 1, streams)
    prompts = [corpus.sample(1, int(plens[i]), seed=args.seed + i)[0]
               for i in range(streams)]
    gs = GracefulShutdown() if args.drain_on_sigterm else None
    nxt = 0
    try:
        while nxt < streams or eng.pending():
            if gs is not None and gs.requested:
                statuses = eng.drain(finish=True)
                counts: dict = {}
                for st in statuses.values():
                    counts[st] = counts.get(st, 0) + 1
                print(f"[drain] signal received: admission stopped, "
                      f"in-flight work settled; request statuses {counts} "
                      f"({streams - nxt} never submitted)")
                break
            while nxt < streams and arrivals[nxt] <= eng.tick:
                eng.submit(prompts[nxt], int(gens[nxt]),
                           deadline_ticks=args.deadline_ticks)
                nxt += 1
            eng.step()
    finally:
        if gs is not None:
            gs.restore()
    eng.assert_no_leaks()
    m = eng.metrics()
    pressure = (f"; preempt {m['preemptions']} expired {m['expired']} "
                f"failed {m['failed']} stragglers {m['stragglers']}"
                if (m["preemptions"] or m["expired"] or m["failed"]
                    or m["stragglers"]) else "")
    print(f"[engine {kv_dtype}] compile {t_compile:.2f}s; {streams} streams "
          f"over {num_slots} slots ({num_pages} pages, overcommit="
          f"{args.overcommit}): {m['tokens_generated']} tokens in "
          f"{m['wall_s']:.2f}s ({m['sustained_tok_s']:.1f} tok/s sustained); "
          f"occupancy {m['mean_slot_occupancy']:.2f}; resident KV "
          f"{m['mean_resident_kv_bytes_per_stream']/1e3:.1f}KB/stream "
          f"(page {page_size} tok, {m['bytes_per_page']/1e3:.1f}KB)"
          f"{pressure}")
    return m


def _serve(args, cfg, model, params, artifact, fp_bytes):
    if args.engine:
        if artifact is not None:
            art_bytes = artifact.nbytes()
            print(f"weights resident as packed int codes: "
                  f"{fp_bytes/1e6:.1f}MB fp32 -> {art_bytes/1e6:.1f}MB packed")
        return _serve_engine(args, cfg, model, params, artifact, fp_bytes)
    corpus = Corpus(CorpusConfig(vocab=cfg.vocab))
    prompts = jnp.asarray(corpus.sample(args.batch, args.prompt_len, seed=7))
    batch = {"tokens": prompts}
    if cfg.family == "vlm":
        rng = np.random.default_rng(0)
        batch["patches"] = jnp.asarray(
            rng.normal(size=(args.batch, cfg.n_patches, cfg.d_model)), jnp.float32)
    if cfg.enc_dec:
        rng = np.random.default_rng(0)
        batch["frames"] = jnp.asarray(
            rng.normal(size=(args.batch, args.prompt_len, cfg.d_model)), jnp.float32)

    if artifact is None:
        gen, _ = _run_once(model, params, batch, args, tag="fp")
        print("sample:", np.asarray(gen[0][:16]))
        return gen

    art_bytes = artifact.nbytes()
    print(f"weights resident as packed int codes: {fp_bytes/1e6:.1f}MB fp32 -> "
          f"{art_bytes/1e6:.1f}MB packed ({art_bytes/fp_bytes:.3f}x)")
    if art_bytes >= fp_bytes:
        raise ArtifactMismatchError(
            f"packed artifact ({art_bytes} bytes) is not smaller than the FP "
            f"model ({fp_bytes} bytes) — the artifact does not belong to "
            f"this model or holds unpacked weights")

    hook = artifact.hook()
    if args.packed_backend != "auto":
        hook = copy.copy(hook)  # NO_QUANT is a shared singleton
        hook.packed_backend = args.packed_backend
    gen, qstat = _run_once(model, artifact.params, batch, args,
                           hook=hook, tag="packed")
    if not args.no_compare_fp:
        _, fstat = _run_once(model, params, batch, args, tag="fp")
        print(f"packed vs fp: {qstat['tok_s']:.1f} vs {fstat['tok_s']:.1f} tok/s "
              f"decode; bytes {art_bytes/1e6:.1f}MB vs {fp_bytes/1e6:.1f}MB")
    print("sample:", np.asarray(gen[0][:16]))
    return gen


if __name__ == "__main__":
    main()
