"""ShapeDtypeStruct input stand-ins for every (arch x shape) cell.

No device allocation: the dry-run lowers against these. Modality
frontends are stubs per the assignment — VLM patch embeddings and
whisper frame embeddings appear here as precomputed (B, P|S, d) floats.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig, ShapeSpec

SDS = jax.ShapeDtypeStruct


def input_specs(cfg: ArchConfig, shape: ShapeSpec) -> dict:
    """Batch pytree of ShapeDtypeStructs for train/prefill steps."""
    B, S = shape.global_batch, shape.seq_len
    batch = {"tokens": SDS((B, S), jnp.int32)}
    if cfg.family == "vlm":
        batch["patches"] = SDS((B, cfg.n_patches, cfg.d_model), jnp.bfloat16)
    if cfg.enc_dec:
        batch["frames"] = SDS((B, S, cfg.d_model), jnp.bfloat16)
    return batch


def decode_specs(cfg: ArchConfig, shape: ShapeSpec, model) -> tuple[dict, dict, SDS]:
    """(token batch, cache, pos) stand-ins for one decode step with a
    KV cache of ``shape.seq_len`` tokens."""
    B, S = shape.global_batch, shape.seq_len
    tokens = SDS((B, 1), jnp.int32)
    cache = jax.eval_shape(lambda: model.init_cache(B, S, jnp.bfloat16))
    pos = SDS((B,), jnp.int32)
    return {"tokens": tokens}, cache, pos


def params_specs(model) -> dict:
    return jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
