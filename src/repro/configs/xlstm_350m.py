"""xlstm-350m [arXiv:2405.04517]: sLSTM + mLSTM blocks, 24L, d=1024."""
import dataclasses

from .base import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-350m", family="ssm",
    n_layers=24, d_model=1024, n_heads=4, n_kv_heads=4, d_ff=0,
    vocab=50304, slstm_every=6, xlstm_expansion=2.0,
    supports_long=True,
    tie_embeddings=False,
    notes="d_ff=0: xLSTM blocks carry their own 2x up/down projections; "
          "1 sLSTM per 6 blocks. O(1) decode state -> long_500k runs.",
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, n_layers=6, d_model=64, n_heads=2, n_kv_heads=2,
        vocab=256, slstm_every=3)
