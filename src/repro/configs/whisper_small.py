"""whisper-small [arXiv:2212.04356]: enc-dec, conv frontend stubbed.

Backbone only per assignment: input_specs provides precomputed frame
embeddings. Positional scheme adapted to the substrate's RoPE
(DESIGN.md §2); LayerNorm + GELU as in the original.
"""
import dataclasses

from .base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-small", family="audio",
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=12, d_ff=3072,
    vocab=51865, norm="ln", mlp_kind="gelu", enc_dec=True,
    tie_embeddings=False,
    notes="12 encoder + 12 decoder layers; decoder = self-attn + "
          "cross-attn + MLP. long_500k skipped (full attention decoder).",
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
        vocab=256)
