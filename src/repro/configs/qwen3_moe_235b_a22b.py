"""qwen3-moe-235b-a22b [hf:Qwen/Qwen3 family]: 128 experts top-8, GQA kv=4."""
import dataclasses

from .base import ArchConfig, MoEArch

CONFIG = ArchConfig(
    name="qwen3-moe-235b-a22b", family="moe",
    n_layers=94, d_model=4096, n_heads=64, n_kv_heads=4, d_ff=1536,
    vocab=151936, head_dim=128, qk_norm=True, rope_theta=1e6,
    tie_embeddings=False,
    moe=MoEArch(n_experts=128, top_k=8, d_ff_expert=1536),
    notes="per-head q/k RMS norm (qwen3); no shared experts.",
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=96,
        vocab=256, head_dim=16,
        moe=MoEArch(n_experts=8, top_k=2, d_ff_expert=96))
