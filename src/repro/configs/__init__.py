from .base import ArchConfig, MoEArch, ShapeSpec, SHAPES, applicable_shapes  # noqa: F401
