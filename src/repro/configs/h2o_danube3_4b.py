"""h2o-danube-3-4b [arXiv:2401.16818]: llama+mistral mix with SWA."""
import dataclasses

from .base import ArchConfig

CONFIG = ArchConfig(
    name="h2o-danube-3-4b", family="dense",
    n_layers=24, d_model=3840, n_heads=32, n_kv_heads=8, d_ff=10240,
    vocab=32000, head_dim=120, window=4096, supports_long=True,
    tie_embeddings=False,
    notes="uniform sliding-window attention (mistral-style) -> bounded "
          "decode cache -> long_500k runs.",
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab=256, head_dim=16, window=32)
