"""gemma3-12b [hf:google/gemma-3 family]: 5:1 local:global attention."""
import dataclasses

from .base import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-12b", family="dense",
    n_layers=48, d_model=3840, n_heads=16, n_kv_heads=8, d_ff=15360,
    vocab=262144, head_dim=256, qk_norm=True, mlp_kind="gelu",
    rope_theta=1e6, local_global=(5, 1), local_window=1024,
    supports_long=True,
    tie_embeddings=False,
    notes="5 local (window 1024) : 1 global per group; global-layer KV is "
          "sequence-sharded in long_500k. 262k vocab dominates bytes.",
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, n_layers=6, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab=256, head_dim=16, local_global=(2, 1), local_window=16)
