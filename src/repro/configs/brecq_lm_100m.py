"""brecq-lm-100m: the paper-scale model for end-to-end BRECQ experiments.

Plays the role ResNet-18 plays in the paper: small enough to train for a
few hundred steps in-framework, big enough that 2-bit RTN collapses and
block reconstruction visibly recovers it.
"""
import dataclasses

from .base import ArchConfig

CONFIG = ArchConfig(
    name="brecq-lm-100m", family="dense",
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=12, d_ff=2048,
    vocab=8192, tie_embeddings=True,
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, n_layers=4, d_model=128, n_heads=4, n_kv_heads=4, d_ff=256,
        vocab=512)
