"""deepseek-moe-16b [arXiv:2401.06066]: 2 shared + 64 routed top-6."""
import dataclasses

from .base import ArchConfig, MoEArch

CONFIG = ArchConfig(
    name="deepseek-moe-16b", family="moe",
    n_layers=28, d_model=2048, n_heads=16, n_kv_heads=16, d_ff=1408,
    vocab=102400,
    moe=MoEArch(n_experts=64, top_k=6, d_ff_expert=1408, n_shared=2,
                first_k_dense=1, first_dense_ff=10944),
    tie_embeddings=False,
    notes="fine-grained experts; layer 0 keeps a dense FFN (hf config "
          "first_k_dense_replace=1).",
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, n_layers=3, d_model=64, n_heads=4, n_kv_heads=4, d_ff=96,
        vocab=256,
        moe=MoEArch(n_experts=8, top_k=2, d_ff_expert=96, n_shared=1,
                    first_k_dense=1, first_dense_ff=128))
