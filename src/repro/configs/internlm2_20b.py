"""internlm2-20b [arXiv:2403.17297]: dense GQA."""
import dataclasses

from .base import ArchConfig

CONFIG = ArchConfig(
    name="internlm2-20b", family="dense",
    n_layers=48, d_model=6144, n_heads=48, n_kv_heads=8, d_ff=16384,
    vocab=92544, rope_theta=1e6, tie_embeddings=False,
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab=256)
