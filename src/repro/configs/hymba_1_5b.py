"""hymba-1.5b [arXiv:2411.13676]: parallel attention + mamba heads."""
import dataclasses

from .base import ArchConfig

CONFIG = ArchConfig(
    name="hymba-1.5b", family="hybrid",
    n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5, d_ff=5504,
    vocab=32001, head_dim=64, ssm_state=16, ssm_expansion=2.0,
    hymba_window=2048, supports_long=True,
    tie_embeddings=False,
    notes="each block runs attention heads and a selective-SSM head on "
          "the same input, outputs averaged. Attention uses SWA(2048) so "
          "the decode state stays bounded -> long_500k runs.",
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab=256, head_dim=16, ssm_state=8, hymba_window=32)
