"""llama-3.2-vision-90b [hf:meta-llama/Llama-3.2-*-Vision].

100 layers = 80 self-attn + 20 gated cross-attn (1 per group of 5).
The vision tower is a STUB per assignment: input_specs supplies
precomputed patch embeddings (B, n_patches, d_model).
"""
import dataclasses

from .base import ArchConfig

CONFIG = ArchConfig(
    name="llama-3.2-vision-90b", family="vlm",
    n_layers=100, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=28672,
    vocab=128256, rope_theta=500000.0, tie_embeddings=False,
    xattn_every=5, n_patches=1024,
    notes="tanh-gated cross-attn layers; image frontend stubbed.",
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab=256, xattn_every=2, n_patches=16)
