"""Architecture config schema + input shape definitions.

Every assigned arch provides ``CONFIG`` (exact published numbers) and
``reduced()`` (CPU-smoke-scale variant of the same family) through one
:class:`ArchConfig`. The dry-run, launcher and tests consume only this
schema.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEArch:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0
    first_k_dense: int = 0  # leading dense-FFN layers (deepseek)
    first_dense_ff: int = 0


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | ssm | vlm | audio | hybrid
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None  # default d_model // n_heads
    norm: str = "rms"  # rms | ln
    mlp_kind: str = "swiglu"  # swiglu | gelu
    qk_norm: bool = False
    rope_theta: float = 10000.0
    tie_embeddings: bool = True
    # attention pattern
    window: Optional[int] = None  # uniform sliding window (danube)
    local_global: Optional[Tuple[int, int]] = None  # (n_local, n_global) per group, gemma
    local_window: int = 1024
    # moe
    moe: Optional[MoEArch] = None
    # vlm: one cross-attn layer per `xattn_every` group
    xattn_every: Optional[int] = None
    n_patches: int = 1024
    # enc-dec (whisper): n_layers applies to BOTH encoder and decoder
    enc_dec: bool = False
    # xlstm: one sLSTM per group of `slstm_every` (rest mLSTM)
    slstm_every: Optional[int] = None
    xlstm_expansion: float = 2.0
    # hybrid (hymba)
    ssm_state: int = 0
    ssm_expansion: float = 2.0
    hymba_window: Optional[int] = 2048  # SWA for the attention heads in long ctx
    # applicability
    supports_long: bool = False
    notes: str = ""

    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def applicable_shapes(cfg: ArchConfig) -> list[str]:
    out = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.supports_long:
        out.append("long_500k")
    return out
