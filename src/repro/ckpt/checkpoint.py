"""Step-addressed checkpoints with atomic commit and elastic restore.

Layout:
  <dir>/step_000123/arrays.npz   flattened '/'-joined tree paths -> arrays
  <dir>/step_000123/manifest.json  {step, time, treedef hash, user meta}
A checkpoint only counts once ``manifest.json`` exists — the save writes
into ``step_X.tmp`` and renames, so a preempted save can never be
mistaken for a complete one (fault-tolerance requirement).

Elastic restore: arrays are stored host-complete and re-placed with
whatever shardings the *current* mesh wants (``device_put`` per leaf),
so a run checkpointed on N devices restarts on M devices unchanged. At
multi-host scale the same layout shards per host (process index in the
filename); this container is single-host, noted in DESIGN.md.

Async: ``save_async`` hands the (host-synced) arrays to a writer thread;
``wait`` joins it before the next save or exit.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np

SEP = "/"


class CheckpointReadError(RuntimeError):
    """A checkpoint's array payload could not be read (truncated file,
    corrupt zip, missing member). Carries the path that failed so callers
    (e.g. `repro.deploy.QuantizedArtifact.load`) can raise their own
    typed error naming the artifact."""

    def __init__(self, path, cause: Exception, member: Optional[str] = None):
        super().__init__(f"cannot read checkpoint arrays at {path}: "
                         f"{type(cause).__name__}: {cause}")
        self.path = str(path)
        self.cause = cause
        # flat tree path of the npz member that failed, when known (the
        # zip layer's own CRC catches damage member-by-member)
        self.member = member.removesuffix(".npy") if member else None


def _load_npz(path: Path):
    """np.load with truncation/corruption mapped to CheckpointReadError."""
    try:
        return np.load(path)
    except Exception as e:  # BadZipFile, EOFError, OSError, ValueError...
        raise CheckpointReadError(path, e) from e


def _read_member_lax(z, name: str) -> np.ndarray:
    """Re-read one npz member with the zip CRC check disabled — the
    non-strict escape hatch for artifacts whose payload bytes are known
    (or accepted) to be damaged."""
    import io

    f = z.zip.open(name)
    f._expected_crc = None  # CPython zipfile: None disables the CRC check
    return np.lib.format.read_array(io.BytesIO(f.read()), allow_pickle=False)


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = SEP.join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        flat[key] = np.asarray(jax.device_get(leaf))
    return flat


def _unflatten_into(like, flat: dict[str, np.ndarray], shardings=None):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(like)
    shard_leaves = (jax.tree_util.tree_leaves(shardings)
                    if shardings is not None else [None] * len(leaves))
    out = []
    for (path, leaf), sh in zip(leaves, shard_leaves):
        key = SEP.join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        arr = flat[key]
        if sh is not None:
            out.append(jax.device_put(arr, sh))
        else:
            out.append(jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef.treedef if hasattr(treedef, "treedef") else treedef, out)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None

    # -- save -------------------------------------------------------------------

    def save(self, step: int, tree: Any, meta: Optional[dict] = None):
        flat = _flatten(tree)
        self._write(step, flat, meta or {})

    def save_async(self, step: int, tree: Any, meta: Optional[dict] = None):
        self.wait()
        flat = _flatten(tree)  # device_get on caller thread (consistent view)
        self._thread = threading.Thread(
            target=self._write, args=(step, flat, meta or {}), daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, flat: dict, meta: dict):
        final = self.dir / f"step_{step:08d}"
        tmp = self.dir / f"step_{step:08d}.tmp"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        np.savez(tmp / "arrays.npz", **flat)
        (tmp / "manifest.json").write_text(json.dumps(
            {"step": step, "time": time.time(), "n_arrays": len(flat),
             "meta": meta}))
        if final.exists():
            shutil.rmtree(final)
        os.replace(tmp, final)
        self._gc()

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: max(0, len(steps) - self.keep)]:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)

    # -- restore -----------------------------------------------------------------

    def all_steps(self) -> list[int]:
        out = []
        for p in self.dir.glob("step_*"):
            m = re.fullmatch(r"step_(\d+)", p.name)
            if m and (p / "manifest.json").exists():
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, like: Any, shardings=None) -> Any:
        d = self.dir / f"step_{step:08d}"
        flat = {}
        with _load_npz(d / "arrays.npz") as z:
            for k in z.files:
                try:
                    flat[k] = z[k]
                except Exception as e:  # member truncated/corrupt mid-array
                    raise CheckpointReadError(d / "arrays.npz", e,
                                              member=k) from e
        return _unflatten_into(like, flat, shardings)

    def restore_nested(self, step: int, strict: bool = True) -> dict:
        """Structure-free restore: rebuild nested dicts from the flat
        '/'-joined keys. Only valid for pure-dict trees (params-shaped
        checkpoints, deployment artifacts) — list/tuple nodes flatten to
        integer keys and are not reconstructed. Dtypes (incl. int8
        packed codes) round-trip exactly through the npz.

        ``strict=False`` retries a member that fails the zip layer's own
        CRC with the check disabled (``QuantizedArtifact.load(...,
        verify=False)``); a torn zip is still unreadable."""
        d = self.dir / f"step_{step:08d}"
        tree: dict = {}
        with _load_npz(d / "arrays.npz") as z:
            for key in z.files:
                node = tree
                parts = key.split(SEP)
                for p in parts[:-1]:
                    node = node.setdefault(p, {})
                try:
                    arr = z[key]
                except Exception as e:  # member truncated/corrupt mid-array
                    if strict:
                        raise CheckpointReadError(d / "arrays.npz", e,
                                                  member=key) from e
                    arr = _read_member_lax(z, key + ".npy")
                if arr.dtype.kind == "V" and arr.dtype.itemsize == 2:
                    # npz stores ml_dtypes.bfloat16 as an anonymous
                    # 2-byte void; f16 round-trips natively, so V2 is bf16
                    import ml_dtypes

                    arr = arr.view(ml_dtypes.bfloat16)
                node[parts[-1]] = jax.numpy.asarray(arr)
        return tree

    def manifest(self, step: int) -> dict:
        return json.loads((self.dir / f"step_{step:08d}" / "manifest.json").read_text())
