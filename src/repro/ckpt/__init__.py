from .checkpoint import CheckpointManager, CheckpointReadError  # noqa: F401
