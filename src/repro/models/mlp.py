"""Feed-forward variants: SwiGLU (llama family) and GELU (whisper/gemma)."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from . import common as cm
from .common import Ctx

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class MLPSpec:
    d_model: int
    d_ff: int
    kind: str = "swiglu"  # 'swiglu' | 'gelu'


def init(key, spec: MLPSpec):
    ks = jax.random.split(key, 3)
    if spec.kind == "swiglu":
        return {
            "w_gate": cm.dense_init(ks[0], spec.d_model, spec.d_ff),
            "w_up": cm.dense_init(ks[1], spec.d_model, spec.d_ff),
            "w_down": cm.dense_init(ks[2], spec.d_ff, spec.d_model),
        }
    return {
        "w_up": cm.dense_init(ks[0], spec.d_model, spec.d_ff),
        "w_down": cm.dense_init(ks[1], spec.d_ff, spec.d_model),
    }


def apply(ctx: Ctx, p, spec: MLPSpec, x: Array) -> Array:
    if spec.kind == "swiglu":
        g = cm.dense(ctx, p, "w_gate", x)
        u = cm.dense(ctx, p, "w_up", x)
        return cm.dense(ctx, p, "w_down", jax.nn.silu(g) * u)
    h = jax.nn.gelu(cm.dense(ctx, p, "w_up", x))
    return cm.dense(ctx, p, "w_down", h)
