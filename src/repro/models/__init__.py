from .registry import ARCH_IDS, ALIASES, build_model, get_config, get_model  # noqa: F401
