"""Arch registry: name -> (ArchConfig, model builder)."""
from __future__ import annotations

import importlib
from typing import Optional

from ..configs.base import ArchConfig
from .encdec import EncDecLM
from .transformer import LM

ARCH_IDS = [
    "xlstm_350m",
    "deepseek_moe_16b",
    "qwen3_moe_235b_a22b",
    "llama32_vision_90b",
    "internlm2_20b",
    "tinyllama_1_1b",
    "h2o_danube3_4b",
    "gemma3_12b",
    "whisper_small",
    "hymba_1_5b",
    # the paper-scale model used for BRECQ end-to-end experiments
    "brecq_lm_100m",
]

# CLI aliases matching the assignment spelling
ALIASES = {
    "xlstm-350m": "xlstm_350m",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
    "llama-3.2-vision-90b": "llama32_vision_90b",
    "internlm2-20b": "internlm2_20b",
    "tinyllama-1.1b": "tinyllama_1_1b",
    "h2o-danube-3-4b": "h2o_danube3_4b",
    "gemma3-12b": "gemma3_12b",
    "whisper-small": "whisper_small",
    "hymba-1.5b": "hymba_1_5b",
}


def get_config(name: str, *, reduced: bool = False) -> ArchConfig:
    name = ALIASES.get(name, name).replace("-", "_")
    mod = importlib.import_module(f"repro.configs.{name}")
    return mod.reduced() if reduced else mod.CONFIG


def build_model(cfg: ArchConfig, *, moe_impl: Optional[str] = None):
    """Instantiate the model object for a config."""
    if moe_impl is None:
        # exact token-choice for small models; capacity routing at scale
        moe_impl = "capacity" if (cfg.moe and cfg.moe.n_experts >= 16) else "dense"
    if cfg.enc_dec:
        return EncDecLM(cfg, moe_impl=moe_impl)
    return LM(cfg, moe_impl=moe_impl)


def get_model(name: str, *, reduced: bool = False, moe_impl: Optional[str] = None):
    cfg = get_config(name, reduced=reduced)
    return cfg, build_model(cfg, moe_impl=moe_impl)
