"""Decoder-only LM assembly covering dense / MoE / VLM / SSM / hybrid archs.

A model is: embed -> [stack_0 ... stack_k] -> final norm -> head.
Each *stack* is a scan over ``n`` identical (super-)blocks; a block is a
sequence of :class:`SubLayer` (time mixer + optional channel mixer).
This one assembly expresses:

  dense            1 stack,  block = [attn + mlp]
  sliding window   same, with ``window`` set
  gemma3 5:1       block = [5 x attn(local) + 1 x attn(global)], n = L/6
  moe              block = [attn + moe]  (+ leading dense stack, deepseek)
  vlm              block = [4 x (attn+mlp) + 1 x (xattn+mlp)]
  xlstm            block = [5 x mlstm + 1 x slstm], no FFN
  hymba            block = [parallel(attn, ssm) + mlp]

BRECQ consumes the same graph through begin()/apply_block()/finish():
the block boundary here *is* the paper's reconstruction unit.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from . import attention as attn_mod
from . import common as cm
from . import mlp as mlp_mod
from . import moe as moe_mod
from . import ssm as ssm_mod
from . import xlstm as xlstm_mod
from .common import Ctx, NO_QUANT, QuantHook

Array = jax.Array
Params = Any


@dataclasses.dataclass(frozen=True)
class SubLayer:
    mixer: str  # 'attn' | 'xattn' | 'mlstm' | 'slstm' | 'hymba'
    window: Optional[int] = None
    ffn: Optional[str] = None  # 'mlp' | 'moe' | None
    causal: bool = True
    d_ff: int = 0  # mlp width override (0 -> cfg.d_ff)


@dataclasses.dataclass(frozen=True)
class StackDef:
    name: str
    n: int
    subs: tuple[SubLayer, ...]


def build_stacks(cfg: ArchConfig) -> list[StackDef]:
    if cfg.family == "ssm":  # xlstm
        k = cfg.slstm_every or 6
        assert cfg.n_layers % k == 0
        subs = tuple([SubLayer("mlstm")] * (k - 1) + [SubLayer("slstm")])
        return [StackDef("body", cfg.n_layers // k, subs)]
    if cfg.family == "hybrid":
        return [StackDef("body", cfg.n_layers,
                         (SubLayer("hymba", window=cfg.hymba_window, ffn="mlp"),))]
    if cfg.family == "vlm":
        k = cfg.xattn_every or 5
        assert cfg.n_layers % k == 0
        subs = tuple([SubLayer("attn", ffn="mlp")] * (k - 1)
                     + [SubLayer("xattn", ffn="mlp")])
        return [StackDef("body", cfg.n_layers // k, subs)]
    if cfg.family == "moe":
        assert cfg.moe is not None
        stacks = []
        n_moe = cfg.n_layers - cfg.moe.first_k_dense
        if cfg.moe.first_k_dense:
            stacks.append(StackDef(
                "dense0", cfg.moe.first_k_dense,
                (SubLayer("attn", ffn="mlp", d_ff=cfg.moe.first_dense_ff),)))
        stacks.append(StackDef("moe", n_moe, (SubLayer("attn", ffn="moe"),)))
        return stacks
    # dense family (incl. gemma local:global and SWA)
    if cfg.local_global is not None:
        nl, ng = cfg.local_global
        grp = nl + ng
        assert cfg.n_layers % grp == 0
        subs = tuple([SubLayer("attn", window=cfg.local_window, ffn="mlp")] * nl
                     + [SubLayer("attn", ffn="mlp")] * ng)
        return [StackDef("body", cfg.n_layers // grp, subs)]
    return [StackDef("body", cfg.n_layers, (SubLayer("attn", window=cfg.window, ffn="mlp"),))]


# ---------------------------------------------------------------------------
# per-sublayer specs
# ---------------------------------------------------------------------------


def _attn_spec(cfg: ArchConfig, sub: SubLayer, cross: bool = False) -> attn_mod.AttnSpec:
    return attn_mod.AttnSpec(
        d_model=cfg.d_model, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
        head_dim=cfg.hd, rope_theta=cfg.rope_theta, window=sub.window,
        causal=sub.causal and not cross, use_rope=not cross, qk_norm=cfg.qk_norm)


def _mlp_spec(cfg: ArchConfig, sub: SubLayer) -> mlp_mod.MLPSpec:
    return mlp_mod.MLPSpec(cfg.d_model, sub.d_ff or cfg.d_ff, cfg.mlp_kind)


def _moe_spec(cfg: ArchConfig, impl: str) -> moe_mod.MoESpec:
    m = cfg.moe
    return moe_mod.MoESpec(cfg.d_model, m.d_ff_expert, m.n_experts, m.top_k,
                           n_shared=m.n_shared, impl=impl)


def _xlstm_spec(cfg: ArchConfig) -> xlstm_mod.XLSTMSpec:
    return xlstm_mod.XLSTMSpec(cfg.d_model, cfg.n_heads, cfg.xlstm_expansion)


def _ssm_spec(cfg: ArchConfig) -> ssm_mod.SSMSpec:
    return ssm_mod.SSMSpec(cfg.d_model, int(cfg.d_model * cfg.ssm_expansion), cfg.ssm_state)


def _norm_init(cfg: ArchConfig):
    return cm.rmsnorm_init(cfg.d_model) if cfg.norm == "rms" else cm.layernorm_init(cfg.d_model)


def _norm(cfg: ArchConfig, p, x):
    return cm.rmsnorm(p, x) if cfg.norm == "rms" else cm.layernorm(p, x)


# ---------------------------------------------------------------------------
# LM
# ---------------------------------------------------------------------------


class LM:
    """Decoder-only language model over the stack/sub-layer graph."""

    def __init__(self, cfg: ArchConfig, *, moe_impl: str = "dense"):
        self.cfg = cfg
        self.stacks = build_stacks(cfg)
        self.moe_impl = moe_impl

    # -- init ---------------------------------------------------------------

    def _init_sub(self, key, sub: SubLayer) -> Params:
        cfg = self.cfg
        ks = jax.random.split(key, 4)
        p: dict = {"norm1": _norm_init(cfg)}
        if sub.mixer == "attn":
            p["attn"] = attn_mod.init(ks[0], _attn_spec(cfg, sub))
        elif sub.mixer == "xattn":
            p["attn"] = attn_mod.init(ks[0], _attn_spec(cfg, sub, cross=True))
            p["xgate"] = jnp.zeros((), jnp.float32)
        elif sub.mixer == "mlstm":
            p["mix"] = xlstm_mod.mlstm_init(ks[0], _xlstm_spec(cfg))
        elif sub.mixer == "slstm":
            p["mix"] = xlstm_mod.slstm_init(ks[0], _xlstm_spec(cfg))
        elif sub.mixer == "hymba":
            p["attn"] = attn_mod.init(ks[0], _attn_spec(cfg, sub))
            p["ssm"] = ssm_mod.init(ks[1], _ssm_spec(cfg))
        else:
            raise ValueError(sub.mixer)
        if sub.ffn == "mlp":
            p["norm2"] = _norm_init(cfg)
            p["mlp"] = mlp_mod.init(ks[2], _mlp_spec(cfg, sub))
        elif sub.ffn == "moe":
            p["norm2"] = _norm_init(cfg)
            p["moe"] = moe_mod.init(ks[2], _moe_spec(cfg, self.moe_impl))
        return p

    def _init_block(self, key, stack: StackDef) -> Params:
        ks = jax.random.split(key, len(stack.subs))
        return {f"sub{i}": self._init_sub(ks[i], s) for i, s in enumerate(stack.subs)}

    def init(self, key) -> Params:
        cfg = self.cfg
        ks = jax.random.split(key, 3 + len(self.stacks))
        params: dict = {"embed": cm.embed_init(ks[0], cfg.vocab, cfg.d_model),
                        "final_norm": _norm_init(cfg)}
        if not cfg.tie_embeddings:
            params["head"] = {"w": jax.random.normal(ks[1], (cfg.d_model, cfg.vocab), jnp.float32) * 0.02}
        for i, stack in enumerate(self.stacks):
            bkeys = jax.random.split(ks[2 + i], stack.n)
            params[stack.name] = jax.vmap(partial(self._init_block, stack=stack))(bkeys)
        return params

    # -- sub-layer / block application ---------------------------------------

    def _apply_sub(self, ctx: Ctx, sub: SubLayer, idx: int, p: Params, x: Array) -> tuple[Array, Array]:
        cfg = self.cfg
        aux = jnp.zeros((), jnp.float32)
        sc = ctx.scoped(f"sub{idx}")
        h = _norm(cfg, p["norm1"], x)
        if sub.mixer == "attn":
            mix = attn_mod.apply(sc.scoped("attn"), p["attn"], _attn_spec(cfg, sub), h)
        elif sub.mixer == "xattn":
            mem = ctx.extras["memory"]
            out = attn_mod.apply(sc.scoped("attn"), p["attn"], _attn_spec(cfg, sub, cross=True), h, kv_x=mem)
            mix = jnp.tanh(p["xgate"]) * out
        elif sub.mixer == "mlstm":
            mix = xlstm_mod.mlstm_apply(sc.scoped("mix"), p["mix"], _xlstm_spec(cfg), h)
        elif sub.mixer == "slstm":
            mix = xlstm_mod.slstm_apply(sc.scoped("mix"), p["mix"], _xlstm_spec(cfg), h)
        elif sub.mixer == "hymba":
            a = attn_mod.apply(sc.scoped("attn"), p["attn"], _attn_spec(cfg, sub), h)
            s = ssm_mod.apply(sc.scoped("ssm"), p["ssm"], _ssm_spec(cfg), h)
            mix = 0.5 * (a + s)
        else:
            raise ValueError(sub.mixer)
        x = x + mix
        if sub.ffn == "mlp":
            h = _norm(cfg, p["norm2"], x)
            x = x + mlp_mod.apply(sc.scoped("mlp"), p["mlp"], _mlp_spec(cfg, sub), h)
        elif sub.ffn == "moe":
            h = _norm(cfg, p["norm2"], x)
            x = x + moe_mod.apply(sc.scoped("moe"), p["moe"], _moe_spec(cfg, self.moe_impl), h)
            aux = aux + moe_mod.aux_loss(sc.scoped("moe"), p["moe"], _moe_spec(cfg, self.moe_impl), h)
        return x, aux

    def apply_block(self, ctx: Ctx, stack: StackDef, p: Params, x: Array) -> tuple[Array, Array]:
        aux = jnp.zeros((), jnp.float32)
        for i, sub in enumerate(stack.subs):
            x, a = self._apply_sub(ctx, sub, i, p[f"sub{i}"], x)
            aux = aux + a
        return x, aux

    # -- full forward ---------------------------------------------------------

    def begin(self, params: Params, batch: dict, quant: QuantHook = NO_QUANT) -> tuple[Array, Ctx]:
        tokens = batch["tokens"]
        B, S = tokens.shape
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        ctx = Ctx(cfg=self.cfg, positions=positions, quant=quant)
        x = cm.embed_lookup(ctx, params["embed"], tokens)
        if self.cfg.family == "vlm":
            ctx.extras["memory"] = batch["patches"]
        return x, ctx

    def finish(self, params: Params, x: Array, ctx: Ctx) -> Array:
        x = _norm(self.cfg, params["final_norm"], x)
        # tied embeddings pass the embed node itself so lm_head can see a
        # packed int8 table (table_qscale) and dequantize it correctly
        head_p = params["head"] if "head" in params else params["embed"]
        return cm.lm_head(ctx, head_p, x)

    def forward(self, params: Params, batch: dict, quant: QuantHook = NO_QUANT,
                *, remat: Optional[str] = "dots", act_q=None,
                act_shard=None) -> tuple[Array, Array]:
        """Scan-based forward. Returns (logits, moe_aux).

        ``act_shard`` (optional fn) pins the hidden-state sharding at the
        embed output and on every scan carry — without it GSPMD can lose
        the batch sharding through the loop and replicate activations.
        """
        shard = (lambda t: act_shard(t)) if act_shard else (lambda t: t)
        x, ctx = self.begin(params, batch, quant)
        if act_shard is not None:
            ctx.extras["moe_shard"] = act_shard
        x = shard(x)
        aux = jnp.zeros((), jnp.float32)
        for stack in self.stacks:
            def body(carry, p_i, stack=stack):
                x, aux = carry
                x, a = self.apply_block(ctx, stack, p_i, x)
                return (shard(x), aux + a), None

            body_fn = _maybe_remat(body, remat)
            (x, aux), _ = jax.lax.scan(body_fn, (x, aux), params[stack.name])
        return self.finish(params, x, ctx), aux

    def loss(self, params: Params, batch: dict, quant: QuantHook = NO_QUANT,
             *, remat: Optional[str] = "dots", aux_weight: float = 0.01,
             act_shard=None) -> Array:
        logits, aux = self.forward(params, batch, quant, remat=remat,
                                   act_shard=act_shard)
        tokens = batch["tokens"]
        return cm.softmax_xent(logits[:, :-1], tokens[:, 1:]) + aux_weight * aux

    # -- serving ----------------------------------------------------------------

    def _init_sub_cache(self, sub: SubLayer, batch: int, max_len: int, dtype):
        cfg = self.cfg
        if sub.mixer == "attn":
            return {"attn": attn_mod.init_cache(_attn_spec(cfg, sub), batch, max_len, dtype)}
        if sub.mixer == "xattn":
            P = cfg.n_patches
            spec = _attn_spec(cfg, sub, cross=True)
            return {"xk": jnp.zeros((batch, P, spec.n_kv_heads, spec.head_dim), dtype),
                    "xv": jnp.zeros((batch, P, spec.n_kv_heads, spec.head_dim), dtype)}
        if sub.mixer == "mlstm":
            return {"mix": xlstm_mod.mlstm_init_cache(_xlstm_spec(cfg), batch)}
        if sub.mixer == "slstm":
            return {"mix": xlstm_mod.slstm_init_cache(_xlstm_spec(cfg), batch)}
        if sub.mixer == "hymba":
            return {"attn": attn_mod.init_cache(_attn_spec(cfg, sub), batch, max_len, dtype),
                    "ssm": ssm_mod.init_cache(_ssm_spec(cfg), batch, dtype)}
        raise ValueError(sub.mixer)

    def init_cache(self, batch: int, max_len: int, dtype=jnp.bfloat16):
        cache = {}
        for stack in self.stacks:
            one = {f"sub{i}": self._init_sub_cache(s, batch, max_len, dtype)
                   for i, s in enumerate(stack.subs)}
            cache[stack.name] = jax.tree.map(
                lambda a: jnp.broadcast_to(a[None], (stack.n, *a.shape)), one)
        return cache

    def init_paged_cache(self, num_pages: int, page_size: int,
                         kv_dtype: str = "int8"):
        """Paged KV pools for the serve engine — one pool per attention
        sub-layer, stacked along the scan dim like :meth:`init_cache`.
        All layers share one block table (they cache the same token
        sequence), so only the pools live here. Recurrent / cross-attn
        mixers have no paged form and are rejected up front."""
        cfg = self.cfg
        cache = {}
        for stack in self.stacks:
            one = {}
            for i, sub in enumerate(stack.subs):
                if sub.mixer != "attn":
                    raise ValueError(
                        f"paged KV serving needs attention-only mixers; "
                        f"stack {stack.name!r} sub {i} is {sub.mixer!r}")
                spec = _attn_spec(cfg, sub)
                one[f"sub{i}"] = {"attn": cm.init_paged_kv(
                    num_pages, page_size, spec.n_kv_heads, spec.head_dim,
                    kv_dtype)}
            cache[stack.name] = jax.tree.map(
                lambda a: jnp.broadcast_to(a[None], (stack.n, *a.shape)), one)
        return cache

    def _sub_prefill(self, ctx: Ctx, sub: SubLayer, idx: int, p, x, cache):
        cfg = self.cfg
        sc = ctx.scoped(f"sub{idx}")
        h = _norm(cfg, p["norm1"], x)
        if sub.mixer == "attn":
            out, cache["attn"] = attn_mod.prefill(sc.scoped("attn"), p["attn"], _attn_spec(cfg, sub), h, cache["attn"])
            mix = out
        elif sub.mixer == "xattn":
            spec = _attn_spec(cfg, sub, cross=True)
            xc = attn_mod.xattn_cache(sc.scoped("attn"), p["attn"], spec, ctx.extras["memory"])
            cache = {"xk": xc["k"].astype(cache["xk"].dtype), "xv": xc["v"].astype(cache["xv"].dtype)}
            out = attn_mod.apply(sc.scoped("attn"), p["attn"], spec, h, kv_x=ctx.extras["memory"])
            mix = jnp.tanh(p["xgate"]) * out
        elif sub.mixer in ("mlstm", "slstm"):
            # recurrent prefill: run the parallel form, then rebuild the state
            # by replaying the sequence through the chunk scan (mlstm) /
            # closed-form final state (slstm).
            mix, cache["mix"] = _xlstm_prefill(sc.scoped("mix"), sub.mixer, p["mix"], _xlstm_spec(cfg), h, cache["mix"])
        elif sub.mixer == "hymba":
            a, cache["attn"] = attn_mod.prefill(sc.scoped("attn"), p["attn"], _attn_spec(cfg, sub), h, cache["attn"])
            s, cache["ssm"] = _ssm_prefill(sc.scoped("ssm"), p["ssm"], _ssm_spec(cfg), h, cache["ssm"])
            mix = 0.5 * (a + s)
        else:
            raise ValueError(sub.mixer)
        x = x + mix
        if sub.ffn == "mlp":
            x = x + mlp_mod.apply(sc.scoped("mlp"), p["mlp"], _mlp_spec(cfg, sub), _norm(cfg, p["norm2"], x))
        elif sub.ffn == "moe":
            x = x + moe_mod.apply(sc.scoped("moe"), p["moe"], _moe_spec(cfg, self.moe_impl), _norm(cfg, p["norm2"], x))
        return x, cache

    def _sub_decode(self, ctx: Ctx, sub: SubLayer, idx: int, p, x, cache):
        cfg = self.cfg
        sc = ctx.scoped(f"sub{idx}")
        h = _norm(cfg, p["norm1"], x)
        if sub.mixer == "attn":
            out, cache["attn"] = attn_mod.decode(sc.scoped("attn"), p["attn"], _attn_spec(cfg, sub), h, cache["attn"])
            mix = out
        elif sub.mixer == "xattn":
            spec = _attn_spec(cfg, sub, cross=True)
            out = attn_mod.xattn_decode(sc.scoped("attn"), p["attn"], spec,
                                        h, {"k": cache["xk"], "v": cache["xv"]})
            mix = jnp.tanh(p["xgate"]) * out
        elif sub.mixer == "mlstm":
            mix, cache["mix"] = xlstm_mod.mlstm_decode(sc.scoped("mix"), p["mix"], _xlstm_spec(cfg), h, cache["mix"])
        elif sub.mixer == "slstm":
            mix, cache["mix"] = xlstm_mod.slstm_decode(sc.scoped("mix"), p["mix"], _xlstm_spec(cfg), h, cache["mix"])
        elif sub.mixer == "hymba":
            a, cache["attn"] = attn_mod.decode(sc.scoped("attn"), p["attn"], _attn_spec(cfg, sub), h, cache["attn"])
            s, cache["ssm"] = ssm_mod.decode(sc.scoped("ssm"), p["ssm"], _ssm_spec(cfg), h, cache["ssm"])
            mix = 0.5 * (a + s)
        else:
            raise ValueError(sub.mixer)
        x = x + mix
        if sub.ffn == "mlp":
            x = x + mlp_mod.apply(sc.scoped("mlp"), p["mlp"], _mlp_spec(cfg, sub), _norm(cfg, p["norm2"], x))
        elif sub.ffn == "moe":
            x = x + moe_mod.apply(sc.scoped("moe"), p["moe"], _moe_spec(cfg, self.moe_impl), _norm(cfg, p["norm2"], x))
        return x, cache

    def prefill(self, params, batch: dict, cache, quant: QuantHook = NO_QUANT,
                *, remat: Optional[str] = "dots", act_shard=None):
        """Process the prompt; returns (last-token logits, filled cache)."""
        shard = (lambda t: act_shard(t)) if act_shard else (lambda t: t)
        x, ctx = self.begin(params, batch, quant)
        if act_shard is not None:
            ctx.extras["moe_shard"] = act_shard
        x = shard(x)
        for stack in self.stacks:
            def body(x, xs, stack=stack):
                p_i, c_i = xs
                for i, sub in enumerate(stack.subs):
                    x, c_i[f"sub{i}"] = self._sub_prefill(ctx, sub, i, p_i[f"sub{i}"], x, c_i[f"sub{i}"])
                return shard(x), c_i

            body_fn = _maybe_remat(body, remat)
            x, cache[stack.name] = jax.lax.scan(body_fn, x, (params[stack.name], cache[stack.name]))
        logits = self.finish(params, x[:, -1:], ctx)
        return logits[:, 0], cache

    def decode_step(self, params, tokens: Array, cache, pos: Array,
                    quant: QuantHook = NO_QUANT, extras: Optional[dict] = None,
                    act_shard=None, *, all_logits: bool = False):
        """Decode C tokens in one cached step.

        tokens (B, C); pos (B,) absolute position of ``tokens[:, 0]``
        (consecutive positions are assigned within the chunk). C = 1 is
        plain decode; C > 1 is a chunked-prefill step through the same
        cached path. Returns last-position logits (B, V), or the full
        (B, C, V) when ``all_logits``.
        """
        B, C = tokens.shape
        shard = (lambda t: act_shard(t)) if act_shard else (lambda t: t)
        positions = (pos[:, None] + jnp.arange(C)[None]).astype(jnp.int32)
        ctx = Ctx(cfg=self.cfg, positions=positions, quant=quant, decode=True)
        if extras:
            ctx.extras.update(extras)
        if act_shard is not None:
            ctx.extras["moe_shard"] = act_shard
        x = shard(cm.embed_lookup(ctx, params["embed"], tokens))
        for stack in self.stacks:
            def body(x, xs, stack=stack):
                p_i, c_i = xs
                for i, sub in enumerate(stack.subs):
                    x, c_i[f"sub{i}"] = self._sub_decode(ctx, sub, i, p_i[f"sub{i}"], x, c_i[f"sub{i}"])
                return shard(x), c_i

            x, cache[stack.name] = jax.lax.scan(body, x, (params[stack.name], cache[stack.name]))
        logits = self.finish(params, x, ctx)
        return (logits if all_logits else logits[:, -1]), cache


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------


def _maybe_remat(fn, remat: Optional[str]):
    if remat is None or remat == "none":
        return fn
    if remat == "full":
        return jax.checkpoint(fn)
    if remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    raise ValueError(remat)


def _xlstm_prefill(ctx, mixer, p, spec, h, state):
    """Parallel forward + state rebuild for xLSTM prefill."""
    if mixer == "mlstm":
        out = xlstm_mod.mlstm_apply(ctx, p, spec, h)
        # rebuild final state with the chunk scan (cheap second pass over gates)
        q, k, v, ig, fg, _ = xlstm_mod._mlstm_qkvif(ctx, p, spec, h)
        B, S = h.shape[:2]
        L = min(spec.chunk, S)
        nc = S // L

        def rs(t):
            return t.reshape(B, nc, L, *t.shape[2:]).swapaxes(0, 1)

        carry = (state["C"], state["n"], state["m"])
        (C, n, m), _ = jax.lax.scan(xlstm_mod._mlstm_chunk, carry,
                                    (rs(q), rs(k), rs(v), rs(ig), rs(fg)))
        return out, {"C": C, "n": n, "m": m}
    out = xlstm_mod.slstm_apply(ctx, p, spec, h)
    # sequentially consistent final state via a light scan over gates only
    z, ig, lf, og = xlstm_mod._slstm_gates(ctx, p, h, spec.d_inner)

    def step(carry, t):
        c, n, m = carry
        zt, it, ft = t
        m_new = jnp.maximum(ft + m, it)
        fa = jnp.exp(ft + m - m_new)
        ib = jnp.exp(it - m_new)
        return (fa * c + ib * zt, fa * n + ib, m_new), None

    (c, n, m), _ = jax.lax.scan(step, (state["c"], state["n"], state["m"]),
                                (z.swapaxes(0, 1), ig.swapaxes(0, 1), lf.swapaxes(0, 1)))
    return out, {"c": c, "n": n, "m": m}


def _ssm_prefill(ctx, p, spec, h, state):
    """Mamba prefill: parallel output + final recurrent state."""
    import jax.numpy as jnp  # local alias for clarity

    B, S, _ = h.shape
    xz = cm.dense(ctx, p, "in_proj", h)
    xi, z = jnp.split(xz, 2, axis=-1)
    xi_c = jax.nn.silu(ssm_mod._conv_causal(xi, p["conv_w"]))
    a, b, Cc = ssm_mod._ssm_coeffs(ctx, p, spec, xi_c)

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, br + ar * bl

    _, hs = jax.lax.associative_scan(combine, (a.astype(jnp.float32), b.astype(jnp.float32)), axis=1)
    y = jnp.einsum("bsdn,bsn->bsd", hs, Cc.astype(jnp.float32)).astype(h.dtype)
    y = (y + p["D"] * xi_c) * jax.nn.silu(z)
    out = cm.dense(ctx, p, "out_proj", y)
    K = spec.d_conv - 1
    new_state = {"h": hs[:, -1], "conv": xi[:, S - K:].astype(state["conv"].dtype)}
    return out, new_state
