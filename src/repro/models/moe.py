"""Mixture-of-Experts layers (deepseek-moe, qwen3-moe).

Two execution paths share one parameterization:

* ``impl='dense'`` — every expert runs on every token, combined by the
  (sparse) gate matrix. Exact token-choice semantics; used for reduced
  configs, BRECQ calibration and unit tests.
* ``impl='capacity'`` — deployment path: per-expert top-C token
  selection (gather -> grouped einsum -> scatter-add). FLOPs scale with
  k/E like the real model; experts shard over the ``model`` mesh axis
  (EP). Tokens beyond capacity are dropped, mirroring GShard/Switch-style
  capacity routing; the difference vs. exact token-choice is recorded in
  DESIGN.md.

The router stays FP under quantization (see DESIGN.md §2); expert weights
are stacked (E, d_in, d_out) and quantize per-output-channel.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from . import common as cm
from . import mlp as mlp_mod
from .common import Ctx

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class MoESpec:
    d_model: int
    d_ff: int  # per-expert hidden
    n_experts: int
    top_k: int
    n_shared: int = 0  # shared (always-on) experts, deepseek-style
    capacity_factor: float = 1.25
    norm_topk: bool = True
    impl: str = "dense"  # 'dense' | 'capacity'


def init(key, spec: MoESpec):
    ks = jax.random.split(key, 5)
    scale = 1.0 / jnp.sqrt(spec.d_model)
    p = {
        "router": {"w": jax.random.normal(ks[0], (spec.d_model, spec.n_experts), jnp.float32) * scale},
        "w_gate": {"w": jax.random.uniform(ks[1], (spec.n_experts, spec.d_model, spec.d_ff), jnp.float32, -scale, scale)},
        "w_up": {"w": jax.random.uniform(ks[2], (spec.n_experts, spec.d_model, spec.d_ff), jnp.float32, -scale, scale)},
        "w_down": {"w": jax.random.uniform(ks[3], (spec.n_experts, spec.d_ff, spec.d_model), jnp.float32, -scale, scale)},
    }
    if spec.n_shared:
        p["shared"] = mlp_mod.init(
            ks[4], mlp_mod.MLPSpec(spec.d_model, spec.d_ff * spec.n_shared, "swiglu"))
    return p


def _router_probs(ctx: Ctx, p, spec: MoESpec, x: Array) -> Array:
    # router is FP: bypass the quant hook on purpose. x: (..., d) -> (..., E)
    logits = jnp.einsum("...d,de->...e", x.astype(jnp.float32), p["router"]["w"])
    return jax.nn.softmax(logits, axis=-1)


def _topk(x: Array, k: int) -> tuple[Array, Array]:
    """Partition-friendly, differentiable top-k on the last axis.

    jax.lax.top_k lowers to a TopK custom-call that GSPMD cannot
    partition (it replicates the operand — measured 309 GB of gathers on
    the qwen3 train cell). A sort HLO partitions on every non-sorted dim;
    its indices need no gradient (stop_gradient), and the selected values
    are re-gathered with a batched row gather so the backward pass is the
    plain scatter-add GSPMD already partitions."""
    iota = jax.lax.broadcasted_iota(jnp.int32, x.shape, x.ndim - 1)
    _, idx = jax.lax.sort_key_val(jax.lax.stop_gradient(x), iota, dimension=-1)
    idx = jax.lax.rev(jax.lax.slice_in_dim(idx, x.shape[-1] - k, x.shape[-1],
                                           axis=x.ndim - 1), (x.ndim - 1,))
    take_row = lambda row, t: row[t]
    for _ in range(x.ndim - 1):
        take_row = jax.vmap(take_row)
    return take_row(x, idx), idx


def _topk_gates(probs: Array, spec: MoESpec) -> tuple[Array, Array]:
    gates, eids = _topk(probs, spec.top_k)  # (..., k)
    if spec.norm_topk:
        gates = gates / jnp.maximum(jnp.sum(gates, -1, keepdims=True), 1e-9)
    return gates, eids


def _expert_mm(ctx: Ctx, p, name: str, xe: Array) -> Array:
    """One stacked-expert contraction: (..., E, C, K) @ (E, K, N).

    Packed nodes run the grouped ``qmm`` tier — expert codes stay
    resident int8 and dequantize per (expert, tile) inside the kernel,
    instead of materializing a transient f32 (E, K, N) dequant per scan
    step. Activation fake-quant is applied by :func:`_expert_ffn` (one
    quantized activation shared across the gate/up matmuls), so the
    weight-provider is told not to re-apply it.
    """
    node = p[name]
    path = f"{ctx.scope}/{name}"
    if "qscale" in node:
        return ctx.quant.packed_matmul(path, xe, node, apply_act=False)
    w = ctx.quant.weight(path, node["w"])
    return jnp.einsum("...ecd,edf->...ecf", xe, w.astype(xe.dtype))


def _expert_ffn(ctx: Ctx, p, xe: Array) -> Array:
    """(E, C, d) or (B, E, C, d) -> same, through stacked swiglu experts.

    The hidden intermediates are pinned to the dispatch sharding so GSPMD
    resolves the fsdp-axis on expert weights by gathering the (small)
    weight shards instead of resharding the (large) activations."""
    shard = ctx.extras.get("moe_shard") or (lambda t, kind: t)
    xe = ctx.quant.act(f"{ctx.scope}/w_gate", xe)
    g = shard(_expert_mm(ctx, p, "w_gate", xe), "expert_major")
    u = shard(_expert_mm(ctx, p, "w_up", xe), "expert_major")
    h = jax.nn.silu(g) * u
    h = ctx.quant.act(f"{ctx.scope}/w_down", h)
    return shard(_expert_mm(ctx, p, "w_down", h), "expert_major")


def apply(ctx: Ctx, p, spec: MoESpec, x: Array) -> Array:
    """x: (B, S, d). Batch-major throughout so GSPMD keeps everything on
    the data shards; ``ctx.extras['moe_shard']`` (fn(x, kind)) pins the
    routing/dispatch intermediates."""
    B, S, d = x.shape
    shard = ctx.extras.get("moe_shard") or (lambda t, kind: t)
    probs = shard(_router_probs(ctx, p, spec, x), "tokens")  # (B,S,E)
    gates, eids = _topk_gates(probs, spec)  # (B,S,k)

    if spec.impl == "dense":
        # combine matrix (B,S,E): gate weight where selected, else 0
        comb = jnp.zeros((B, S, spec.n_experts), x.dtype)
        bidx = jnp.arange(B)[:, None, None]
        sidx = jnp.arange(S)[None, :, None]
        comb = comb.at[bidx, sidx, eids].set(gates.astype(x.dtype))
        # all experts on all tokens (exact; reduced configs only)
        xe = jnp.broadcast_to(x[:, None], (B, spec.n_experts, S, d))
        ye = _expert_ffn(ctx, p, xe)  # (B,E,S,d)
        out = jnp.einsum("bse,besd->bsd", comb, ye)
        return out + _shared(ctx, p, spec, x)

    # capacity path: PER-SEQUENCE dispatch so routing gathers stay local
    # to each data shard (a global top-C would make GSPMD all-gather every
    # token). Capacity is per (sequence, expert); experts shard over the
    # "model" axis and the combine psum is the only EP collective.
    E = spec.n_experts
    cap = int(max(1, round(S * spec.top_k * spec.capacity_factor / E)))
    cap = min(cap, S)
    # All gathers/scatters below are vmapped over B so XLA sees explicit
    # operand-batching dims — a hand-rolled arange(B) index tensor makes
    # the scatter unpartitionable and GSPMD replicates the full batch.
    sidx = jnp.broadcast_to(jnp.arange(S)[:, None], eids.shape[1:])

    def sel_b(eids_b, gates_b):
        # (E, S): gate weight if token s picked expert e (top-k entries
        # are distinct experts, so scatter-max == scatter-set)
        z = jnp.full((E, S), -jnp.inf, jnp.float32)
        return z.at[eids_b, sidx].max(gates_b.astype(jnp.float32))

    sel = shard(jax.vmap(sel_b)(eids, gates), "expert_major")  # (B,E,S)
    scores, tidx = _topk(sel, cap)  # (B, E, cap)
    scores = shard(scores, "expert_major")
    tidx = shard(tidx, "expert_major")
    w = jnp.where(jnp.isfinite(scores), scores, 0.0).astype(x.dtype)
    xe = shard(jax.vmap(lambda xb, tb: xb[tb])(x, tidx), "expert_major")
    ye = _expert_ffn(ctx, p, xe)  # (B,E,cap,d)
    ye = shard(ye * w[..., None], "expert_major")
    out_b = jax.vmap(lambda tb, yb: jnp.zeros((S, d), x.dtype).at[tb].add(yb))(
        tidx, ye)
    return shard(out_b, "tokens") + _shared(ctx, p, spec, x)

def _shared(ctx: Ctx, p, spec: MoESpec, x: Array) -> Array:
    if not spec.n_shared:
        return jnp.zeros((), x.dtype)
    shared_spec = mlp_mod.MLPSpec(spec.d_model, spec.d_ff * spec.n_shared, "swiglu")
    return mlp_mod.apply(ctx.scoped("shared"), p["shared"], shared_spec, x)


def aux_loss(ctx: Ctx, p, spec: MoESpec, x: Array) -> Array:
    """Switch-style load-balancing loss (used by the training loop)."""
    probs = _router_probs(ctx, p, spec, x)  # (B,S,E)
    _, eids = _topk_gates(probs, spec)
    onehot = jax.nn.one_hot(eids, spec.n_experts).sum(2)  # (B,S,E)
    frac_tokens = jnp.mean(onehot, axis=(0, 1))
    frac_probs = jnp.mean(probs, axis=(0, 1))
    return spec.n_experts * jnp.sum(frac_tokens * frac_probs)
