"""Shared building blocks for all model families.

Conventions
-----------
* Params are nested dicts of ``jnp`` arrays. A linear layer is
  ``{'w': (in, out)}`` (+ optional ``'b'``). Weight layout is always
  (reduction_dim, output_dim) so quantization group axes are uniform.
* Every matmul goes through :func:`dense`, which consults the quant
  context ``ctx.quant`` — the single hook BRECQ needs inside models.
* ``ctx`` is a :class:`Ctx` carrying config, positions, masks and the
  quant hook. It is closed over by scan bodies; all array members are
  valid tracers.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

Array = jax.Array
Params = Any


# ---------------------------------------------------------------------------
# quant hook
# ---------------------------------------------------------------------------


class QuantHook:
    """Interface the models call; the default is a no-op (FP model).

    ``weight(name, w)``: returns the (possibly fake-quantized) weight.
    ``act(name, x)``: returns the (possibly fake-quantized) activation.
    The BRECQ engine installs real implementations during calibration;
    the serving path installs a baked/LSQ variant.

    Weight-provider protocol: when a params node carries packed int
    codes (a ``qscale`` sibling — the `repro.deploy` artifact format),
    :func:`dense`/:func:`lm_head` hand the whole matmul to
    ``packed_matmul`` instead of materializing an f32 weight. The
    default executes via the packed ``qmm`` dispatcher (weights stay int
    codes in HBM; dequant happens tile-wise in-register), after routing
    the activation through ``act`` so serve-time LSQ still applies.
    ``qmm`` picks the execution tier by shape — decode gemv for M up to
    a sublane of rows, the tiled prefill GEMM otherwise, and the grouped
    expert kernel for stacked 3-D nodes (x is then (..., E, C, K)).
    ``packed_backend`` picks the qmm execution path ('auto': Pallas on
    TPU, XLA reference elsewhere). Callers that already applied
    activation fake-quant themselves (the MoE layer shares one
    quantized activation across its gate/up matmuls) pass
    ``apply_act=False``.
    """

    packed_backend: str = "auto"

    def weight(self, name: str, w: Array) -> Array:
        return w

    def act(self, name: str, x: Array) -> Array:
        return x

    def packed_matmul(self, name: str, x: Array, node: Params,
                      apply_act: bool = True) -> Array:
        from ..kernels.qmatmul.ops import from_node, qmm

        if apply_act:
            x = self.act(name, x)
        return qmm(x, from_node(node, x.shape[-1], path=name),
                   backend=self.packed_backend)


NO_QUANT = QuantHook()


@dataclasses.dataclass
class Ctx:
    """Per-forward context threaded through blocks."""

    cfg: Any
    positions: Array  # (B, S) absolute positions of the current tokens
    quant: QuantHook = dataclasses.field(default_factory=lambda: NO_QUANT)
    deterministic: bool = True
    # decode-time info
    decode: bool = False
    cache_index: Optional[Array] = None  # scalar: #tokens already cached
    # modality extras (VLM image embeds, enc-dec memory)
    extras: dict = dataclasses.field(default_factory=dict)
    # name scope for quant hook paths
    scope: str = ""

    def scoped(self, name: str) -> "Ctx":
        return dataclasses.replace(self, scope=f"{self.scope}/{name}" if self.scope else name)


# ---------------------------------------------------------------------------
# initialisation helpers
# ---------------------------------------------------------------------------


def dense_init(key, d_in: int, d_out: int, dtype=jnp.float32) -> Params:
    scale = 1.0 / jnp.sqrt(d_in)
    return {"w": jax.random.uniform(key, (d_in, d_out), dtype, -scale, scale)}


def dense(ctx: Ctx, p: Params, name: str, x: Array) -> Array:
    """Quant-aware linear: x @ W. The only matmul entry point.

    A ``qscale`` sibling marks a packed-int deployment weight
    (`repro.deploy` artifact format); it is executed through the quant
    hook's weight-provider (``packed_matmul`` -> ``qmm``), with bits and
    group inferred from the shapes.
    """
    node = p[name]
    path = f"{ctx.scope}/{name}" if ctx.scope else name
    if "qscale" in node:
        y = ctx.quant.packed_matmul(path, x, node)
    else:
        w = ctx.quant.weight(path, node["w"])
        x = ctx.quant.act(path, x)
        y = jnp.einsum("...i,io->...o", x, w.astype(x.dtype))
    if "b" in node:
        y = y + node["b"].astype(y.dtype)
    return y


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def rmsnorm_init(d: int) -> Params:
    return {"g": jnp.ones((d,), jnp.float32)}


def rmsnorm(p: Params, x: Array, eps: float = 1e-6) -> Array:
    """Variance reduced in f32; normalization stays in x.dtype.

    Deliberate: a full f32 copy of the hidden state as the first op of a
    rematerialized block gets loop-hoisted by XLA into an f32 replica of
    the whole saved-activation stack (~2x remat memory). The f32->reduce
    chain here fuses into the reduction instead.
    """
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps).astype(x.dtype)
    return x * inv * p["g"].astype(x.dtype)


def layernorm_init(d: int) -> Params:
    return {"g": jnp.ones((d,), jnp.float32), "b": jnp.zeros((d,), jnp.float32)}


def layernorm(p: Params, x: Array, eps: float = 1e-5) -> Array:
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True).astype(x.dtype)
    var = jnp.var(x32, axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps).astype(x.dtype)
    return (x - mu) * inv * p["g"].astype(x.dtype) + p["b"].astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float = 10000.0) -> Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: Array, positions: Array, theta: float = 10000.0) -> Array:
    """x: (B, S, H, hd); positions: (B, S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B, S, hd/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# embeddings / head
# ---------------------------------------------------------------------------


def embed_init(key, vocab: int, d: int) -> Params:
    return {"table": jax.random.normal(key, (vocab, d), jnp.float32) * 0.02}


def embed_lookup(ctx: Ctx, p: Params, tokens: Array) -> Array:
    if "table_qscale" in p:  # int8 deployment table: gather, then dequant
        rows = jnp.take(p["table"], tokens, axis=0).astype(jnp.float32)
        return rows * p["table_qscale"][0]
    table = ctx.quant.weight("embed/table", p["table"])
    return jnp.take(table, tokens, axis=0)


def lm_head(ctx: Ctx, p: Params, x: Array) -> Array:
    """Output projection to vocab logits; may be tied to the embedding.

    ``p`` is either a head node (``{"w": (d, V)}``, possibly packed with
    a ``qscale``) or — when embeddings are tied — the embedding node
    itself (``{"table": (V, d)}``, possibly int8 with ``table_qscale``).
    """
    if "qscale" in p:
        return ctx.quant.packed_matmul("head/w", x, p)
    if "table_qscale" in p:  # tied to an int8 table: (V, d) -> (d, V)
        w = (p["table"].astype(jnp.float32) * p["table_qscale"][0]).T
    elif "table" in p:  # tied FP table
        w = ctx.quant.weight("head/w", p["table"].T)
        x = ctx.quant.act("head/w", x)
    else:
        w = ctx.quant.weight("head/w", p["w"])  # (d, vocab)
        x = ctx.quant.act("head/w", x)
    return jnp.einsum("...d,dv->...v", x, w.astype(x.dtype))


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------


def softmax_xent(logits: Array, labels: Array, mask: Optional[Array] = None) -> Array:
    """Mean next-token cross entropy. logits (B,S,V), labels (B,S)."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - ll
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


# ---------------------------------------------------------------------------
# attention masks
# ---------------------------------------------------------------------------


def causal_mask(q_pos: Array, k_pos: Array, window: Optional[int] = None) -> Array:
    """(..., Sq, Sk) boolean mask. ``window`` enables sliding-window attn."""
    m = q_pos[..., :, None] >= k_pos[..., None, :]
    if window is not None:
        m = m & (q_pos[..., :, None] - k_pos[..., None, :] < window)
    return m


MASK_VALUE = -1e30


def mha(q: Array, k: Array, v: Array, mask: Optional[Array]) -> Array:
    """Plain attention. q: (B,Sq,H,hd), k/v: (B,Sk,K,hd) with GQA repeat.

    Suitable for short sequences; long-sequence paths use
    :func:`chunked_attention`.
    """
    B, Sq, H, hd = q.shape
    K = k.shape[2]
    if K != H:
        rep = H // K
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32)
    scores = scores / jnp.sqrt(hd).astype(jnp.float32)
    if mask is not None:
        scores = jnp.where(mask[:, None] if mask.ndim == 3 else mask, scores, MASK_VALUE)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


def chunked_attention(
    q: Array,
    k: Array,
    v: Array,
    q_pos: Array,
    k_pos: Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    q_chunk: int = 1024,
    kv_chunk: int = 1024,
    iota_pos: bool = False,
) -> Array:
    """Memory-efficient (flash-style) attention via double lax.scan.

    Online-softmax over KV chunks, scanned over Q chunks. Peak transient
    is (B, H, q_chunk, kv_chunk) instead of (B, H, Sq, Sk). This is the
    XLA path; the Pallas TPU kernel mirrors the same schedule.

    ``iota_pos=True`` asserts positions are plain aranges (train/prefill):
    masks are then derived from broadcasted iota + scalar chunk offsets,
    so XLA never materializes position-dependent mask stacks (those
    dominate memory otherwise), and fully-masked KV chunks contribute a
    constant that folds away.

    q: (B,Sq,H,hd) k/v: (B,Sk,K,hd) q_pos: (B,Sq) k_pos: (B,Sk)
    """
    B, Sq, H, hd = q.shape
    Sk, K = k.shape[1], k.shape[2]
    q_chunk = min(q_chunk, Sq)
    kv_chunk = min(kv_chunk, Sk)
    assert Sq % q_chunk == 0 and Sk % kv_chunk == 0, (Sq, q_chunk, Sk, kv_chunk)
    rep = H // K
    nq, nk = Sq // q_chunk, Sk // kv_chunk
    scale = 1.0 / jnp.sqrt(hd)

    qc = q.reshape(B, nq, q_chunk, H, hd).transpose(1, 0, 2, 3, 4)
    kc = k.reshape(B, nk, kv_chunk, K, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, nk, kv_chunk, K, hd).transpose(1, 0, 2, 3, 4)
    if iota_pos:
        qp = jnp.arange(nq, dtype=jnp.int32) * q_chunk  # chunk start offsets
        kp = jnp.arange(nk, dtype=jnp.int32) * kv_chunk
        rel = (jnp.arange(q_chunk, dtype=jnp.int32)[:, None]
               - jnp.arange(kv_chunk, dtype=jnp.int32)[None, :])  # (qc, kc)
    else:
        qp = q_pos.reshape(B, nq, q_chunk).transpose(1, 0, 2)
        kp = k_pos.reshape(B, nk, kv_chunk).transpose(1, 0, 2)

    def q_step(_, q_in, kv_lo=0, kv_hi=nk):
        qi, qpi = q_in  # (B, qc, H, hd), (B, qc) or scalar chunk offset

        def kv_step(carry, kv_in):
            m_prev, l_prev, acc = carry
            ki, vi, kpi = kv_in  # (B, kc, K, hd), (B, kc) or scalar
            if rep != 1:
                ki = jnp.repeat(ki, rep, axis=2)
                vi = jnp.repeat(vi, rep, axis=2)
            s = jnp.einsum("bqhd,bkhd->bhqk", qi, ki).astype(jnp.float32) * scale
            if iota_pos:
                # delta(q_abs - k_abs) = rel + (q0 - k0); mask from scalars
                delta = rel + (qpi - kpi)  # (qc, kc)
                mask = delta >= 0 if causal else jnp.full_like(delta, True, bool)
                if window is not None:
                    mask = mask & (delta < window)
                if causal or window is not None:
                    s = jnp.where(mask[None, None], s, MASK_VALUE)
            else:
                mask = qpi[:, None, :, None] >= kpi[:, None, None, :] if causal else True
                if window is not None:
                    mask = mask & (qpi[:, None, :, None] - kpi[:, None, None, :] < window)
                if causal or window is not None:
                    s = jnp.where(mask, s, MASK_VALUE)
            m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m_prev - m_new)
            l_new = l_prev * corr + jnp.sum(p, axis=-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bhqk,bkhd->bhqd", p.astype(vi.dtype), vi
            ).astype(jnp.float32)
            return (m_new, l_new, acc), None

        init = (
            jnp.full((B, H, q_chunk), -jnp.inf, jnp.float32),
            jnp.zeros((B, H, q_chunk), jnp.float32),
            jnp.zeros((B, H, q_chunk, hd), jnp.float32),
        )
        (m, l, acc), _ = jax.lax.scan(
            kv_step, init, (kc[kv_lo:kv_hi], vc[kv_lo:kv_hi], kp[kv_lo:kv_hi]))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return None, out.transpose(0, 2, 1, 3).astype(q.dtype)  # (B, qc, H, hd)

    if iota_pos and causal and q_chunk == kv_chunk and Sq == Sk and nq <= 8:
        # Triangle unroll: q-chunk loop unrolled in python with statically
        # bounded inner KV scans — fully-masked chunk pairs are never
        # computed (2x fewer attention FLOPs/bytes; more with a window).
        # Bounded to nq<=8: at 32k (nq=32) the unroll made GSPMD reshard
        # k/v per chunk and collectives grew 5.6x (measured, cell A).
        outs = []
        for i in range(nq):
            lo = 0
            if window is not None:
                lo = max(0, (i * q_chunk - (window - 1)) // kv_chunk)
            _, o = q_step(None, (qc[i], qp[i]), kv_lo=lo, kv_hi=i + 1)
            outs.append(o)
        return jnp.stack(outs, 0).transpose(1, 0, 2, 3, 4).reshape(B, Sq, H, hd)

    _, outs = jax.lax.scan(q_step, None, (qc, qp))
    return outs.transpose(1, 0, 2, 3, 4).reshape(B, Sq, H, hd)


def decode_attend(
    q: Array,
    k_cache: Array,
    v_cache: Array,
    k_pos: Array,
    cur_pos: Array,
    *,
    window: Optional[int] = None,
    shard=None,
) -> Array:
    """Decode attention against a cache for one or a few query tokens.

    GQA-native (no head-repeat of the cache): the cache stays in its
    (B, S, K, hd) layout — typically sequence-sharded — and the grouped
    einsums contract against it in place. ``shard`` optionally pins the
    score sharding so GSPMD keeps the reduction distributed.

    q: (B,C,H,hd) — C=1 for single-token decode, C>1 for a chunked
    prefill step reading KV already appended to the cache (per-token
    causality falls out of the position mask); caches (B,S,K,hd); k_pos
    (B,S) absolute positions of cache slots (-1 for empty); cur_pos
    (B,C) current position of each query token.
    """
    B, C, H, hd = q.shape
    K = k_cache.shape[2]
    G = H // K
    qg = q.reshape(B, C, K, G, hd)
    s = jnp.einsum("bckgd,bskd->bckgs", qg, k_cache).astype(jnp.float32)
    s = s / jnp.sqrt(hd)
    valid = (k_pos[:, None] >= 0) & (k_pos[:, None] <= cur_pos[..., None])
    if window is not None:
        valid = valid & (cur_pos[..., None] - k_pos[:, None] < window)
    s = jnp.where(valid[:, :, None, None, :], s, MASK_VALUE)
    if shard is not None:
        s = shard(s, "scores")
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    out = jnp.einsum("bckgs,bskd->bckgd", p, v_cache)
    return out.reshape(B, C, H, hd)


# ---------------------------------------------------------------------------
# paged KV cache (serve engine)
# ---------------------------------------------------------------------------
#
# The serve engine stores KV in a global page pool per attention layer
# instead of one dense (B, S, K, hd) buffer per stream. A *page* holds
# ``page_size`` consecutive token slots for every kv head; a stream owns
# an ordered list of pages (its *block table* row, shared by all layers
# since every layer caches the same token sequence). Token at absolute
# position ``t`` always lives at row ``t`` of its stream's gathered view
# (identity layout: page ``t // page_size``, offset ``t % page_size``),
# so masks reduce to plain position comparisons and batched serving is
# bitwise independent of which physical pages a stream happened to get.
#
# Attention reads KV through this handle — :func:`paged_append` then
# :func:`paged_attend` — never through dense arrays. ``kv_dtype='int8'``
# stores codes + per-(token, head) scales produced by
# ``kernels.kvattn.quantize_kv`` and decodes single-token steps through
# ``kernels.kvattn.attend_int8`` (the int8 decode-attention kernel);
# float dtypes are the reference mode. Scales are stored float16: the
# resident-bytes win is the point of int8 KV, and at head_dim 32 an f32
# scale pair would eat a third of it.

PAGED_KV_DTYPES = ("int8", "float16", "bfloat16", "float32")


def init_paged_kv(num_pages: int, page_size: int, n_kv_heads: int,
                  head_dim: int, kv_dtype: str = "int8") -> Params:
    """One attention layer's share of the paged KV pool.

    int8 pools carry ``k_scale``/``v_scale`` pages beside the code
    pages; float pools are just typed pages. Page 0 is reserved by the
    engine as the write sink for inactive slots and never handed to a
    stream."""
    if kv_dtype not in PAGED_KV_DTYPES:
        raise ValueError(f"kv_dtype {kv_dtype!r} not in {PAGED_KV_DTYPES}")
    if kv_dtype == "int8":
        return {
            "k_pages": jnp.zeros((num_pages, page_size, n_kv_heads, head_dim), jnp.int8),
            "v_pages": jnp.zeros((num_pages, page_size, n_kv_heads, head_dim), jnp.int8),
            "k_scale": jnp.zeros((num_pages, page_size, n_kv_heads), jnp.float16),
            "v_scale": jnp.zeros((num_pages, page_size, n_kv_heads), jnp.float16),
        }
    dt = jnp.dtype(kv_dtype)
    return {
        "k_pages": jnp.zeros((num_pages, page_size, n_kv_heads, head_dim), dt),
        "v_pages": jnp.zeros((num_pages, page_size, n_kv_heads, head_dim), dt),
    }


def is_paged(cache: Params) -> bool:
    """Distinguishes a paged-pool cache node from the dense ``{k, v,
    pos}`` ring buffer — the dispatch point for the KV handle."""
    return isinstance(cache, dict) and "k_pages" in cache


def _page_rows(block_tables: Array, positions: Array, page_size: int) -> Array:
    """Flat pool-row index for each (stream, position). Writes with no
    real page — unallocated block-table entries (-1), positions past the
    table's capacity (padded chunk tails) — land on page 0, the engine's
    write sink."""
    pidx = positions // page_size
    page_ids = jnp.take_along_axis(
        block_tables, jnp.clip(pidx, 0, block_tables.shape[1] - 1), axis=1)
    page_ids = jnp.where(pidx < block_tables.shape[1], page_ids, -1)
    return jnp.maximum(page_ids, 0) * page_size + positions % page_size


def paged_append(cache: Params, k: Array, v: Array, block_tables: Array,
                 positions: Array, page_size: int) -> Params:
    """Write C new tokens' K/V into the page pool.

    k, v: (B, C, K, hd) float; block_tables (B, max_pages) int32 (-1 =
    unallocated); positions (B, C) absolute token positions. int8 pools
    quantize through ``kernels.kvattn.quantize_kv`` on the way in.
    Distinct streams own distinct pages, so the scatter has no
    cross-stream collisions; all inactive-slot writes land on page 0.
    """
    B, C = positions.shape
    rows = _page_rows(block_tables, positions, page_size).reshape(-1)

    def scat(pool, vals):
        flat = pool.reshape(pool.shape[0] * page_size, *pool.shape[2:])
        flat = flat.at[rows].set(
            vals.reshape(B * C, *vals.shape[2:]).astype(pool.dtype))
        return flat.reshape(pool.shape)

    if "k_scale" in cache:
        from ..kernels.kvattn.ops import quantize_kv

        k8, v8, ks, vs = quantize_kv(k, v)
        return {"k_pages": scat(cache["k_pages"], k8),
                "v_pages": scat(cache["v_pages"], v8),
                "k_scale": scat(cache["k_scale"], ks),
                "v_scale": scat(cache["v_scale"], vs)}
    return {"k_pages": scat(cache["k_pages"], k),
            "v_pages": scat(cache["v_pages"], v)}


def paged_view(cache: Params, block_tables: Array, page_size: int):
    """Gather a dense per-stream view of the pool.

    Returns ``(gather, kpos)``: ``gather(pool)`` -> (B, S_cap, K, hd)
    with token ``t`` at row ``t`` (S_cap = max_pages * page_size), and
    ``kpos`` (B, S_cap) int32 — the row's token position where the row's
    page is allocated, -1 elsewhere (rows of an allocated page beyond
    the stream's written length are masked by the caller's ``<= cur``
    position check, exactly like the dense cache's empty slots)."""
    B, MP = block_tables.shape
    s_cap = MP * page_size
    rows = (jnp.maximum(block_tables, 0)[..., None] * page_size
            + jnp.arange(page_size, dtype=jnp.int32)).reshape(B, s_cap)

    def gather(pool):
        flat = pool.reshape(pool.shape[0] * page_size, *pool.shape[2:])
        return flat[rows]

    allocated = jnp.repeat(block_tables >= 0, page_size, axis=1)
    kpos = jnp.where(allocated, jnp.arange(s_cap, dtype=jnp.int32)[None], -1)
    return gather, kpos


def paged_attend(q: Array, cache: Params, block_tables: Array,
                 positions: Array, page_size: int, *,
                 window: Optional[int] = None, backend: str = "auto") -> Array:
    """Attention over a paged KV cache: the read half of the handle.

    q: (B, C, H, hd); positions (B, C) absolute positions of the query
    tokens (already appended). Single-token int8 decode goes through the
    ``kernels.kvattn`` int8 decode-attention kernel (``attend_int8``);
    chunked-prefill reads (C > 1) and float pools dequantize the
    gathered view and share :func:`decode_attend`.
    """
    gather, kpos = paged_view(cache, block_tables, page_size)
    if "k_scale" in cache:
        k8, v8 = gather(cache["k_pages"]), gather(cache["v_pages"])
        ks = gather(cache["k_scale"]).astype(jnp.float32)
        vs = gather(cache["v_scale"]).astype(jnp.float32)
        if q.shape[1] == 1:
            from ..kernels.kvattn.ops import attend_int8

            out = attend_int8(q[:, 0], k8, v8, ks, vs, kpos, positions[:, 0],
                              window=window, backend=backend)
            return out[:, None]
        k = (k8.astype(jnp.float32) * ks[..., None]).astype(q.dtype)
        v = (v8.astype(jnp.float32) * vs[..., None]).astype(q.dtype)
        return decode_attend(q, k, v, kpos, positions, window=window)
    k = gather(cache["k_pages"]).astype(q.dtype)
    v = gather(cache["v_pages"]).astype(q.dtype)
    return decode_attend(q, k, v, kpos, positions, window=window)
