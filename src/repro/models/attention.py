"""GQA / sliding-window / cross attention with KV caches.

A single :class:`AttnSpec` covers all assigned archs' attention variants.
Caches are ring buffers for windowed layers and linear buffers otherwise.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from . import common as cm
from .common import Ctx

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class AttnSpec:
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    rope_theta: float = 10000.0
    window: Optional[int] = None  # sliding-window size, None = global
    causal: bool = True
    use_rope: bool = True
    qk_norm: bool = False  # qwen3-style per-head RMS on q/k
    q_chunk: int = 1024
    kv_chunk: int = 1024


def init(key, spec: AttnSpec):
    ks = jax.random.split(key, 6)
    p = {
        "wq": cm.dense_init(ks[0], spec.d_model, spec.n_heads * spec.head_dim),
        "wk": cm.dense_init(ks[1], spec.d_model, spec.n_kv_heads * spec.head_dim),
        "wv": cm.dense_init(ks[2], spec.d_model, spec.n_kv_heads * spec.head_dim),
        "wo": cm.dense_init(ks[3], spec.n_heads * spec.head_dim, spec.d_model),
    }
    if spec.qk_norm:
        p["q_norm"] = cm.rmsnorm_init(spec.head_dim)
        p["k_norm"] = cm.rmsnorm_init(spec.head_dim)
    return p


def _project_qkv(ctx: Ctx, p, spec: AttnSpec, x: Array, kv_x: Optional[Array] = None):
    B, S = x.shape[:2]
    kv_src = x if kv_x is None else kv_x
    Skv = kv_src.shape[1]
    q = cm.dense(ctx, p, "wq", x).reshape(B, S, spec.n_heads, spec.head_dim)
    k = cm.dense(ctx, p, "wk", kv_src).reshape(B, Skv, spec.n_kv_heads, spec.head_dim)
    v = cm.dense(ctx, p, "wv", kv_src).reshape(B, Skv, spec.n_kv_heads, spec.head_dim)
    if spec.qk_norm:
        q = cm.rmsnorm(p["q_norm"], q)
        k = cm.rmsnorm(p["k_norm"], k)
    return q, k, v


def apply(ctx: Ctx, p, spec: AttnSpec, x: Array,
          kv_x: Optional[Array] = None, kv_pos: Optional[Array] = None) -> Array:
    """Full-sequence attention (train / prefill without cache write).

    ``kv_x`` switches to cross-attention against that source (no rope on
    cross K by convention here; encoder positions use ``kv_pos``).
    """
    B, S = x.shape[:2]
    q, k, v = _project_qkv(ctx, p, spec, x, kv_x)
    q_pos = ctx.positions
    if kv_x is None:
        k_pos = ctx.positions
        if spec.use_rope:
            q = cm.apply_rope(q, q_pos, spec.rope_theta)
            k = cm.apply_rope(k, k_pos, spec.rope_theta)
        out = cm.chunked_attention(
            q, k, v, q_pos, k_pos, causal=spec.causal, window=spec.window,
            q_chunk=spec.q_chunk, kv_chunk=spec.kv_chunk, iota_pos=True)
    else:
        k_pos = kv_pos if kv_pos is not None else (
            jnp.broadcast_to(jnp.arange(kv_x.shape[1]), (B, kv_x.shape[1])))
        out = cm.chunked_attention(
            q, k, v, q_pos, k_pos, causal=False, window=None,
            q_chunk=spec.q_chunk, kv_chunk=spec.kv_chunk)
    out = out.reshape(B, S, spec.n_heads * spec.head_dim)
    return cm.dense(ctx, p, "wo", out)


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------


def init_cache(spec: AttnSpec, batch: int, max_len: int, dtype=jnp.bfloat16):
    """Cache dict. Windowed layers use a ring buffer of size ``window``."""
    slots = min(max_len, spec.window) if spec.window is not None else max_len
    return {
        "k": jnp.zeros((batch, slots, spec.n_kv_heads, spec.head_dim), dtype),
        "v": jnp.zeros((batch, slots, spec.n_kv_heads, spec.head_dim), dtype),
        "pos": jnp.full((batch, slots), -1, jnp.int32),
    }


def prefill(ctx: Ctx, p, spec: AttnSpec, x: Array, cache) -> tuple[Array, dict]:
    """Run full attention over the prompt and fill the cache."""
    B, S = x.shape[:2]
    q, k, v = _project_qkv(ctx, p, spec, x)
    if spec.use_rope:
        q = cm.apply_rope(q, ctx.positions, spec.rope_theta)
        k = cm.apply_rope(k, ctx.positions, spec.rope_theta)
    out = cm.chunked_attention(
        q, k, v, ctx.positions, ctx.positions, causal=spec.causal,
        window=spec.window, q_chunk=spec.q_chunk, kv_chunk=spec.kv_chunk,
        iota_pos=True)
    slots = cache["k"].shape[1]
    if slots >= S:
        cache = {
            "k": jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype), (0, 0, 0, 0)),
            "v": jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype), (0, 0, 0, 0)),
            "pos": jax.lax.dynamic_update_slice(cache["pos"], ctx.positions.astype(jnp.int32), (0, 0)),
        }
    else:  # ring buffer smaller than the prompt: keep the tail
        tail_k = k[:, S - slots:]
        tail_v = v[:, S - slots:]
        tail_p = ctx.positions[:, S - slots:]
        # ring-consistent placement: slot = pos % slots
        idx = tail_p[0] % slots
        cache = {
            "k": cache["k"].at[:, idx].set(tail_k.astype(cache["k"].dtype)),
            "v": cache["v"].at[:, idx].set(tail_v.astype(cache["v"].dtype)),
            "pos": cache["pos"].at[:, idx].set(tail_p.astype(jnp.int32)),
        }
    out = out.reshape(B, S, spec.n_heads * spec.head_dim)
    return cm.dense(ctx, p, "wo", out), cache


def decode(ctx: Ctx, p, spec: AttnSpec, x: Array, cache) -> tuple[Array, dict]:
    """Cached decode: append C new tokens to the cache, attend over it.

    ``ctx.positions`` is (B, C) with the tokens' absolute positions —
    C = 1 for plain decode, C > 1 for a chunked-prefill step through the
    same cached path. ``cache`` is either the dense ring buffer from
    :func:`init_cache` or one layer's paged-pool slice (serve engine),
    dispatched through ``cm.is_paged``; the paged path reads the block
    tables from ``ctx.extras["paged"]``.
    """
    B, C = x.shape[:2]
    q, k, v = _project_qkv(ctx, p, spec, x)
    if spec.use_rope:
        q = cm.apply_rope(q, ctx.positions, spec.rope_theta)
        k = cm.apply_rope(k, ctx.positions, spec.rope_theta)
    if cm.is_paged(cache):
        pg = ctx.extras["paged"]
        cache = cm.paged_append(cache, k, v, pg["block_tables"],
                                ctx.positions, pg["page_size"])
        out = cm.paged_attend(q, cache, pg["block_tables"], ctx.positions,
                              pg["page_size"], window=spec.window,
                              backend=pg.get("backend", "auto"))
        out = out.reshape(B, C, spec.n_heads * spec.head_dim)
        return cm.dense(ctx, p, "wo", out), cache
    slots = cache["k"].shape[1]
    pos = ctx.positions  # (B, C)
    slot = (pos % slots).astype(jnp.int32)
    # vmapped per-batch scatter: explicit arange(B) indices would make the
    # scatter unpartitionable and GSPMD would re-gather the whole cache
    upd = jax.vmap(lambda c, s, val: c.at[s].set(val))
    shard = ctx.extras.get("cache_shard") or (lambda t, leaf: t)
    cache = {
        "k": shard(upd(cache["k"], slot, k.astype(cache["k"].dtype)), "k"),
        "v": shard(upd(cache["v"], slot, v.astype(cache["v"].dtype)), "v"),
        "pos": shard(upd(cache["pos"], slot, pos.astype(jnp.int32)), "pos"),
    }
    # replicate the (tiny) query so attention computes against the cache
    # IN PLACE (seq-sharded); without this GSPMD all-gathers the cache to
    # match the head-sharded q (kv heads rarely divide the model axis)
    q = shard(q, "q")
    out = cm.decode_attend(
        q, cache["k"].astype(q.dtype), cache["v"].astype(q.dtype),
        cache["pos"], pos, window=spec.window,
        shard=(shard if "cache_shard" in ctx.extras else None))
    out = out.reshape(B, C, spec.n_heads * spec.head_dim)
    return cm.dense(ctx, p, "wo", out), cache


# cross-attention cache: static K/V computed once from the memory --------------


def xattn_cache(ctx: Ctx, p, spec: AttnSpec, memory: Array):
    B, Sm = memory.shape[:2]
    k = cm.dense(ctx, p, "wk", memory).reshape(B, Sm, spec.n_kv_heads, spec.head_dim)
    v = cm.dense(ctx, p, "wv", memory).reshape(B, Sm, spec.n_kv_heads, spec.head_dim)
    if spec.qk_norm:
        k = cm.rmsnorm(p["k_norm"], k)
    return {"k": k, "v": v}


def xattn_decode(ctx: Ctx, p, spec: AttnSpec, x: Array, xcache) -> Array:
    B = x.shape[0]
    q = cm.dense(ctx, p, "wq", x).reshape(B, 1, spec.n_heads, spec.head_dim)
    if spec.qk_norm:
        q = cm.rmsnorm(p["q_norm"], q)
    Sm = xcache["k"].shape[1]
    k_pos = jnp.broadcast_to(jnp.arange(Sm), (B, Sm))
    out = cm.decode_attend(q, xcache["k"].astype(q.dtype), xcache["v"].astype(q.dtype),
                           k_pos, jnp.full((B, 1), Sm, jnp.int32), window=None)
    out = out.reshape(B, 1, spec.n_heads * spec.head_dim)
    return cm.dense(ctx, p, "wo", out)
