"""xLSTM blocks (mLSTM matrix-memory + sLSTM scalar-memory).

Adaptations recorded in DESIGN.md:
* mLSTM training uses the *chunkwise-parallel* form (intra-chunk
  quadratic + inter-chunk recurrent state via lax.scan) — the TPU-native
  equivalent of the paper's CUDA kernels, and what makes prefill_32k /
  long_500k sub-quadratic in memory.
* sLSTM is implemented without hidden-to-gate recurrence (R = 0) so it
  trains with two associative scans (max-plus for the stabilizer, then a
  first-order linear recurrence); decode is the exact recurrent form.
* Decode state is O(1) in sequence length: (heads, hd, hd) matrix memory
  per mLSTM block — this is why xlstm runs the long_500k cell.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from . import common as cm
from .common import Ctx

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class XLSTMSpec:
    d_model: int
    n_heads: int
    expansion: float = 2.0
    chunk: int = 256

    @property
    def d_inner(self) -> int:
        return int(self.d_model * self.expansion)

    @property
    def head_dim(self) -> int:
        return self.d_inner // self.n_heads


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------


def mlstm_init(key, spec: XLSTMSpec):
    ks = jax.random.split(key, 7)
    d, di = spec.d_model, spec.d_inner
    return {
        "in_proj": cm.dense_init(ks[0], d, 2 * di),  # (x branch, z gate branch)
        "wq": cm.dense_init(ks[1], di, di),
        "wk": cm.dense_init(ks[2], di, di),
        "wv": cm.dense_init(ks[3], di, di),
        "w_if": cm.dense_init(ks[4], di, 2 * spec.n_heads),  # input & forget gate pre-acts
        "out_norm": cm.rmsnorm_init(spec.head_dim),
        "out_proj": cm.dense_init(ks[5], di, d),
    }


def _mlstm_qkvif(ctx: Ctx, p, spec: XLSTMSpec, x: Array):
    B, S, _ = x.shape
    H, hd = spec.n_heads, spec.head_dim
    xz = cm.dense(ctx, p, "in_proj", x)
    xi, z = jnp.split(xz, 2, axis=-1)
    q = cm.dense(ctx, p, "wq", xi).reshape(B, S, H, hd)
    k = cm.dense(ctx, p, "wk", xi).reshape(B, S, H, hd) / jnp.sqrt(hd)
    v = cm.dense(ctx, p, "wv", xi).reshape(B, S, H, hd)
    gif = cm.dense(ctx, p, "w_if", xi).astype(jnp.float32)
    ig, fg = jnp.split(gif.reshape(B, S, 2, H), 2, axis=2)
    return q, k, v, ig[:, :, 0], fg[:, :, 0], z  # gates (B,S,H)


def _chunk_state_init(B: int, H: int, hd: int):
    return (
        jnp.zeros((B, H, hd, hd), jnp.float32),  # C
        jnp.zeros((B, H, hd), jnp.float32),  # n
        jnp.full((B, H), -1e30, jnp.float32),  # m (running stabilizer)
    )


def _mlstm_chunk(carry, inp):
    """One chunk of the chunkwise-parallel mLSTM. Shapes per chunk L."""
    C, n, m = carry
    q, k, v, ig, fg = inp  # q/k/v (B,L,H,hd), gates (B,L,H)
    B, L, H, hd = q.shape
    lf = jax.nn.log_sigmoid(fg)  # (B,L,H)
    F = jnp.cumsum(lf, axis=1)  # inclusive cumulative log-forget
    G = F[:, -1]  # (B,H) total chunk decay
    # intra-chunk pair weights: w_ij = F_i - F_j + i_j  (j <= i)
    wij = F[:, :, None, :] - F[:, None, :, :] + ig[:, None, :, :]  # (B,i,j,H)
    causal = jnp.tril(jnp.ones((L, L), bool))[None, :, :, None]
    wij = jnp.where(causal, wij, -jnp.inf)
    # state contribution weight at step i: F_i + m_prev
    w_state = F + m[:, None, :]  # (B,L,H)
    m_loc = jnp.maximum(jnp.max(wij, axis=2), w_state)  # (B,L,H)
    m_i = jnp.maximum(m_loc, -1e30)
    dmat = jnp.exp(wij - m_i[:, :, None, :])  # (B,i,j,H)
    s = jnp.einsum("bihd,bjhd->bijh", q.astype(jnp.float32), k.astype(jnp.float32))
    sv = jnp.einsum("bijh,bjhd->bihd", s * dmat, v.astype(jnp.float32))
    sn = jnp.einsum("bijh,bjhd->bihd", dmat, k.astype(jnp.float32))
    w_st = jnp.exp(w_state - m_i)  # (B,L,H)
    # C is stored v-major: C[d,e] = v_d k_e, so q contracts the k index (e)
    inter = jnp.einsum("bihe,bhde->bihd", q.astype(jnp.float32), C) * w_st[..., None]
    inter_n = n[:, None] * w_st[..., None]  # (B,L,H,hd)
    num = sv + inter
    den = jnp.einsum("bihd,bihd->bih", q.astype(jnp.float32), sn + inter_n)
    den = jnp.maximum(jnp.abs(den), jnp.exp(-m_i))
    h = num / den[..., None]  # (B,L,H,hd)
    # ---- state update to chunk end ----
    m_new = jnp.maximum(G + m, jnp.max(G[:, None] - F + ig, axis=1))  # (B,H)
    wj = jnp.exp(G[:, None] - F + ig - m_new[:, None])  # (B,L,H)
    C_new = jnp.exp(G + m - m_new)[..., None, None] * C + jnp.einsum(
        "bjhd,bjhe->bhde", v.astype(jnp.float32) * wj[..., None], k.astype(jnp.float32))
    n_new = jnp.exp(G + m - m_new)[..., None] * n + jnp.sum(
        k.astype(jnp.float32) * wj[..., None], axis=1)
    return (C_new, n_new, m_new), h


def mlstm_apply(ctx: Ctx, p, spec: XLSTMSpec, x: Array) -> Array:
    B, S, _ = x.shape
    H, hd = spec.n_heads, spec.head_dim
    L = min(spec.chunk, S)
    assert S % L == 0, (S, L)
    q, k, v, ig, fg, z = _mlstm_qkvif(ctx, p, spec, x)

    def rs(t):  # (B,S,...) -> (nc, B, L, ...)
        return t.reshape(B, S // L, L, *t.shape[2:]).swapaxes(0, 1)

    carry = _chunk_state_init(B, H, hd)
    _, hs = jax.lax.scan(_mlstm_chunk, carry, (rs(q), rs(k), rs(v), rs(ig), rs(fg)))
    h = hs.swapaxes(0, 1).reshape(B, S, H, hd).astype(x.dtype)
    h = cm.rmsnorm(p["out_norm"], h).reshape(B, S, H * hd)
    h = h * jax.nn.silu(z)
    return cm.dense(ctx, p, "out_proj", h)


def mlstm_init_cache(spec: XLSTMSpec, batch: int):
    C, n, m = _chunk_state_init(batch, spec.n_heads, spec.head_dim)
    return {"C": C, "n": n, "m": m}


def mlstm_decode(ctx: Ctx, p, spec: XLSTMSpec, x: Array, cache) -> tuple[Array, dict]:
    """Exact recurrent step. x: (B,1,d)."""
    B = x.shape[0]
    H, hd = spec.n_heads, spec.head_dim
    q, k, v, ig, fg, z = _mlstm_qkvif(ctx, p, spec, x)
    q, k, v = (t[:, 0].astype(jnp.float32) for t in (q, k, v))  # (B,H,hd)
    ig, fg = ig[:, 0], fg[:, 0]  # (B,H)
    lf = jax.nn.log_sigmoid(fg)
    m_new = jnp.maximum(lf + cache["m"], ig)
    a = jnp.exp(lf + cache["m"] - m_new)
    b = jnp.exp(ig - m_new)
    C = a[..., None, None] * cache["C"] + jnp.einsum("bhd,bhe->bhde", v * b[..., None], k)
    n = a[..., None] * cache["n"] + k * b[..., None]
    # C[d,e] = v_d k_e: retrieval contracts q with the k index (e)
    num = jnp.einsum("bhe,bhde->bhd", q, C)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", q, n)), jnp.exp(-m_new))
    h = (num / den[..., None]).astype(x.dtype)
    h = cm.rmsnorm(p["out_norm"], h).reshape(B, 1, H * hd)
    h = h * jax.nn.silu(z)
    return cm.dense(ctx, p, "out_proj", h), {"C": C, "n": n, "m": m_new}


# ---------------------------------------------------------------------------
# sLSTM (R = 0 variant; see module docstring)
# ---------------------------------------------------------------------------


def slstm_init(key, spec: XLSTMSpec):
    ks = jax.random.split(key, 3)
    d, di = spec.d_model, spec.d_inner
    return {
        "w_in": cm.dense_init(ks[0], d, 4 * di),  # z, i~, f~, o pre-acts
        "out_norm": cm.rmsnorm_init(spec.head_dim),
        "out_proj": cm.dense_init(ks[1], di, d),
    }


def _slstm_gates(ctx: Ctx, p, x: Array, di: int):
    pre = cm.dense(ctx, p, "w_in", x)
    z, ig, fg, og = jnp.split(pre, 4, axis=-1)
    return (jnp.tanh(z).astype(jnp.float32), ig.astype(jnp.float32),
            jax.nn.log_sigmoid(fg.astype(jnp.float32)), jax.nn.sigmoid(og))


def slstm_apply(ctx: Ctx, p, spec: XLSTMSpec, x: Array) -> Array:
    B, S, _ = x.shape
    di = spec.d_inner
    z, ig, lf, og = _slstm_gates(ctx, p, x, di)
    # stabilizer: m_t = max(lf_t + m_{t-1}, ig_t) — max-plus associative scan.
    # Each step is the map f(m) = max(m + a, b); composition of (a1,b1)
    # then (a2,b2) is max(m + a1+a2, max(b1+a2, b2)), which is associative.
    def compose(l, r):
        al, bl = l
        ar, br = r
        return al + ar, jnp.maximum(bl + ar, br)

    a0 = lf  # (B,S,di)
    b0 = ig
    acc_a, acc_b = jax.lax.associative_scan(compose, (a0, b0), axis=1)
    m0 = jnp.full((B, 1, di), -1e30, jnp.float32)
    m = jnp.maximum(m0 + acc_a, acc_b)  # (B,S,di)
    m_prev = jnp.concatenate([m0, m[:, :-1]], axis=1)
    # linear recurrences for c and n with per-step coefficients
    fa = jnp.exp(lf + m_prev - m)
    ib = jnp.exp(ig - m)

    def lin(lc, rc):
        al, bl = lc
        ar, br = rc
        return al * ar, br + ar * bl

    _, c = jax.lax.associative_scan(lin, (fa, ib * z), axis=1)
    _, n = jax.lax.associative_scan(lin, (fa, ib), axis=1)
    h = og * (c / jnp.maximum(n, 1e-6)).astype(x.dtype)
    H, hd = spec.n_heads, spec.head_dim
    h = cm.rmsnorm(p["out_norm"], h.reshape(B, S, H, hd)).reshape(B, S, di)
    return cm.dense(ctx, p, "out_proj", h)


def slstm_init_cache(spec: XLSTMSpec, batch: int):
    return {
        "c": jnp.zeros((batch, spec.d_inner), jnp.float32),
        "n": jnp.zeros((batch, spec.d_inner), jnp.float32),
        "m": jnp.full((batch, spec.d_inner), -1e30, jnp.float32),
    }


def slstm_decode(ctx: Ctx, p, spec: XLSTMSpec, x: Array, cache) -> tuple[Array, dict]:
    B = x.shape[0]
    z, ig, lf, og = _slstm_gates(ctx, p, x, spec.d_inner)
    z, ig, lf, og = z[:, 0], ig[:, 0], lf[:, 0], og[:, 0]
    m_new = jnp.maximum(lf + cache["m"], ig)
    fa = jnp.exp(lf + cache["m"] - m_new)
    ib = jnp.exp(ig - m_new)
    c = fa * cache["c"] + ib * z
    n = fa * cache["n"] + ib
    h = og * (c / jnp.maximum(n, 1e-6)).astype(x.dtype)
    H, hd = spec.n_heads, spec.head_dim
    h = cm.rmsnorm(p["out_norm"], h.reshape(B, H, hd)).reshape(B, 1, spec.d_inner)
    return cm.dense(ctx, p, "out_proj", h), {"c": c, "n": n, "m": m_new}
