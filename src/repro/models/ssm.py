"""Selective SSM (Mamba-style) head used by the Hymba hybrid blocks.

Training uses a first-order linear recurrence evaluated with
``jax.lax.associative_scan`` over time; decode carries an explicit
(B, d_inner, d_state) state plus a short conv buffer. Projections are
quant-aware (they dominate the bytes); the per-channel A/D/dt params and
gating stay FP (DESIGN.md §2).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from . import common as cm
from .common import Ctx

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class SSMSpec:
    d_model: int
    d_inner: int
    d_state: int = 16
    d_conv: int = 4


def init(key, spec: SSMSpec):
    ks = jax.random.split(key, 7)
    d, di, n = spec.d_model, spec.d_inner, spec.d_state
    p = {
        "in_proj": cm.dense_init(ks[0], d, 2 * di),  # -> (x, z-gate)
        "wB": cm.dense_init(ks[1], di, n),
        "wC": cm.dense_init(ks[2], di, n),
        "w_dt": cm.dense_init(ks[3], di, di),
        "out_proj": cm.dense_init(ks[4], di, d),
        # FP per-channel params
        "A_log": jnp.log(jnp.broadcast_to(jnp.arange(1, n + 1, dtype=jnp.float32), (di, n))),
        "D": jnp.ones((di,), jnp.float32),
        "dt_bias": jnp.full((di,), -4.6, jnp.float32),  # softplus^-1(0.01)
        "conv_w": jax.random.normal(ks[5], (spec.d_conv, di), jnp.float32) * 0.1,
    }
    return p


def _conv_causal(x: Array, w: Array) -> Array:
    """Depthwise causal conv over time. x: (B,S,di), w: (K,di)."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(xp[:, i : i + x.shape[1]] * w[i] for i in range(K))
    return out


def _ssm_coeffs(ctx: Ctx, p, spec: SSMSpec, xi: Array):
    """Shared between scan/step. xi: (..., di) post-conv activations."""
    dt = jax.nn.softplus(cm.dense(ctx, p, "w_dt", xi) + p["dt_bias"])  # (...,di)
    A = -jnp.exp(p["A_log"])  # (di, n)
    Bc = cm.dense(ctx, p, "wB", xi)  # (..., n)
    Cc = cm.dense(ctx, p, "wC", xi)  # (..., n)
    a = jnp.exp(dt[..., None] * A)  # (..., di, n)
    b = dt[..., None] * Bc[..., None, :] * xi[..., None]  # (..., di, n)
    return a, b, Cc


def apply(ctx: Ctx, p, spec: SSMSpec, x: Array) -> Array:
    """Full-sequence forward. x: (B,S,d) -> (B,S,d)."""
    B, S, _ = x.shape
    xz = cm.dense(ctx, p, "in_proj", x)
    xi, z = jnp.split(xz, 2, axis=-1)
    xi = jax.nn.silu(_conv_causal(xi, p["conv_w"]))
    a, b, Cc = _ssm_coeffs(ctx, p, spec, xi)  # (B,S,di,n)

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, br + ar * bl

    _, h = jax.lax.associative_scan(combine, (a.astype(jnp.float32), b.astype(jnp.float32)), axis=1)
    y = jnp.einsum("bsdn,bsn->bsd", h, Cc.astype(jnp.float32)).astype(x.dtype)
    y = y + p["D"] * xi
    y = y * jax.nn.silu(z)
    return cm.dense(ctx, p, "out_proj", y)


def init_cache(spec: SSMSpec, batch: int, dtype=jnp.float32):
    return {
        "h": jnp.zeros((batch, spec.d_inner, spec.d_state), jnp.float32),
        "conv": jnp.zeros((batch, spec.d_conv - 1, spec.d_inner), dtype),
    }


def decode(ctx: Ctx, p, spec: SSMSpec, x: Array, cache) -> tuple[Array, dict]:
    """One-step decode. x: (B,1,d)."""
    xz = cm.dense(ctx, p, "in_proj", x)
    xi, z = jnp.split(xz, 2, axis=-1)  # (B,1,di)
    buf = jnp.concatenate([cache["conv"], xi.astype(cache["conv"].dtype)], axis=1)
    w = p["conv_w"]
    xi_c = jnp.einsum("bkd,kd->bd", buf.astype(jnp.float32), w)[:, None].astype(x.dtype)
    xi_c = jax.nn.silu(xi_c)
    a, b, Cc = _ssm_coeffs(ctx, p, spec, xi_c[:, 0])  # (B,di,n)
    h = a.astype(jnp.float32) * cache["h"] + b.astype(jnp.float32)
    y = jnp.einsum("bdn,bn->bd", h, Cc.astype(jnp.float32))[:, None].astype(x.dtype)
    y = y + p["D"] * xi_c
    y = y * jax.nn.silu(z)
    new_cache = {"h": h, "conv": buf[:, 1:]}
    return cm.dense(ctx, p, "out_proj", y), new_cache
