"""Whisper-style encoder-decoder backbone.

Per the assignment, the conv/audio frontend is a STUB: ``input_specs``
provides precomputed frame embeddings (B, S_enc, d_model). The encoder is
a bidirectional transformer; the decoder interleaves causal self-attn and
cross-attn over the encoder memory. Exposes the same public API as
:class:`transformer.LM` (forward / loss / init_cache / prefill /
decode_step) plus the BRECQ block decomposition.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from . import attention as attn_mod
from . import common as cm
from . import mlp as mlp_mod
from .common import Ctx, NO_QUANT, QuantHook
from .transformer import (LM, StackDef, SubLayer, _maybe_remat, _norm,
                          _norm_init)

Array = jax.Array
Params = Any


ENC_SUB = SubLayer("attn", causal=False, ffn="mlp")
DEC_SUBS = (SubLayer("attn", ffn=None), SubLayer("xattn", ffn="mlp"))


class EncDecLM(LM):
    """Encoder stack + decoder stack; decoder cross-attends to the encoder."""

    _act_shard = None

    def __init__(self, cfg: ArchConfig, **kw):
        super().__init__(cfg, **kw)
        self.enc_stack = StackDef("enc", cfg.n_layers, (ENC_SUB,))
        self.dec_stack = StackDef("dec", cfg.n_layers, (DEC_SUBS[0], DEC_SUBS[1]))
        self.stacks = [self.dec_stack]  # BRECQ walks enc then dec via blocks()

    def init(self, key) -> Params:
        cfg = self.cfg
        ks = jax.random.split(key, 6)
        params: dict = {
            "embed": cm.embed_init(ks[0], cfg.vocab, cfg.d_model),
            "enc_pos": jnp.zeros((cfg_max_enc(cfg), cfg.d_model), jnp.float32),
            "enc_norm": _norm_init(cfg),
            "final_norm": _norm_init(cfg),
        }
        if not cfg.tie_embeddings:
            params["head"] = {"w": jax.random.normal(ks[1], (cfg.d_model, cfg.vocab), jnp.float32) * 0.02}
        ekeys = jax.random.split(ks[2], self.enc_stack.n)
        params["enc"] = jax.vmap(lambda k: self._init_block(k, self.enc_stack))(ekeys)
        dkeys = jax.random.split(ks[3], self.dec_stack.n)
        params["dec"] = jax.vmap(lambda k: self._init_block(k, self.dec_stack))(dkeys)
        return params

    # -- encoder ---------------------------------------------------------------

    def encode(self, params: Params, frames: Array, quant: QuantHook = NO_QUANT,
               *, remat: Optional[str] = "dots", act_shard=None) -> Array:
        """frames: (B, S_enc, d_model) precomputed embeddings (stub frontend)."""
        shard = (lambda t: act_shard(t)) if act_shard else (lambda t: t)
        B, S, _ = frames.shape
        pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        ctx = Ctx(cfg=self.cfg, positions=pos, quant=quant)
        x = shard(frames + params["enc_pos"][:S])

        def body(x, p_i):
            y, _ = self.apply_block(ctx, self.enc_stack, p_i, x)
            return shard(y), None

        x, _ = jax.lax.scan(_maybe_remat(body, remat), x, params["enc"])
        return _norm(self.cfg, params["enc_norm"], x)

    # -- joint forward -----------------------------------------------------------

    def begin(self, params: Params, batch: dict, quant: QuantHook = NO_QUANT):
        tokens = batch["tokens"]
        B, S = tokens.shape
        pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        ctx = Ctx(cfg=self.cfg, positions=pos, quant=quant)
        if "memory" in batch:
            ctx.extras["memory"] = batch["memory"]
        else:
            ctx.extras["memory"] = self.encode(params, batch["frames"], quant,
                                               act_shard=self._act_shard)
        x = cm.embed_lookup(ctx, params["embed"], tokens)
        return x, ctx

    def forward(self, params: Params, batch: dict, quant: QuantHook = NO_QUANT,
                *, remat: Optional[str] = "dots", act_q=None, act_shard=None):
        shard = (lambda t: act_shard(t)) if act_shard else (lambda t: t)
        self._act_shard = act_shard
        x, ctx = self.begin(params, batch, quant)
        x = shard(x)

        def body(x, p_i):
            y, _ = self.apply_block(ctx, self.dec_stack, p_i, x)
            return shard(y), None

        x, _ = jax.lax.scan(_maybe_remat(body, remat), x, params["dec"])
        return self.finish(params, x, ctx), jnp.zeros((), jnp.float32)

    # -- serving -------------------------------------------------------------------

    def init_cache(self, batch: int, max_len: int, dtype=jnp.bfloat16):
        one = {f"sub{i}": self._init_sub_cache(s, batch, max_len, dtype)
               for i, s in enumerate(self.dec_stack.subs)}
        return {"dec": jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (self.dec_stack.n, *a.shape)), one)}

    def prefill(self, params, batch: dict, cache, quant: QuantHook = NO_QUANT,
                *, remat: Optional[str] = "dots", act_shard=None):
        self._act_shard = act_shard
        x, ctx = self.begin(params, batch, quant)
        if act_shard:
            x = act_shard(x)

        def body(x, xs):
            p_i, c_i = xs
            for i, sub in enumerate(self.dec_stack.subs):
                x, c_i[f"sub{i}"] = self._sub_prefill(ctx, sub, i, p_i[f"sub{i}"], x, c_i[f"sub{i}"])
            return x, c_i

        x, cache["dec"] = jax.lax.scan(_maybe_remat(body, remat), x,
                                       (params["dec"], cache["dec"]))
        logits = self.finish(params, x[:, -1:], ctx)
        return logits[:, 0], cache

    def decode_step(self, params, tokens: Array, cache, pos: Array,
                    quant: QuantHook = NO_QUANT, extras: Optional[dict] = None,
                    act_shard=None):
        positions = pos[:, None].astype(jnp.int32)
        ctx = Ctx(cfg=self.cfg, positions=positions, quant=quant, decode=True)
        x = cm.embed_lookup(ctx, params["embed"], tokens)

        def body(x, xs):
            p_i, c_i = xs
            for i, sub in enumerate(self.dec_stack.subs):
                x, c_i[f"sub{i}"] = self._sub_decode(ctx, sub, i, p_i[f"sub{i}"], x, c_i[f"sub{i}"])
            return x, c_i

        x, cache["dec"] = jax.lax.scan(body, x, (params["dec"], cache["dec"]))
        logits = self.finish(params, x, ctx)
        return logits[:, 0], cache


def cfg_max_enc(cfg: ArchConfig) -> int:
    # learned encoder positions sized to the largest prefill shape we lower
    return 32768
