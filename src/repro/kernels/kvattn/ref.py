"""Pure-jnp oracle for int8-KV decode attention."""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array
MASK = -1e30


def kv_decode_ref(q: Array, k8: Array, v8: Array, kscale: Array,
                  vscale: Array, kpos: Array, cur_pos: Array,
                  window=None) -> Array:
    """q: (B,H,hd); k8/v8: (B,S,K,hd) int8; scales (B,S,K); kpos (B,S);
    cur_pos (B,). GQA via H % K == 0. Returns (B,H,hd)."""
    B, H, hd = q.shape
    S, K = k8.shape[1], k8.shape[2]
    rep = H // K
    k = k8.astype(jnp.float32) * kscale[..., None]
    v = v8.astype(jnp.float32) * vscale[..., None]
    k = jnp.repeat(k, rep, axis=2)
    v = jnp.repeat(v, rep, axis=2)
    s = jnp.einsum("bhd,bshd->bhs", q.astype(jnp.float32), k) / jnp.sqrt(hd)
    valid = (kpos >= 0) & (kpos <= cur_pos[:, None])
    if window is not None:
        valid = valid & (cur_pos[:, None] - kpos < window)
    s = jnp.where(valid[:, None, :], s, MASK)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhs,bshd->bhd", p, v).astype(q.dtype)
