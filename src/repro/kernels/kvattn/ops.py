"""Public wrapper for int8-KV decode attention + cache quantization."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernel import kv_decode
from .ref import kv_decode_ref

Array = jax.Array


def quantize_kv(k: Array, v: Array) -> tuple[Array, Array, Array, Array]:
    """Quantize KV caches to int8 with per-(token, head) scales.

    Args:
      k, v: float caches of shape (B, S, K_heads, head_dim).

    Returns:
      ``(k8, v8, kscale, vscale)`` — int8 codes with the input shapes and
      f32 absmax/127 scales of shape (B, S, K_heads).
    """
    def q(x):
        amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
        scale = jnp.maximum(amax / 127.0, 1e-8)
        codes = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                         -128, 127).astype(jnp.int8)
        return codes, scale

    k8, ks = q(k)
    v8, vs = q(v)
    return k8, v8, ks, vs


def attend_int8(q: Array, k8: Array, v8: Array, kscale: Array, vscale: Array,
                kpos: Array, cur_pos: Array, *, window=None,
                backend: str = "auto") -> Array:
    """Single-step decode attention over an int8-quantized KV cache.

    Args:
      q: current-step queries of shape (B, H, head_dim).
      k8, v8: int8 cache codes of shape (B, S, K_heads, head_dim)
        (``H % K_heads == 0`` for grouped-query sharing).
      kscale, vscale: f32 dequant scales of shape (B, S, K_heads) from
        :func:`quantize_kv`.
      kpos: cache-slot positions, (B, S) int32; negative marks an empty
        slot.
      cur_pos: current decode position per sequence, (B,) int32; slots
        with ``kpos > cur_pos`` (or empty) are masked out.
      window: optional sliding-window size in tokens (positions older
        than ``cur_pos - window`` are masked); ``None`` = full causal.
      backend: ``'auto'`` (Pallas on TPU, XLA reference elsewhere),
        ``'pallas'``, or ``'xla'``.

    Returns:
      Attention output of shape (B, H, head_dim), in ``q``'s dtype.
    """
    if backend == "auto":
        backend = "pallas" if jax.default_backend() == "tpu" else "xla"
    if backend == "xla":
        return kv_decode_ref(q, k8, v8, kscale, vscale, kpos, cur_pos, window)
    interpret = jax.default_backend() != "tpu"
    S = k8.shape[1]
    bs = 512 if S % 512 == 0 else (128 if S % 128 == 0 else S)
    return kv_decode(q, k8, v8, kscale, vscale, kpos, cur_pos,
                     window=window, bs=bs, interpret=interpret)
