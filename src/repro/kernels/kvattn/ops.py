"""Public wrapper for int8-KV decode attention + cache quantization."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernel import kv_decode
from .ref import kv_decode_ref

Array = jax.Array


def quantize_kv(k: Array, v: Array) -> tuple[Array, Array, Array, Array]:
    """bf16 (B,S,K,hd) caches -> int8 codes + per-(token, head) scales."""
    def q(x):
        amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
        scale = jnp.maximum(amax / 127.0, 1e-8)
        codes = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                         -128, 127).astype(jnp.int8)
        return codes, scale

    k8, ks = q(k)
    v8, vs = q(v)
    return k8, v8, ks, vs


def attend_int8(q: Array, k8: Array, v8: Array, kscale: Array, vscale: Array,
                kpos: Array, cur_pos: Array, *, window=None,
                backend: str = "auto") -> Array:
    """Decode attention over the quantized cache. q: (B,H,hd)."""
    if backend == "auto":
        backend = "pallas" if jax.default_backend() == "tpu" else "xla"
    if backend == "xla":
        return kv_decode_ref(q, k8, v8, kscale, vscale, kpos, cur_pos, window)
    interpret = jax.default_backend() != "tpu"
    S = k8.shape[1]
    bs = 512 if S % 512 == 0 else (128 if S % 128 == 0 else S)
    return kv_decode(q, k8, v8, kscale, vscale, kpos, cur_pos,
                     window=window, bs=bs, interpret=interpret)
