"""Decode attention over an int8-quantized KV cache (Pallas TPU).

The decode-time hot loop for quantized serving: the KV cache is stored
int8 with per-(token, kv-head) scales (produced by the same uniform
quantizer as the weights), halving cache bytes vs bf16 — decode is
memory-bound, so this directly moves the §Roofline memory term.

Schedule: grid (B, K, S/bs). For each (batch, kv-head) the GQA query
group (G = H/K rows) stays resident in VMEM while S streams through in
(bs, hd) int8 tiles; dequant + online softmax accumulate in f32 scratch.

VMEM per step (defaults bs=512, hd<=256, G<=16):
  q group (G, hd) f32, k/v tiles (bs, hd) int8, scales (bs,) f32,
  m/l (G,) and acc (G, hd) f32 scratch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..spec import describe_kv_decode

Array = jax.Array
MASK = -1e30


def _kv_kernel(q_ref, k_ref, v_ref, ks_ref, vs_ref, kpos_ref, cur_ref,
               o_ref, m_ref, l_ref, acc_ref, *, ns: int, window, hd: int):
    s = pl.program_id(2)

    @pl.when(s == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)  # (G, hd)
    k = k_ref[0, 0].astype(jnp.float32) * ks_ref[0, 0][:, None]  # (bs, hd)
    scores = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) / (hd ** 0.5)  # (G, bs)
    kp = kpos_ref[0]  # (bs,)
    cur = cur_ref[0]
    valid = (kp >= 0) & (kp <= cur)
    if window is not None:
        valid = valid & (cur - kp < window)
    scores = jnp.where(valid[None, :], scores, MASK)

    m_prev = m_ref[:, 0]  # (G,)
    m_new = jnp.maximum(m_prev, jnp.max(scores, axis=-1))
    p = jnp.exp(scores - m_new[:, None])  # (G, bs)
    corr = jnp.exp(m_prev - m_new)
    v = v_ref[0, 0].astype(jnp.float32) * vs_ref[0, 0][:, None]  # (bs, hd)
    l_ref[:, 0] = l_ref[:, 0] * corr + jnp.sum(p, axis=-1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[:, 0] = m_new

    @pl.when(s == ns - 1)
    def _done():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[:, 0], 1e-30)[:, None]
                       ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("window", "bs", "interpret"))
def kv_decode(q: Array, k8: Array, v8: Array, kscale: Array, vscale: Array,
              kpos: Array, cur_pos: Array, *, window=None, bs: int = 512,
              interpret: bool = False) -> Array:
    """q (B,H,hd); k8/v8 (B,S,K,hd) int8; scales (B,S,K); kpos (B,S) int32;
    cur_pos (B,) int32. Returns (B,H,hd). Tile-math violations raise
    :class:`~repro.kernels.spec.KernelSpecError` naming the shapes."""
    B, H, hd = q.shape
    S, K = k8.shape[1], k8.shape[2]
    bs = min(bs, S)
    sp = describe_kv_decode(q.shape, k8.shape, bs=bs,
                            q_bytes=q.dtype.itemsize)
    G, ns = sp.meta["G"], sp.meta["ns"]

    # regroup: (B, K, G, hd) query groups; (B, K, S, hd) caches
    qg = q.reshape(B, K, G, hd)
    kt = k8.transpose(0, 2, 1, 3)  # (B,K,S,hd)
    vt = v8.transpose(0, 2, 1, 3)
    kst = kscale.transpose(0, 2, 1)  # (B,K,S)
    vst = vscale.transpose(0, 2, 1)

    grid = (B, K, ns)
    out = pl.pallas_call(
        functools.partial(_kv_kernel, ns=ns, window=window, hd=hd),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, G, hd), lambda b, k, s: (b, k, 0, 0)),
            pl.BlockSpec((1, 1, bs, hd), lambda b, k, s: (b, k, s, 0)),
            pl.BlockSpec((1, 1, bs, hd), lambda b, k, s: (b, k, s, 0)),
            pl.BlockSpec((1, 1, bs), lambda b, k, s: (b, k, s)),
            pl.BlockSpec((1, 1, bs), lambda b, k, s: (b, k, s)),
            pl.BlockSpec((1, bs), lambda b, k, s: (b, s)),
            pl.BlockSpec((1,), lambda b, k, s: (b,)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd), lambda b, k, s: (b, k, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, K, G, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qg, kt, vt, kst, vst, kpos, cur_pos)
    return out.reshape(B, H, hd)
