"""Static kernel-launch specs + typed tile-math errors.

The single source of truth for the tile math of every Pallas kernel in
``repro.kernels``: each ``describe_*`` function validates one launch's
shapes — raising :class:`KernelSpecError` that *names the offending
shapes* instead of a bare ``assert`` tuple — and returns a
:class:`KernelSpec` describing the grid, the per-operand VMEM block
shapes, and the estimated VMEM footprint of one program instance.

Two consumers share it:

* the kernel wrappers (``qmatmul/kernel.py``, ``kvattn/kernel.py``,
  ``fakequant/kernel.py``) call their ``describe_*`` before
  ``pl.pallas_call`` so a mis-tiled launch fails typed, with shapes
  named, before any tracing happens;
* the static auditor (``repro.analysis.audit.kernel_check``) calls the
  same functions over the registered configs' weight/cache shapes
  without touching a device, so CI catches a BlockSpec that silently
  mis-tiles (or a VMEM blow-up) the moment a kernel or config changes.

The VMEM model is deliberately simple and documented: input blocks are
double-buffered (Pallas pipelines the HBM copies), the output block and
scratch are single-buffered. ``VMEM_BUDGET_BYTES`` is the declared
per-core budget the auditor enforces (16 MB on current TPUs, with a
safety margin left to the compiler).
"""
from __future__ import annotations

import dataclasses
import math

# Declared VMEM budget per program instance. TPU cores have ~16 MB of
# VMEM; the compiler needs headroom for semaphores/pipelining, so the
# audit budget is deliberately below the hardware size.
VMEM_BUDGET_BYTES = 12 * 1024 * 1024


class KernelSpecError(ValueError):
    """A kernel launch's shapes violate its tiling contract.

    Raised (with the failing shapes named) instead of the bare
    ``assert``s the kernels used to carry — catchable by the static
    auditor and by users feeding odd shapes. Mirrors the
    ``PackedNodeError`` pattern in ``qmatmul/ops.py``.
    """


def _check(cond: bool, kernel: str, msg: str) -> None:
    if not cond:
        raise KernelSpecError(f"{kernel}: {msg}")


@dataclasses.dataclass(frozen=True)
class KernelSpec:
    """Static description of one Pallas kernel launch."""

    kernel: str
    grid: tuple[int, ...]
    blocks: dict  # operand name -> (block shape, dtype bytes)
    scratch: dict  # scratch name -> (shape, dtype bytes)
    meta: dict  # kernel-specific derived tiling (bk, nk, ...)

    @property
    def vmem_bytes(self) -> int:
        """Estimated VMEM per program instance: double-buffered input
        blocks + single-buffered output/scratch."""
        total = 0
        for name, (shape, nbytes) in self.blocks.items():
            mult = 1 if name.startswith("out") else 2
            total += mult * math.prod(shape) * nbytes
        for shape, nbytes in self.scratch.values():
            total += math.prod(shape) * nbytes
        return total

    @property
    def num_programs(self) -> int:
        return math.prod(self.grid)

    def check_budget(self, budget: int = VMEM_BUDGET_BYTES) -> None:
        _check(self.vmem_bytes <= budget, self.kernel,
               f"estimated VMEM {self.vmem_bytes} bytes/program exceeds "
               f"the declared budget {budget} (grid {self.grid}, blocks "
               f"{ {k: v[0] for k, v in self.blocks.items()} })")


def _bits_per(kernel: str, bits: int) -> int:
    _check(bits in (2, 4, 8), kernel,
           f"container bits must be 2, 4 or 8, got {bits}")
    return 8 // bits


def largest_tile(dim: int, cap: int, multiple: int = 1) -> int:
    """Largest divisor of ``dim`` that is <= ``cap`` and a multiple of
    ``multiple``; when no such divisor exists, ``min(dim, cap)`` (the
    caller's divisibility ``_check`` then fails with the shapes named).

    The shared tile-picker for dims real configs do NOT make powers of
    two (d_model 3840, d_ff 10944, vocab 51865): a flat cap would leave
    a ragged last step the kernels' BlockSpecs cannot express.
    """
    for d in range(min(dim, cap), 0, -1):
        if dim % d == 0 and d % multiple == 0:
            return d
    return min(dim, cap)


def _pick_bk(kernel: str, K: int, G: int, per: int) -> tuple[int, int]:
    """(bk, nk): one scale group per k-step, or the largest <=512
    divisor per-channel."""
    bk = largest_tile(K, 512, per) if G == 1 else K // G
    _check(K % bk == 0, kernel,
           f"K={K} is not a multiple of the k-tile bk={bk} "
           f"(scale groups G={G})")
    _check(bk % per == 0, kernel,
           f"k-tile bk={bk} is not a multiple of the packing factor "
           f"per={per} ({8 // per}-bit codes)")
    return bk, K // bk


def describe_qmatmul(x_shape, wp_shape, scales_shape, *, bits: int,
                     bm: int, bn: int, x_bytes: int = 4) -> KernelSpec:
    """Validate + describe a ``qmatmul`` (prefill GEMM) launch.

    x (M, K) @ dequant(wp (K*bits/8, N), scales (K/G, N)) -> (M, N),
    grid (M/bm, N/bn, nk).
    """
    name = "qmatmul"
    per = _bits_per(name, bits)
    M, K = x_shape
    rows, N = wp_shape
    G = scales_shape[0]
    _check(rows * per == K, name,
           f"packed rows {rows} x {per} values/byte != K={K} "
           f"(codes {tuple(wp_shape)}, x {tuple(x_shape)}, bits={bits})")
    _check(scales_shape[1] == N, name,
           f"scales {tuple(scales_shape)} do not span N={N} columns")
    bk, nk = _pick_bk(name, K, G, per)
    _check(M % bm == 0, name, f"M={M} is not a multiple of bm={bm}")
    _check(N % bn == 0, name, f"N={N} is not a multiple of bn={bn}")
    return KernelSpec(
        kernel=name, grid=(M // bm, N // bn, nk),
        blocks={"x": ((bm, bk), x_bytes), "w": ((bk // per, bn), 1),
                "scales": ((1, bn), 4), "out": ((bm, bn), x_bytes)},
        scratch={"acc": ((bm, bn), 4)},
        meta={"bk": bk, "nk": nk, "bm": bm, "bn": bn, "per": per})


def describe_qgemv(x_shape, wp_shape, scales_shape, *, bits: int,
                   bn: int, x_bytes: int = 4) -> KernelSpec:
    """Validate + describe a ``qgemv`` (decode GEMV) launch.

    The whole M extent (decode batch rows) is one skinny block; grid
    (N/bn, nk) with the (M, bn) accumulator VMEM-resident.
    """
    name = "qgemv"
    per = _bits_per(name, bits)
    M, K = x_shape
    rows, N = wp_shape
    G = scales_shape[0]
    _check(rows * per == K, name,
           f"packed rows {rows} x {per} values/byte != K={K} "
           f"(codes {tuple(wp_shape)}, x {tuple(x_shape)}, bits={bits})")
    _check(scales_shape[1] == N, name,
           f"scales {tuple(scales_shape)} do not span N={N} columns")
    bk, nk = _pick_bk(name, K, G, per)
    _check(N % bn == 0, name, f"N={N} is not a multiple of bn={bn}")
    return KernelSpec(
        kernel=name, grid=(N // bn, nk),
        blocks={"x": ((M, bk), x_bytes), "w": ((bk // per, bn), 1),
                "scales": ((1, bn), 4), "out": ((M, bn), x_bytes)},
        scratch={"acc": ((M, bn), 4)},
        meta={"bk": bk, "nk": nk, "bn": bn, "per": per})


def describe_qmatmul_grouped(x_shape, wp_shape, scales_shape, *, bits: int,
                             bm: int, bn: int, x_bytes: int = 4) -> KernelSpec:
    """Validate + describe a ``qmatmul_grouped`` (stacked experts) launch.

    x (E, M, K) @ dequant((E, K*bits/8, N)) -> (E, M, N), expert-major
    grid (E, M/bm, N/bn, nk).
    """
    name = "qmatmul_grouped"
    per = _bits_per(name, bits)
    E, M, K = x_shape
    rows, N = wp_shape[1], wp_shape[2]
    G = scales_shape[1]
    _check(wp_shape[0] == E and scales_shape[0] == E, name,
           f"expert axes disagree: x E={E}, codes {tuple(wp_shape)}, "
           f"scales {tuple(scales_shape)}")
    _check(rows * per == K, name,
           f"packed rows {rows} x {per} values/byte != K={K} "
           f"(codes {tuple(wp_shape)}, x {tuple(x_shape)}, bits={bits})")
    _check(scales_shape[2] == N, name,
           f"scales {tuple(scales_shape)} do not span N={N} columns")
    bk, nk = _pick_bk(name, K, G, per)
    _check(M % bm == 0, name, f"M={M} is not a multiple of bm={bm}")
    _check(N % bn == 0, name, f"N={N} is not a multiple of bn={bn}")
    return KernelSpec(
        kernel=name, grid=(E, M // bm, N // bn, nk),
        blocks={"x": ((1, bm, bk), x_bytes), "w": ((1, bk // per, bn), 1),
                "scales": ((1, 1, bn), 4), "out": ((1, bm, bn), x_bytes)},
        scratch={"acc": ((bm, bn), 4)},
        meta={"bk": bk, "nk": nk, "bm": bm, "bn": bn, "per": per})


def describe_kv_decode(q_shape, k8_shape, *, bs: int,
                       q_bytes: int = 4) -> KernelSpec:
    """Validate + describe a ``kv_decode`` (int8-KV attention) launch.

    q (B, H, hd) over int8 caches (B, S, K_heads, hd); grid (B, K, S/bs)
    with the (G, hd) query group resident while S streams.
    """
    name = "kv_decode"
    B, H, hd = q_shape
    S, K = k8_shape[1], k8_shape[2]
    _check(K > 0 and H % K == 0, name,
           f"query heads H={H} not divisible into kv heads K={K} "
           f"(q {tuple(q_shape)}, cache {tuple(k8_shape)})")
    G = H // K
    _check(S % bs == 0, name,
           f"cache length S={S} is not a multiple of the stream tile "
           f"bs={bs} (cache {tuple(k8_shape)})")
    return KernelSpec(
        kernel=name, grid=(B, K, S // bs),
        blocks={"q": ((1, 1, G, hd), q_bytes), "k": ((1, 1, bs, hd), 1),
                "v": ((1, 1, bs, hd), 1), "kscale": ((1, 1, bs), 4),
                "vscale": ((1, 1, bs), 4), "kpos": ((1, bs), 4),
                "cur": ((1,), 4), "out": ((1, 1, G, hd), q_bytes)},
        scratch={"m": ((G, 1), 4), "l": ((G, 1), 4), "acc": ((G, hd), 4)},
        meta={"bs": bs, "ns": S // bs, "G": G})


def describe_fakequant(w_shape, scale_shape, *, bk: int, bn: int,
                       w_bytes: int = 4) -> KernelSpec:
    """Validate + describe a ``fakequant`` (AdaRound forward) launch.

    w, v (K, N) with a (1, N) or (K, N) scale; grid (K/bk, N/bn).
    """
    name = "fakequant"
    K, N = w_shape
    _check(K % bk == 0, name, f"K={K} is not a multiple of bk={bk} "
           f"(w {tuple(w_shape)})")
    _check(N % bn == 0, name, f"N={N} is not a multiple of bn={bn} "
           f"(w {tuple(w_shape)})")
    _check(scale_shape[0] in (1, K) and scale_shape[1] == N, name,
           f"scale {tuple(scale_shape)} must be (1, {N}) or ({K}, {N})")
    per_row = scale_shape[0] != 1
    return KernelSpec(
        kernel=name, grid=(K // bk, N // bn),
        blocks={"w": ((bk, bn), w_bytes), "v": ((bk, bn), w_bytes),
                "scale": ((bk if per_row else 1, bn), 4),
                "out": ((bk, bn), w_bytes)},
        scratch={},
        meta={"bk": bk, "bn": bn, "per_row": per_row})
