"""Public wrapper for the fused AdaRound forward."""
from __future__ import annotations

import jax

from ...core.quantizer import QConfig, QState
from ..spec import KernelSpecError, largest_tile
from .kernel import fakequant
from .ref import fakequant_ref


def adaround_forward(w: jax.Array, v: jax.Array, st: QState, cfg: QConfig,
                     *, hard: bool = False, backend: str = "auto") -> jax.Array:
    """Kernel-backed equivalent of ``core.adaround.soft_quant`` /
    ``hard_quant`` for 2-D per-channel weights (symmetric, no grouping).

    Args:
      w: FP weight of shape (K, N).
      v: AdaRound rounding logits, same shape as ``w``.
      st: quantizer state; ``st.scale`` must broadcast to (1, N) (one
        scale per output channel).
      cfg: static quantizer config supplying the clip range
        ``[qmin, qmax]``; must be symmetric with ``group_size=None``.
      hard: ``False`` — soft (differentiable) rounding with the rectified
        sigmoid of ``v``; ``True`` — hardened rounding ``(v >= 0)``.
      backend: ``'auto'`` (Pallas on TPU, XLA reference elsewhere),
        ``'pallas'``, or ``'xla'``.

    Returns:
      Fake-quantized weight, shape (K, N), f32.

    Raises:
      KernelSpecError: for weight ranks or quantizer configs the fused
        kernel does not cover (grouped or asymmetric quantization) —
        callers fall back to ``core.adaround`` for those.
    """
    if w.ndim != 2:
        raise KernelSpecError(
            f"adaround_forward: weights must be 2-D (K, N), got shape "
            f"{tuple(w.shape)}")
    if cfg.group_size is not None or not cfg.symmetric:
        raise KernelSpecError(
            f"adaround_forward: only symmetric per-channel quantization is "
            f"fused (group_size=None, symmetric=True); got unsupported "
            f"config group_size={cfg.group_size}, symmetric={cfg.symmetric}")
    scale = st.scale.reshape(-1, w.shape[1])
    if backend == "auto":
        backend = "pallas" if jax.default_backend() == "tpu" else "xla"
    if backend == "xla":
        return fakequant_ref(w, v, scale, cfg.qmin, cfg.qmax, hard)
    interpret = jax.default_backend() != "tpu"
    K, N = w.shape
    bk = largest_tile(K, 256)
    bn = largest_tile(N, 256)
    return fakequant(w, v, scale, qmin=cfg.qmin, qmax=cfg.qmax, hard=hard,
                     bk=bk, bn=bn, interpret=interpret)
