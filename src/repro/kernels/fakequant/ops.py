"""Public wrapper for the fused AdaRound forward."""
from __future__ import annotations

import jax

from ...core.quantizer import QConfig, QState
from .kernel import fakequant
from .ref import fakequant_ref


def adaround_forward(w: jax.Array, v: jax.Array, st: QState, cfg: QConfig,
                     *, hard: bool = False, backend: str = "auto") -> jax.Array:
    """Kernel-backed equivalent of ``core.adaround.soft_quant`` /
    ``hard_quant`` for 2-D per-channel weights (symmetric, no grouping).

    Args:
      w: FP weight of shape (K, N).
      v: AdaRound rounding logits, same shape as ``w``.
      st: quantizer state; ``st.scale`` must broadcast to (1, N) (one
        scale per output channel).
      cfg: static quantizer config supplying the clip range
        ``[qmin, qmax]``; must be symmetric with ``group_size=None``.
      hard: ``False`` — soft (differentiable) rounding with the rectified
        sigmoid of ``v``; ``True`` — hardened rounding ``(v >= 0)``.
      backend: ``'auto'`` (Pallas on TPU, XLA reference elsewhere),
        ``'pallas'``, or ``'xla'``.

    Returns:
      Fake-quantized weight, shape (K, N), f32.
    """
    assert w.ndim == 2 and cfg.group_size is None and cfg.symmetric
    scale = st.scale.reshape(-1, w.shape[1])
    if backend == "auto":
        backend = "pallas" if jax.default_backend() == "tpu" else "xla"
    if backend == "xla":
        return fakequant_ref(w, v, scale, cfg.qmin, cfg.qmax, hard)
    interpret = jax.default_backend() != "tpu"
    K, N = w.shape
    bk = 256 if K % 256 == 0 else (8 if K % 8 == 0 else 1)
    bn = 256 if N % 256 == 0 else (128 if N % 128 == 0 else N)
    return fakequant(w, v, scale, qmin=cfg.qmin, qmax=cfg.qmax, hard=hard,
                     bk=bk, bn=bn, interpret=interpret)
