"""Pure-jnp oracle for the fused AdaRound forward."""
from __future__ import annotations

import jax
import jax.numpy as jnp

ZETA, GAMMA = 1.1, -0.1


def fakequant_ref(w: jax.Array, v: jax.Array, scale: jax.Array,
                  qmin: int, qmax: int, hard: bool) -> jax.Array:
    """w, v: (K, N); scale: (1|K, N) broadcastable. AdaRound forward."""
    if hard:
        h = (v >= 0).astype(jnp.float32)
    else:
        h = jnp.clip(jax.nn.sigmoid(v) * (ZETA - GAMMA) + GAMMA, 0.0, 1.0)
    q = jnp.clip(jnp.floor(w / scale) + h, qmin, qmax)
    return (q * scale).astype(w.dtype)
