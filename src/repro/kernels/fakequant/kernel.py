"""Fused AdaRound forward (Pallas TPU).

The elementwise hot loop of BRECQ calibration: soft/hard rounding of a
weight tile entirely in VMEM — floor, rectified sigmoid, clip, rescale
in one pass instead of five XLA HLOs (one read + one write of W per
step instead of several temporaries).

Tiling: (bk, bn) weight/logit tiles with a broadcast (1, bn) scale row
(per-output-channel scales).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..spec import describe_fakequant

ZETA, GAMMA = 1.1, -0.1


def _fq_kernel(w_ref, v_ref, s_ref, o_ref, *, qmin, qmax, hard):
    w = w_ref[...].astype(jnp.float32)
    s = s_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32)
    if hard:
        h = (v >= 0).astype(jnp.float32)
    else:
        h = jnp.clip(jax.nn.sigmoid(v) * (ZETA - GAMMA) + GAMMA, 0.0, 1.0)
    q = jnp.clip(jnp.floor(w / s) + h, qmin, qmax)
    o_ref[...] = (q * s).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("qmin", "qmax", "hard", "bk",
                                             "bn", "interpret"))
def fakequant(w: jax.Array, v: jax.Array, scale: jax.Array, *, qmin: int,
              qmax: int, hard: bool = False, bk: int = 256, bn: int = 256,
              interpret: bool = False) -> jax.Array:
    """w, v: (K, N); scale: (1, N) or (K, N). AdaRound fake-quant.
    Tile-math violations raise
    :class:`~repro.kernels.spec.KernelSpecError` naming the shapes."""
    K, N = w.shape
    bk = min(bk, K)
    bn = min(bn, N)
    sp = describe_fakequant(w.shape, scale.shape, bk=bk, bn=bn,
                            w_bytes=w.dtype.itemsize)
    per_row = sp.meta["per_row"]
    return pl.pallas_call(
        functools.partial(_fq_kernel, qmin=qmin, qmax=qmax, hard=hard),
        grid=(K // bk, N // bn),
        in_specs=[
            pl.BlockSpec((bk, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bk, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bk if per_row else 1, bn),
                         (lambda i, j: (i, j)) if per_row else (lambda i, j: (0, j))),
        ],
        out_specs=pl.BlockSpec((bk, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((K, N), w.dtype),
        interpret=interpret,
    )(w, v, scale)
