"""Pure-jnp oracle for the packed dequant-matmul kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ...core.quantizer import unpack_int

Array = jax.Array


def dequant(w_packed: Array, scales: Array, bits: int, k: int) -> Array:
    """(K/per, N) packed int8 + (K/G, N) scales -> (K, N) float weights."""
    codes = unpack_int(w_packed, bits, k).astype(jnp.float32)  # (K, N)
    g = k // scales.shape[0]
    codes = codes.reshape(scales.shape[0], g, -1) * scales[:, None, :]
    return codes.reshape(k, -1)


def qmatmul_ref(x: Array, w_packed: Array, scales: Array, bits: int) -> Array:
    """x: (M, K); w_packed: (K*bits/8, N) int8; scales: (K/G, N)."""
    per = 8 // bits
    k = w_packed.shape[0] * per
    w = dequant(w_packed, scales, bits, k)
    return jnp.dot(x.astype(jnp.float32), w).astype(x.dtype)
