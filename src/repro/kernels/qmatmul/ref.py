"""Pure-jnp oracles for the packed dequant-matmul kernels.

``qmatmul_ref`` is the literal prefill oracle (dequantize, then dot).
``qgemv_ref`` is the decode-shaped reference: for M = a few batch rows
the dequant multiply dominates, so it contracts the *integer codes*
first and applies the per-group scales to the (G, M, N) partial sums —
KN scale-multiplies (and a scaled f32 weight copy) become G*N. It is
also what the XLA backend serves decode steps from.
``qmm_grouped_ref`` extends it over stacked experts with a scan so the
residency stays one expert's (K, N) — the full f32 (E, K, N) dequant is
never materialized.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ...core.quantizer import unpack_int

Array = jax.Array


def dequant(w_packed: Array, scales: Array, bits: int, k: int) -> Array:
    """(K/per, N) packed int8 + (K/G, N) scales -> (K, N) float weights."""
    codes = unpack_int(w_packed, bits, k).astype(jnp.float32)  # (K, N)
    g = k // scales.shape[0]
    codes = codes.reshape(scales.shape[0], g, -1) * scales[:, None, :]
    return codes.reshape(k, -1)


def qmatmul_ref(x: Array, w_packed: Array, scales: Array, bits: int) -> Array:
    """x: (M, K); w_packed: (K*bits/8, N) int8; scales: (K/G, N)."""
    per = 8 // bits
    k = w_packed.shape[0] * per
    w = dequant(w_packed, scales, bits, k)
    return jnp.dot(x.astype(jnp.float32), w).astype(x.dtype)


def qgemv_ref(x: Array, w_packed: Array, scales: Array, bits: int) -> Array:
    """Decode-shaped (small-M) reference: scale after the code dot.

    x: (M, K); w_packed: (K*bits/8, N) int8; scales: (G, N). Exact same
    math as :func:`qmatmul_ref` (f32 accumulation, scales are uniform
    within a group) reassociated as ``sum_g s[g] * (x_g @ codes_g)`` —
    no (K, N) *scaled* f32 weight copy, and the per-element dequant
    multiply shrinks from K*N to G*N per output tile.
    """
    per = 8 // bits
    k = w_packed.shape[0] * per
    m = x.shape[0]
    g_rows = scales.shape[0]
    codes = unpack_int(w_packed, bits, k).astype(jnp.float32)  # (K, N)
    if g_rows == 1:  # per-channel: one plain dot, then an (M, N) scale
        out = jnp.dot(x.astype(jnp.float32), codes) * scales
    else:  # grouped: G batched (M, K/G) dots, scales on the partials
        cg = codes.reshape(g_rows, k // g_rows, -1)
        xg = x.astype(jnp.float32).reshape(m, g_rows, k // g_rows)
        partial = jnp.einsum("mgk,gkn->gmn", xg, cg)
        out = jnp.einsum("gmn,gn->mn", partial, scales.astype(jnp.float32))
    return out.astype(x.dtype)


def qmm_grouped_ref(x: Array, w_packed: Array, scales: Array, bits: int) -> Array:
    """Stacked-expert decode reference: one expert resident at a time.

    x: (E, M, K); w_packed: (E, K*bits/8, N) int8; scales: (E, G, N).
    A ``lax.scan`` over E keeps the unpack transient at one (K, N) tile
    (the decode residency contract the MoE trace test pins down);
    per-expert math is :func:`qgemv_ref`'s scale-after-dot form.
    """

    def step(_, ews):
        xe, we, se = ews
        return None, qgemv_ref(xe, we, se, bits)

    _, out = jax.lax.scan(step, None, (x, w_packed, scales))
    return out


def qmm_grouped_dense_ref(x: Array, w_packed: Array, scales: Array,
                          bits: int) -> Array:
    """Stacked-expert prefill reference: one batched einsum over E.

    Same contract as :func:`qmm_grouped_ref`; at prefill arithmetic
    intensity (many rows per expert) the (E, K, N) dequant transient is
    a good trade against serializing E contractions, so the dispatcher
    routes large per-expert row counts here and small (decode) ones to
    the scan form.
    """
    per = 8 // bits
    k = w_packed.shape[-2] * per
    g_rows = scales.shape[-2]
    codes = unpack_int(w_packed, bits, k, axis=-2).astype(jnp.float32)
    cg = codes.reshape(*codes.shape[:-2], g_rows, k // g_rows, codes.shape[-1])
    w = (cg * scales[..., :, None, :]).reshape(*codes.shape)
    out = jnp.einsum("emk,ekn->emn", x.astype(jnp.float32), w)
    return out.astype(x.dtype)
