"""Public jit'd wrapper for the packed dequant-matmul.

``qmm(x, qw)`` consumes a :class:`QuantizedLinear` produced from BRECQ
output (pack_weights). On CPU this runs the Pallas kernel in interpret
mode (correctness) or the XLA reference (speed); on TPU it compiles the
Pallas kernel.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from ...core.quantizer import pack_int
from .kernel import qmatmul
from .ref import qmatmul_ref

Array = jax.Array


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class QuantizedLinear:
    """Deployment weight format: packed codes + per-group scales."""

    packed: Array  # (K * bits/8, N) int8
    scales: Array  # (K/G, N) f32
    bits: int
    k: int  # original reduction dim

    def tree_flatten(self):
        return (self.packed, self.scales), (self.bits, self.k)

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        return cls(leaves[0], leaves[1], *aux)


def pack_weights(codes: Array, scales, bits: int) -> QuantizedLinear:
    """codes: (K, N) int8 in [-2^{b-1}, 2^{b-1}-1]; scales broadcastable."""
    k, n = codes.shape
    scales = jnp.asarray(scales, jnp.float32).reshape(-1, n)
    return QuantizedLinear(pack_int(codes, bits), scales, bits, k)


def from_node(node, k: int) -> QuantizedLinear:
    """View a packed params node (`repro.deploy` format) as a
    :class:`QuantizedLinear`. ``k`` is the original reduction dim;
    container bits are inferred from the packed row count."""
    wp, scales = node["w"], node["qscale"]
    assert wp.ndim == 2, f"qmm consumes 2-D packed weights, got {wp.shape}"
    per = k // wp.shape[0]
    return QuantizedLinear(wp, scales, 8 // per, k)


def qmm(x: Array, qw: QuantizedLinear, *, backend: str = "auto") -> Array:
    """Packed dequant-matmul: ``x @ dequant(qw)``.

    Args:
      x: activations of shape (..., K), any float dtype; leading dims are
        flattened to M rows for the kernel and restored on return.
      qw: packed weight from :func:`pack_weights` — int8 container codes
        (2/4/8-bit, ``K * bits/8`` rows) plus per-(group, out-channel)
        f32 scales.
      backend: ``'auto'`` (Pallas on TPU, XLA reference elsewhere),
        ``'pallas'`` (interpret mode off-TPU), or ``'xla'``.

    Returns:
      f32 output of shape (..., N).

    Ragged M (not a multiple of the 8/128 sublane tile) is zero-padded up
    to the tile multiple and the output sliced back, instead of degrading
    to bm=1 — a grid of M single-row MXU calls.
    """
    lead = x.shape[:-1]
    x2 = x.reshape(-1, qw.k)
    if backend == "auto":
        backend = "pallas" if jax.default_backend() == "tpu" else "xla"
    if backend == "xla":
        out = qmatmul_ref(x2, qw.packed, qw.scales, qw.bits)
    else:
        interpret = jax.default_backend() != "tpu"
        m = x2.shape[0]
        bm = 128 if m % 128 == 0 else 8
        pad = (-m) % bm
        if pad:
            x2 = jnp.pad(x2, ((0, pad), (0, 0)))
        out = qmatmul(x2, qw.packed, qw.scales, bits=qw.bits, bm=bm,
                      interpret=interpret)
        if pad:
            out = out[:m]
    return out.reshape(*lead, -1)
