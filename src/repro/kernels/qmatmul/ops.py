"""Public jit'd wrapper + shape-driven tier dispatcher for the packed
dequant-matmul.

``qmm(x, qw)`` consumes a :class:`QuantizedLinear` produced from BRECQ
output (pack_weights / from_node) and routes it to one of three
execution tiers by shape alone — callers (``QuantHook.packed_matmul``,
``launch/serve.py``, the dryrun decode cells) never pick a kernel:

  decode    M <= DECODE_M_MAX rows (a decode step's batch): the skinny
            ``qgemv`` kernel — no zero-row padding of M up to the 8/128
            sublane tile, scales applied to the partial sums.
  prefill   everything else 2-D: the tiled ``qmatmul`` GEMM.
  grouped   stacked expert nodes (packed.ndim == 3): ``qmatmul_grouped``
            over (E, K*bits/8, N), one expert grid step at a time.

On CPU each tier runs its XLA reference (the Pallas kernels are
exercised in interpret mode by tests); on TPU the Pallas kernels
compile. ``backend`` / ``QuantHook.packed_backend`` still forces a path.

Decode-shaped calls can additionally dispatch by *measurement* instead
of the M-threshold guess: an installed per-shape table of timed tier
winners (:func:`set_dispatch_table`, built by
``repro.deploy.budget.cost``) overrides the heuristic — see
:func:`select_tier` / ``REPRO_QMM_DISPATCH``.
"""
from __future__ import annotations

import dataclasses
import os

import jax
import jax.numpy as jnp

from ...core.quantizer import pack_int
from .kernel import qgemv, qmatmul, qmatmul_grouped
from .ref import (qgemv_ref, qmatmul_ref, qmm_grouped_dense_ref,
                  qmm_grouped_ref)

Array = jax.Array

# Largest row count served by the decode tier: one f32 sublane tile.
# Decode steps are M = batch rows; beyond 8 rows the MXU-tiled prefill
# GEMM wins anyway, so the gemv specialization stops paying.
DECODE_M_MAX = 8

# Decode-tier opt-out. On some backends the gemv specialization loses to
# the tiled GEMM even at decode shapes (BENCH_serve.json records
# decode_ratio_tier_vs_legacy < 1 on CPU); operators can force those
# shapes onto the prefill tier without a rebuild:
#   env   REPRO_QMM_DECODE_TIER=0|false|off   (read at import)
#   code  set_decode_tier(False)              (overrides the env)
_FALSY = ("0", "false", "off", "no")
_DECODE_TIER_FORCED: bool | None = None  # set_decode_tier override


def _env_decode_tier() -> bool:
    return os.environ.get("REPRO_QMM_DECODE_TIER", "1").lower() not in _FALSY


def decode_tier_enabled() -> bool:
    """Whether decode-shaped matmuls may use the gemv tier."""
    if _DECODE_TIER_FORCED is not None:
        return _DECODE_TIER_FORCED
    return _env_decode_tier()


def set_decode_tier(enabled: bool | None) -> None:
    """Force the decode tier on/off (``None`` returns control to the
    ``REPRO_QMM_DECODE_TIER`` env var). Takes effect at the next trace —
    already-compiled programs keep the tier they were traced with."""
    global _DECODE_TIER_FORCED
    _DECODE_TIER_FORCED = enabled


# Measured dispatch. The M <= DECODE_M_MAX heuristic guesses which tier
# wins at decode shapes; BENCH_serve.json records it guessing wrong on
# CPU (decode_ratio_tier_vs_legacy < 1). A measured dispatch table —
# (K, N, container_bits) -> winning tier, produced by timing each
# eligible tier at the artifact's actual shapes
# (repro.deploy.budget.cost.measure_cost_table, installed via
# install_dispatch) — overrides the guess for the shapes it covers:
#   env   REPRO_QMM_DISPATCH=heuristic|measured  (forces the mode)
#   auto  (default): measured iff a table is installed
_DISPATCH_TABLE: dict[tuple[int, int, int], str] | None = None


def set_dispatch_table(table: dict[tuple[int, int, int], str] | None) -> None:
    """Install (or clear) the measured dispatch table. Takes effect at
    the next trace, like :func:`set_decode_tier`."""
    global _DISPATCH_TABLE
    _DISPATCH_TABLE = table


def dispatch_mode() -> str:
    """Resolved dispatch mode: the ``REPRO_QMM_DISPATCH`` env override
    when set, else ``'measured'`` iff a table is installed."""
    mode = os.environ.get("REPRO_QMM_DISPATCH", "auto").lower()
    if mode in ("heuristic", "measured"):
        return mode
    return "measured" if _DISPATCH_TABLE else "heuristic"

# Trace-time tier counters (reset with ``reset_tier_counts``): each jit
# trace that routes through qmm bumps its tier once, so tests and the
# serve benchmark can assert which kernels a program actually compiled
# against without instrumenting jaxprs.
TIER_COUNTS = {"decode": 0, "prefill": 0, "grouped": 0}


def reset_tier_counts() -> None:
    for k in TIER_COUNTS:
        TIER_COUNTS[k] = 0


class PackedNodeError(TypeError):
    """A params node does not have the packed layout qmm consumes."""


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class QuantizedLinear:
    """Deployment weight format: packed codes + per-group scales.

    2-D (a single linear) or stacked 3-D (an expert group):

      packed  (K * bits/8, N) int8        or (E, K * bits/8, N)
      scales  (K/G, N) f32                or (E, K/G, N)
    """

    packed: Array
    scales: Array
    bits: int
    k: int  # original reduction dim

    def tree_flatten(self):
        return (self.packed, self.scales), (self.bits, self.k)

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        return cls(leaves[0], leaves[1], *aux)


def pack_weights(codes: Array, scales, bits: int) -> QuantizedLinear:
    """codes: (K, N) int8 in [-2^{b-1}, 2^{b-1}-1]; scales broadcastable."""
    k, n = codes.shape
    scales = jnp.asarray(scales, jnp.float32).reshape(-1, n)
    return QuantizedLinear(pack_int(codes, bits), scales, bits, k)


def from_node(node, k: int, path: str | None = None) -> QuantizedLinear:
    """View a packed params node (`repro.deploy` format) as a
    :class:`QuantizedLinear`. ``k`` is the original reduction dim;
    container bits are inferred from the packed row count. 3-D nodes
    (stacked experts) route to the grouped tier; ``path`` names the
    offending node in errors."""
    from ...deploy.pack import code_layout

    wp, scales = node["w"], node["qscale"]
    where = f" at {path!r}" if path else ""
    if wp.ndim not in (2, 3):
        raise PackedNodeError(
            f"packed node{where}: codes must be 2-D (K*bits/8, N) or "
            f"stacked 3-D (E, K*bits/8, N), got shape {wp.shape}")
    if scales.ndim != wp.ndim:
        raise PackedNodeError(
            f"packed node{where}: qscale rank {scales.ndim} does not match "
            f"codes rank {wp.ndim} (shapes {scales.shape} vs {wp.shape})")
    try:
        bits, _ = code_layout(wp, k)
    except ValueError as e:
        raise PackedNodeError(f"packed node{where}: {e}") from None
    return QuantizedLinear(wp, scales, bits, k)


def select_tier(m: int, qw: QuantizedLinear) -> str:
    """Execution tier for ``m`` activation rows against ``qw`` — the one
    dispatch predicate, shared by :func:`qmm` and its tests.

    Decode-shaped 2-D matmuls (``m <= DECODE_M_MAX``) consult the
    measured dispatch table when the mode resolves to ``'measured'``
    (:func:`dispatch_mode`); shapes the table does not cover — and the
    heuristic mode — fall back to the gemv guess. The decode-tier
    opt-out (:func:`set_decode_tier` / ``REPRO_QMM_DECODE_TIER``)
    still wins over everything."""
    if qw.packed.ndim == 3:
        return "grouped"
    if m > DECODE_M_MAX or not decode_tier_enabled():
        return "prefill"
    if _DISPATCH_TABLE is not None and dispatch_mode() == "measured":
        tier = _DISPATCH_TABLE.get((qw.k, qw.packed.shape[-1], qw.bits))
        if tier is not None:
            return tier
    return "decode"


def _pad_cols(qw: QuantizedLinear, bn: int) -> tuple[QuantizedLinear, int]:
    """Zero-pad ragged N up to a multiple of ``bn`` (padded scales are
    zero, so the extra columns cost nothing numerically and are sliced
    off after the kernel)."""
    n = qw.packed.shape[-1]
    pad = (-n) % bn
    if not pad:
        return qw, n
    widths = [(0, 0)] * (qw.packed.ndim - 1) + [(0, pad)]
    return dataclasses.replace(
        qw, packed=jnp.pad(qw.packed, widths),
        scales=jnp.pad(qw.scales, widths)), n


def _qmm_2d(x2: Array, qw: QuantizedLinear, backend: str, tier: str) -> Array:
    if backend == "xla":
        ref = qgemv_ref if tier == "decode" else qmatmul_ref
        return ref(x2, qw.packed, qw.scales, qw.bits)
    interpret = jax.default_backend() != "tpu"
    n = qw.packed.shape[-1]
    bn = 128 if n >= 128 else n
    qw, n = _pad_cols(qw, bn)
    if tier == "decode":
        out = qgemv(x2, qw.packed, qw.scales, bits=qw.bits, bn=bn,
                    interpret=interpret)
    else:
        m = x2.shape[0]
        bm = 128 if m % 128 == 0 else 8
        pad = (-m) % bm
        if pad:
            x2 = jnp.pad(x2, ((0, pad), (0, 0)))
        out = qmatmul(x2, qw.packed, qw.scales, bits=qw.bits, bm=bm, bn=bn,
                      interpret=interpret)
        if pad:
            out = out[:m]
    return out[:, :n] if out.shape[-1] != n else out


def _qmm_grouped(x: Array, qw: QuantizedLinear, backend: str) -> Array:
    """x (..., E, C, K) @ stacked qw (E, K*bits/8, N) -> (..., E, C, N)."""
    if x.ndim < 3:
        raise PackedNodeError(
            f"grouped qmm: stacked codes {qw.packed.shape} need (..., E, C, "
            f"K) activations, got rank-{x.ndim} {x.shape}")
    e, c, k = x.shape[-3], x.shape[-2], x.shape[-1]
    if e != qw.packed.shape[0] or k != qw.k:
        raise PackedNodeError(
            f"grouped qmm: activations (..., E={e}, C={c}, K={k}) do not "
            f"match stacked codes {qw.packed.shape} (E, K*bits/8, N)")
    lead = x.shape[:-3]
    # (..., E, C, K) -> (E, B'*C, K): experts become the leading grid dim
    xg = jnp.moveaxis(x.reshape(-1, e, c, k), 1, 0).reshape(e, -1, k)
    if backend == "xla":
        # decode rows: scan over E (one expert's (K, N) resident at a
        # time); prefill rows: batched einsum (dequant transient is a
        # good trade against serializing E contractions)
        ref = (qmm_grouped_ref if xg.shape[1] <= DECODE_M_MAX
               else qmm_grouped_dense_ref)
        out = ref(xg, qw.packed, qw.scales, qw.bits)
    else:
        m = xg.shape[1]
        bm = m if m <= DECODE_M_MAX else (128 if m % 128 == 0 else 8)
        pad = (-m) % bm
        if pad:
            xg = jnp.pad(xg, ((0, 0), (0, pad), (0, 0)))
        n = qw.packed.shape[-1]
        bn = 128 if n >= 128 else n
        qw, n = _pad_cols(qw, bn)
        out = qmatmul_grouped(xg, qw.packed, qw.scales, bits=qw.bits, bm=bm,
                              bn=bn, interpret=jax.default_backend() != "tpu")
        out = out[:, :m, :n]
    nn = out.shape[-1]
    return jnp.moveaxis(out.reshape(e, -1, c, nn), 0, 1).reshape(*lead, e, c, nn)


def qmm(x: Array, qw: QuantizedLinear, *, backend: str = "auto") -> Array:
    """Packed dequant-matmul: ``x @ dequant(qw)``, tier picked by shape.

    Args:
      x: activations, any float dtype. For a 2-D ``qw``: shape (..., K);
        leading dims are flattened to M rows for the kernel and restored
        on return. For a stacked 3-D ``qw``: shape (..., E, C, K), with
        the expert axis aligned to the codes' leading axis.
      qw: packed weight from :func:`pack_weights` / :func:`from_node` —
        int8 container codes (2/4/8-bit, ``K * bits/8`` rows) plus
        per-(group, out-channel) f32 scales.
      backend: ``'auto'`` (Pallas on TPU, XLA reference elsewhere),
        ``'pallas'`` (interpret mode off-TPU), or ``'xla'``.

    Returns:
      f32 output of shape (..., N) / (..., E, C, N).

    Tier selection (:func:`select_tier`): M <= ``DECODE_M_MAX`` rows run
    the ``qgemv`` decode kernel at the true row count; larger M runs the
    tiled prefill GEMM with ragged M zero-padded up to the 8/128 sublane
    tile; 3-D stacked nodes run the grouped expert kernel. Ragged N is
    zero-padded (zero scales) up to the lane tile and sliced back.
    """
    if backend == "auto":
        backend = "pallas" if jax.default_backend() == "tpu" else "xla"
    if qw.packed.ndim == 3:
        TIER_COUNTS["grouped"] += 1
        return _qmm_grouped(x, qw, backend)
    lead = x.shape[:-1]
    x2 = x.reshape(-1, qw.k)
    tier = select_tier(x2.shape[0], qw)
    TIER_COUNTS[tier] += 1
    return _qmm_2d(x2, qw, backend, tier).reshape(*lead, -1)
