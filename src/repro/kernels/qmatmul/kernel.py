"""Packed-int weight dequant-matmul Pallas TPU kernel.

The serving GEMM for BRECQ-quantized models: weights live in HBM as
packed int2/int4/int8 codes (offset-binary, packed along the reduction
axis) with per-group scales; the kernel streams (bk, bn) weight tiles
into VMEM, unpacks + dequantizes in-register, and accumulates on the MXU
in f32.

Tiling (VMEM working set per step, defaults bm=bn=128, bk=group):
  x tile      (bm, bk)            bf16/f32
  w tile      (bk/per, bn) int8   <- 8/bits codes per byte
  scale tile  (1, bn)             one group per k-step (bk == group_size)
  acc scratch (bm, bn) f32

Constraint: group_size == bk (one scale row per k-tile), or per-channel
scales (scales shape (1, N)). MXU dims stay multiples of 128.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array


def _unpack_tile(wp: Array, bits: int) -> Array:
    """(bk/per, bn) int8 -> (bk, bn) f32 centred codes.

    All integer work stays in int8: the arithmetic right shift
    sign-extends, but ``& mask`` keeps only the low ``bits`` bits, which
    match the logical-shift result whenever shift+bits <= 8 — so no
    widening to int32 and no unsigned view are needed. One broadcasted
    shift replaces the per-field temporaries + stack, leaving a single
    reshape to interleave the ``per`` fields along the k axis.
    """
    if bits == 8:
        return wp.astype(jnp.float32)
    per = 8 // bits
    mask = jnp.int8((1 << bits) - 1)
    # iota (not a captured constant: Pallas kernels must build arrays
    # in-kernel) gives the per-field shift amounts 0, bits, 2*bits, ...
    shifts = (jax.lax.broadcasted_iota(jnp.int32, (1, per, 1), 1)
              .astype(jnp.int8) * jnp.int8(bits))
    fields = (wp[:, None, :] >> shifts) & mask  # (bk/per, per, bn)
    codes = fields.reshape(wp.shape[0] * per, wp.shape[1]) - jnp.int8(2 ** (bits - 1))
    return codes.astype(jnp.float32)


def _qmatmul_kernel(x_ref, w_ref, s_ref, o_ref, acc_ref, *, bits: int, nk: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    codes = _unpack_tile(w_ref[...], bits)  # (bk, bn)
    w = codes * s_ref[...].astype(jnp.float32)  # scale row broadcasts
    acc_ref[...] += jax.lax.dot(
        x_ref[...].astype(jnp.float32), w,
        preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bits", "bm", "bn", "interpret"))
def qmatmul(x: Array, w_packed: Array, scales: Array, *, bits: int,
            bm: int = 128, bn: int = 128, interpret: bool = True) -> Array:
    """x (M, K) @ dequant(w_packed (K/per, N), scales (K/G, N)) -> (M, N)."""
    per = 8 // bits
    M, K = x.shape
    N = w_packed.shape[1]
    G = scales.shape[0]
    assert w_packed.shape[0] * per == K, (w_packed.shape, K, bits)
    if G == 1:
        bk = min(K, 512)
    else:
        bk = K // G  # one scale group per k-step
    assert K % bk == 0 and bk % per == 0, (K, bk, per)
    nk = K // bk
    bm = min(bm, M)
    bn = min(bn, N)
    assert M % bm == 0 and N % bn == 0, (M, bm, N, bn)

    grid = (M // bm, N // bn, nk)
    return pl.pallas_call(
        functools.partial(_qmatmul_kernel, bits=bits, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk // per, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, bn), lambda i, j, k: (k if G > 1 else 0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, w_packed, scales)
