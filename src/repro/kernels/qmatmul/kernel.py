"""Packed-int weight dequant-matmul Pallas TPU kernels.

The serving GEMMs for BRECQ-quantized models: weights live in HBM as
packed int2/int4/int8 codes (offset-binary, packed along the reduction
axis) with per-group scales; the kernels stream (bk, bn) weight tiles
into VMEM, unpack + dequantize in-register, and accumulate on the MXU
in f32. Three entry points, one per serving tier (see ``ops.qmm``):

  qmatmul          prefill GEMM — grid over (M, N, K) tiles
  qgemv            decode GEMV — M = batch rows (<= 8), no M grid and no
                   M padding; scale-major k loop (scales applied to the
                   (M, bn) partial sum, not the (bk, bn) weight tile)
  qmatmul_grouped  stacked MoE experts — qgemv's schedule with a leading
                   expert grid dim consuming (E, K/per, N) nodes directly

Tiling (VMEM working set per step, defaults bm=bn=128, bk=group):
  x tile      (bm, bk)            bf16/f32
  w tile      (bk/per, bn) int8   <- 8/bits codes per byte
  scale tile  (1, bn)             one group per k-step (bk == group_size)
  acc scratch (bm, bn) f32

Constraint: group_size == bk (one scale row per k-tile), or per-channel
scales (scales shape (1, N)). MXU dims stay multiples of 128 on the
prefill tier; the decode tiers keep M at the true row count.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..spec import (describe_qgemv, describe_qmatmul,
                    describe_qmatmul_grouped)

Array = jax.Array


def _unpack_tile(wp: Array, bits: int) -> Array:
    """(bk/per, bn) int8 -> (bk, bn) f32 centred codes.

    All integer work stays in int8: the arithmetic right shift
    sign-extends, but ``& mask`` keeps only the low ``bits`` bits, which
    match the logical-shift result whenever shift+bits <= 8 — so no
    widening to int32 and no unsigned view are needed. One broadcasted
    shift replaces the per-field temporaries + stack, leaving a single
    reshape to interleave the ``per`` fields along the k axis.
    """
    if bits == 8:
        return wp.astype(jnp.float32)
    per = 8 // bits
    mask = jnp.int8((1 << bits) - 1)
    # iota (not a captured constant: Pallas kernels must build arrays
    # in-kernel) gives the per-field shift amounts 0, bits, 2*bits, ...
    shifts = (jax.lax.broadcasted_iota(jnp.int32, (1, per, 1), 1)
              .astype(jnp.int8) * jnp.int8(bits))
    fields = (wp[:, None, :] >> shifts) & mask  # (bk/per, per, bn)
    codes = fields.reshape(wp.shape[0] * per, wp.shape[1]) - jnp.int8(2 ** (bits - 1))
    return codes.astype(jnp.float32)


def _qmatmul_kernel(x_ref, w_ref, s_ref, o_ref, acc_ref, *, bits: int, nk: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    codes = _unpack_tile(w_ref[...], bits)  # (bk, bn)
    w = codes * s_ref[...].astype(jnp.float32)  # scale row broadcasts
    acc_ref[...] += jax.lax.dot(
        x_ref[...].astype(jnp.float32), w,
        preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bits", "bm", "bn", "interpret"))
def qmatmul(x: Array, w_packed: Array, scales: Array, *, bits: int,
            bm: int = 128, bn: int = 128, interpret: bool = False) -> Array:
    """x (M, K) @ dequant(w_packed (K/per, N), scales (K/G, N)) -> (M, N).

    Tile-math violations raise :class:`~repro.kernels.spec.KernelSpecError`
    with the offending shapes named (see ``spec.describe_qmatmul``).
    """
    M, K = x.shape
    N = w_packed.shape[1]
    G = scales.shape[0]
    bm = min(bm, M)
    bn = min(bn, N)
    sp = describe_qmatmul(x.shape, w_packed.shape, scales.shape, bits=bits,
                          bm=bm, bn=bn, x_bytes=x.dtype.itemsize)
    per, bk, nk = sp.meta["per"], sp.meta["bk"], sp.meta["nk"]

    grid = sp.grid
    return pl.pallas_call(
        functools.partial(_qmatmul_kernel, bits=bits, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk // per, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, bn), lambda i, j, k: (k if G > 1 else 0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, w_packed, scales)


def _qgemv_kernel(x_ref, w_ref, s_ref, o_ref, acc_ref, *, bits: int, nk: int):
    """Decode GEMV step: dot raw codes, then scale the (M, bn) partial.

    The scale row is uniform within the k-step's group, so
    ``(x @ (codes * s)) == (x @ codes) * s`` exactly — applying it after
    the dot turns bk*bn dequant multiplies into M*bn (M <= 8), and the
    f32 dequantized weight tile never exists, in VMEM or HBM.
    """
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    codes = _unpack_tile(w_ref[...], bits)  # (bk, bn), centred f32 codes
    part = jax.lax.dot(x_ref[...].astype(jnp.float32), codes,
                       preferred_element_type=jnp.float32)
    acc_ref[...] += part * s_ref[...].astype(jnp.float32)

    @pl.when(k == nk - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bits", "bn", "interpret"))
def qgemv(x: Array, w_packed: Array, scales: Array, *, bits: int,
          bn: int = 128, interpret: bool = False) -> Array:
    """Decode-shaped x (M, K) @ dequant(w_packed, scales) -> (M, N).

    M is the decode batch (a handful of rows): the whole M extent is one
    skinny block — no M grid dim and no zero-row padding to the 8/128
    sublane tile. The grid is (N tiles, k steps) with k innermost
    ("scale-major": the k-loop walks scale groups while the (M, bn)
    accumulator stays resident in VMEM), and each step applies its scale
    row to the partial sum instead of the weight tile.
    """
    M, K = x.shape
    N = w_packed.shape[1]
    bn = min(bn, N)
    sp = describe_qgemv(x.shape, w_packed.shape, scales.shape, bits=bits,
                        bn=bn, x_bytes=x.dtype.itemsize)
    per, bk, nk = sp.meta["per"], sp.meta["bk"], sp.meta["nk"]
    G = scales.shape[0]

    grid = sp.grid
    return pl.pallas_call(
        functools.partial(_qgemv_kernel, bits=bits, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((M, bk), lambda j, k: (0, k)),
            pl.BlockSpec((bk // per, bn), lambda j, k: (k, j)),
            pl.BlockSpec((1, bn), lambda j, k: (k if G > 1 else 0, j)),
        ],
        out_specs=pl.BlockSpec((M, bn), lambda j, k: (0, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        scratch_shapes=[pltpu.VMEM((M, bn), jnp.float32)],
        interpret=interpret,
    )(x, w_packed, scales)


def _qmm_grouped_kernel(x_ref, w_ref, s_ref, o_ref, acc_ref, *, bits: int,
                        nk: int):
    k = pl.program_id(3)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    codes = _unpack_tile(w_ref[0], bits)  # (bk, bn)
    part = jax.lax.dot(x_ref[0].astype(jnp.float32), codes,
                       preferred_element_type=jnp.float32)
    acc_ref[...] += part * s_ref[0].astype(jnp.float32)

    @pl.when(k == nk - 1)
    def _done():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bits", "bm", "bn", "interpret"))
def qmatmul_grouped(x: Array, w_packed: Array, scales: Array, *, bits: int,
                    bm: int = 128, bn: int = 128,
                    interpret: bool = False) -> Array:
    """Grouped expert GEMM: x (E, M, K) @ dequant((E, K/per, N)) -> (E, M, N).

    The expert dim is the leading (outermost) grid axis, so each
    expert's packed codes stream through VMEM exactly once per call —
    the stacked node is consumed directly and no (E, K, N) dequantized
    copy ever exists. Per-expert scheduling and the scale-after-dot
    trick match :func:`qgemv`; M (tokens routed per expert) keeps the
    true row count when it is at most one sublane tile.
    """
    E, M, K = x.shape
    N = w_packed.shape[2]
    bm = min(bm, M)
    bn = min(bn, N)
    sp = describe_qmatmul_grouped(x.shape, w_packed.shape, scales.shape,
                                  bits=bits, bm=bm, bn=bn,
                                  x_bytes=x.dtype.itemsize)
    per, bk, nk = sp.meta["per"], sp.meta["bk"], sp.meta["nk"]
    G = scales.shape[1]

    grid = sp.grid
    return pl.pallas_call(
        functools.partial(_qmm_grouped_kernel, bits=bits, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bm, bk), lambda e, i, j, k: (e, i, k)),
            pl.BlockSpec((1, bk // per, bn), lambda e, i, j, k: (e, k, j)),
            pl.BlockSpec((1, 1, bn),
                         lambda e, i, j, k: (e, k if G > 1 else 0, j)),
        ],
        out_specs=pl.BlockSpec((1, bm, bn), lambda e, i, j, k: (e, i, j)),
        out_shape=jax.ShapeDtypeStruct((E, M, N), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, w_packed, scales)
