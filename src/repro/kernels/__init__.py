"""Pallas TPU kernels for the quantized deployment path.

Each kernel lives in its own subpackage:
  qmatmul/   packed int2/int4/int8 weight dequant-matmul (the serving GEMM)
  kvattn/    decode attention over an int8-quantized KV cache
  fakequant/ fused AdaRound forward (calibration hot loop)

Layout per subpackage: kernel.py (pl.pallas_call + BlockSpec), ops.py
(jit'd public wrapper with interpret/XLA fallbacks), ref.py (pure-jnp
oracle used by the allclose sweeps in tests/).

``spec.py`` is the shared static layer: per-kernel ``describe_*``
functions validate a launch's tile math (raising
:class:`~repro.kernels.spec.KernelSpecError` with the offending shapes
named) and return grid/block/VMEM descriptions that
``repro.analysis.audit.kernel_check`` sweeps without a device.
"""
from .spec import KernelSpec, KernelSpecError  # noqa: F401
