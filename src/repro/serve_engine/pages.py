"""Host-side KV page allocator with per-owner refcounts.

The device side never frees anything — pools are fixed buffers and a
page is "freed" by the host dropping its id back into the free list.
Correctness therefore hangs on this allocator's bookkeeping, which is
why it refcounts: the no-leak invariant the scheduler tests pin is
``pages_in_use == 0`` (and every refcount gone) after all requests
finish or are cancelled.

Page 0 is reserved at construction as the *write sink*: device-side
appends from inactive slots / padded chunk tails are clamped onto it
(see ``models.common._page_rows``), so it is never handed to a stream
and its contents are never read.
"""
from __future__ import annotations


class PagePoolExhausted(RuntimeError):
    """The pool cannot satisfy a reservation or allocation.

    Typed so the scheduler can catch exactly this condition (and
    preempt a victim stream under overcommit) without masking real
    bookkeeping bugs behind a bare ``RuntimeError``.
    """


class PagePool:
    """Free-list allocator over ``num_pages`` KV pages.

    Pages are owned by request uids; :meth:`free_owner` releases
    everything a request holds, so cancel/finish paths cannot
    half-release. ``reserve`` implements admission control: under
    ``overcommit='none'`` a request is only admitted when its
    worst-case page need (prompt + max_new tokens) is covered, so
    decode can never hit pool exhaustion mid-stream. Under overcommit
    the engine reserves less up front and grows the reservation
    just-in-time via :meth:`add_reservation`; a ``False`` return there
    is the signal that triggers preemption.
    """

    def __init__(self, num_pages: int):
        if num_pages < 2:
            raise ValueError("need at least 2 pages (page 0 is the write sink)")
        self.num_pages = num_pages
        self._free = list(range(num_pages - 1, 0, -1))  # pop() -> lowest id
        self._owner_pages: dict[object, list[int]] = {}
        self._reserved: dict[object, int] = {}

    # -- capacity ---------------------------------------------------------

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def pages_in_use(self) -> int:
        return sum(len(v) for v in self._owner_pages.values())

    @property
    def reserved_pages(self) -> int:
        return sum(self._reserved.values())

    def available(self) -> int:
        """Pages neither allocated nor promised to an admitted request."""
        return self.free_pages - self.reserved_pages

    # -- reservations (admission control) ---------------------------------

    def can_reserve(self, n: int) -> bool:
        return self.available() >= n

    def reserve(self, owner, n: int) -> None:
        if owner in self._reserved or owner in self._owner_pages:
            raise ValueError(f"owner {owner!r} already admitted")
        if not self.can_reserve(n):
            raise PagePoolExhausted(
                f"page pool exhausted: want {n}, available {self.available()}")
        self._reserved[owner] = n
        self._owner_pages[owner] = []

    def add_reservation(self, owner, n: int = 1) -> bool:
        """Grow an admitted owner's reservation by ``n`` pages.

        Returns False (without changing anything) when the pool has no
        unpromised pages left — the caller decides what gives way.
        """
        if owner not in self._owner_pages:
            raise ValueError(f"owner {owner!r} not admitted")
        if self.available() < n:
            return False
        self._reserved[owner] = self._reserved.get(owner, 0) + n
        return True

    def reserved_for(self, owner) -> int:
        """Unspent reservation (pages promised but not yet allocated)."""
        return self._reserved.get(owner, 0)

    # -- allocation --------------------------------------------------------

    def alloc(self, owner) -> int:
        """Take one page against ``owner``'s reservation."""
        if self._reserved.get(owner, 0) <= 0:
            raise PagePoolExhausted(
                f"owner {owner!r} has no reservation left")
        if not self._free:
            raise PagePoolExhausted(
                "free list empty with reservations outstanding — "
                "reservation accounting is corrupt")
        page = self._free.pop()
        self._reserved[owner] -= 1
        self._owner_pages[owner].append(page)
        return page

    def owned(self, owner) -> list[int]:
        return list(self._owner_pages.get(owner, ()))

    def refcount(self, owner) -> int:
        return len(self._owner_pages.get(owner, ()))

    def free_owner(self, owner) -> list[int]:
        """Release every page and any unspent reservation of ``owner``.

        Returns the freed page ids (the engine zeroes their block-table
        entries). Idempotent: freeing an unknown owner is a no-op.
        """
        pages = self._owner_pages.pop(owner, [])
        self._reserved.pop(owner, None)
        for p in pages:
            self._free.append(p)
        return pages

    def check_no_leaks(self) -> None:
        """Assert the pool is back to its pristine state."""
        if self._owner_pages or self._reserved:
            raise AssertionError(
                f"leaked pages: owners={ {k: len(v) for k, v in self._owner_pages.items()} } "
                f"reservations={dict(self._reserved)}")
        if len(self._free) != self.num_pages - 1:
            raise AssertionError(
                f"free list has {len(self._free)} pages, expected {self.num_pages - 1}")
