"""Continuous-batching scheduler: slots, chunked prefill, paged decode.

The engine owns ``num_slots`` decode slots and one paged KV pool
(``models.LM.init_paged_cache``). A tick is: admit waiting requests
into free slots (reserving their worst-case page need up front, so
decode can never hit pool exhaustion mid-stream), advance ONE
prefilling stream by one chunk (round-robin — keeps time-to-first-token
bounded without starving decode), then run one batched decode step over
every decoding slot. Two compiled programs cover everything: a
(num_slots, 1) decode step and a (1, prefill_chunk) prefill step, both
the same ``decode_step`` cached path — chunked prefill *is* multi-token
decode.

Scheduling is host-side Python over numpy block tables; the device sees
fixed-shape programs and a traced block table, so slot churn never
recompiles. Inactive slots decode a dummy token against an all--1 block
table row, which routes their KV writes to the reserved sink page (see
``models.common``). Outputs are greedy argmax — the engine serves
deterministic synthetic traffic for benchmarks and tests.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models.common import NO_QUANT, PAGED_KV_DTYPES
from .pages import PagePool

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    num_slots: int = 8
    page_size: int = 16
    num_pages: int = 257          # includes the reserved sink page 0
    max_len: int = 256            # hard cap on prompt + generated per stream
    prefill_chunk: int = 32
    kv_dtype: str = "int8"        # member of models.common.PAGED_KV_DTYPES
    backend: str = "auto"         # kvattn backend for the int8 decode read
    record_logits: bool = False   # keep per-step decode logits (tests only)

    @property
    def max_pages_per_stream(self) -> int:
        return -(-self.max_len // self.page_size)

    def __post_init__(self):
        if self.kv_dtype not in PAGED_KV_DTYPES:
            raise ValueError(f"kv_dtype {self.kv_dtype!r} not in {PAGED_KV_DTYPES}")
        if self.num_pages < 2:
            raise ValueError("num_pages must be >= 2 (page 0 is the sink)")


# request lifecycle: waiting -> prefill -> decode -> done | cancelled
@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray
    max_new: int
    state: str = "waiting"
    slot: int = -1
    prefill_off: int = 0
    generated: list = dataclasses.field(default_factory=list)
    logits: list = dataclasses.field(default_factory=list)


RequestState = ("waiting", "prefill", "decode", "done", "cancelled")


class ServeEngine:
    """Request-level serving over one model + weight set.

    ``quant`` is the artifact's :class:`QuantHook` (weights stay packed
    int codes through every linear); ``NO_QUANT`` serves FP weights.
    """

    def __init__(self, model, params, cfg: EngineConfig = EngineConfig(), *,
                 quant=NO_QUANT):
        self.model = model
        self.params = params
        self.cfg = cfg
        self.cache = model.init_paged_cache(cfg.num_pages, cfg.page_size,
                                            cfg.kv_dtype)
        self.pool = PagePool(cfg.num_pages)
        self.block_tables = np.full(
            (cfg.num_slots, cfg.max_pages_per_stream), -1, np.int32)
        self.slot_req: list[Optional[Request]] = [None] * cfg.num_slots
        self.waiting: deque[Request] = deque()
        self.requests: dict[int, Request] = {}
        self.events: list[tuple[int, str, int]] = []
        self.tick = 0
        self._uid = 0
        self._pf_ptr = 0
        self._decode_ticks = 0
        self.decode_tick_log: list[int] = []  # tick ids that ran a decode step
        self._tokens_generated = 0
        self._occupancy: list[float] = []
        self._resident: list[float] = []
        self._peak_pages = 0
        self._wall_s = 0.0
        self._compile_s: Optional[float] = None
        # whole-model KV bytes per page: every pool leaf is
        # (stack_n, num_pages, page_size, ...), so nbytes/num_pages sums
        # one page's footprint across all layers (scales included)
        self.bytes_per_page = sum(
            leaf.nbytes // cfg.num_pages
            for leaf in jax.tree.leaves(self.cache))

        ps, backend = cfg.page_size, cfg.backend

        def extras(bt):
            return {"paged": {"block_tables": bt, "page_size": ps,
                              "backend": backend}}

        def decode_fn(params, tokens, cache, pos, bt):
            return model.decode_step(params, tokens, cache, pos, quant,
                                     extras=extras(bt))

        def chunk_fn(params, tokens, cache, pos, bt):
            return model.decode_step(params, tokens, cache, pos, quant,
                                     extras=extras(bt), all_logits=True)

        self._decode_jit = jax.jit(decode_fn)
        self._chunk_jit = jax.jit(chunk_fn)
        self._decode_c = self._chunk_c = None

    @classmethod
    def from_artifact(cls, artifact_dir: str, *, arch: Optional[str] = None,
                      reduced: bool = False,
                      cfg: Optional[EngineConfig] = None) -> "ServeEngine":
        """Build an engine from a saved artifact directory.

        The load verifies schema + per-leaf checksums first, so a
        corrupted artifact raises ``ArtifactCorruptionError`` before any
        engine state exists — no slot is ever admitted against damaged
        weights. KV dtype / page size default from the manifest (written
        at export) when ``cfg`` is not given.
        """
        from ..deploy import QuantizedArtifact
        from ..models import get_model

        artifact = QuantizedArtifact.load(artifact_dir, verify=True)
        m = artifact.manifest
        if cfg is None:
            cfg = EngineConfig(kv_dtype=m.get("kv_dtype", "int8"),
                               page_size=int(m.get("kv_page_size", 16)))
        _, model = get_model(arch or m["arch"], reduced=reduced)
        return cls(model, artifact.params, cfg, quant=artifact.hook())

    # -- request surface ---------------------------------------------------

    def submit(self, prompt, max_new: int, uid: Optional[int] = None) -> int:
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if max_new < 1:
            raise ValueError("max_new must be >= 1")
        if len(prompt) + max_new > self.cfg.max_len:
            raise ValueError(
                f"prompt {len(prompt)} + max_new {max_new} exceeds "
                f"max_len {self.cfg.max_len}")
        if uid is None:
            uid = self._uid
        self._uid = max(self._uid, uid) + 1
        req = Request(uid, prompt, max_new)
        self.requests[uid] = req
        self.waiting.append(req)
        self._log("submit", uid)
        return uid

    def cancel(self, uid: int) -> bool:
        """Abort a request; its pages return to the pool immediately."""
        req = self.requests.get(uid)
        if req is None or req.state in ("done", "cancelled"):
            return False
        if req.state == "waiting":
            self.waiting.remove(req)
        else:
            self._release(req)
        req.state = "cancelled"
        self._log("cancel", uid)
        return True

    def pending(self) -> bool:
        return bool(self.waiting) or any(r is not None for r in self.slot_req)

    # -- scheduler tick ----------------------------------------------------

    def step(self) -> bool:
        """One tick: admit, one prefill chunk, one batched decode step."""
        self._ensure_compiled()
        t0 = time.time()
        self._admit()
        did = self._prefill_one()
        did = self._decode_all() or did
        self._peak_pages = max(self._peak_pages, self.pool.pages_in_use)
        self.tick += 1
        self._wall_s += time.time() - t0
        return did or self.pending()

    def run(self, max_ticks: Optional[int] = None) -> dict:
        """Tick until every submitted request finishes; returns metrics."""
        limit = self.tick + max_ticks if max_ticks is not None else None
        while self.pending() and (limit is None or self.tick < limit):
            self.step()
        if self.pending():
            raise RuntimeError(f"run() hit max_ticks={max_ticks} with "
                               f"requests still pending")
        return self.metrics()

    def compile(self) -> float:
        """AOT-compile both device programs; returns compile seconds.
        Called lazily by step() — call it up front to keep compile out
        of measured serving walls."""
        if self._compile_s is None:
            cfg = self.cfg
            t0 = time.time()
            bt = jnp.asarray(self.block_tables)
            tok = jnp.zeros((cfg.num_slots, 1), jnp.int32)
            pos = jnp.zeros((cfg.num_slots,), jnp.int32)
            self._decode_c = self._decode_jit.lower(
                self.params, tok, self.cache, pos, bt).compile()
            tokc = jnp.zeros((1, cfg.prefill_chunk), jnp.int32)
            self._chunk_c = self._chunk_jit.lower(
                self.params, tokc, self.cache, pos[:1], bt[:1]).compile()
            self._compile_s = time.time() - t0
        return self._compile_s

    # -- invariants / metrics ----------------------------------------------

    def assert_no_leaks(self) -> None:
        """Every page refcount back to zero and every block table clear."""
        self.pool.check_no_leaks()
        if (self.block_tables != -1).any():
            raise AssertionError("block table rows not cleared after release")

    def metrics(self) -> dict:
        toks = self._tokens_generated
        return {
            "ticks": self.tick,
            "decode_ticks": self._decode_ticks,
            "tokens_generated": toks,
            "wall_s": self._wall_s,
            "compile_s": self._compile_s or 0.0,
            "sustained_tok_s": toks / self._wall_s if self._wall_s else 0.0,
            "mean_slot_occupancy": (float(np.mean(self._occupancy))
                                    if self._occupancy else 0.0),
            "bytes_per_page": self.bytes_per_page,
            "peak_pages_in_use": self._peak_pages,
            "mean_resident_kv_bytes_per_stream": (
                float(np.mean(self._resident)) if self._resident else 0.0),
            "kv_dtype": self.cfg.kv_dtype,
            "page_size": self.cfg.page_size,
            "num_slots": self.cfg.num_slots,
        }

    # -- internals ---------------------------------------------------------

    def _log(self, event: str, uid: int) -> None:
        self.events.append((self.tick, event, uid))

    def _pages_for(self, n_tokens: int) -> int:
        return -(-n_tokens // self.cfg.page_size)

    def _admit(self) -> None:
        free = [s for s in range(self.cfg.num_slots) if self.slot_req[s] is None]
        while self.waiting and free:
            req = self.waiting[0]
            need = self._pages_for(len(req.prompt) + req.max_new)
            if not self.pool.can_reserve(need):
                break  # head-of-line: preserve FIFO completion order
            self.waiting.popleft()
            self.pool.reserve(req.uid, need)
            req.slot = free.pop(0)
            self.slot_req[req.slot] = req
            req.state = "prefill"
            self._log("admit", req.uid)

    def _release(self, req: Request) -> None:
        self.pool.free_owner(req.uid)
        if req.slot >= 0:
            self.block_tables[req.slot, :] = -1
            self.slot_req[req.slot] = None
            req.slot = -1

    def _ensure_pages(self, req: Request, last_pos: int) -> None:
        """Lazily allocate pages to cover positions [0, last_pos]."""
        need = last_pos // self.cfg.page_size + 1
        while self.pool.refcount(req.uid) < need:
            n = self.pool.refcount(req.uid)
            self.block_tables[req.slot, n] = self.pool.alloc(req.uid)

    def _ensure_compiled(self) -> None:
        if self._decode_c is None:
            self.compile()

    def _prefill_one(self) -> bool:
        ns = self.cfg.num_slots
        for i in range(ns):
            s = (self._pf_ptr + i) % ns
            req = self.slot_req[s]
            if req is not None and req.state == "prefill":
                self._pf_ptr = (s + 1) % ns
                self._prefill_chunk(req)
                return True
        return False

    def _prefill_chunk(self, req: Request) -> None:
        C = self.cfg.prefill_chunk
        off = req.prefill_off
        chunk = req.prompt[off:off + C]
        n_real = len(chunk)
        if n_real < C:  # ragged tail: pads write to the sink / dead rows
            chunk = np.pad(chunk, (0, C - n_real))
        self._ensure_pages(req, off + n_real - 1)
        s = req.slot
        logits, self.cache = self._chunk_c(
            self.params, jnp.asarray(chunk[None]), self.cache,
            jnp.full((1,), off, jnp.int32),
            jnp.asarray(self.block_tables[s:s + 1]))
        req.prefill_off = off + n_real
        self._log("prefill_chunk", req.uid)
        if req.prefill_off >= len(req.prompt):
            lg = np.asarray(logits[0, n_real - 1])
            req.generated.append(int(lg.argmax()))
            if self.cfg.record_logits:
                req.logits.append(lg)
            req.state = "decode"
            self._tokens_generated += 1
            self._log("first_token", req.uid)
            self._maybe_finish(req)

    def _decode_all(self) -> bool:
        cfg = self.cfg
        decoding = [s for s in range(cfg.num_slots)
                    if self.slot_req[s] is not None
                    and self.slot_req[s].state == "decode"]
        if not decoding:
            return False
        tokens = np.zeros((cfg.num_slots, 1), np.int32)
        pos = np.zeros((cfg.num_slots,), np.int32)
        # non-decoding slots get an all--1 block table row so their dummy
        # writes land on the sink page instead of a prefilling stream's KV
        bt = np.full_like(self.block_tables, -1)
        for s in decoding:
            req = self.slot_req[s]
            pos[s] = len(req.prompt) + len(req.generated) - 1
            tokens[s, 0] = req.generated[-1]
            self._ensure_pages(req, int(pos[s]))
            bt[s] = self.block_tables[s]
        logits, self.cache = self._decode_c(
            self.params, jnp.asarray(tokens), self.cache,
            jnp.asarray(pos), jnp.asarray(bt))
        lg = np.asarray(logits)
        for s in decoding:
            req = self.slot_req[s]
            req.generated.append(int(lg[s].argmax()))
            if self.cfg.record_logits:
                req.logits.append(lg[s])
            self._maybe_finish(req)
        self._decode_ticks += 1
        self.decode_tick_log.append(self.tick)
        self._tokens_generated += len(decoding)
        self._occupancy.append(len(decoding) / cfg.num_slots)
        active = sum(r is not None for r in self.slot_req)
        if active:
            self._resident.append(
                self.pool.pages_in_use * self.bytes_per_page / active)
        return True

    def _maybe_finish(self, req: Request) -> None:
        if len(req.generated) >= req.max_new:
            self._release(req)
            req.state = "done"
            self._log("finish", req.uid)
