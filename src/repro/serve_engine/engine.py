"""Continuous-batching scheduler: slots, chunked prefill, paged decode.

The engine owns ``num_slots`` decode slots and one paged KV pool
(``models.LM.init_paged_cache``). A tick is: expire overdue requests,
admit waiting requests into free slots, advance ONE prefilling stream
by one chunk (round-robin — keeps time-to-first-token bounded without
starving decode), then run one batched decode step over every decoding
slot. Two compiled programs cover everything: a (num_slots, 1) decode
step and a (1, prefill_chunk) prefill step, both the same
``decode_step`` cached path — chunked prefill *is* multi-token decode.

Admission is governed by ``EngineConfig.overcommit``:

* ``'none'`` (reference) reserves the worst-case page need
  (``prompt + max_new``) up front, so decode can never hit pool
  exhaustion mid-stream — but most of the pool sits promised-and-empty
  under load.
* ``'prompt'`` reserves only the prompt's pages plus
  ``overcommit_headroom``; decode grows the reservation just-in-time.
  When the pool has nothing left to promise, the scheduler **preempts**
  a victim stream (lowest priority, newest admission): its pages are
  freed and it is re-queued for re-prefill of ``prompt + generated``.
  Greedy decode is deterministic and chunked prefill is the same
  compiled path that built the KV the first time, so a preempted
  stream's final tokens are bit-identical to an unpreempted run.

Scheduling is host-side Python over numpy block tables; the device sees
fixed-shape programs and a traced block table, so slot churn never
recompiles. Inactive slots decode a dummy token against an all--1 block
table row, which routes their KV writes to the reserved sink page (see
``models.common``). Outputs are greedy argmax — the engine serves
deterministic synthetic traffic for benchmarks and tests.

Faults are isolated per stream: a non-finite logit row fails only that
request (state ``failed``); everything else in the batch continues.
``drain()`` is the graceful way out — stop admission, finish (or
preempt-and-report) in-flight work, return per-request statuses.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..launch.watchdog import StepWatchdog
from ..models.common import NO_QUANT, PAGED_KV_DTYPES
from .pages import PagePool, PagePoolExhausted

Array = jax.Array

OVERCOMMIT_MODES = ("none", "prompt")


class RequestRejected(ValueError):
    """``submit()`` refused a request. ``reason`` is a stable slug that
    also lands in ``engine.events`` as a ``reject:<reason>`` entry."""

    def __init__(self, msg: str, *, reason: str, uid: Optional[int] = None):
        super().__init__(msg)
        self.reason = reason
        self.uid = uid


class EngineStalledError(RuntimeError):
    """``run(max_ticks=)`` expired with requests still pending.

    Completed work is NOT thrown away: the error carries the engine
    ``metrics`` snapshot and the per-request ``states`` map so a caller
    can harvest every finished stream before deciding what to do.
    """

    def __init__(self, max_ticks, metrics: dict, states: dict):
        self.metrics = metrics
        self.states = states
        stuck = sorted(u for u, s in states.items() if s in ACTIVE_STATES)
        super().__init__(
            f"run() hit max_ticks={max_ticks} with requests still pending "
            f"(uids {stuck}); .metrics and .states carry the completed work")


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    num_slots: int = 8
    page_size: int = 16
    num_pages: int = 257          # includes the reserved sink page 0
    max_len: int = 256            # hard cap on prompt + generated per stream
    prefill_chunk: int = 32
    kv_dtype: str = "int8"        # member of models.common.PAGED_KV_DTYPES
    backend: str = "auto"         # kvattn backend for the int8 decode read
    record_logits: bool = False   # keep per-step decode logits (tests only)
    overcommit: str = "none"      # 'none' (worst-case reserve) | 'prompt'
    overcommit_headroom: int = 1  # pages reserved beyond the prompt

    @property
    def max_pages_per_stream(self) -> int:
        return -(-self.max_len // self.page_size)

    @property
    def program_shape(self) -> tuple:
        """The fields the two compiled device programs depend on.
        Scheduler policy (overcommit, headroom, record_logits) is
        host-side only — engines differing just there can share
        compiled programs (see ``ServeEngine`` ``share_compiled``)."""
        return (self.num_slots, self.page_size, self.num_pages,
                self.max_len, self.prefill_chunk, self.kv_dtype,
                self.backend)

    def __post_init__(self):
        if self.kv_dtype not in PAGED_KV_DTYPES:
            raise ValueError(f"kv_dtype {self.kv_dtype!r} not in {PAGED_KV_DTYPES}")
        if self.num_pages < 2:
            raise ValueError("num_pages must be >= 2 (page 0 is the sink)")
        if self.overcommit not in OVERCOMMIT_MODES:
            raise ValueError(
                f"overcommit {self.overcommit!r} not in {OVERCOMMIT_MODES}")
        if self.overcommit_headroom < 0:
            raise ValueError("overcommit_headroom must be >= 0")


# request lifecycle:
#   waiting -> prefill -> decode -> done
#                  |          |--> cancelled | expired | failed
#                  +----------+--> (preempted) -> waiting   [pages freed,
#                                  re-prefill of prompt+generated resumes
#                                  bit-exact]
@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray
    max_new: int
    priority: int = 0                      # higher survives preemption longer
    deadline_tick: Optional[int] = None    # absolute tick; None = no deadline
    state: str = "waiting"
    slot: int = -1
    prefill_off: int = 0
    admit_seq: int = -1                    # admission order (newest = victim)
    preemptions: int = 0
    error: Optional[str] = None            # set when state == 'failed'
    generated: list = dataclasses.field(default_factory=list)
    logits: list = dataclasses.field(default_factory=list)
    # tokens the current prefill pass feeds: the prompt, or — after a
    # preemption — prompt + generated[:-1], rebuilding the exact KV the
    # stream held so decode resumes by feeding generated[-1]
    prefill_src: Optional[np.ndarray] = None


RequestState = ("waiting", "prefill", "decode", "done", "cancelled",
                "expired", "failed")
ACTIVE_STATES = ("waiting", "prefill", "decode")
TERMINAL_STATES = ("done", "cancelled", "expired", "failed")


class ServeEngine:
    """Request-level serving over one model + weight set.

    ``quant`` is the artifact's :class:`QuantHook` (weights stay packed
    int codes through every linear); ``NO_QUANT`` serves FP weights.

    ``share_compiled`` is a test/bench convenience: another engine with
    the *same* model, quant hook and program shape
    (``EngineConfig.program_shape`` — scheduler policy may differ)
    whose two AOT programs are reused instead of re-lowered (the
    programs close over none of the per-engine state — params, cache
    and block tables are arguments).
    """

    def __init__(self, model, params, cfg: EngineConfig = EngineConfig(), *,
                 quant=NO_QUANT, share_compiled: "ServeEngine" = None):
        self.model = model
        self.params = params
        self.cfg = cfg
        self.cache = model.init_paged_cache(cfg.num_pages, cfg.page_size,
                                            cfg.kv_dtype)
        self.pool = PagePool(cfg.num_pages)
        self.block_tables = np.full(
            (cfg.num_slots, cfg.max_pages_per_stream), -1, np.int32)
        self.slot_req: list[Optional[Request]] = [None] * cfg.num_slots
        self.waiting: deque[Request] = deque()
        self.requests: dict[int, Request] = {}
        self.events: list[tuple[int, str, int]] = []
        self.tick = 0
        self.draining = False
        self._uid = 0
        self._pf_ptr = 0
        self._admit_seq = 0
        self._decode_ticks = 0
        self.decode_tick_log: list[int] = []  # tick ids that ran a decode step
        self._tokens_generated = 0
        self._occupancy: list[float] = []
        self._resident: list[float] = []
        self._peak_pages = 0
        self._wall_s = 0.0
        self._compile_s: Optional[float] = None
        self._preemptions = 0
        self._replay_chunks = 0   # prefill chunks spent rebuilding preempted KV
        self._expired = 0
        self._failed = 0
        self._cancelled = 0
        # per-tick stall detector; notes land in watchdog_notes, counts
        # in metrics()['stragglers']
        self.watchdog_notes: list[str] = []
        self._watchdog = StepWatchdog(log=self.watchdog_notes.append,
                                      label="tick")
        # whole-model KV bytes per page: every pool leaf is
        # (stack_n, num_pages, page_size, ...), so nbytes/num_pages sums
        # one page's footprint across all layers (scales included)
        self.bytes_per_page = sum(
            leaf.nbytes // cfg.num_pages
            for leaf in jax.tree.leaves(self.cache))

        ps, backend = cfg.page_size, cfg.backend

        def extras(bt):
            return {"paged": {"block_tables": bt, "page_size": ps,
                              "backend": backend}}

        def decode_fn(params, tokens, cache, pos, bt):
            return model.decode_step(params, tokens, cache, pos, quant,
                                     extras=extras(bt))

        def chunk_fn(params, tokens, cache, pos, bt):
            return model.decode_step(params, tokens, cache, pos, quant,
                                     extras=extras(bt), all_logits=True)

        self._decode_jit = jax.jit(decode_fn)
        self._chunk_jit = jax.jit(chunk_fn)
        self._decode_c = self._chunk_c = None
        if share_compiled is not None:
            donor = share_compiled
            if donor.cfg.program_shape != cfg.program_shape:
                raise ValueError("share_compiled donor has a different "
                                 "program shape — compiled programs would "
                                 "not match")
            self._decode_jit = donor._decode_jit
            self._chunk_jit = donor._chunk_jit
            self._decode_c = donor._decode_c
            self._chunk_c = donor._chunk_c
            self._compile_s = donor._compile_s

    @classmethod
    def from_artifact(cls, artifact_dir: str, *, arch: Optional[str] = None,
                      reduced: bool = False,
                      cfg: Optional[EngineConfig] = None) -> "ServeEngine":
        """Build an engine from a saved artifact directory.

        The load verifies schema + per-leaf checksums first, so a
        corrupted artifact raises ``ArtifactCorruptionError`` before any
        engine state exists — no slot is ever admitted against damaged
        weights. KV dtype / page size default from the manifest (written
        at export) when ``cfg`` is not given.
        """
        from ..deploy import QuantizedArtifact
        from ..models import get_model

        artifact = QuantizedArtifact.load(artifact_dir, verify=True)
        m = artifact.manifest
        if cfg is None:
            cfg = EngineConfig(kv_dtype=m.get("kv_dtype", "int8"),
                               page_size=int(m.get("kv_page_size", 16)))
        _, model = get_model(arch or m["arch"], reduced=reduced)
        return cls(model, artifact.params, cfg, quant=artifact.hook())

    # -- request surface ---------------------------------------------------

    def submit(self, prompt, max_new: int, uid: Optional[int] = None, *,
               priority: int = 0,
               deadline_ticks: Optional[int] = None) -> int:
        """Queue a request; returns its uid.

        ``priority``: preemption victims are picked lowest-priority
        first (ties: newest admission). ``deadline_ticks``: relative
        deadline — if the request has not finished within that many
        ticks of submission it moves to the terminal ``expired`` state
        and its pages are reclaimed.

        Raises :class:`RequestRejected` (a ``ValueError``) with a
        ``reason`` slug that is also logged to ``events``.
        """
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if uid is None:
            uid = self._uid

        def reject(reason: str, msg: str):
            self._log(f"reject:{reason}", uid)
            raise RequestRejected(msg, reason=reason, uid=uid)

        if self.draining:
            reject("draining", "engine is draining — admission is stopped")
        live = self.requests.get(uid)
        if live is not None and live.state in ACTIVE_STATES:
            reject("duplicate_uid",
                   f"uid {uid} is still live (state {live.state!r}) — "
                   f"resubmitting would orphan it in the scheduler")
        if max_new < 1:
            reject("bad_max_new", "max_new must be >= 1")
        if len(prompt) + max_new > self.cfg.max_len:
            reject("too_long",
                   f"prompt {len(prompt)} + max_new {max_new} exceeds "
                   f"max_len {self.cfg.max_len}")
        if self._pages_for(len(prompt) + max_new) > self.cfg.num_pages - 1:
            reject("exceeds_pool",
                   f"request needs {self._pages_for(len(prompt) + max_new)} "
                   f"pages at worst case but the pool only has "
                   f"{self.cfg.num_pages - 1} — it could never finish")
        if deadline_ticks is not None and deadline_ticks < 1:
            reject("bad_deadline", "deadline_ticks must be >= 1")
        self._uid = max(self._uid, uid) + 1
        req = Request(uid, prompt, max_new, priority=priority,
                      deadline_tick=(None if deadline_ticks is None
                                     else self.tick + int(deadline_ticks)))
        self.requests[uid] = req
        self.waiting.append(req)
        self._log("submit", uid)
        return uid

    def cancel(self, uid: int) -> bool:
        """Abort a request; its pages return to the pool immediately."""
        req = self.requests.get(uid)
        if req is None or req.state in TERMINAL_STATES:
            return False
        if req.state == "waiting":
            self.waiting.remove(req)
        else:
            self._release(req)
        req.state = "cancelled"
        self._cancelled += 1
        self._log("cancel", uid)
        return True

    def pending(self) -> bool:
        return bool(self.waiting) or any(r is not None for r in self.slot_req)

    # -- scheduler tick ----------------------------------------------------

    def step(self) -> bool:
        """One tick: expire, admit, one prefill chunk, one batched decode."""
        self._ensure_compiled()
        self._watchdog.start()
        t0 = time.perf_counter()
        self._expire_deadlines()
        self._admit()
        did = self._prefill_one()
        did = self._decode_all() or did
        self._peak_pages = max(self._peak_pages, self.pool.pages_in_use)
        self._wall_s += time.perf_counter() - t0
        self._watchdog.stop(self.tick)
        self.tick += 1
        return did or self.pending()

    def run(self, max_ticks: Optional[int] = None, *, strict: bool = True,
            shutdown=None) -> dict:
        """Tick until every submitted request finishes; returns metrics.

        ``max_ticks`` bounds the work. If it expires with requests still
        pending, ``strict=True`` raises :class:`EngineStalledError`
        carrying metrics + per-request states (completed work is never
        thrown away); ``strict=False`` returns the metrics dict with
        ``stalled=True`` and the ``states`` map instead.

        ``shutdown``: a ``launch.watchdog.GracefulShutdown`` — when its
        ``requested`` flag flips (SIGTERM/SIGINT), the engine drains
        gracefully and returns metrics with ``drained=True`` + the
        per-request ``states``.
        """
        limit = self.tick + max_ticks if max_ticks is not None else None
        while self.pending() and (limit is None or self.tick < limit):
            if shutdown is not None and shutdown.requested:
                states = self.drain(finish=True)
                m = self.metrics()
                m["drained"] = True
                m["states"] = states
                return m
            self.step()
        if self.pending():
            states = {u: r.state for u, r in self.requests.items()}
            if strict:
                raise EngineStalledError(max_ticks, self.metrics(), states)
            m = self.metrics()
            m["stalled"] = True
            m["states"] = states
            return m
        return self.metrics()

    def drain(self, *, finish: bool = True,
              max_ticks: Optional[int] = None) -> dict:
        """Graceful drain: stop admission, settle in-flight work, report.

        ``finish=True`` keeps ticking until every slotted request
        reaches a terminal state (bounded by each stream's ``max_new``,
        or by ``max_ticks``); ``finish=False`` preempts in-flight
        streams immediately. Either way no pages stay allocated — still-
        unfinished streams end ``waiting`` (pages freed, resumable) and
        ``assert_no_leaks()`` passes. Returns ``{uid: state}`` for every
        request the engine has seen. Idempotent.
        """
        self.draining = True
        self._log("drain", -1)
        if finish:
            limit = self.tick + max_ticks if max_ticks is not None else None
            while (any(r is not None for r in self.slot_req)
                   and (limit is None or self.tick < limit)):
                self.step()
        for req in list(self.slot_req):
            if req is not None:
                self._preempt(req)
        return {u: r.state for u, r in self.requests.items()}

    def compile(self) -> float:
        """AOT-compile both device programs; returns compile seconds.
        Called lazily by step() — call it up front to keep compile out
        of measured serving walls."""
        if self._compile_s is None:
            cfg = self.cfg
            t0 = time.perf_counter()
            bt = jnp.asarray(self.block_tables)
            tok = jnp.zeros((cfg.num_slots, 1), jnp.int32)
            pos = jnp.zeros((cfg.num_slots,), jnp.int32)
            self._decode_c = self._decode_jit.lower(
                self.params, tok, self.cache, pos, bt).compile()
            tokc = jnp.zeros((1, cfg.prefill_chunk), jnp.int32)
            self._chunk_c = self._chunk_jit.lower(
                self.params, tokc, self.cache, pos[:1], bt[:1]).compile()
            self._compile_s = time.perf_counter() - t0
        return self._compile_s

    # -- invariants / metrics ----------------------------------------------

    def assert_no_leaks(self) -> None:
        """Every page refcount back to zero and every block table clear."""
        self.pool.check_no_leaks()
        if (self.block_tables != -1).any():
            raise AssertionError("block table rows not cleared after release")

    def metrics(self) -> dict:
        toks = self._tokens_generated
        return {
            "ticks": self.tick,
            "decode_ticks": self._decode_ticks,
            "tokens_generated": toks,
            "wall_s": self._wall_s,
            "compile_s": self._compile_s or 0.0,
            "sustained_tok_s": toks / self._wall_s if self._wall_s else 0.0,
            "mean_slot_occupancy": (float(np.mean(self._occupancy))
                                    if self._occupancy else 0.0),
            "bytes_per_page": self.bytes_per_page,
            "peak_pages_in_use": self._peak_pages,
            "mean_resident_kv_bytes_per_stream": (
                float(np.mean(self._resident)) if self._resident else 0.0),
            "kv_dtype": self.cfg.kv_dtype,
            "page_size": self.cfg.page_size,
            "num_slots": self.cfg.num_slots,
            "overcommit": self.cfg.overcommit,
            "preemptions": self._preemptions,
            "replay_prefill_chunks": self._replay_chunks,
            "expired": self._expired,
            "failed": self._failed,
            "cancelled": self._cancelled,
            "stragglers": self._watchdog.stragglers,
            "mean_tick_s": self._watchdog.mean or 0.0,
            "draining": self.draining,
        }

    # -- internals ---------------------------------------------------------

    def _log(self, event: str, uid: int) -> None:
        self.events.append((self.tick, event, uid))

    def _pages_for(self, n_tokens: int) -> int:
        return -(-n_tokens // self.cfg.page_size)

    def _admission_need(self, req: Request) -> int:
        """Pages to reserve at admission under the overcommit policy."""
        worst = self._pages_for(len(req.prompt) + req.max_new)
        if self.cfg.overcommit == "none" or req.preemptions:
            # resumed streams reserve pessimistically: re-admitting a
            # victim optimistically just to evict it again burns replay
            # prefill chunks for nothing (admit/evict thrash), so a
            # stream comes back only once it is guaranteed to finish
            return worst
        # 'prompt': what prefill will write, plus a little headroom
        return min(self._pages_for(len(req.prompt))
                   + self.cfg.overcommit_headroom, worst)

    def _expire_deadlines(self) -> None:
        for req in [*self.waiting,
                    *(r for r in self.slot_req if r is not None)]:
            if (req.deadline_tick is not None
                    and self.tick >= req.deadline_tick):
                if req.state == "waiting":
                    self.waiting.remove(req)
                else:
                    self._release(req)
                req.state = "expired"
                self._expired += 1
                self._log("expired", req.uid)

    def _admit(self) -> None:
        if self.draining:
            return
        free = [s for s in range(self.cfg.num_slots) if self.slot_req[s] is None]
        while self.waiting and free:
            req = self.waiting[0]
            need = self._admission_need(req)
            if not self.pool.can_reserve(need):
                break  # head-of-line: preserve FIFO completion order
            self.waiting.popleft()
            self.pool.reserve(req.uid, need)
            req.slot = free.pop(0)
            self.slot_req[req.slot] = req
            req.state = "prefill"
            req.prefill_off = 0
            req.admit_seq = self._admit_seq
            self._admit_seq += 1
            # after a preemption the prefill replays prompt + all-but-the-
            # last generated token, rebuilding the stream's exact KV;
            # decode then resumes by feeding generated[-1]
            req.prefill_src = (
                req.prompt if not req.generated else
                np.concatenate([req.prompt,
                                np.asarray(req.generated[:-1], np.int32)]))
            self._log("admit" if req.preemptions == 0 else "readmit", req.uid)

    def _release(self, req: Request) -> None:
        self.pool.free_owner(req.uid)
        if req.slot >= 0:
            self.block_tables[req.slot, :] = -1
            self.slot_req[req.slot] = None
            req.slot = -1

    def _preempt(self, req: Request) -> None:
        """Evict a slotted stream: free its pages, re-queue it (front —
        it was admitted before anything still waiting) for a bit-exact
        re-prefill resume."""
        self._release(req)
        req.state = "waiting"
        req.prefill_off = 0
        req.preemptions += 1
        self._preemptions += 1
        self.waiting.appendleft(req)
        self._log("preempt", req.uid)

    def _preempt_for(self, req: Request) -> bool:
        """Pick and evict a victim so ``req`` can take a page. Lowest
        priority first, newest admission among equals; ``req`` itself is
        never a candidate. False when no victim exists."""
        cands = [r for r in self.slot_req if r is not None and r is not req]
        if not cands:
            return False
        victim = min(cands, key=lambda r: (r.priority, -r.admit_seq))
        self._preempt(victim)
        return True

    def _fail(self, req: Request, reason: str) -> None:
        """Per-stream fault isolation: only this request dies."""
        self._release(req)
        req.state = "failed"
        req.error = reason
        self._failed += 1
        self._log("failed", req.uid)

    def _ensure_pages(self, req: Request, last_pos: int) -> None:
        """Lazily allocate pages to cover positions [0, last_pos].

        Under overcommit the reservation grows just-in-time; when the
        pool has nothing left to promise, a victim stream is preempted
        until it does. A lone stream can always finish: submit() caps
        worst-case need at the pool size."""
        need = last_pos // self.cfg.page_size + 1
        while self.pool.refcount(req.uid) < need:
            if self.pool.reserved_for(req.uid) <= 0:
                while not self.pool.add_reservation(req.uid, 1):
                    if not self._preempt_for(req):
                        raise PagePoolExhausted(
                            f"request {req.uid} needs a page but the pool is "
                            f"exhausted and no victim remains")
            n = self.pool.refcount(req.uid)
            self.block_tables[req.slot, n] = self.pool.alloc(req.uid)

    def _ensure_compiled(self) -> None:
        if self._decode_c is None:
            self.compile()

    def _prefill_one(self) -> bool:
        ns = self.cfg.num_slots
        for i in range(ns):
            s = (self._pf_ptr + i) % ns
            req = self.slot_req[s]
            if req is not None and req.state == "prefill":
                self._pf_ptr = (s + 1) % ns
                self._prefill_chunk(req)
                return True
        return False

    def _prefill_chunk(self, req: Request) -> None:
        C = self.cfg.prefill_chunk
        src = req.prefill_src if req.prefill_src is not None else req.prompt
        off = req.prefill_off
        chunk = src[off:off + C]
        n_real = len(chunk)
        if n_real < C:  # ragged tail: pads write to the sink / dead rows
            chunk = np.pad(chunk, (0, C - n_real))
        self._ensure_pages(req, off + n_real - 1)
        s = req.slot
        logits, self.cache = self._chunk_c(
            self.params, jnp.asarray(chunk[None]), self.cache,
            jnp.full((1,), off, jnp.int32),
            jnp.asarray(self.block_tables[s:s + 1]))
        req.prefill_off = off + n_real
        if req.preemptions:
            self._replay_chunks += 1
        self._log("prefill_chunk", req.uid)
        if req.prefill_off >= len(src):
            if req.generated:
                # resumed stream: KV rebuilt, tokens already pinned —
                # decode continues from generated[-1]
                req.state = "decode"
                self._log("resume", req.uid)
                return
            lg = np.asarray(logits[0, n_real - 1])
            if not np.isfinite(lg).all():
                self._fail(req, "non-finite logits at prefill")
                return
            req.generated.append(int(lg.argmax()))
            if self.cfg.record_logits:
                req.logits.append(lg)
            req.state = "decode"
            self._tokens_generated += 1
            self._log("first_token", req.uid)
            self._maybe_finish(req)

    def _decode_all(self) -> bool:
        cfg = self.cfg
        decoding = [s for s in range(cfg.num_slots)
                    if self.slot_req[s] is not None
                    and self.slot_req[s].state == "decode"]
        if not decoding:
            return False
        tokens = np.zeros((cfg.num_slots, 1), np.int32)
        pos = np.zeros((cfg.num_slots,), np.int32)
        # non-decoding slots get an all--1 block table row so their dummy
        # writes land on the sink page instead of a prefilling stream's KV
        bt = np.full_like(self.block_tables, -1)
        staged = []
        for s in decoding:
            req = self.slot_req[s]
            if req is None or req.state != "decode":
                continue  # preempted this tick by an earlier slot's page grab
            pos[s] = len(req.prompt) + len(req.generated) - 1
            tokens[s, 0] = req.generated[-1]
            self._ensure_pages(req, int(pos[s]))
            bt[s] = self.block_tables[s]
            staged.append(s)
        # a later slot's _ensure_pages may have preempted an earlier
        # staged one — its pages are gone, so route its write to the sink
        # and drop it from this tick's batch (it re-prefills on readmit)
        live = [s for s in staged if self.slot_req[s] is not None
                and self.slot_req[s].state == "decode"]
        for s in set(staged) - set(live):
            bt[s] = -1
        if not live:
            return False
        logits, self.cache = self._decode_c(
            self.params, jnp.asarray(tokens), self.cache,
            jnp.asarray(pos), jnp.asarray(bt))
        lg = np.asarray(logits)
        n_ok = 0
        for s in live:
            req = self.slot_req[s]
            row = lg[s]
            if not np.isfinite(row).all():
                self._fail(req, "non-finite logits")
                continue
            req.generated.append(int(row.argmax()))
            if self.cfg.record_logits:
                req.logits.append(row)
            n_ok += 1
            self._maybe_finish(req)
        self._decode_ticks += 1
        self.decode_tick_log.append(self.tick)
        self._tokens_generated += n_ok
        self._occupancy.append(len(live) / cfg.num_slots)
        active = sum(r is not None for r in self.slot_req)
        if active:
            self._resident.append(
                self.pool.pages_in_use * self.bytes_per_page / active)
        return True

    def _maybe_finish(self, req: Request) -> None:
        if len(req.generated) >= req.max_new:
            self._release(req)
            req.state = "done"
            self._log("finish", req.uid)
