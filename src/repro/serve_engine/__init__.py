"""Request-level serving over a packed :class:`~repro.deploy.QuantizedArtifact`.

Slot-based continuous batching with a paged KV cache: new prompts are
admitted into freed decode slots, prefill runs in chunks interleaved
with decode ticks, and KV lives in per-layer page pools (int8 codes +
scales through ``kernels/kvattn``, or float reference mode) indexed by
one block table per stream. Admission overcommit + preemption,
per-request deadlines, per-stream fault isolation and graceful drain
make the engine survive pressure instead of refusing it. See
``docs/serving.md``.
"""
from .engine import (ACTIVE_STATES, TERMINAL_STATES, EngineConfig,
                     EngineStalledError, Request, RequestRejected,
                     RequestState, ServeEngine)
from .pages import PagePool, PagePoolExhausted

__all__ = ["ACTIVE_STATES", "TERMINAL_STATES", "EngineConfig",
           "EngineStalledError", "PagePool", "PagePoolExhausted", "Request",
           "RequestRejected", "RequestState", "ServeEngine"]
