"""Request-level serving over a packed :class:`~repro.deploy.QuantizedArtifact`.

Slot-based continuous batching with a paged KV cache: new prompts are
admitted into freed decode slots, prefill runs in chunks interleaved
with decode ticks, and KV lives in per-layer page pools (int8 codes +
scales through ``kernels/kvattn``, or float reference mode) indexed by
one block table per stream. See ``docs/serving.md``.
"""
from .engine import EngineConfig, Request, RequestState, ServeEngine
from .pages import PagePool

__all__ = ["EngineConfig", "PagePool", "Request", "RequestState", "ServeEngine"]
