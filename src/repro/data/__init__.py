from .synthetic import Corpus, CorpusConfig, arch_extras_fn, make_batches  # noqa: F401
