"""Deterministic synthetic corpus with learnable structure.

A sparse first-order Markov chain over the vocab (each token has k
successors with zipf-ish weights) plus periodic copy segments. Small LMs
reach well below the unigram entropy within a few hundred steps, so
quantization damage is measurable — the role ImageNet plays in the paper.

Sharding: every (seed, host, step) triple maps to an independent RNG
stream, so multi-host training needs no data communication and restarts
are reproducible (fault-tolerance requirement).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class CorpusConfig:
    vocab: int
    branching: int = 12  # successors per token
    copy_period: int = 64  # every N tokens, re-emit an earlier span
    copy_len: int = 8
    seed: int = 1234


class Corpus:
    def __init__(self, cfg: CorpusConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        k = cfg.branching
        self.successors = rng.integers(0, cfg.vocab, (cfg.vocab, k)).astype(np.int32)
        w = 1.0 / np.arange(1, k + 1) ** 1.2
        self.weights = (w / w.sum()).astype(np.float64)

    def sample(self, batch: int, seq: int, *, seed: int, host: int = 0,
               step: int = 0) -> np.ndarray:
        """(batch, seq) int32 tokens; deterministic in (seed, host, step)."""
        cfg = self.cfg
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, seed, host, step]))
        toks = np.empty((batch, seq), np.int32)
        cur = rng.integers(0, cfg.vocab, batch)
        choices = rng.choice(cfg.branching, size=(batch, seq), p=self.weights)
        toks[:, 0] = cur
        for t in range(1, seq):
            cur = self.successors[cur, choices[:, t]]
            # copy mechanism: splice in an earlier span periodically
            if cfg.copy_period and t % cfg.copy_period == 0 and t > cfg.copy_len:
                src = t - cfg.copy_len - 1
                toks[:, t - cfg.copy_len: t] = toks[:, src: src + cfg.copy_len]
                cur = toks[:, t - 1]
            toks[:, t] = cur
        return toks


def make_batches(corpus: Corpus, n_batches: int, batch: int, seq: int,
                 *, seed: int, host: int = 0, start_step: int = 0,
                 extras_fn=None) -> list[dict]:
    """List of {'tokens': (B,S)} (+ arch extras) jnp-ready batches."""
    import jax.numpy as jnp

    out = []
    for i in range(n_batches):
        toks = corpus.sample(batch, seq, seed=seed, host=host, step=start_step + i)
        b = {"tokens": jnp.asarray(toks)}
        if extras_fn is not None:
            b.update(extras_fn(batch, seq, start_step + i))
        out.append(b)
    return out


def arch_extras_fn(cfg):
    """Per-arch stub-modality extras (VLM patches / whisper frames)."""
    import jax.numpy as jnp

    if cfg.family == "vlm":
        def fn(batch, seq, step):
            rng = np.random.default_rng(np.random.SeedSequence([7, step]))
            return {"patches": jnp.asarray(
                rng.normal(size=(batch, cfg.n_patches, cfg.d_model)).astype(np.float32))}

        return fn
    if cfg.enc_dec:
        def fn(batch, seq, step):
            rng = np.random.default_rng(np.random.SeedSequence([11, step]))
            return {"frames": jnp.asarray(
                rng.normal(size=(batch, seq, cfg.d_model)).astype(np.float32))}

        return fn
    return None
