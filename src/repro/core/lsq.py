"""Learned-step-size (LSQ, Esser et al. 2020) activation quantization.

BRECQ quantizes activations by learning only the step size ``s`` per
tensor with the gradient of Eq. (18):

    dL/ds = dL/dx_hat * ( -x/s + x_hat/s )      inside the range
    dL/ds = dL/dx_hat * qmin_or_qmax            outside (clipped)

Weights use AdaRound; activations cannot (they change per input), so the
step size is the only learnable.  Per the paper's appendix B.4.4 we do
NOT apply the LSQ gradient scale.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

Array = jax.Array


def init_act_scale(x: Array, bits: int, symmetric: bool = False) -> Array:
    """Init from the first calibration batch: minmax over the tensor."""
    if symmetric:
        qmax = 2 ** (bits - 1) - 1
        return jnp.maximum(jnp.max(jnp.abs(x)) / qmax, 1e-8).astype(jnp.float32)
    qmax = 2**bits - 1
    return jnp.maximum(jnp.max(x) / qmax, 1e-8).astype(jnp.float32)


@partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def lsq_quant(x: Array, s: Array, bits: int, symmetric: bool = False) -> Array:
    """Fake-quantize ``x`` with learnable step ``s`` (scalar per tensor)."""
    if symmetric:
        n, p = -(2 ** (bits - 1)), 2 ** (bits - 1) - 1
    else:
        n, p = 0, 2**bits - 1
    return jnp.clip(jnp.round(x / s), n, p) * s


def _lsq_fwd(x, s, bits, symmetric):
    return lsq_quant(x, s, bits, symmetric), (x, s)


def _lsq_bwd(bits, symmetric, res, g):
    x, s = res
    if symmetric:
        n, p = -(2 ** (bits - 1)), 2 ** (bits - 1) - 1
    else:
        n, p = 0, 2**bits - 1
    xs = x / s
    in_range = (xs >= n) & (xs <= p)
    # dL/dx: straight-through inside range
    gx = g * in_range
    # dL/ds per Eq. (18)
    rounded = jnp.clip(jnp.round(xs), n, p)
    ds_elem = jnp.where(in_range, rounded - xs, rounded)  # clipped -> n or p
    gs = jnp.sum(g * ds_elem).astype(s.dtype).reshape(s.shape)
    return gx, gs


lsq_quant.defvjp(_lsq_fwd, _lsq_bwd)
