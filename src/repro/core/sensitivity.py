"""Layer sensitivities for mixed precision (paper Sec. 3.4).

After the three unified-precision calibrations (2/4/8-bit), measure per
layer the Fisher-weighted block-output error when ONLY that layer is
quantized (diagonal term), and — at 2-bit — the pairwise interaction
inside each block (off-diagonal term):

    offdiag(l1, l2) = joint(l1, l2) - diag(l1) - diag(l2).

Everything is stored in a lookup table; the genetic search then never
touches the network again (paper: "mixed-precision training only needs
to check the lookup table").
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from ..models.common import NO_QUANT, QuantHook
from . import adaround
from .fisher import FisherStream
from .reconstruction import (PTQResult, ReconConfig, Walker, _apply_unit,
                             _concat_batches, _slice_batch)

Array = jax.Array


@dataclasses.dataclass
class SensTable:
    diag: dict[tuple[str, int], float]  # (path, bits) -> loss
    offdiag: dict[tuple[str, str], float]  # (p1, p2) both 2-bit -> interaction
    block_of: dict[str, int]  # path -> block index
    shapes: dict[str, tuple]  # path -> weight shape

    # -- persistence: measuring needs three calibrations + per-layer
    # probes, so fig2 / the budget solver tabulate once and reload ------------

    def to_json(self) -> dict:
        return {"diag": [[p, b, v] for (p, b), v in sorted(self.diag.items())],
                "offdiag": [[p1, p2, v] for (p1, p2), v
                            in sorted(self.offdiag.items())],
                "block_of": dict(self.block_of),
                "shapes": {p: list(s) for p, s in self.shapes.items()}}

    @classmethod
    def from_json(cls, doc: dict) -> "SensTable":
        return cls(
            diag={(p, int(b)): float(v) for p, b, v in doc["diag"]},
            offdiag={(p1, p2): float(v) for p1, p2, v in doc["offdiag"]},
            block_of={p: int(b) for p, b in doc["block_of"].items()},
            shapes={p: tuple(s) for p, s in doc["shapes"].items()})

    def save(self, path: str) -> None:
        import json

        with open(path, "w") as f:
            json.dump(self.to_json(), f, indent=1)

    @classmethod
    def load(cls, path: str) -> "SensTable":
        import json

        with open(path) as f:
            return cls.from_json(json.load(f))


class _SelectHook(QuantHook):
    """Hard-quantize only the selected paths, using calibrated rounding."""

    def __init__(self, results: dict[int, PTQResult], select: dict[str, int]):
        self.results = results
        self.select = select

    def weight(self, path, w):
        bits = self.select.get(path)
        if bits is None:
            return w
        res = self.results[bits]
        if path in res.v:
            st, cfg = res.qstates[path]
            return adaround.hard_quant(w, res.v[path], st, cfg)
        if path in res.qstates:
            from .quantizer import quantize_dequant

            st, cfg = res.qstates[path]
            return quantize_dequant(w, st, cfg)
        return w


def measure(model, params, calib_batches, results: dict[int, PTQResult],
            bits_options=(2, 4, 8), n_samples: int = 32,
            use_fisher: bool = True, pair_bits: int = 2) -> SensTable:
    """Build the sensitivity lookup table."""
    walker = Walker(model)
    calib = _concat_batches(calib_batches)
    sub = _slice_batch(calib, jnp.arange(min(n_samples, calib["tokens"].shape[0])))

    # fisher at block outputs: the subset is small (n <= n_samples), so
    # memory is not binding here — 'full' keeps the one-backward cost of
    # the eps trick, and f32 keeps the table's absolute losses exact
    nb = len(walker.blocks())
    fisher = FisherStream(walker, params, [sub], mode="full") if use_fisher else None

    # paths per block (from any result's qstates, grouped by prefix)
    any_res = results[min(results)]
    block_paths: dict[int, list[str]] = {i: [] for i in range(nb)}
    block_of: dict[str, int] = {}
    for bi in range(nb):
        prefix = walker.block_path(bi) + "/"
        for p in any_res.qstates:
            if p.startswith(prefix):
                block_paths[bi].append(p)
                block_of[p] = bi

    shapes = {}
    from .reconstruction import enumerate_weights

    weights = enumerate_weights(model, params, _slice_batch(calib, jnp.arange(1)))
    for p in block_of:
        shapes[p] = tuple(weights[p].shape)

    diag: dict[tuple[str, int], float] = {}
    offdiag: dict[tuple[str, str], float] = {}

    # FP stream through blocks on the subset
    x_fp = jax.jit(lambda b: walker.stem(params, b)[0])(sub)
    mem_fp = None

    for bi in range(nb):
        z_fp = jax.jit(lambda x, m: _apply_unit(
            walker, params, [bi], NO_QUANT, x, sub, m))(x_fp, mem_fp)
        g2 = fisher.for_block(bi) if fisher is not None else None

        def unit_err(select: dict[str, int]) -> float:
            hook = _SelectHook(results, select)
            z = _apply_unit(walker, params, [bi], hook, x_fp, sub, mem_fp)
            err = (z - z_fp).astype(jnp.float32) ** 2
            if g2 is not None:
                err = err * g2
            return float(jnp.mean(err))

        err_fn = unit_err  # dict-keyed selection: retrace per call is fine here

        for p in block_paths[bi]:
            for b in bits_options:
                if b in results:
                    diag[(p, b)] = err_fn({p: b})
        for p1, p2 in itertools.combinations(block_paths[bi], 2):
            joint = err_fn({p1: pair_bits, p2: pair_bits})
            offdiag[(p1, p2)] = joint - diag[(p1, pair_bits)] - diag[(p2, pair_bits)]

        x_fp = z_fp
        if walker.encdec and bi == walker.enc_n - 1:
            mem_fp, x_fp = walker.boundary_transition(params, sub, x_fp)

    return SensTable(diag=diag, offdiag=offdiag, block_of=block_of, shapes=shapes)
