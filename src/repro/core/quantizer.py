"""Uniform quantizers: the parameter-space primitives BRECQ builds on.

Everything here is functional and jit-safe. A quantizer is described by a
static :class:`QConfig` plus a pytree of per-tensor state (``QState``:
scales and, for AdaRound, the rounding logits ``v``).

Paper mapping (Sec. 2):
  * uniform symmetric grid  Q_b = s * {-2^{b-1}, ..., 2^{b-1}-1}
  * scale init either min-max or the MSE-optimal grid search (the
    "OMSE" baseline in Table 2 uses the same search).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class QConfig:
    """Static description of a uniform quantizer.

    Attributes:
      bits: bit-width b; grid has 2^b levels.
      symmetric: symmetric signed grid (weights) vs asymmetric unsigned
        (post-ReLU/softmax activations use ``symmetric=False``).
      channel_axis: axis that keeps its own scale (per-channel); ``None``
        means one scale per tensor.
      group_size: optional sub-channel grouping along the *reduction*
        axis (axis 0 for (in, out) weight layout); each group of
        ``group_size`` rows shares a scale. TPU-friendly values are
        multiples of 128. ``None`` disables grouping.
      scale_method: 'minmax' | 'mse'.
    """

    bits: int = 8
    symmetric: bool = True
    channel_axis: Optional[int] = None
    group_size: Optional[int] = None
    scale_method: str = "minmax"

    @property
    def qmin(self) -> int:
        return -(2 ** (self.bits - 1)) if self.symmetric else 0

    @property
    def qmax(self) -> int:
        return 2 ** (self.bits - 1) - 1 if self.symmetric else 2**self.bits - 1


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class QState:
    """Learnable / derived quantizer state. A pytree."""

    scale: Array  # broadcastable against the tensor
    zero_point: Array  # 0 for symmetric

    def tree_flatten(self):
        return (self.scale, self.zero_point), None

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        return cls(*leaves)


# ---------------------------------------------------------------------------
# scale initialisation
# ---------------------------------------------------------------------------


def _reduce_axes(x: Array, cfg: QConfig) -> tuple[int, ...]:
    if cfg.channel_axis is None:
        return tuple(range(x.ndim))
    ax = cfg.channel_axis % x.ndim
    return tuple(i for i in range(x.ndim) if i != ax)


def _group_reshape(x: Array, cfg: QConfig) -> Array:
    """Reshape (..., in, out) -> (..., groups, group_size, out).

    Grouping is along the *reduction* axis (-2) so it applies both to 2-D
    linear weights and to stacked (E, in, out) MoE expert weights.
    """
    assert x.ndim >= 2, "group quantization expects (..., in, out) weights"
    g = cfg.group_size
    assert g is not None and x.shape[-2] % g == 0, (x.shape, g)
    return x.reshape(*x.shape[:-2], x.shape[-2] // g, g, x.shape[-1])


def _minmax_scale(x: Array, cfg: QConfig) -> QState:
    axes = _reduce_axes(x, cfg)
    if cfg.symmetric:
        amax = jnp.max(jnp.abs(x), axis=axes, keepdims=True)
        scale = jnp.maximum(amax / cfg.qmax, 1e-8)
        zp = jnp.zeros_like(scale)
    else:
        lo = jnp.min(x, axis=axes, keepdims=True)
        hi = jnp.max(x, axis=axes, keepdims=True)
        scale = jnp.maximum((hi - lo) / (cfg.qmax - cfg.qmin), 1e-8)
        zp = jnp.round(-lo / scale)
    return QState(scale.astype(jnp.float32), zp.astype(jnp.float32))


def _mse_scale(x: Array, cfg: QConfig, num_candidates: int = 80) -> QState:
    """Grid-search the clip ratio minimising ||x - q(x)||^2 (paper's OMSE)."""
    base = _minmax_scale(x, cfg)
    ratios = jnp.linspace(0.35, 1.0, num_candidates)
    axes = _reduce_axes(x, cfg)

    def err_for(ratio):
        st = QState(base.scale * ratio, base.zero_point)
        err = quantize_dequant(x, st, cfg) - x
        return jnp.sum(err * err, axis=axes, keepdims=True)

    errs = jax.vmap(err_for)(ratios)  # (C, *scale_shape)
    best = jnp.argmin(errs, axis=0)
    ratio = ratios[best]
    return QState(base.scale * ratio, base.zero_point)


def init_qstate(x: Array, cfg: QConfig) -> QState:
    """Initialise scales for tensor ``x`` under ``cfg``."""
    if cfg.group_size is not None:
        xg = _group_reshape(x, cfg)
        # one scale per (group, out-channel): reduce over the group axis only
        axes = (-2,)
        if cfg.symmetric:
            amax = jnp.max(jnp.abs(xg), axis=axes, keepdims=True)
            scale = jnp.maximum(amax / cfg.qmax, 1e-8)
            zp = jnp.zeros_like(scale)
            st = QState(scale.astype(jnp.float32), zp.astype(jnp.float32))
        else:
            lo = jnp.min(xg, axis=axes, keepdims=True)
            hi = jnp.max(xg, axis=axes, keepdims=True)
            scale = jnp.maximum((hi - lo) / (cfg.qmax - cfg.qmin), 1e-8)
            st = QState(scale.astype(jnp.float32), jnp.round(-lo / scale))
        if cfg.scale_method == "mse":
            ratios = jnp.linspace(0.35, 1.0, 80)

            def err_for(ratio):
                s2 = QState(st.scale * ratio, st.zero_point)
                q = _qdq_raw(xg, s2, cfg)
                return jnp.sum((q - xg) ** 2, axis=axes, keepdims=True)

            errs = jax.vmap(err_for)(ratios)
            ratio = ratios[jnp.argmin(errs, axis=0)]
            st = QState(st.scale * ratio, st.zero_point)
        return st
    if cfg.scale_method == "mse":
        return _mse_scale(x, cfg)
    return _minmax_scale(x, cfg)


# ---------------------------------------------------------------------------
# quantize / dequantize
# ---------------------------------------------------------------------------


def _qdq_raw(x: Array, st: QState, cfg: QConfig) -> Array:
    q = jnp.clip(jnp.round(x / st.scale) + st.zero_point, cfg.qmin, cfg.qmax)
    return (q - st.zero_point) * st.scale


def quantize_int(x: Array, st: QState, cfg: QConfig) -> Array:
    """Return the integer codes (int8 container regardless of bits<=8)."""
    if cfg.group_size is not None:
        xg = _group_reshape(x, cfg)
        q = jnp.clip(jnp.round(xg / st.scale) + st.zero_point, cfg.qmin, cfg.qmax)
        return q.reshape(x.shape).astype(jnp.int8)
    q = jnp.clip(jnp.round(x / st.scale) + st.zero_point, cfg.qmin, cfg.qmax)
    return q.astype(jnp.int8)


def dequantize_int(q: Array, st: QState, cfg: QConfig, shape=None) -> Array:
    if cfg.group_size is not None:
        qg = _group_reshape(q.astype(jnp.float32), cfg)
        w = (qg - st.zero_point) * st.scale
        return w.reshape(q.shape)
    return (q.astype(jnp.float32) - st.zero_point) * st.scale


def quantize_dequant(x: Array, st: QState, cfg: QConfig) -> Array:
    """Fake-quantize (round-to-nearest). Used by RTN and scale search."""
    if cfg.group_size is not None:
        xg = _group_reshape(x, cfg)
        return _qdq_raw(xg, st, cfg).reshape(x.shape)
    return _qdq_raw(x, st, cfg)


# STE variant for QAT baseline -------------------------------------------------


@partial(jax.custom_vjp, nondiff_argnums=(2,))
def fake_quant_ste(x: Array, st: QState, cfg: QConfig) -> Array:
    return quantize_dequant(x, st, cfg)


def _fq_fwd(x, st, cfg):
    return quantize_dequant(x, st, cfg), (x, st)


def _fq_bwd(cfg, res, g):
    x, st = res
    # straight-through inside the clip range, zero outside
    if cfg.group_size is not None:
        xg = _group_reshape(x, cfg)
        lo = (cfg.qmin - st.zero_point) * st.scale
        hi = (cfg.qmax - st.zero_point) * st.scale
        mask = ((xg >= lo) & (xg <= hi)).reshape(x.shape)
    else:
        lo = (cfg.qmin - st.zero_point) * st.scale
        hi = (cfg.qmax - st.zero_point) * st.scale
        mask = (x >= lo) & (x <= hi)
    return (g * mask, jax.tree.map(jnp.zeros_like, st))


fake_quant_ste.defvjp(_fq_fwd, _fq_bwd)


# ---------------------------------------------------------------------------
# packing (deployment format consumed by kernels/qmatmul)
# ---------------------------------------------------------------------------


def pack_int(q: Array, bits: int, axis: int = 0) -> Array:
    """Pack sub-byte integer codes along ``axis`` into an int8 container.

    int8 -> identity; int4 -> 2 values/byte; int2 -> 4 values/byte.
    Values are stored offset-binary (code + 2^{b-1}) so unpacking is
    mask/shift only.
    """
    if bits == 8:
        return q.astype(jnp.int8)
    per = 8 // bits
    axis = axis % q.ndim
    assert q.shape[axis] % per == 0, (q.shape, axis, bits)
    off = (q.astype(jnp.int32) + 2 ** (bits - 1)).astype(jnp.uint8)
    new_shape = (*q.shape[:axis], q.shape[axis] // per, per, *q.shape[axis + 1:])
    off = off.reshape(new_shape)
    out = jnp.zeros((*q.shape[:axis], q.shape[axis] // per, *q.shape[axis + 1:]),
                    jnp.uint8)
    for i in range(per):
        out = out | (jnp.take(off, i, axis=axis + 1) << (bits * i))
    return out.astype(jnp.int8)


def unpack_int(p: Array, bits: int, rows: int, axis: int = 0) -> Array:
    """Inverse of :func:`pack_int`: int8 codes with ``rows`` along ``axis``."""
    if bits == 8:
        return p.astype(jnp.int8)
    per = 8 // bits
    axis = axis % p.ndim
    mask = (1 << bits) - 1
    u = p.astype(jnp.uint8)
    parts = [((u >> (bits * i)) & mask).astype(jnp.int32) - 2 ** (bits - 1)
             for i in range(per)]
    out = jnp.stack(parts, axis=axis + 1)
    out = out.reshape(*p.shape[:axis], rows, *p.shape[axis + 1:])
    return out.astype(jnp.int8)
