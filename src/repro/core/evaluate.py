"""Quantized-model evaluation: perplexity / loss / top-1 next-token accuracy.

The LM analogue of the paper's ImageNet top-1 columns. All methods are
evaluated through the same Walker so FP / RTN / BRECQ comparisons share
one code path.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ..models.common import NO_QUANT
from .hooks import ServeHook
from .reconstruction import Walker


def evaluate(model, params, batches: list[dict], act_scales: Optional[dict] = None,
             a_bits: Optional[int] = None) -> dict:
    """Evaluate a (possibly quantized) model on next-token prediction.

    Args:
      model: block-graph model (same API ``quantize`` consumes).
      params: parameters to evaluate — FP originals, the baked
        ``PTQResult.params_q``, or a packed
        :class:`repro.deploy.QuantizedArtifact` (its ``act_scales`` and
        manifest ``a_bits`` are applied automatically; weights execute
        through the packed ``qmm`` path).
      batches: eval batches, each with ``tokens`` of shape (B, S).
      act_scales: path -> LSQ step size from calibration; together with
        ``a_bits`` enables activation fake-quant at serve time. Pass both
        or neither.
      a_bits: activation bit-width matching ``act_scales``.

    Returns:
      dict with ``loss`` (mean next-token cross-entropy, nats),
      ``ppl`` (exp(loss)) and ``top1`` (next-token accuracy in [0, 1]),
      averaged over ``batches``.
    """
    from ..deploy import QuantizedArtifact

    if isinstance(params, QuantizedArtifact):
        act_scales = act_scales or params.act_scales
        a_bits = a_bits or params.a_bits
        params = params.params
    walker = Walker(model)
    hook = ServeHook(act_scales, a_bits) if (act_scales and a_bits) else NO_QUANT

    @jax.jit
    def batch_metrics(batch):
        logits = walker.run(params, batch, hook)
        tokens = batch["tokens"]
        lg, lb = logits[:, :-1].astype(jnp.float32), tokens[:, 1:]
        logz = jax.nn.logsumexp(lg, axis=-1)
        ll = jnp.take_along_axis(lg, lb[..., None], axis=-1)[..., 0]
        nll = logz - ll
        top1 = (jnp.argmax(lg, -1) == lb).astype(jnp.float32)
        return jnp.mean(nll), jnp.mean(top1)

    losses, accs = [], []
    for b in batches:
        l, a = batch_metrics(b)
        losses.append(float(l))
        accs.append(float(a))
    loss = sum(losses) / len(losses)
    return {"loss": loss, "ppl": float(jnp.exp(jnp.asarray(loss))),
            "top1": sum(accs) / len(accs)}
