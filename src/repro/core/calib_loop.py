"""Device-resident calibration inner loop + compiled-unit program cache.

The BRECQ reconstruction loop used to be host-driven: one ``np.random``
draw, two jitted dispatches (grad then Adam) and a blocking
``float(loss)`` sync *per iteration*, with every unit re-tracing its
step functions from scratch.  This module replaces that with:

  * one jitted **program per unit structure** — the whole optimization
    (minibatch sampling, value_and_grad, Adam update, beta schedule) runs
    as a single ``jax.lax.scan`` over iterations, entirely on device;
  * **on-device sampling** via ``jax.random`` (fold_in per unit, split
    per iteration), so no host round-trip per minibatch;
  * the loss trajectory returned as one ``(iters,)`` array → exactly one
    host↔device sync per unit;
  * a **compiled-unit cache**: programs are keyed by the *structure* of
    the unit (block stack defs, canonical quantizer configs, ReconConfig
    statics, argument shapes) — never by the block index.  The 2nd..Nth
    identical transformer blocks therefore reuse the compiled step
    instead of re-tracing, which dominates wall time at bench scale
    where ``iters`` is small.

Paths are *canonicalised* inside a program: block ``j`` of a unit runs
under scope ``u{j}`` regardless of its absolute position in the model,
so ``body.0/attn/wq`` and ``body.5/attn/wq`` trace to the identical
jaxpr.  Callers translate between real and canonical paths at the
boundary.

A ``step`` (single-iteration) variant of every program is kept for the
``loop_impl='python'`` reference mode: it executes the *same* traced
step body once per Python-level iteration (the pre-optimization
dispatch pattern), which is what ``benchmarks/table5_calib_speed.py``
reports as the "before" throughput and what the equivalence tests
compare against.
"""
from __future__ import annotations

import dataclasses
import weakref
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from ..models.common import NO_QUANT
from ..optim import adam
from . import adaround, lsq
from .hooks import AdaRoundHook, LayerCaptureHook, RecordingHook

Array = jax.Array


# ---------------------------------------------------------------------------
# cache plumbing
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class UnitPrograms:
    """Compiled entry points for one unit structure.

    ``model_ref`` is a weakref: the cache must not pin models alive, and
    it doubles as the identity guard against id() reuse after GC.
    ``walker_cell`` holds a weakref to the *latest* Walker (one exists
    per quantize() call); ``get_unit_programs`` refreshes it on every
    fetch so a program traced lazily on a later call sees a live
    walker."""

    scan: Callable  # full fused loop: one dispatch per unit
    step: Callable  # single iteration (reference / python mode)
    hard: Callable  # hardened forward over the full calib set
    fwd: Callable  # FP forward over the full calib set
    model_ref: Any
    walker_cell: list


@dataclasses.dataclass
class LayerPrograms:
    scan: Callable
    step: Callable


@dataclasses.dataclass
class ProbeProgram:
    """Cached unit probe: which weight paths a unit structure touches
    (known at trace time, no execution needed) plus a jitted activation
    capture used only when ``a_bits`` is set."""

    wpaths: tuple  # canonical weight paths in model-traversal order
    acts: Callable  # jitted (bparams, x1, batch1, mem1) -> {cpath: act}
    model_ref: Any
    walker_cell: list


@dataclasses.dataclass
class CaptureProgram:
    """Cached layer-wise input capture: runs one block under canonical
    scopes with finished paths hard-quantized and returns the input of
    the target linear."""

    run: Callable  # (bparams, states_done, v_done, s_done, x, batch, mem)
    model_ref: Any
    walker_cell: list


_CACHE: dict[tuple, Any] = {}
_TRACE_LOG: list[str] = []  # appended at trace time; tests assert on it
_HITS = {"unit": 0, "layer": 0, "probe": 0, "cap": 0}
_MISSES = {"unit": 0, "layer": 0, "probe": 0, "cap": 0}

# Declared buffer donations of the calibration scan/step programs:
# (opt, ostate) — positions in the scan_program signature. The static
# auditor (repro.analysis.audit) re-lowers the programs with these
# argnums unconditionally (``_donate`` drops them on CPU) and fails if
# the lowering no longer marks them donated.
UNIT_DONATE = (2, 3)
LAYER_DONATE = (2, 3)

# Audit capture hook: when a list is installed here, run_unit_loop /
# run_layer_loop append (tag, jitted_program, args) for every scan-mode
# dispatch, giving the auditor real program + argument pairs to re-lower
# without re-implementing the calibration plumbing.
AUDIT_CAPTURE: list | None = None


def cache_stats() -> dict:
    return {"unit_hits": _HITS["unit"], "unit_misses": _MISSES["unit"],
            "layer_hits": _HITS["layer"], "layer_misses": _MISSES["layer"],
            "probe_hits": _HITS["probe"], "probe_misses": _MISSES["probe"],
            "cap_hits": _HITS["cap"], "cap_misses": _MISSES["cap"],
            "entries": len(_CACHE), "traces": len(_TRACE_LOG)}


def clear_cache() -> None:
    _CACHE.clear()
    _TRACE_LOG.clear()
    for d in (_HITS, _MISSES):
        for k in d:
            d[k] = 0


def trace_log() -> list[str]:
    return list(_TRACE_LOG)


def _tree_sig(tree) -> tuple:
    """Hashable (treedef, shapes, dtypes) signature of a pytree.

    Accepts arrays or anything shape/dtype-shaped (ShapeDtypeStruct), so
    callers can build signatures without materializing data."""
    leaves, treedef = jax.tree.flatten(tree)
    return (str(treedef),
            tuple((tuple(getattr(l, "shape", ())), str(getattr(l, "dtype", type(l).__name__)))
                  for l in leaves))


def _rc_sig(rc, bs: int) -> tuple:
    return (rc.iters, bs, rc.lr_v, rc.lr_s, rc.lam, rc.beta,
            rc.input_source, rc.input_mix_prob, rc.a_bits, rc.stream_dtype)


def _donate(*argnums: int) -> tuple:
    # buffer donation is a no-op (and warns) on CPU; only request it where
    # the runtime can honour it.
    return argnums if jax.default_backend() != "cpu" else ()


def _sweep_dead() -> None:
    """Drop cache entries whose model died: they can never hit again and
    only pin compiled executables."""
    for k in [k for k, v in _CACHE.items()
              if getattr(v, "model_ref", None) is not None
              and v.model_ref() is None]:
        del _CACHE[k]


# ---------------------------------------------------------------------------
# unit programs (block / stage / net granularity)
# ---------------------------------------------------------------------------


def unit_cache_key(model, stackdefs, is_dec, cfg_items, rc, bs,
                   bparams, states, opt, data) -> tuple:
    # the opt treedef (via _tree_sig) already encodes which v/s paths
    # the programs optimize, so canonical path lists need no extra slot
    return ("unit", id(model), tuple(stackdefs), is_dec, tuple(cfg_items),
            _rc_sig(rc, bs), _tree_sig(bparams),
            _tree_sig(states), _tree_sig(opt), _tree_sig(data))


def get_unit_programs(model, walker, stackdefs, is_dec, cfgs: dict,
                      rc, bs: int, N: int,
                      bparams, states, opt, data) -> UnitPrograms:
    """Fetch (or build) the compiled programs for one unit structure.

    ``cfgs``: canonical path -> QConfig (static). ``states``/``opt`` are
    only used for their structure in the cache key; ``data`` is the tuple
    of stream arrays the programs will consume.
    """
    key = unit_cache_key(model, stackdefs, is_dec, sorted(cfgs.items()),
                         rc, bs, bparams, states, opt, data)
    hit = _CACHE.get(key)
    if hit is not None and hit.model_ref() is model:
        hit.walker_cell[0] = weakref.ref(walker)
        _HITS["unit"] += 1
        return hit
    _MISSES["unit"] += 1
    _sweep_dead()
    progs = _build_unit_programs(model, walker, stackdefs, is_dec, cfgs,
                                 rc, bs, N)
    _CACHE[key] = progs
    return progs


def _build_unit_programs(model, walker, stackdefs, is_dec, cfgs: dict,
                         rc, bs: int, N: int) -> UnitPrograms:
    rep_bi = walker.enc_n if is_dec else 0
    a_bits = rc.a_bits
    lr_ratio = rc.lr_s / rc.lr_v
    acfg = adam.AdamConfig(lr=rc.lr_v)
    sdt = jnp.dtype(rc.stream_dtype)  # stream storage dtype; compute is f32
    stackdefs = tuple(stackdefs)
    # weakrefs, dereferenced only at trace time: the cache (and the jit
    # wrappers it holds) must not keep models/walkers alive. Tracing
    # only happens while a quantize() call is fetching this entry, so
    # the refreshed walker_cell and the guarded model are always live.
    model_ref = weakref.ref(model)
    walker_cell = [weakref.ref(walker)]

    def apply_unit(hook, bparams, x, batch, mem):
        mdl, wkr = model_ref(), walker_cell[0]()
        # streams may be stored bf16 (ReconConfig.stream_dtype); blocks
        # always compute in f32
        x = x.astype(jnp.float32)
        mem = mem.astype(jnp.float32) if mem is not None else None
        ctx = wkr.ctx_for(batch, rep_bi, mem)
        for j, (sd, p_j) in enumerate(zip(stackdefs, bparams)):
            ctx2 = dataclasses.replace(ctx, quant=hook, scope=f"u{j}")
            x, _ = mdl.apply_block(ctx2, sd, p_j, x)
        return x

    def qstates_of(states):
        return {p: (states[p], cfgs[p]) for p in cfgs}

    def unit_loss(opt_, qstates, bparams, xin, zt, g2b, batch, mem, it, nelem):
        hook = AdaRoundHook(qstates, opt_, a_bits, soft=True)
        x = apply_unit(hook, bparams, xin, batch, mem)
        err = (x - zt).astype(jnp.float32) ** 2
        if g2b is not None:
            err = err * g2b
        beta, enabled = rc.beta(it, rc.iters)
        reg = sum(adaround.round_reg(v, beta) for v in opt_["v"].values())
        return jnp.mean(err) + rc.lam * enabled * reg / nelem

    def one_step(carry, it, bparams, states, x_q, x_fp, z_fp, g2, batch, mem,
                 lr_scale):
        opt_, ostate, key = carry
        key, k_idx, k_mix = jax.random.split(key, 3)
        idx = jax.random.choice(k_idx, N, shape=(bs,), replace=False)
        if rc.input_source == "fp":
            xin = x_fp[idx]
        elif rc.input_source == "mix":
            keep = jax.random.uniform(k_mix, (bs,)) < rc.input_mix_prob
            xin = jnp.where(keep[:, None, None], x_fp[idx], x_q[idx])
        else:
            xin = x_q[idx]
        g2b = g2[idx] if g2 is not None else None
        bsl = {k: v[idx] for k, v in batch.items()}
        msl = mem[idx] if mem is not None else None
        nelem = sum(v.size for v in opt_["v"].values())
        # lr_scale is a *traced* scalar (guarded retries halve it without
        # re-tracing or breaking the structural program cache)
        lr_tree = {"v": {p: lr_scale for p in opt_["v"]},
                   "s": {p: lr_ratio * lr_scale for p in opt_["s"]}}
        loss, grads = jax.value_and_grad(unit_loss)(
            opt_, qstates_of(states), bparams, xin, z_fp[idx], g2b, bsl, msl,
            it.astype(jnp.float32), nelem)
        opt_, ostate = adam.update(acfg, grads, ostate, opt_, lr_tree)
        return (opt_, ostate, key), loss

    def scan_program(bparams, states, opt_, ostate, key,
                     x_q, x_fp, z_fp, g2, batch, mem, lr_scale):
        _TRACE_LOG.append("unit_scan")
        carry, losses = jax.lax.scan(
            lambda c, it: one_step(c, it, bparams, states, x_q, x_fp, z_fp,
                                   g2, batch, mem, lr_scale),
            (opt_, ostate, key), jnp.arange(rc.iters, dtype=jnp.int32))
        opt_, ostate, _ = carry
        return opt_, ostate, losses

    def step_program(bparams, states, opt_, ostate, key, it,
                     x_q, x_fp, z_fp, g2, batch, mem, lr_scale):
        _TRACE_LOG.append("unit_step")
        carry, loss = one_step((opt_, ostate, key), it, bparams, states,
                               x_q, x_fp, z_fp, g2, batch, mem, lr_scale)
        return (*carry, loss)

    def hard_program(bparams, states, opt_, x, batch, mem):
        _TRACE_LOG.append("unit_hard")
        hook = AdaRoundHook(qstates_of(states), opt_, a_bits, soft=False)
        return apply_unit(hook, bparams, x, batch, mem).astype(sdt)

    def fwd_program(bparams, x, batch, mem):
        _TRACE_LOG.append("unit_fwd")
        return apply_unit(NO_QUANT, bparams, x, batch, mem).astype(sdt)

    return UnitPrograms(
        scan=jax.jit(scan_program, donate_argnums=_donate(*UNIT_DONATE)),
        step=jax.jit(step_program, donate_argnums=_donate(*UNIT_DONATE)),
        hard=jax.jit(hard_program),
        fwd=jax.jit(fwd_program),
        model_ref=model_ref, walker_cell=walker_cell)


def run_unit_loop(progs: UnitPrograms, rc, bparams, states, opt, ostate, key,
                  x_q, x_fp, z_fp, g2, batch, mem, lr_scale: float = 1.0):
    """Drive the optimization; returns (opt, losses ndarray) with O(1)
    syncs in scan mode (one device fetch for the whole trajectory).
    ``lr_scale`` multiplies both learning rates at runtime (guarded-retry
    backoff) without invalidating the compiled program."""
    lr_scale = jnp.asarray(lr_scale, jnp.float32)
    if rc.loop_impl == "python":
        # pre-optimization dispatch pattern: per-iteration host round trip
        losses = []
        for it in range(rc.iters):
            opt, ostate, key, l = progs.step(
                bparams, states, opt, ostate, key,
                jnp.asarray(it, jnp.int32), x_q, x_fp, z_fp, g2, batch, mem,
                lr_scale)
            losses.append(float(l))
        return opt, np.asarray(losses, np.float64)
    if AUDIT_CAPTURE is not None:
        AUDIT_CAPTURE.append(("unit_scan", progs.scan,
                              (bparams, states, opt, ostate, key, x_q, x_fp,
                               z_fp, g2, batch, mem, lr_scale)))
    opt, ostate, losses = progs.scan(bparams, states, opt, ostate, key,
                                     x_q, x_fp, z_fp, g2, batch, mem, lr_scale)
    return opt, np.asarray(losses)  # the single sync for the trajectory


# ---------------------------------------------------------------------------
# unit probe cache (weight-path discovery + activation capture)
# ---------------------------------------------------------------------------


def get_unit_probe(model, walker, stackdefs, is_dec, bparams,
                   x1, batch1, mem1) -> ProbeProgram:
    """Fetch (or build) the probe for one unit structure.

    The probe replaces the former eager 1-row ``RecordingHook`` forward
    that ran per unit: weight paths are discovered **at trace time** via
    ``jax.eval_shape`` (no device execution), and the activation capture
    is a jitted program shared by every structurally identical unit —
    only executed when activation quantization needs real values.
    Returned paths are canonical (``u{j}/...``); callers map them back to
    real block paths.
    """
    stackdefs = tuple(stackdefs)
    key = ("probe", id(model), stackdefs, is_dec,
           _tree_sig((bparams, x1, batch1, mem1)))
    hit = _CACHE.get(key)
    if hit is not None and hit.model_ref() is model:
        hit.walker_cell[0] = weakref.ref(walker)
        _HITS["probe"] += 1
        return hit
    _MISSES["probe"] += 1
    _sweep_dead()
    probe = _build_unit_probe(model, walker, stackdefs, is_dec,
                              bparams, x1, batch1, mem1)
    _CACHE[key] = probe
    return probe


def _build_unit_probe(model, walker, stackdefs, is_dec,
                      bparams, x1, batch1, mem1) -> ProbeProgram:
    _TRACE_LOG.append("unit_probe")
    rep_bi = walker.enc_n if is_dec else 0
    model_ref = weakref.ref(model)
    walker_cell = [weakref.ref(walker)]
    wcell: dict[str, tuple] = {}

    def probe_fn(bparams, x, batch, mem):
        mdl, wkr = model_ref(), walker_cell[0]()
        rec = RecordingHook(capture_acts=True)
        x = x.astype(jnp.float32)
        mem = mem.astype(jnp.float32) if mem is not None else None
        ctx = wkr.ctx_for(batch, rep_bi, mem)
        for j, (sd, p_j) in enumerate(zip(stackdefs, bparams)):
            ctx2 = dataclasses.replace(ctx, quant=rec, scope=f"u{j}")
            x, _ = mdl.apply_block(ctx2, sd, p_j, x)
        wcell["wpaths"] = tuple(rec.weights)
        return dict(rec.acts)

    # abstract trace: fills wpaths without compiling or executing anything
    jax.eval_shape(probe_fn, bparams, x1, batch1, mem1)
    return ProbeProgram(wpaths=wcell["wpaths"], acts=jax.jit(probe_fn),
                        model_ref=model_ref, walker_cell=walker_cell)


# ---------------------------------------------------------------------------
# layer-wise input-capture cache
# ---------------------------------------------------------------------------


def get_capture_program(model, walker, stackdefs, is_dec, target: str,
                        cfg_items, a_bits, rc, data) -> CaptureProgram:
    """Fetch (or build) the capture program for one (block structure,
    target linear, finished-path set) combination.

    Replaces the fresh ``jax.jit`` the layer-wise loop used to build per
    linear per block: with canonical paths, block ``k``'s j-th linear
    reuses block 0's compiled capture. ``cfg_items``: (canonical path,
    QConfig) for the already-finished paths (static); ``data`` is the
    argument tuple, used only for its shape/dtype signature.
    """
    stackdefs = tuple(stackdefs)
    key = ("cap", id(model), stackdefs, is_dec, target, tuple(cfg_items),
           a_bits, rc.stream_dtype, _tree_sig(data))
    hit = _CACHE.get(key)
    if hit is not None and hit.model_ref() is model:
        hit.walker_cell[0] = weakref.ref(walker)
        _HITS["cap"] += 1
        return hit
    _MISSES["cap"] += 1
    _sweep_dead()
    prog = _build_capture_program(model, walker, stackdefs, is_dec, target,
                                  dict(cfg_items), a_bits, rc)
    _CACHE[key] = prog
    return prog


def _build_capture_program(model, walker, stackdefs, is_dec, target: str,
                           cfgd: dict, a_bits, rc) -> CaptureProgram:
    rep_bi = walker.enc_n if is_dec else 0
    sdt = jnp.dtype(rc.stream_dtype)
    model_ref = weakref.ref(model)
    walker_cell = [weakref.ref(walker)]

    def cap_program(bparams, states_done, v_done, s_done, x, batch, mem):
        _TRACE_LOG.append("layer_cap")
        mdl, wkr = model_ref(), walker_cell[0]()
        qst = {p: (states_done[p], cfgd[p]) for p in cfgd}
        hook = LayerCaptureHook(qst, v_done, target, s_done, a_bits)
        x = x.astype(jnp.float32)
        mem = mem.astype(jnp.float32) if mem is not None else None
        ctx = wkr.ctx_for(batch, rep_bi, mem)
        for j, (sd, p_j) in enumerate(zip(stackdefs, bparams)):
            ctx2 = dataclasses.replace(ctx, quant=hook, scope=f"u{j}")
            x, _ = mdl.apply_block(ctx2, sd, p_j, x)
        return hook.captured.astype(sdt)

    return CaptureProgram(run=jax.jit(cap_program), model_ref=model_ref,
                          walker_cell=walker_cell)


# ---------------------------------------------------------------------------
# layer programs (per-linear AdaRound baseline)
# ---------------------------------------------------------------------------


def get_layer_programs(qc, rc, bs: int, lead: int, W, st, opt, xin, zt
                       ) -> LayerPrograms:
    key = ("layer", qc, _rc_sig(rc, bs), lead, _tree_sig((W, st, opt, xin, zt)))
    hit = _CACHE.get(key)
    if hit is not None:
        _HITS["layer"] += 1
        return hit
    _MISSES["layer"] += 1
    progs = _build_layer_programs(qc, rc, bs, lead)
    _CACHE[key] = progs
    return progs


def _build_layer_programs(qc, rc, bs: int, lead: int) -> LayerPrograms:
    a_bits = rc.a_bits
    acfg = adam.AdamConfig(lr=rc.lr_v)
    lr_ratio = rc.lr_s / rc.lr_v

    def layer_loss(opt_, W, st, xb, zb, it):
        w_q = adaround.soft_quant(W, opt_["v"], st, qc)
        x = xb.astype(jnp.float32)  # captures may be stored bf16
        if a_bits is not None:
            x = lsq.lsq_quant(x, opt_["s"], a_bits, True)
        z = jnp.matmul(x, w_q.astype(x.dtype))
        beta, enabled = rc.beta(it, rc.iters)
        reg = adaround.round_reg(opt_["v"], beta)
        return (jnp.mean((z - zb).astype(jnp.float32) ** 2)
                + rc.lam * enabled * reg / opt_["v"].size)

    def one_step(carry, it, W, st, xin, zt, lr_scale):
        opt_, ostate, key = carry
        key, k_idx = jax.random.split(key)
        idx = jax.random.choice(k_idx, lead, shape=(bs,), replace=False)
        lr_tree = {"v": lr_scale,
                   **({"s": lr_ratio * lr_scale} if "s" in opt_ else {})}
        loss, grads = jax.value_and_grad(layer_loss)(
            opt_, W, st, xin[idx], zt[idx], it.astype(jnp.float32))
        opt_, ostate = adam.update(acfg, grads, ostate, opt_, lr_tree)
        return (opt_, ostate, key), loss

    def scan_program(W, st, opt_, ostate, key, xin, zt, lr_scale):
        _TRACE_LOG.append("layer_scan")
        carry, losses = jax.lax.scan(
            lambda c, it: one_step(c, it, W, st, xin, zt, lr_scale),
            (opt_, ostate, key), jnp.arange(rc.iters, dtype=jnp.int32))
        opt_, ostate, _ = carry
        return opt_, ostate, losses

    def step_program(W, st, opt_, ostate, key, it, xin, zt, lr_scale):
        _TRACE_LOG.append("layer_step")
        carry, loss = one_step((opt_, ostate, key), it, W, st, xin, zt,
                               lr_scale)
        return (*carry, loss)

    return LayerPrograms(
        scan=jax.jit(scan_program, donate_argnums=_donate(*LAYER_DONATE)),
        step=jax.jit(step_program, donate_argnums=_donate(*LAYER_DONATE)))


def run_layer_loop(progs: LayerPrograms, rc, W, st, opt, ostate, key, xin, zt,
                   lr_scale: float = 1.0):
    lr_scale = jnp.asarray(lr_scale, jnp.float32)
    if rc.loop_impl == "python":
        losses = []
        for it in range(rc.iters):
            opt, ostate, key, l = progs.step(
                W, st, opt, ostate, key, jnp.asarray(it, jnp.int32), xin, zt,
                lr_scale)
            losses.append(float(l))
        return opt, np.asarray(losses, np.float64)
    if AUDIT_CAPTURE is not None:
        AUDIT_CAPTURE.append(("layer_scan", progs.scan,
                              (W, st, opt, ostate, key, xin, zt, lr_scale)))
    opt, ostate, losses = progs.scan(W, st, opt, ostate, key, xin, zt, lr_scale)
    return opt, np.asarray(losses)
