"""Resumable-calibration journal: per-unit progress on disk.

``quantize(workdir=...)`` writes one snapshot after every reconstructed
unit through :class:`repro.ckpt.CheckpointManager` (atomic temp-dir +
rename, ``keep=1``), holding exactly the state a restart cannot
recompute deterministically:

  * the activation streams (``x_fp`` / ``x_q`` and, past the enc->dec
    boundary, ``mem_fp`` / ``mem_q``) — everything downstream of the
    completed units;
  * the accumulated rounding logits ``v`` and LSQ act scales ``s``;
  * per-unit stats (JSON) and the next unit index.

Everything else — quantizer states, the 8-bit embed/head handling, the
Fisher stream, per-unit PRNG keys (``fold_in(base_key, ui)``) — is a
pure function of (params, calib set, ReconConfig) and is recomputed on
resume, which is what makes a resumed run bit-identical to an
uninterrupted one.

A snapshot records a *signature* of the run that produced it (ReconConfig
repr, arch, unit count, calib-set shapes). Resuming against a journal
written by a different run raises :class:`CalibJournalError` instead of
silently mixing incompatible streams.

:class:`CalibrationInterrupted` is how ``quantize`` reports a clean
SIGTERM/SIGINT exit: the current unit finished, the journal is durable,
and re-calling ``quantize`` with the same ``workdir`` continues from the
next unit.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import numpy as np

from ..ckpt.checkpoint import CheckpointManager, CheckpointReadError

Array = jax.Array

_ESC = "%2F"  # calibration paths contain '/', the ckpt tree separator


class CalibJournalError(RuntimeError):
    """The journal in ``workdir`` cannot be used by this run (written by
    a different config/model/calib set, or unreadable)."""


class CalibrationInterrupted(RuntimeError):
    """Calibration checkpointed at a unit boundary and stopped on
    SIGTERM/SIGINT. The journal in ``workdir`` is complete through
    ``next_unit - 1``; re-run ``quantize`` with the same ``workdir`` to
    continue."""

    def __init__(self, workdir: str, next_unit: int, n_units: int):
        super().__init__(
            f"calibration interrupted by signal after unit {next_unit - 1}; "
            f"journal at {workdir} holds {next_unit}/{n_units} units — "
            f"re-run quantize(workdir=...) to resume")
        self.workdir = str(workdir)
        self.next_unit = next_unit
        self.n_units = n_units


def _jsonable(obj: Any) -> Any:
    """Stats trees carry numpy arrays/scalars; manifest meta is JSON."""
    if isinstance(obj, np.ndarray):
        return obj.tolist()
    if isinstance(obj, (np.floating, np.integer)):
        return obj.item()
    if isinstance(obj, dict):
        return {k: _jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_jsonable(v) for v in obj]
    return obj


class CalibJournal:
    """Per-unit calibration progress in ``workdir`` (see module doc)."""

    def __init__(self, workdir: str, signature: dict):
        self.workdir = str(workdir)
        self.signature = _jsonable(signature)
        self._mgr = CheckpointManager(workdir, keep=1)

    # -- write ----------------------------------------------------------------

    def save(self, next_unit: int, x_fp: Array, x_q: Array,
             mem_fp: Optional[Array], mem_q: Optional[Array],
             v_all: dict, s_all: dict, unit_stats: list,
             stream_peak: int) -> None:
        tree = {"x_fp": x_fp, "x_q": x_q,
                "v": {k.replace("/", _ESC): v for k, v in v_all.items()},
                "s": {k.replace("/", _ESC): v for k, v in s_all.items()}}
        if mem_fp is not None:
            tree["mem_fp"] = mem_fp
        if mem_q is not None:
            tree["mem_q"] = mem_q
        self._mgr.save(next_unit, tree, meta={
            "signature": self.signature, "next_unit": next_unit,
            "units": _jsonable(unit_stats), "stream_peak": int(stream_peak)})

    # -- read -----------------------------------------------------------------

    def load(self) -> Optional[dict]:
        """Latest snapshot as a dict, or None when the journal is empty.

        Raises :class:`CalibJournalError` when the snapshot was written
        by an incompatible run or cannot be read back."""
        step = self._mgr.latest_step()
        if step is None:
            return None
        meta = self._mgr.manifest(step)["meta"]
        sig = meta.get("signature")
        if sig != self.signature:
            diff = [k for k in set(self.signature) | set(sig or {})
                    if (sig or {}).get(k) != self.signature.get(k)]
            raise CalibJournalError(
                f"journal at {self.workdir} was written by a different "
                f"calibration run (mismatched: {sorted(diff)}); point "
                f"workdir at a fresh directory or delete the stale journal")
        try:
            tree = self._mgr.restore_nested(step)
        except CheckpointReadError as e:
            raise CalibJournalError(
                f"journal at {self.workdir} is unreadable (truncated or "
                f"corrupt snapshot): {e}") from e
        return {
            "next_unit": int(meta["next_unit"]),
            "x_fp": tree["x_fp"], "x_q": tree["x_q"],
            "mem_fp": tree.get("mem_fp"), "mem_q": tree.get("mem_q"),
            "v_all": {k.replace(_ESC, "/"): v
                      for k, v in tree.get("v", {}).items()},
            "s_all": {k.replace(_ESC, "/"): v
                      for k, v in tree.get("s", {}).items()},
            "unit_stats": list(meta.get("units", [])),
            "stream_peak": int(meta.get("stream_peak", 0)),
        }
