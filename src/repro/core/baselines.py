"""PTQ baselines the paper compares against (Tables 2-4).

* RTN            — round-to-nearest with minmax or MSE ("OMSE") scales.
* Bias correction (Nagel et al. 2019) — RTN + per-layer expected-output
                   correction folded into a bias term.
* AdaQuant       (Hubara et al. 2020) — per-layer continuous weight
                   perturbation optimized through an STE quantizer.
* LAPQ           (Nahshan et al. 2019) — loss-aware per-layer clip-scale
                   search on the task loss (coordinate descent flavour).

All share the Walker/QuantHook machinery so accuracy comparisons are
apples-to-apples with BRECQ.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models.common import NO_QUANT, QuantHook
from ..optim import adam
from . import lsq
from .hooks import RTNHook
from .quantizer import (QConfig, QState, fake_quant_ste, init_qstate,
                        quantize_dequant)
from .reconstruction import (ReconConfig, Walker, _apply_unit, _cap,
                             _concat_batches, _LayerHook, _slice_batch, bake,
                             enumerate_weights, init_states)

Array = jax.Array


# ---------------------------------------------------------------------------
# RTN
# ---------------------------------------------------------------------------


def quantize_rtn(model, params, calib_batches, w_bits: int,
                 a_bits: Optional[int] = None, scale_method: str = "mse",
                 w_group: Optional[int] = None,
                 keep_embed_head_8bit: bool = True):
    """Round-to-nearest PTQ baseline (no reconstruction).

    Args:
      model: block-graph model (same API as ``quantize``).
      params: FP parameters (never mutated).
      calib_batches: calibration batches; only used for weight
        enumeration and (when ``a_bits`` is set) minmax activation scales.
      w_bits: weight bit-width for block weights.
      a_bits: activation bit-width; ``None`` means weight-only.
      scale_method: ``'minmax'`` or ``'mse'`` (the paper's OMSE search).
      w_group: optional per-group weight quantization (rows per group
        along the reduction axis); ``None`` keeps per-channel scales.
      keep_embed_head_8bit: keep embedding/head at 8 bits.

    Returns:
      ``(params_q, act_scales)`` — a params copy with round-to-nearest
      weights baked in, and path -> activation scale (empty dict when
      ``a_bits`` is None). Feed both to ``evaluate``.
    """
    rc = ReconConfig(w_bits=w_bits, a_bits=a_bits, scale_method=scale_method,
                     w_group=w_group, keep_embed_head_8bit=keep_embed_head_8bit)
    calib = _concat_batches(calib_batches)
    probe = _slice_batch(calib, jnp.arange(1))
    weights = enumerate_weights(model, params, probe)
    qstates, embed_head = init_states(model, weights, rc)
    params_q = bake(model, params, qstates, {}, embed_head)
    act_scales = {}
    if a_bits is not None:
        walker = Walker(model)
        act_scales = _calibrate_act_scales(model, walker, params_q, calib, a_bits)
    return params_q, act_scales


def _calibrate_act_scales(model, walker, params_q, calib, a_bits: int) -> dict:
    """Minmax activation scales captured on the quantized model."""

    class _AllCap(QuantHook):
        def __init__(self):
            self.scales: dict[str, Array] = {}

        def act(self, path, x):
            s = lsq.init_act_scale(x, a_bits, symmetric=True)
            prev = self.scales.get(path)
            self.scales[path] = s if prev is None else jnp.maximum(prev, s)
            return x

    cap = _AllCap()
    walker.run(params_q, _slice_batch(calib, jnp.arange(min(8, calib["tokens"].shape[0]))), cap)
    return {k: jax.device_get(v) * 1.0 for k, v in cap.scales.items()}


# ---------------------------------------------------------------------------
# Bias correction
# ---------------------------------------------------------------------------


def quantize_bias_correction(model, params, calib_batches, w_bits: int,
                             scale_method: str = "minmax"):
    """RTN + expected-output correction: b += E[x](W - W_q), per layer.

    Matches Nagel et al. 2019 (no data-free BN trick here; we have real
    calibration activations). Only 2-D linears are corrected; stacked MoE
    expert weights stay RTN (noted in DESIGN.md).
    """
    rc = ReconConfig(w_bits=w_bits, scale_method=scale_method)
    calib = _concat_batches(calib_batches)
    probe = _slice_batch(calib, jnp.arange(1))
    weights = enumerate_weights(model, params, probe)
    qstates, embed_head = init_states(model, weights, rc)
    walker = Walker(model)

    x_q, _ = walker.stem(params, calib, RTNHook(embed_head))
    mem_q = None
    corrections: dict[str, Array] = {}
    v_done: dict[str, Array] = {}  # unused, hook API compat

    for bi in range(len(walker.blocks())):
        rec_hook = _BiasCorrHook(qstates, corrections)
        x_q = jax.jit(lambda x, m, h=rec_hook: _apply_unit(
            walker, params, [bi], h, x, calib, m))(x_q, mem_q)
        corrections.update(rec_hook.new_corr)
        if walker.encdec and bi == walker.enc_n - 1:
            mem_q, x_q = walker.boundary_transition(params, calib, x_q, RTNHook(embed_head))

    params_q = bake(model, params, qstates, {}, embed_head)
    params_q = _install_biases(params_q, corrections)
    return params_q, {}


class _BiasCorrHook(QuantHook):
    """Quantizes weights RTN and records E[x](W - Wq) for 2-D linears."""

    def __init__(self, qstates, existing):
        self.qstates = qstates
        self.new_corr: dict[str, Array] = {}
        self._pending: dict[str, Array] = {}
        self.existing = existing

    def act(self, path, x):
        if path in self.qstates:
            self._pending[path] = x
        return x

    def weight(self, path, w):
        if path not in self.qstates:
            return w
        st, cfg = self.qstates[path]
        wq = quantize_dequant(w, st, cfg)
        x = self._pending.get(path)
        if x is not None and w.ndim == 2:
            xm = jnp.mean(x.reshape(-1, x.shape[-1]).astype(jnp.float32), axis=0)
            self.new_corr[path] = xm @ (w - wq).astype(jnp.float32)
        return wq


def _install_biases(params_q, corrections: dict[str, Array]):
    for path, corr in corrections.items():
        parts = path.split("/")
        if "." not in parts[0]:
            continue  # embed/head: skip
        sname, ri = parts[0].rsplit(".", 1)
        ri = int(ri)
        node = params_q[sname]
        for k in parts[1:]:
            node = node[k]
        if "b" not in node:
            stacked = node["w"]
            node["b"] = jnp.zeros((stacked.shape[0], corr.shape[-1]), jnp.float32)
        node["b"] = node["b"].at[ri].add(corr)
    return params_q


# ---------------------------------------------------------------------------
# AdaQuant
# ---------------------------------------------------------------------------


def quantize_adaquant(model, params, calib_batches, w_bits: int,
                      a_bits: Optional[int] = None, iters: int = 400,
                      calib_bs: int = 8, lr: float = 1e-3, seed: int = 0):
    """Per-layer continuous weight perturbation through an STE quantizer."""
    rc = ReconConfig(w_bits=w_bits, a_bits=a_bits, scale_method="mse")
    calib = _concat_batches(calib_batches)
    N = calib["tokens"].shape[0]
    probe = _slice_batch(calib, jnp.arange(1))
    weights = enumerate_weights(model, params, probe)
    qstates, embed_head = init_states(model, weights, rc)
    walker = Walker(model)
    rng = np.random.default_rng(seed)

    x_fp, _ = walker.stem(params, calib)
    x_q, _ = walker.stem(params, calib, RTNHook(embed_head))
    mem_fp = mem_q = None
    deltas: dict[str, Array] = {}
    s_done: dict[str, Array] = {}

    for bi in range(len(walker.blocks())):
        from .hooks import RecordingHook

        rec = RecordingHook(capture_acts=True)
        _apply_unit(walker, params, [bi], rec, x_q[:1], _slice_batch(calib, jnp.arange(1)),
                    None if mem_q is None else mem_q[:1])
        wpaths = [p for p in rec.weights if p in qstates]
        z_fp = jax.jit(lambda x, m: _apply_unit(walker, params, [bi], NO_QUANT, x, calib, m))(x_fp, mem_fp)
        for path in wpaths:
            W = weights[path]
            st, qc = qstates[path]
            done_hook_states = {p: deltas[p] for p in deltas}
            xin_q = jax.jit(lambda x, m: _cap_adaquant(
                walker, params, bi, qstates, deltas, s_done, a_bits, path, x, calib, m))(x_q, mem_q)
            xin_fp = jax.jit(lambda x, m: _cap(walker, params, bi, qstates, {}, {},
                                               dataclasses.replace(rc, a_bits=None),
                                               path, x, calib, m))(x_fp, mem_fp)
            zt = jnp.matmul(xin_fp, W.astype(xin_fp.dtype))
            if a_bits is not None:
                s_done[path] = lsq.init_act_scale(xin_q, a_bits, symmetric=True)
            opt = {"dw": jnp.zeros_like(W)}

            def layer_loss(opt, xb, zb):
                wq = fake_quant_ste(W + opt["dw"], st, qc)
                x = xb
                if a_bits is not None:
                    x = lsq.lsq_quant(x, s_done[path], a_bits, True)
                z = jnp.matmul(x, wq.astype(x.dtype))
                return jnp.mean((z - zb).astype(jnp.float32) ** 2)

            grad_fn = jax.jit(jax.value_and_grad(layer_loss))
            acfg = adam.AdamConfig(lr=lr)
            ostate = adam.init(opt)
            step_fn = jax.jit(lambda o, s, g: adam.update(acfg, g, s, o))
            for it in range(iters):
                idx = jnp.asarray(rng.choice(N, size=min(calib_bs, N), replace=False))
                _, g = grad_fn(opt, xin_q[idx], zt[idx])
                opt, ostate = step_fn(opt, ostate, g)
            deltas[path] = opt["dw"]
        x_q = jax.jit(lambda x, m: _apply_unit(
            walker, params, [bi],
            _AdaQuantHook(qstates, deltas, s_done, a_bits), x, calib, m))(x_q, mem_q)
        x_fp = z_fp
        if walker.encdec and bi == walker.enc_n - 1:
            mem_fp, x_fp = walker.boundary_transition(params, calib, x_fp)
            mem_q, x_q = walker.boundary_transition(params, calib, x_q, RTNHook(embed_head))

    # bake: w -> qdq(w + dw)
    adj = {p: (qstates[p], deltas[p]) for p in deltas}
    params_q = bake(model, params,
                    {p: qstates[p] for p in qstates if p not in deltas}, {}, embed_head)
    params_q = _bake_deltas(model, params_q, adj)
    return params_q, dict(s_done)


class _AdaQuantHook(QuantHook):
    def __init__(self, qstates, deltas, s_done, a_bits):
        self.qstates = qstates
        self.deltas = deltas
        self.s_done = s_done
        self.a_bits = a_bits

    def weight(self, path, w):
        if path in self.deltas:
            st, cfg = self.qstates[path]
            return quantize_dequant(w + self.deltas[path], st, cfg)
        return w

    def act(self, path, x):
        if self.a_bits is not None and path in self.s_done:
            return lsq.lsq_quant(x, self.s_done[path], self.a_bits, True)
        return x


def _cap_adaquant(walker, params, bi, qstates, deltas, s_done, a_bits, path, x, calib, mem):
    hook = _AdaQuantHook(qstates, deltas, s_done, a_bits)
    cap: dict[str, Array] = {}

    orig_act = hook.act

    def act(p, xx):
        xx = orig_act(p, xx)
        if p == path:
            cap["x"] = xx
        return xx

    hook.act = act
    _apply_unit(walker, params, [bi], hook, x, calib, mem)
    return cap["x"]


def _bake_deltas(model, params_q, adj):
    from .reconstruction import bake as _  # noqa: F401  (path helper reuse)

    def set_leaf(path, fn):
        parts = path.split("/")
        sname, ri = parts[0].rsplit(".", 1)
        ri = int(ri)
        node = params_q[sname]
        keys = parts[1:] + ["w"]
        for k in keys[:-1]:
            node = node[k]
        leaf = node[keys[-1]]
        node[keys[-1]] = leaf.at[ri].set(fn(leaf[ri]))

    for path, ((st, cfg), dw) in adj.items():
        set_leaf(path, lambda w, st=st, cfg=cfg, dw=dw: quantize_dequant(w + dw, st, cfg))
    return params_q


# ---------------------------------------------------------------------------
# LAPQ-style loss-aware scale search
# ---------------------------------------------------------------------------


def quantize_lapq(model, params, calib_batches, w_bits: int,
                  a_bits: Optional[int] = None,
                  ratios=(0.5, 0.6, 0.7, 0.8, 0.9, 1.0), rounds: int = 1):
    """Coordinate-descent over per-layer clip ratios minimising task loss."""
    rc = ReconConfig(w_bits=w_bits, a_bits=a_bits, scale_method="minmax")
    calib = _concat_batches(calib_batches)
    probe = _slice_batch(calib, jnp.arange(min(8, calib["tokens"].shape[0])))
    weights = enumerate_weights(model, params, _slice_batch(calib, jnp.arange(1)))
    qstates, embed_head = init_states(model, weights, rc)
    walker = Walker(model)

    paths = list(qstates.keys())
    chosen = {p: 1.0 for p in paths}

    def loss_with(scales: dict[str, float]) -> float:
        states = {p: (QState(qstates[p][0].scale * scales[p], qstates[p][0].zero_point),
                      qstates[p][1]) for p in paths}
        states.update(embed_head)
        hook = RTNHook(states)
        return float(walker.loss(params, probe, hook))

    eval_fn = loss_with
    for _ in range(rounds):
        for p in paths:
            best_r, best_l = chosen[p], None
            for r in ratios:
                trial = dict(chosen)
                trial[p] = r
                l = eval_fn(trial)
                if best_l is None or l < best_l:
                    best_l, best_r = l, r
            chosen[p] = best_r

    states = {p: (QState(qstates[p][0].scale * chosen[p], qstates[p][0].zero_point),
                  qstates[p][1]) for p in paths}
    params_q = bake(model, params, states, {}, embed_head)
    act_scales = {}
    if a_bits is not None:
        act_scales = _calibrate_act_scales(model, walker, params_q, calib, a_bits)
    return params_q, act_scales
