"""AdaRound learned rounding (Nagel et al. 2020), as used by BRECQ.

Weights are floor-quantized and a per-weight logit ``v`` chooses floor vs
ceil through a rectified sigmoid.  During reconstruction the *soft*
rounding value h(v) in [0,1] flows gradients; after calibration the
rounding is hardened to {0,1} (Eq. 16 of the paper).

The regularizer f_reg = sum(1 - |2 h(v) - 1|^beta) pushes h(v) to binary
as beta anneals (Eq. 17).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .quantizer import QConfig, QState, _group_reshape

Array = jax.Array

# rectified-sigmoid stretch constants from the AdaRound paper
ZETA = 1.1
GAMMA = -0.1


def rect_sigmoid(v: Array) -> Array:
    """h(v) = clip(sigmoid(v) * (zeta - gamma) + gamma, 0, 1)."""
    return jnp.clip(jax.nn.sigmoid(v) * (ZETA - GAMMA) + GAMMA, 0.0, 1.0)


def init_v(w: Array, st: QState, cfg: QConfig) -> Array:
    """Initialise v so that soft-quantization reproduces round-to-nearest."""
    if cfg.group_size is not None:
        wg = _group_reshape(w, cfg)
        frac = wg / st.scale - jnp.floor(wg / st.scale)
        frac = frac.reshape(w.shape)
    else:
        frac = w / st.scale - jnp.floor(w / st.scale)
    # invert h(v) = frac  =>  sigmoid(v) = (frac - gamma)/(zeta - gamma)
    p = jnp.clip((frac - GAMMA) / (ZETA - GAMMA), 1e-4, 1 - 1e-4)
    return jnp.log(p / (1 - p)).astype(jnp.float32)


def soft_quant(w: Array, v: Array, st: QState, cfg: QConfig) -> Array:
    """Differentiable AdaRound forward: s * clip(floor(w/s) + h(v), n, p)."""
    if cfg.group_size is not None:
        wg = _group_reshape(w, cfg)
        hg = rect_sigmoid(v).reshape(wg.shape)
        q = jnp.clip(jnp.floor(wg / st.scale) + hg + st.zero_point,
                     cfg.qmin, cfg.qmax)
        return ((q - st.zero_point) * st.scale).reshape(w.shape)
    q = jnp.clip(jnp.floor(w / st.scale) + rect_sigmoid(v) + st.zero_point,
                 cfg.qmin, cfg.qmax)
    return (q - st.zero_point) * st.scale


def hard_quant(w: Array, v: Array, st: QState, cfg: QConfig) -> Array:
    """Post-calibration forward: h(v) hardened to {0, 1}."""
    hard = (v >= 0).astype(w.dtype)
    if cfg.group_size is not None:
        wg = _group_reshape(w, cfg)
        q = jnp.clip(jnp.floor(wg / st.scale) + hard.reshape(wg.shape)
                     + st.zero_point, cfg.qmin, cfg.qmax)
        return ((q - st.zero_point) * st.scale).reshape(w.shape)
    q = jnp.clip(jnp.floor(w / st.scale) + hard + st.zero_point,
                 cfg.qmin, cfg.qmax)
    return (q - st.zero_point) * st.scale


def hard_int_codes(w: Array, v: Array, st: QState, cfg: QConfig) -> Array:
    """Integer codes after hardening (deployment path, feeds pack_int)."""
    hard = (v >= 0).astype(jnp.float32)
    if cfg.group_size is not None:
        wg = _group_reshape(w, cfg)
        q = jnp.clip(jnp.floor(wg / st.scale) + hard.reshape(wg.shape)
                     + st.zero_point, cfg.qmin, cfg.qmax)
        return q.reshape(w.shape).astype(jnp.int8)
    q = jnp.clip(jnp.floor(w / st.scale) + hard + st.zero_point,
                 cfg.qmin, cfg.qmax)
    return q.astype(jnp.int8)


def round_reg(v: Array, beta: Array) -> Array:
    """f_reg = sum_i (1 - |2 h(v_i) - 1|^beta)."""
    return jnp.sum(1.0 - jnp.abs(2.0 * rect_sigmoid(v) - 1.0) ** beta)


@dataclasses.dataclass(frozen=True)
class BetaSchedule:
    """Anneal beta high->low so h(v) converges to binary.

    ``warmup`` fraction of iterations applies no regularization at all
    (AdaRound default 0.2), then beta decays linearly beta_hi -> beta_lo.
    """

    beta_hi: float = 20.0
    beta_lo: float = 2.0
    warmup: float = 0.2

    def __call__(self, it: Array, total: int) -> tuple[Array, Array]:
        """Returns (beta, reg_enabled)."""
        t = jnp.clip((it / total - self.warmup) / (1.0 - self.warmup), 0.0, 1.0)
        beta = self.beta_hi + (self.beta_lo - self.beta_hi) * t
        enabled = (it >= self.warmup * total).astype(jnp.float32)
        return beta, enabled
