"""Quantization hooks: the glue between models (which only know
``QuantHook``) and the BRECQ machinery (quantizer/adaround/lsq)."""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from ..models.common import QuantHook
from . import adaround, lsq
from .quantizer import QConfig, QState, quantize_dequant

Array = jax.Array


class RecordingHook(QuantHook):
    """Records every (path, shape) the model touches; used to enumerate
    quantizable layers and to capture linear inputs for layer-wise
    reconstruction.

    Safe to use inside a traced function: ``weights`` records concrete
    shapes either way, and ``acts`` holds tracers that the enclosing
    program can return as outputs (this is how the cached unit probe in
    :mod:`calib_loop` extracts activations without an eager forward)."""

    def __init__(self, capture_acts: bool = False):
        self.weights: dict[str, tuple] = {}
        self.acts: dict[str, Array] = {}
        self.capture_acts = capture_acts

    def weight(self, path: str, w: Array) -> Array:
        self.weights[path] = tuple(w.shape)
        return w

    def act(self, path: str, x: Array) -> Array:
        if self.capture_acts:
            self.acts[path] = x
        return x


class RTNHook(QuantHook):
    """Round-to-nearest fake quantization per path (baseline + init)."""

    def __init__(self, states: dict[str, tuple[QState, QConfig]],
                 act_scales: Optional[dict[str, Array]] = None,
                 a_bits: Optional[int] = None):
        self.states = states
        self.act_scales = act_scales or {}
        self.a_bits = a_bits

    def weight(self, path: str, w: Array) -> Array:
        if path in self.states:
            st, cfg = self.states[path]
            return quantize_dequant(w, st, cfg)
        return w

    def act(self, path: str, x: Array) -> Array:
        if self.a_bits is not None and path in self.act_scales:
            return lsq.lsq_quant(x, self.act_scales[path], self.a_bits, True)
        return x


class AdaRoundHook(QuantHook):
    """Soft (differentiable) or hard AdaRound weights + LSQ activations.

    ``opt`` is the pytree of optimization variables: {'v': {path: arr},
    's': {path: scalar}} so jax.grad can differentiate through the hook.
    """

    def __init__(self, states: dict[str, tuple[QState, QConfig]],
                 opt: dict, a_bits: Optional[int] = None, soft: bool = True):
        self.states = states
        self.opt = opt
        self.a_bits = a_bits
        self.soft = soft

    def weight(self, path: str, w: Array) -> Array:
        if path not in self.states or path not in self.opt["v"]:
            return w
        st, cfg = self.states[path]
        fn = adaround.soft_quant if self.soft else adaround.hard_quant
        return fn(w, self.opt["v"][path], st, cfg)

    def act(self, path: str, x: Array) -> Array:
        if self.a_bits is None or path not in self.opt.get("s", {}):
            return x
        return lsq.lsq_quant(x, self.opt["s"][path], self.a_bits, True)


class LayerCaptureHook(QuantHook):
    """Layer-wise reconstruction hook: hard-quantizes already-finished
    paths (``v_done``) and captures the input activation of one
    ``target`` linear. Path keys may be real (``body.3/attn/wq``) or
    canonical (``u0/attn/wq``) — the hook only matches strings, so the
    cached capture programs in :mod:`calib_loop` run it under canonical
    scopes."""

    def __init__(self, qstates, v_done: dict, target: Optional[str],
                 act_scales: Optional[dict] = None, a_bits: Optional[int] = None):
        self.qstates = qstates
        self.v_done = v_done
        self.target = target
        self.captured: Optional[Array] = None
        self.act_scales = act_scales or {}
        self.a_bits = a_bits

    def weight(self, path, w):
        if path in self.v_done:
            st, cfg = self.qstates[path]
            return adaround.hard_quant(w, self.v_done[path], st, cfg)
        return w

    def act(self, path, x):
        if self.a_bits is not None and path in self.act_scales:
            x = lsq.lsq_quant(x, self.act_scales[path], self.a_bits, True)
        if path == self.target:
            self.captured = x
        return x


class ServeHook(QuantHook):
    """Post-calibration serving hook: weights are already baked into the
    params; only activation fake-quant remains."""

    def __init__(self, act_scales: dict[str, Array], a_bits: int):
        self.act_scales = act_scales
        self.a_bits = a_bits

    def act(self, path: str, x: Array) -> Array:
        s = self.act_scales.get(path)
        if s is None:
            return x
        return lsq.lsq_quant(x, s, self.a_bits, True)


class StackedActHook(QuantHook):
    """Activation hook for the scan-based forward: scales for the current
    block are a per-path dict sliced out of the stacked (n, ...) tree."""

    def __init__(self, scales: dict[str, Array], a_bits: int):
        self.scales = scales
        self.a_bits = a_bits

    def act(self, path: str, x: Array) -> Array:
        s = self.scales.get(path)
        if s is None:
            return x
        return lsq.lsq_quant(x, s, self.a_bits, True)
