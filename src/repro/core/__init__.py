from .adaround import BetaSchedule  # noqa: F401
from .journal import (CalibJournal, CalibJournalError,  # noqa: F401
                      CalibrationInterrupted)
from .quantizer import QConfig, QState, init_qstate, quantize_dequant  # noqa: F401
from .reconstruction import PTQResult, ReconConfig, Walker, quantize  # noqa: F401
