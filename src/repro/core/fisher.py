"""Diagonal Fisher (squared-gradient) capture at block outputs.

BRECQ Sec. 3.3: the pre-activation Hessian of each reconstruction unit is
approximated by the diagonal FIM, whose entries are the squared gradients
of the task loss w.r.t. the unit's output, evaluated per calibration
sample. Gradients come from the epsilon trick: add a zero perturbation at
a block output; d(loss)/d(eps) is exactly dL/dz.

Two residency modes (:class:`FisherStream`):

* ``mode='stream'`` (default) — g^2 is computed **per block, on demand**,
  chunked over the calibration batches: one backward per (block, batch),
  each batch's squared gradient cast to ``dtype`` (bf16 by default)
  immediately, with the normalising mean reduced in f32. Peak residency
  is one block's ``(N, S, d)`` array regardless of model depth, at the
  cost of one extra backward per reconstruction unit.
* ``mode='full'`` — the reference behaviour: one backward per batch
  captures *all* block outputs at once (a single eps per block), keeping
  ``nb x N x S x d`` f32 resident for the whole calibration run. Kept for
  parity tests and for granularities that consume every block anyway.

See ``docs/memory.md`` for the full calibration memory model.
"""
from __future__ import annotations

import time
import weakref
from typing import Any, Optional

import jax
import jax.numpy as jnp

Array = jax.Array

# jitted per-block grad programs, keyed by (model id, block, shapes) so
# repeated quantize() calls on the same model never re-trace. Guarded by
# a model weakref like the calib_loop caches.
_GRAD_CACHE: dict[tuple, Any] = {}


def clear_cache() -> None:
    _GRAD_CACHE.clear()


def _batch_sig(batch: dict) -> tuple:
    return tuple(sorted((k, tuple(v.shape), str(v.dtype))
                        for k, v in batch.items()))


class FisherStream:
    """Per-block diagonal-Fisher provider with bounded residency.

    Args:
      walker: a ``reconstruction.Walker`` over the FP model.
      params: FP parameters (never mutated).
      calib_batches: list of calibration batches; g^2 is computed batch by
        batch and concatenated along the leading (sample) axis.
      mode: ``'stream'`` (per-block on demand) or ``'full'`` (all blocks
        upfront, f32 — the seed behaviour).
      dtype: storage dtype for streamed g^2 (``'full'`` always keeps f32).

    Attributes:
      wall_s: cumulative seconds spent in Fisher computation.
      peak_bytes: estimated peak residency in bytes — one block's array in
        ``'stream'`` mode, the sum of all blocks in ``'full'`` mode.
    """

    def __init__(self, walker, params, calib_batches: list[dict],
                 mode: str = "stream", dtype=jnp.bfloat16):
        if mode not in ("stream", "full"):
            raise ValueError(f"fisher mode must be 'stream' or 'full', got {mode!r}")
        self.walker = walker
        self.params = params
        self.batches = calib_batches
        self.mode = mode
        self.dtype = jnp.dtype(dtype)
        self.wall_s = 0.0
        self.peak_bytes = 0
        self._full: Optional[list[Array]] = None
        if mode == "full":
            t0 = time.time()
            self._full = jax.block_until_ready(self._compute_full())
            self.peak_bytes = sum(f.size * f.dtype.itemsize for f in self._full)
            self.wall_s += time.time() - t0

    # -- full (reference) mode ---------------------------------------------

    def _compute_full(self) -> list[Array]:
        walker = self.walker
        nb = len(walker.blocks())
        grad_fn = jax.jit(lambda eps, b: jax.grad(
            lambda e: walker.loss(self.params, b, eps=e))(eps))
        parts: list[list[Array]] = [[] for _ in range(nb)]
        for b in self.batches:
            eps = _zero_eps(walker, self.params, b)
            grads = grad_fn(eps, b)
            for bi, g in enumerate(grads):
                parts[bi].append(g.astype(jnp.float32) ** 2)
        fisher = [jnp.concatenate(p, 0) for p in parts]
        return [f / jnp.maximum(jnp.mean(f), 1e-20) for f in fisher]

    # -- streamed mode ------------------------------------------------------

    def _grad_fn(self, bi: int):
        """Jitted dL/dz_bi for one batch, cached across quantize() calls."""
        walker = self.walker
        model = walker.model
        nb = len(walker.blocks())
        key = ("fisher_grad", id(model), bi, nb,
               _batch_sig(self.batches[0]), str(self.dtype))
        hit = _GRAD_CACHE.get(key)
        if hit is not None and hit[0]() is model:
            hit[1][0] = weakref.ref(walker)
            return hit[2]
        for k in [k for k, v in _GRAD_CACHE.items() if v[0]() is None]:
            del _GRAD_CACHE[k]
        model_ref = weakref.ref(model)
        walker_cell = [weakref.ref(walker)]
        dtype = self.dtype

        def g2_of(params, batch):
            wkr = walker_cell[0]()
            e0 = _eps_zero_for(wkr, params, batch, bi)

            def loss_fn(e):
                eps: list = [None] * nb
                eps[bi] = e
                return wkr.loss(params, batch, eps=eps)

            g = jax.grad(loss_fn)(e0)
            g2 = g.astype(jnp.float32) ** 2
            # f32 reduction for the normalising mean; bf16 storage
            return g2.astype(dtype), jnp.sum(g2, dtype=jnp.float32)

        fn = jax.jit(g2_of)
        _GRAD_CACHE[key] = (model_ref, walker_cell, fn)
        return fn

    def for_block(self, bi: int) -> Array:
        """Normalised g^2 at block ``bi``'s output, shape ``(N, S, d)``.

        In ``'stream'`` mode each call recomputes (nothing is retained
        between calls — that is the point); in ``'full'`` mode it indexes
        the precomputed list.
        """
        if self._full is not None:
            return self._full[bi]
        t0 = time.time()
        fn = self._grad_fn(bi)
        parts, total, count = [], jnp.float32(0.0), 0
        for b in self.batches:
            g2, s = fn(self.params, b)
            parts.append(g2)
            total = total + s
            count += g2.size
        g2 = jnp.concatenate(parts, 0)
        mean = jnp.maximum(total / count, 1e-20)
        # sync before timing: async dispatch would otherwise book the
        # Fisher compute into the caller's opt_wall_s
        g2 = jax.block_until_ready(g2 / mean.astype(g2.dtype))
        self.peak_bytes = max(self.peak_bytes, g2.size * g2.dtype.itemsize)
        self.wall_s += time.time() - t0
        return g2


def _eps_zero_for(walker, params, batch: dict, bi: int) -> Array:
    """Zero perturbation with the shape of block ``bi``'s output."""
    x0, _ = walker.stem(params, batch)
    if walker.encdec and bi >= walker.enc_n:
        B, S = batch["tokens"].shape
        return jnp.zeros((B, S, x0.shape[-1]), x0.dtype)
    return jnp.zeros_like(x0)


def _zero_eps(walker, params, batch: dict) -> list[Array]:
    """One zero perturbation per block (full-mode eps trick)."""
    x, ctx = walker.stem(params, batch)
    eps = []
    for bi in range(len(walker.blocks())):
        eps.append(jnp.zeros_like(x))
        x = walker.apply_block(params, bi, x, ctx)
        if walker.encdec and bi == walker.enc_n - 1:
            _, x = walker.boundary_transition(params, batch, x)
            ctx = walker.ctx_for(batch, bi + 1, None)
    return eps


def block_grads(model, params, batch: dict) -> list[Array]:
    """Per-block output gradients dL/dz_i of the FP model on one batch.

    Returns a list aligned with ``model_blocks(model)``: each entry has
    the block-output shape (B, S, d).
    """
    blocks = model_blocks(model)

    def loss_fn(eps_list):
        x, ctx = model.begin(params, batch)
        for (stack, ri), eps in zip(blocks, eps_list):
            p_i = jax.tree.map(lambda a: a[ri], params[stack.name])
            x, _ = model.apply_block(ctx, stack, p_i, x)
            x = x + eps
        logits = model.finish(params, x, ctx)
        tokens = batch["tokens"]
        from ..models.common import softmax_xent

        return softmax_xent(logits[:, :-1], tokens[:, 1:])

    x0, _ = model.begin(params, batch)
    eps0 = [jnp.zeros_like(x0) for _ in blocks]
    return jax.grad(loss_fn)(eps0)


def model_blocks(model) -> list[tuple[Any, int]]:
    """Flattened (stack, rel_idx) order of all reconstruction blocks."""
    out = []
    for stack in brecq_stacks(model):
        for ri in range(stack.n):
            out.append((stack, ri))
    return out


def brecq_stacks(model):
    """Stacks walked by BRECQ, in forward order (encoder first for enc-dec)."""
    if hasattr(model, "enc_stack"):
        return [model.enc_stack, model.dec_stack]
    return model.stacks
