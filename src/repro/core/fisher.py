"""Diagonal Fisher (squared-gradient) capture at block outputs.

BRECQ Sec. 3.3: the pre-activation Hessian of each reconstruction unit is
approximated by the diagonal FIM, whose entries are the squared gradients
of the task loss w.r.t. the unit's output. We capture them for *all*
blocks in one backward pass with the epsilon trick: add a zero
perturbation at every block output; d(loss)/d(eps) is exactly dL/dz.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

Array = jax.Array


def block_grads(model, params, batch: dict) -> list[Array]:
    """Per-block output gradients dL/dz_i of the FP model on one batch.

    Returns a list aligned with ``model_blocks(model)``: each entry has
    the block-output shape (B, S, d).
    """
    blocks = model_blocks(model)

    def loss_fn(eps_list):
        x, ctx = model.begin(params, batch)
        for (stack, ri), eps in zip(blocks, eps_list):
            p_i = jax.tree.map(lambda a: a[ri], params[stack.name])
            x, _ = model.apply_block(ctx, stack, p_i, x)
            x = x + eps
        logits = model.finish(params, x, ctx)
        tokens = batch["tokens"]
        from ..models.common import softmax_xent

        return softmax_xent(logits[:, :-1], tokens[:, 1:])

    x0, _ = model.begin(params, batch)
    eps0 = [jnp.zeros_like(x0) for _ in blocks]
    return jax.grad(loss_fn)(eps0)


def model_blocks(model) -> list[tuple[Any, int]]:
    """Flattened (stack, rel_idx) order of all reconstruction blocks."""
    out = []
    for stack in brecq_stacks(model):
        for ri in range(stack.n):
            out.append((stack, ri))
    return out


def brecq_stacks(model):
    """Stacks walked by BRECQ, in forward order (encoder first for enc-dec)."""
    if hasattr(model, "enc_stack"):
        return [model.enc_stack, model.dec_stack]
    return model.stacks
