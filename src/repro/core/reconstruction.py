"""BRECQ block reconstruction engine (paper Alg. 1).

Pipeline:
  1. Enumerate quantizable weights by walking the model once.
  2. Capture the FP activation stream and, with one backward pass per
     calibration batch (epsilon trick), the diagonal Fisher at every
     block output.
  3. Partition blocks into reconstruction units: layer / block / stage /
     net (Sec. 3.2). Units never cross the enc->dec boundary.
  4. Per unit: optimize AdaRound logits (+ LSQ activation step sizes)
     with Adam on the Fisher-weighted output MSE + beta-annealed rounding
     regularizer. Inputs come from the *quantized* stream (error
     propagates, as in the reference implementation); targets from the
     FP stream.
  5. Harden rounding, advance the quantized stream, continue.
  6. Bake hard-quantized weights back into a params copy for serving.

Execution here is python-level block-by-block (calibration happens on
paper-scale models); training/serving use the scan-based forward.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models.common import NO_QUANT, Ctx, QuantHook
from ..optim import adam
from . import adaround, lsq
from .adaround import BetaSchedule
from .hooks import AdaRoundHook, RecordingHook, RTNHook
from .quantizer import QConfig, QState, init_qstate, quantize_dequant

Array = jax.Array
Params = Any


# ---------------------------------------------------------------------------
# model walker: python-level block-by-block execution
# ---------------------------------------------------------------------------


class Walker:
    """Sequential (non-scan) execution of a model's block graph."""

    def __init__(self, model):
        self.model = model
        self.encdec = hasattr(model, "enc_stack")
        self.enc_n = self.model.enc_stack.n if self.encdec else 0

    def blocks(self) -> list[tuple[Any, int]]:
        if self.encdec:
            stacks = [self.model.enc_stack, self.model.dec_stack]
        else:
            stacks = self.model.stacks
        return [(s, i) for s in stacks for i in range(s.n)]

    def block_path(self, bi: int) -> str:
        stack, ri = self.blocks()[bi]
        return f"{stack.name}.{ri}"

    def stem(self, params, batch, quant=NO_QUANT):
        """Activations entering block 0 (+ its ctx)."""
        if self.encdec:
            frames = batch["frames"]
            B, S, _ = frames.shape
            pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
            ctx = Ctx(cfg=self.model.cfg, positions=pos, quant=quant)
            return frames + params["enc_pos"][:S], ctx
        return self.model.begin(params, batch, quant)

    def ctx_for(self, batch, bi: int, memory: Optional[Array], quant=NO_QUANT) -> Ctx:
        """Ctx entering block ``bi`` given the stream's encoder memory."""
        cfg = self.model.cfg
        if self.encdec and bi >= self.enc_n:
            tokens = batch["tokens"]
            B, S = tokens.shape
            pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
            ctx = Ctx(cfg=cfg, positions=pos, quant=quant)
            ctx.extras["memory"] = memory
            return ctx
        if self.encdec:
            frames = batch["frames"]
            B, S, _ = frames.shape
            pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
            return Ctx(cfg=cfg, positions=pos, quant=quant)
        tokens = batch["tokens"]
        B, S = tokens.shape
        pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        ctx = Ctx(cfg=cfg, positions=pos, quant=quant)
        if cfg.family == "vlm":
            ctx.extras["memory"] = batch["patches"]
        return ctx

    def apply_block(self, params, bi: int, x, ctx, quant=NO_QUANT):
        stack, ri = self.blocks()[bi]
        p_i = jax.tree.map(lambda a: a[ri], params[stack.name])
        ctx2 = dataclasses.replace(ctx, quant=quant, scope=self.block_path(bi))
        y, _ = self.model.apply_block(ctx2, stack, p_i, x)
        return y

    def boundary_transition(self, params, batch, x, quant=NO_QUANT):
        """enc output -> (memory, decoder stem x)."""
        from ..models.transformer import _norm

        memory = _norm(self.model.cfg, params["enc_norm"], x)
        hook = quant if quant is not None else NO_QUANT
        table = hook.weight("embed/table", params["embed"]["table"])
        xdec = jnp.take(table, batch["tokens"], axis=0)
        return memory, xdec

    def run(self, params, batch, quant=NO_QUANT, eps: Optional[list] = None):
        """Full forward block-by-block (used for eval & the Fisher pass)."""
        x, ctx = self.stem(params, batch, quant)
        memory = None
        for bi in range(len(self.blocks())):
            x = self.apply_block(params, bi, x, ctx, quant)
            if eps is not None:
                x = x + eps[bi]
            if self.encdec and bi == self.enc_n - 1:
                memory, x = self.boundary_transition(params, batch, x, quant)
                ctx = self.ctx_for(batch, bi + 1, memory, quant)
        return self.model.finish(params, x, ctx)

    def loss(self, params, batch, quant=NO_QUANT, eps=None):
        from ..models.common import softmax_xent

        logits = self.run(params, batch, quant, eps)
        tokens = batch["tokens"]
        return softmax_xent(logits[:, :-1], tokens[:, 1:])


# ---------------------------------------------------------------------------
# configuration
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ReconConfig:
    w_bits: int = 4
    a_bits: Optional[int] = None  # None = weight-only
    w_group: Optional[int] = None  # per-group quantization (beyond-paper)
    scale_method: str = "mse"
    iters: int = 800  # paper: 20k/block; CI uses less
    calib_bs: int = 8
    lr_v: float = 1e-3
    lr_s: float = 4e-5
    granularity: str = "block"  # layer | block | stage | net
    n_stages: int = 4
    use_fisher: bool = True
    keep_embed_head_8bit: bool = True
    lam: float = 0.01
    beta: BetaSchedule = dataclasses.field(default_factory=BetaSchedule)
    input_source: str = "quant"  # 'quant' | 'fp' | 'mix'
    input_mix_prob: float = 0.5  # QDrop-style mixing (beyond paper)
    per_layer_bits: Optional[dict] = None  # path -> bits (mixed precision)
    seed: int = 0


@dataclasses.dataclass
class PTQResult:
    params_q: Params
    act_scales: dict  # path -> scalar ({} when a_bits is None)
    qstates: dict  # path -> (QState, QConfig)
    v: dict  # path -> rounding logits
    stats: dict


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------


def _concat_batches(batches: list[dict]) -> dict:
    return {k: jnp.concatenate([b[k] for b in batches], 0) for k in batches[0]}


def _slice_batch(batch: dict, idx) -> dict:
    return {k: v[idx] for k, v in batch.items()}


class _ValHook(QuantHook):
    def __init__(self):
        self.vals: dict[str, Array] = {}

    def weight(self, path, w):
        self.vals[path] = w
        return w


def enumerate_weights(model, params, batch) -> dict[str, Array]:
    """path -> weight array for every quant-eligible weight."""
    walker = Walker(model)
    hook = _ValHook()
    walker.run(params, batch, hook)
    return hook.vals


def _bits_for(rc: ReconConfig, path: str) -> int:
    if rc.per_layer_bits and path in rc.per_layer_bits:
        return rc.per_layer_bits[path]
    return rc.w_bits


def init_states(model, weights: dict[str, Array], rc: ReconConfig):
    """Quantizer state for block weights + 8-bit embed/head handling."""
    qstates: dict[str, tuple[QState, QConfig]] = {}
    embed_head: dict[str, tuple[QState, QConfig]] = {}
    for path, w in weights.items():
        if path in ("embed/table", "head/w"):
            if not rc.keep_embed_head_8bit:
                continue
            if path == "head/w" and model.cfg.tie_embeddings:
                continue  # tied: baking the embed covers the head
            cfg = QConfig(bits=8, channel_axis=-1, scale_method="mse")
            embed_head[path] = (init_qstate(w, cfg), cfg)
        else:
            cfg = QConfig(bits=_bits_for(rc, path), channel_axis=-1,
                          group_size=rc.w_group, scale_method=rc.scale_method)
            qstates[path] = (init_qstate(w, cfg), cfg)
    return qstates, embed_head


def _partition(walker: Walker, rc: ReconConfig) -> list[list[int]]:
    nb = len(walker.blocks())
    if rc.granularity in ("layer", "block"):
        return [[i] for i in range(nb)]
    segs = _segments(walker)
    if rc.granularity == "net":
        return segs
    if rc.granularity == "stage":
        units = []
        for seg in segs:
            k = max(1, (len(seg) + rc.n_stages - 1) // rc.n_stages)
            units += [seg[i:i + k] for i in range(0, len(seg), k)]
        return units
    raise ValueError(rc.granularity)


def _segments(walker: Walker) -> list[list[int]]:
    nb = len(walker.blocks())
    if walker.encdec:
        return [list(range(walker.enc_n)), list(range(walker.enc_n, nb))]
    return [list(range(nb))]


# ---------------------------------------------------------------------------
# engine
# ---------------------------------------------------------------------------


def quantize(model, params, calib_batches: list[dict], rc: ReconConfig) -> PTQResult:
    """Run BRECQ calibration; returns hard-quantized params + act scales."""
    t0 = time.time()
    walker = Walker(model)
    nb = len(walker.blocks())
    calib = _concat_batches(calib_batches)
    N = calib["tokens"].shape[0]
    rng = np.random.default_rng(rc.seed)

    probe = _slice_batch(calib, jnp.arange(1))
    weights = enumerate_weights(model, params, probe)
    qstates, embed_head = init_states(model, weights, rc)
    q_stem_hook = RTNHook(embed_head)

    # -- Fisher at every block output (FP model, eps trick) -------------------
    fisher: list[Optional[Array]] = [None] * nb
    if rc.use_fisher and rc.granularity != "layer":
        grad_fn = jax.jit(lambda eps, b: jax.grad(
            lambda e: walker.loss(params, b, eps=e))(eps))
        parts: list[list[Array]] = [[] for _ in range(nb)]
        for b in calib_batches:
            eps = _zero_eps(walker, params, b)
            grads = grad_fn(eps, b)
            for bi, g in enumerate(grads):
                parts[bi].append(g.astype(jnp.float32) ** 2)
        fisher = [jnp.concatenate(p, 0) for p in parts]
        fisher = [f / jnp.maximum(jnp.mean(f), 1e-20) for f in fisher]

    # -- streams ------------------------------------------------------------------
    x_fp = jax.jit(lambda b: walker.stem(params, b)[0])(calib)
    x_q = jax.jit(lambda b: walker.stem(params, b, q_stem_hook)[0])(calib)
    mem_fp: Optional[Array] = None
    mem_q: Optional[Array] = None

    units = _partition(walker, rc)
    v_all: dict[str, Array] = {}
    s_all: dict[str, Array] = {}
    stats = {"units": [], "granularity": rc.granularity}

    for unit in units:
        if rc.granularity == "layer":
            x_fp, x_q, v_u, s_u, ustat = _reconstruct_layerwise(
                model, walker, params, weights, calib, unit[0], x_fp, x_q,
                mem_fp, mem_q, qstates, rc, rng)
        else:
            x_fp, x_q, v_u, s_u, ustat = _reconstruct_unit(
                model, walker, params, weights, calib, unit, x_fp, x_q,
                mem_fp, mem_q, fisher, qstates, rc, rng)
        v_all.update(v_u)
        s_all.update(s_u)
        stats["units"].append(ustat)
        # enc->dec boundary transition between units
        if walker.encdec and max(unit) == walker.enc_n - 1:
            mem_fp, x_fp = walker.boundary_transition(params, calib, x_fp)
            mem_q, x_q = walker.boundary_transition(params, calib, x_q, q_stem_hook)

    params_q = bake(model, params, qstates, v_all, embed_head)
    stats.update(wall_s=time.time() - t0, n_units=len(units),
                 n_weights=len(qstates))
    all_states = dict(qstates)
    all_states.update(embed_head)
    return PTQResult(params_q=params_q, act_scales=s_all, qstates=all_states,
                     v=v_all, stats=stats)


def _zero_eps(walker, params, batch):
    x, ctx = walker.stem(params, batch)
    eps = []
    for bi in range(len(walker.blocks())):
        eps.append(jnp.zeros_like(x))
        x = walker.apply_block(params, bi, x, ctx)
        if walker.encdec and bi == walker.enc_n - 1:
            _, x = walker.boundary_transition(params, batch, x)
            ctx = walker.ctx_for(batch, bi + 1, None)
    return eps


def _apply_unit(walker, params, unit, hook, x, batch, memory):
    """Run the unit's contiguous blocks under ``hook``."""
    ctx = walker.ctx_for(batch, min(unit), memory)
    for bi in sorted(unit):
        x = walker.apply_block(params, bi, x, ctx, hook)
    return x


# ---------------------------------------------------------------------------
# block / stage / net units
# ---------------------------------------------------------------------------


def _reconstruct_unit(model, walker, params, weights, calib, unit, x_fp, x_q,
                      mem_fp, mem_q, fisher, qstates, rc: ReconConfig, rng):
    t0 = time.time()
    N = calib["tokens"].shape[0]

    # which paths does this unit touch?
    rec = RecordingHook(capture_acts=True)
    _ = _apply_unit(walker, params, unit, rec, x_q[:1], _slice_batch(calib, jnp.arange(1)), _m1(mem_q))
    wpaths = [p for p in rec.weights if p in qstates]

    fp_fn = jax.jit(lambda x, b, m: _apply_unit(walker, params, unit, NO_QUANT, x, b, m))
    z_fp = fp_fn(x_fp, calib, mem_fp)
    g2 = fisher[max(unit)] if rc.use_fisher else None

    if not wpaths:
        hard0 = jax.jit(lambda x, b, m: _apply_unit(walker, params, unit, NO_QUANT, x, b, m))
        return z_fp, hard0(x_q, calib, mem_q), {}, {}, {"unit": unit, "skipped": True}

    v0 = {p: adaround.init_v(weights[p], *qstates[p]) for p in wpaths}
    s0 = {}
    if rc.a_bits is not None:
        for p, a in rec.acts.items():
            s0[p] = lsq.init_act_scale(a, rc.a_bits, symmetric=True)
    opt = {"v": v0, "s": s0}
    lr_tree = {"v": {p: 1.0 for p in v0}, "s": {p: rc.lr_s / rc.lr_v for p in s0}}
    nelem = sum(v.size for v in v0.values())

    def unit_loss(opt, xin, zt, g2b, batch, mem, it):
        hook = AdaRoundHook(qstates, opt, rc.a_bits, soft=True)
        x = _apply_unit(walker, params, unit, hook, xin, batch, mem)
        err = (x - zt).astype(jnp.float32) ** 2
        if g2b is not None:
            err = err * g2b
        beta, enabled = rc.beta(it, rc.iters)
        reg = sum(adaround.round_reg(v, beta) for v in opt["v"].values())
        return jnp.mean(err) + rc.lam * enabled * reg / nelem

    grad_fn = jax.jit(jax.value_and_grad(unit_loss))
    acfg = adam.AdamConfig(lr=rc.lr_v)
    ostate = adam.init(opt)
    step_fn = jax.jit(lambda o, s, g: adam.update(acfg, g, s, o, lr_tree))

    losses = []
    for it in range(rc.iters):
        idx = jnp.asarray(rng.choice(N, size=min(rc.calib_bs, N), replace=False))
        if rc.input_source == "fp":
            xin = x_fp[idx]
        elif rc.input_source == "mix":
            m = jnp.asarray(rng.random(len(idx)) < rc.input_mix_prob)
            xin = jnp.where(m[:, None, None], x_fp[idx], x_q[idx])
        else:
            xin = x_q[idx]
        g2b = g2[idx] if g2 is not None else None
        l, grads = grad_fn(opt, xin, z_fp[idx], g2b, _slice_batch(calib, idx),
                           _m1(mem_q, idx), jnp.asarray(it, jnp.float32))
        opt, ostate = step_fn(opt, ostate, grads)
        losses.append(float(l))

    hard_fn = jax.jit(lambda o, x, b, m: _apply_unit(
        walker, params, unit, AdaRoundHook(qstates, o, rc.a_bits, soft=False), x, b, m))
    x_q2 = hard_fn(opt, x_q, calib, mem_q)
    stat = {"unit": list(unit), "paths": len(wpaths), "iters": rc.iters,
            "loss_first": losses[0], "loss_last": losses[-1],
            "final_recon_mse": float(jnp.mean((x_q2 - z_fp).astype(jnp.float32) ** 2)),
            "wall_s": time.time() - t0}
    return z_fp, x_q2, opt["v"], opt["s"], stat


def _m1(mem, idx=None):
    if mem is None:
        return None
    return mem[idx] if idx is not None else mem


# ---------------------------------------------------------------------------
# layer-wise units (AdaRound baseline: per-linear MSE, no Fisher)
# ---------------------------------------------------------------------------


class _LayerHook(QuantHook):
    """Hard-quantizes finished paths; captures the input of one target."""

    def __init__(self, qstates, v_done: dict, target: Optional[str],
                 act_scales: Optional[dict] = None, a_bits: Optional[int] = None):
        self.qstates = qstates
        self.v_done = v_done
        self.target = target
        self.captured: Optional[Array] = None
        self.act_scales = act_scales or {}
        self.a_bits = a_bits

    def weight(self, path, w):
        if path in self.v_done:
            st, cfg = self.qstates[path]
            return adaround.hard_quant(w, self.v_done[path], st, cfg)
        return w

    def act(self, path, x):
        if self.a_bits is not None and path in self.act_scales:
            x = lsq.lsq_quant(x, self.act_scales[path], self.a_bits, True)
        if path == self.target:
            self.captured = x
        return x


def _reconstruct_layerwise(model, walker, params, weights, calib, bi, x_fp, x_q,
                           mem_fp, mem_q, qstates, rc: ReconConfig, rng):
    """AdaRound-style: each linear reconstructs its own output z = x W."""
    t0 = time.time()
    N = calib["tokens"].shape[0]
    rec = RecordingHook(capture_acts=True)
    _ = _apply_unit(walker, params, [bi], rec, x_q[:1], _slice_batch(calib, jnp.arange(1)), _m1(mem_q))
    wpaths = [p for p in rec.weights if p in qstates]

    fp_fn = jax.jit(lambda x, b, m: _apply_unit(walker, params, [bi], NO_QUANT, x, b, m))
    z_fp = fp_fn(x_fp, calib, mem_fp)

    v_done: dict[str, Array] = {}
    s_done: dict[str, Array] = {}
    acfg = adam.AdamConfig(lr=rc.lr_v)

    for path in wpaths:
        W = weights[path]
        st, qc = qstates[path]

        # capture this linear's inputs on both streams
        xin_q = jax.jit(lambda x, m: _cap(walker, params, bi, qstates, v_done,
                                          s_done, rc, path, x, calib, m))(x_q, mem_q)
        xin_fp = jax.jit(lambda x, m: _cap(walker, params, bi, qstates, {},
                                           {}, dataclasses.replace(rc, a_bits=None),
                                           path, x, calib, m))(x_fp, mem_fp)
        zt = jnp.matmul(xin_fp, W.astype(xin_fp.dtype))
        if rc.a_bits is not None:
            s_done[path] = lsq.init_act_scale(xin_q, rc.a_bits, symmetric=True)
        v = adaround.init_v(W, st, qc)
        opt = {"v": {path: v}, "s": ({path: s_done[path]} if rc.a_bits else {})}
        ostate = adam.init(opt)
        lr_tree = {"v": {path: 1.0}, "s": {path: rc.lr_s / rc.lr_v} if rc.a_bits else {}}

        def layer_loss(opt, xb, zb, it):
            w_q = adaround.soft_quant(W, opt["v"][path], st, qc)
            x = xb
            if rc.a_bits is not None:
                x = lsq.lsq_quant(x, opt["s"][path], rc.a_bits, True)
            z = jnp.matmul(x, w_q.astype(x.dtype))
            beta, enabled = rc.beta(it, rc.iters)
            reg = adaround.round_reg(opt["v"][path], beta)
            return (jnp.mean((z - zb).astype(jnp.float32) ** 2)
                    + rc.lam * enabled * reg / v.size)

        grad_fn = jax.jit(jax.value_and_grad(layer_loss))
        step_fn = jax.jit(lambda o, s, g: adam.update(acfg, g, s, o, lr_tree))
        lead = xin_q.shape[0]
        for it in range(rc.iters):
            idx = jnp.asarray(rng.choice(lead, size=min(rc.calib_bs, lead), replace=False))
            _, grads = grad_fn(opt, xin_q[idx], zt[idx], jnp.asarray(it, jnp.float32))
            opt, ostate = step_fn(opt, ostate, grads)
        v_done[path] = opt["v"][path]
        if rc.a_bits is not None:
            s_done[path] = opt["s"][path]

    hard_hook = _LayerHook(qstates, v_done, None, s_done, rc.a_bits)
    x_q2 = jax.jit(lambda x, m: _apply_unit(walker, params, [bi], hard_hook, x, calib, m))(x_q, mem_q)
    stat = {"unit": [bi], "paths": len(wpaths), "iters": rc.iters,
            "final_recon_mse": float(jnp.mean((x_q2 - z_fp).astype(jnp.float32) ** 2)),
            "wall_s": time.time() - t0}
    return z_fp, x_q2, v_done, s_done, stat


def _cap(walker, params, bi, qstates, v_done, s_done, rc, path, x, calib, mem):
    hook = _LayerHook(qstates, v_done, path, s_done, rc.a_bits)
    _apply_unit(walker, params, [bi], hook, x, calib, mem)
    return hook.captured


# ---------------------------------------------------------------------------
# baking
# ---------------------------------------------------------------------------


def bake(model, params, qstates, v_all, embed_head) -> Params:
    """Write hard-quantized weights back into a params copy."""
    params_q = jax.tree.map(lambda x: x, params)

    def set_leaf(path: str, fn):
        parts = path.split("/")
        if "." in parts[0]:
            sname, ri = parts[0].rsplit(".", 1)
            ri = int(ri)
            keys = parts[1:] + ["w"]
            node = params_q[sname]
            for k in keys[:-1]:
                node = node[k]
            leaf = node[keys[-1]]
            node[keys[-1]] = leaf.at[ri].set(fn(leaf[ri]))
        else:
            node = params_q
            for k in parts[:-1]:
                node = node[k]
            node[parts[-1]] = fn(node[parts[-1]])

    for path, (st, cfg) in qstates.items():
        if path in v_all:
            v = v_all[path]
            set_leaf(path, lambda w, v=v, st=st, cfg=cfg: adaround.hard_quant(w, v, st, cfg))
        else:
            set_leaf(path, lambda w, st=st, cfg=cfg: quantize_dequant(w, st, cfg))
    for path, (st, cfg) in embed_head.items():
        set_leaf(path, lambda w, st=st, cfg=cfg: quantize_dequant(w, st, cfg))
    return params_q
