"""BRECQ block reconstruction engine (paper Alg. 1).

Pipeline:
  1. Enumerate quantizable weights by walking the model once.
  2. Capture the FP activation stream and, with one backward pass per
     calibration batch (epsilon trick), the diagonal Fisher at every
     block output.
  3. Partition blocks into reconstruction units: layer / block / stage /
     net (Sec. 3.2). Units never cross the enc->dec boundary.
  4. Per unit: optimize AdaRound logits (+ LSQ activation step sizes)
     with Adam on the Fisher-weighted output MSE + beta-annealed rounding
     regularizer. Inputs come from the *quantized* stream (error
     propagates, as in the reference implementation); targets from the
     FP stream.
  5. Harden rounding, advance the quantized stream, continue.
  6. Bake hard-quantized weights back into a params copy for serving.

Execution here is python-level block-by-block (calibration happens on
paper-scale models); training/serving use the scan-based forward.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..launch.watchdog import GracefulShutdown, StepWatchdog
from ..models.common import NO_QUANT, Ctx, QuantHook
from ..optim import adam
from . import adaround, calib_loop, lsq
from .adaround import BetaSchedule
from .fisher import FisherStream
from .hooks import LayerCaptureHook, RTNHook
from .journal import CalibJournal, CalibrationInterrupted
from .quantizer import QConfig, QState, init_qstate, quantize_dequant

# re-export for baselines.py (the hook moved to hooks.py so calib_loop's
# cached capture programs can use it without a circular import)
_LayerHook = LayerCaptureHook

Array = jax.Array
Params = Any


# ---------------------------------------------------------------------------
# model walker: python-level block-by-block execution
# ---------------------------------------------------------------------------


class Walker:
    """Sequential (non-scan) execution of a model's block graph."""

    def __init__(self, model):
        self.model = model
        self.encdec = hasattr(model, "enc_stack")
        self.enc_n = self.model.enc_stack.n if self.encdec else 0

    def blocks(self) -> list[tuple[Any, int]]:
        if self.encdec:
            stacks = [self.model.enc_stack, self.model.dec_stack]
        else:
            stacks = self.model.stacks
        return [(s, i) for s in stacks for i in range(s.n)]

    def block_path(self, bi: int) -> str:
        stack, ri = self.blocks()[bi]
        return f"{stack.name}.{ri}"

    def stem(self, params, batch, quant=NO_QUANT):
        """Activations entering block 0 (+ its ctx)."""
        if self.encdec:
            frames = batch["frames"]
            B, S, _ = frames.shape
            pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
            ctx = Ctx(cfg=self.model.cfg, positions=pos, quant=quant)
            return frames + params["enc_pos"][:S], ctx
        return self.model.begin(params, batch, quant)

    def ctx_for(self, batch, bi: int, memory: Optional[Array], quant=NO_QUANT) -> Ctx:
        """Ctx entering block ``bi`` given the stream's encoder memory."""
        cfg = self.model.cfg
        if self.encdec and bi >= self.enc_n:
            tokens = batch["tokens"]
            B, S = tokens.shape
            pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
            ctx = Ctx(cfg=cfg, positions=pos, quant=quant)
            ctx.extras["memory"] = memory
            return ctx
        if self.encdec:
            frames = batch["frames"]
            B, S, _ = frames.shape
            pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
            return Ctx(cfg=cfg, positions=pos, quant=quant)
        tokens = batch["tokens"]
        B, S = tokens.shape
        pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        ctx = Ctx(cfg=cfg, positions=pos, quant=quant)
        if cfg.family == "vlm":
            ctx.extras["memory"] = batch["patches"]
        return ctx

    def apply_block(self, params, bi: int, x, ctx, quant=NO_QUANT):
        stack, ri = self.blocks()[bi]
        p_i = jax.tree.map(lambda a: a[ri], params[stack.name])
        ctx2 = dataclasses.replace(ctx, quant=quant, scope=self.block_path(bi))
        y, _ = self.model.apply_block(ctx2, stack, p_i, x)
        return y

    def boundary_transition(self, params, batch, x, quant=NO_QUANT):
        """enc output -> (memory, decoder stem x)."""
        from ..models import common as cm
        from ..models.transformer import _norm

        memory = _norm(self.model.cfg, params["enc_norm"], x)
        hook = quant if quant is not None else NO_QUANT
        tokens = batch["tokens"]
        B, S = tokens.shape
        pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        ctx = Ctx(cfg=self.model.cfg, positions=pos, quant=hook)
        # embed_lookup (not a raw table gather) so a packed int8 table
        # from a deployment artifact dequantizes correctly here too
        xdec = cm.embed_lookup(ctx, params["embed"], tokens)
        return memory, xdec

    def run(self, params, batch, quant=NO_QUANT, eps: Optional[list] = None):
        """Full forward block-by-block (used for eval & the Fisher pass).

        ``eps`` is an optional per-block list of output perturbations;
        ``None`` entries are skipped, so the streamed Fisher pass can
        perturb a single block without materializing zeros for the rest.
        """
        x, ctx = self.stem(params, batch, quant)
        memory = None
        for bi in range(len(self.blocks())):
            x = self.apply_block(params, bi, x, ctx, quant)
            if eps is not None and eps[bi] is not None:
                x = x + eps[bi]
            if self.encdec and bi == self.enc_n - 1:
                memory, x = self.boundary_transition(params, batch, x, quant)
                ctx = self.ctx_for(batch, bi + 1, memory, quant)
        return self.model.finish(params, x, ctx)

    def loss(self, params, batch, quant=NO_QUANT, eps=None):
        from ..models.common import softmax_xent

        logits = self.run(params, batch, quant, eps)
        tokens = batch["tokens"]
        return softmax_xent(logits[:, :-1], tokens[:, 1:])


# ---------------------------------------------------------------------------
# configuration
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ReconConfig:
    """Static configuration for one BRECQ calibration run.

    Attributes:
      w_bits: weight bit-width for block weights (paper Tables 1-3 use
        2/3/4; embed/head are handled separately, see
        ``keep_embed_head_8bit``).
      a_bits: activation bit-width; ``None`` disables activation
        quantization (weight-only PTQ).
      w_group: per-group weight quantization along the reduction axis
        (group size in rows, TPU-friendly multiples of 128); ``None``
        keeps per-channel scales.
      scale_method: scale init, ``'minmax'`` or ``'mse'`` (paper's OMSE
        grid search).
      iters: AdaRound/LSQ optimization iterations per unit (paper: 20k;
        CI/bench use far less).
      calib_bs: minibatch size (sequences) drawn per iteration.
      lr_v: Adam learning rate for the rounding logits ``v``.
      lr_s: Adam learning rate for LSQ activation step sizes.
      granularity: reconstruction unit size — ``'layer'`` (per-linear
        AdaRound baseline), ``'block'`` (paper default), ``'stage'`` or
        ``'net'`` (Sec. 3.2 ablation).
      n_stages: number of stages per segment at ``granularity='stage'``.
      use_fisher: weight the unit output MSE by the diagonal FIM
        (squared block-output gradients, Sec. 3.3). Ignored at
        ``granularity='layer'``.
      keep_embed_head_8bit: quantize embedding table and LM head at 8
        bits instead of ``w_bits`` (paper keeps first/last layers 8-bit).
      lam: weight of the AdaRound rounding regularizer.
      beta: the regularizer's annealing schedule.
      input_source: unit inputs come from the ``'quant'`` stream (error
        propagates, paper default), the ``'fp'`` stream, or a QDrop-style
        per-sequence ``'mix'``.
      input_mix_prob: probability of the FP input when
        ``input_source='mix'``.
      per_layer_bits: optional path -> bits override for mixed precision.
      seed: PRNG seed for on-device minibatch sampling.
      loop_impl: ``'scan'`` — fused device-resident loop (one dispatch +
        one sync per unit); ``'python'`` — same traced step driven one
        iteration at a time (reference mode for equivalence tests and
        ``benchmarks/table5_calib_speed.py``'s baseline).
      stream_dtype: storage dtype for the calibration activation streams
        (``x_fp``/``x_q``, enc-dec memory, unit targets) and — in
        ``fisher_mode='stream'`` — the accumulated Fisher. ``'bfloat16'``
        (default) halves calibration HBM; ``'float32'`` is the exact
        reference mode used by the equivalence tests. Compute inside the
        optimization programs is always f32.
      fisher_mode: ``'stream'`` (default) computes the diagonal Fisher
        per reconstruction unit on demand, so peak residency is one
        block-output array ``(N, S, d)`` regardless of depth, at the cost
        of one extra backward pass per unit per calib batch; ``'full'``
        is the reference all-blocks-resident eps-trick capture
        (``nb x N x S x d`` f32).
      unit_guard: per-unit health guard (block/stage/net units). After a
        unit optimizes, its loss trajectory and reconstruction MSE are
        checked against the unit's own RTN baseline (hard forward with
        the *initial* rounding/scales — identical to round-to-nearest);
        a non-finite trace or an MSE worse than ``rtn * mse_guard_ratio``
        triggers a retry from the initial state at a reduced learning
        rate, and after ``unit_retries`` failed retries the unit degrades
        to its RTN baseline instead of failing the job. Device-OOM during
        the optimization retries with a halved calibration minibatch.
      unit_retries: bounded retries per unhealthy unit before RTN
        fallback.
      retry_lr_decay: learning-rate backoff factor per retry (applied to
        both ``lr_v`` and ``lr_s`` as a runtime scalar — retries reuse
        the compiled program).
      mse_guard_ratio: tolerance of the MSE guard; a unit only counts as
        unhealthy when its reconstruction MSE exceeds the RTN baseline
        by this factor (optimization starts *at* RTN, so small
        low-iteration wobble must not trip the guard).
    """

    w_bits: int = 4
    a_bits: Optional[int] = None  # None = weight-only
    w_group: Optional[int] = None  # per-group quantization (beyond-paper)
    scale_method: str = "mse"
    iters: int = 800  # paper: 20k/block; CI uses less
    calib_bs: int = 8
    lr_v: float = 1e-3
    lr_s: float = 4e-5
    granularity: str = "block"  # layer | block | stage | net
    n_stages: int = 4
    use_fisher: bool = True
    keep_embed_head_8bit: bool = True
    lam: float = 0.01
    beta: BetaSchedule = dataclasses.field(default_factory=BetaSchedule)
    input_source: str = "quant"  # 'quant' | 'fp' | 'mix'
    input_mix_prob: float = 0.5  # QDrop-style mixing (beyond paper)
    per_layer_bits: Optional[dict] = None  # path -> bits (mixed precision)
    seed: int = 0
    loop_impl: str = "scan"  # 'scan' | 'python' (reference)
    stream_dtype: str = "bfloat16"  # 'bfloat16' | 'float32' (reference)
    fisher_mode: str = "stream"  # 'stream' | 'full' (reference)
    unit_guard: bool = True  # NaN/MSE guard + retry/degrade per unit
    unit_retries: int = 2  # retries before RTN fallback
    retry_lr_decay: float = 0.5  # lr backoff per retry (runtime scalar)
    mse_guard_ratio: float = 1.5  # unhealthy iff mse > rtn_mse * ratio


@dataclasses.dataclass
class PTQResult:
    params_q: Params
    act_scales: dict  # path -> scalar ({} when a_bits is None)
    qstates: dict  # path -> (QState, QConfig)
    v: dict  # path -> rounding logits
    stats: dict


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------


def _concat_batches(batches: list[dict]) -> dict:
    return {k: jnp.concatenate([b[k] for b in batches], 0) for k in batches[0]}


def _slice_batch(batch: dict, idx) -> dict:
    return {k: v[idx] for k, v in batch.items()}


class _ValHook(QuantHook):
    def __init__(self):
        self.vals: dict[str, Array] = {}

    def weight(self, path, w):
        self.vals[path] = w
        return w


def enumerate_weights(model, params, batch) -> dict[str, Array]:
    """path -> weight array for every quant-eligible weight."""
    walker = Walker(model)
    hook = _ValHook()
    walker.run(params, batch, hook)
    return hook.vals


def _bits_for(rc: ReconConfig, path: str) -> int:
    if rc.per_layer_bits and path in rc.per_layer_bits:
        return rc.per_layer_bits[path]
    return rc.w_bits


def init_states(model, weights: dict[str, Array], rc: ReconConfig):
    """Quantizer state for block weights + 8-bit embed/head handling."""
    qstates: dict[str, tuple[QState, QConfig]] = {}
    embed_head: dict[str, tuple[QState, QConfig]] = {}
    for path, w in weights.items():
        if path in ("embed/table", "head/w"):
            if not rc.keep_embed_head_8bit:
                continue
            if path == "head/w" and model.cfg.tie_embeddings:
                continue  # tied: baking the embed covers the head
            cfg = QConfig(bits=8, channel_axis=-1, scale_method="mse")
            embed_head[path] = (init_qstate(w, cfg), cfg)
        else:
            cfg = QConfig(bits=_bits_for(rc, path), channel_axis=-1,
                          group_size=rc.w_group, scale_method=rc.scale_method)
            qstates[path] = (init_qstate(w, cfg), cfg)
    return qstates, embed_head


def _partition(walker: Walker, rc: ReconConfig) -> list[list[int]]:
    nb = len(walker.blocks())
    if rc.granularity in ("layer", "block"):
        return [[i] for i in range(nb)]
    segs = _segments(walker)
    if rc.granularity == "net":
        return segs
    if rc.granularity == "stage":
        units = []
        for seg in segs:
            k = max(1, (len(seg) + rc.n_stages - 1) // rc.n_stages)
            units += [seg[i:i + k] for i in range(0, len(seg), k)]
        return units
    raise ValueError(rc.granularity)


def _segments(walker: Walker) -> list[list[int]]:
    nb = len(walker.blocks())
    if walker.encdec:
        return [list(range(walker.enc_n)), list(range(walker.enc_n, nb))]
    return [list(range(nb))]


# ---------------------------------------------------------------------------
# engine
# ---------------------------------------------------------------------------


def _nbytes(a: Optional[Array]) -> int:
    return 0 if a is None else a.size * a.dtype.itemsize


def quantize(model, params, calib_batches: list[dict], rc: ReconConfig, *,
             workdir: Optional[str] = None) -> PTQResult:
    """Run BRECQ calibration (paper Alg. 1) and return quantized params.

    Args:
      model: a model exposing the block-graph API (``begin`` /
        ``apply_block`` / ``finish``); see ``models/``.
      params: FP parameters (never mutated).
      calib_batches: list of calibration batches (the paper's 1024
        images; here token/frame batches). They are concatenated into
        one calibration set of N sequences.
      rc: static :class:`ReconConfig`.
      workdir: optional journal directory making the run resumable. A
        snapshot (streams + accumulated v/s + per-unit stats) is written
        atomically after every reconstructed unit; a re-run with the same
        ``workdir`` skips completed units and continues bit-identically
        to an uninterrupted run. While a journal is active, SIGTERM /
        SIGINT finish the current unit, persist it, and raise
        :class:`~repro.core.journal.CalibrationInterrupted` instead of
        dying mid-unit (prior signal handlers are restored on exit). A
        journal written by a different config/model/calib set raises
        :class:`~repro.core.journal.CalibJournalError`.

    Returns:
      :class:`PTQResult` with:
        * ``params_q`` — a params copy with hard-quantized weights baked
          in (ready for ``evaluate`` / serving);
        * ``act_scales`` — path -> learned LSQ step size (empty when
          ``rc.a_bits`` is None);
        * ``qstates`` — path -> (QState, QConfig) for every quantized
          weight incl. the 8-bit embed/head;
        * ``v`` — path -> final AdaRound rounding logits;
        * ``stats`` — calibration telemetry:
            - ``calib_wall_s``: total wall time (seconds),
            - ``fisher_wall_s``: seconds spent in the Fisher pass,
            - ``calib_iters_per_s``: aggregate optimizer throughput
              (iterations/second),
            - ``calib_peak_bytes``: estimated peak calibration residency
              (bytes) = live activation streams + Fisher arrays; with
              ``fisher_mode='stream'`` the Fisher term covers one unit,
              not ``nb x N x S x d``,
            - ``calib_peak_bytes_detail``: ``{'streams': bytes,
              'fisher': bytes}`` breakdown,
            - ``unit_cache`` (and ``layer_cache`` / ``probe_cache`` where
              applicable): compiled-program cache hits/misses,
            - robustness: ``unit_retries`` / ``unit_fallbacks`` /
              ``unit_oom_halvings`` aggregates from the per-unit guard,
              ``stragglers`` from the per-unit wall-time watchdog, and
              ``resumed_at_unit`` when a journal resume skipped units,
            - per unit (``stats['units']``): ``loss_trace``,
              ``final_recon_mse``, ``opt_wall_s``, ``calib_iters_per_s``,
              ``cache_hit`` (guarded units add ``retries``, ``fallback``,
              ``rtn_recon_mse``, ``oom_halvings``, ``calib_bs``).
    """
    if rc.loop_impl not in ("scan", "python"):
        raise ValueError(f"loop_impl must be 'scan' or 'python', got {rc.loop_impl!r}")
    if rc.fisher_mode not in ("stream", "full"):
        raise ValueError(
            f"fisher_mode must be 'stream' or 'full', got {rc.fisher_mode!r}")
    sdtype = jnp.dtype(rc.stream_dtype)
    if sdtype not in (jnp.dtype(jnp.bfloat16), jnp.dtype(jnp.float32)):
        raise ValueError(
            f"stream_dtype must be 'bfloat16' or 'float32', got {rc.stream_dtype!r}")
    t0 = time.time()
    walker = Walker(model)
    calib = _concat_batches(calib_batches)
    base_key = jax.random.PRNGKey(rc.seed)
    cache0 = calib_loop.cache_stats()

    probe = _slice_batch(calib, jnp.arange(1))
    weights = enumerate_weights(model, params, probe)
    qstates, embed_head = init_states(model, weights, rc)
    q_stem_hook = RTNHook(embed_head)

    # -- diagonal Fisher at block outputs (FP model, eps trick) ---------------
    # 'stream' computes g^2 per unit on demand inside _reconstruct_unit;
    # 'full' precomputes every block here (reference residency).
    fisher: Optional[FisherStream] = None
    if rc.use_fisher and rc.granularity != "layer":
        fisher = FisherStream(walker, params, calib_batches,
                              mode=rc.fisher_mode, dtype=sdtype)

    units = _partition(walker, rc)

    # -- resumable journal + preemption-safe shutdown (workdir mode) ----------
    journal: Optional[CalibJournal] = None
    shutdown: Optional[GracefulShutdown] = None
    snap = None
    if workdir is not None:
        sig = {"rc": repr(rc), "arch": getattr(model.cfg, "name", None),
               "n_units": len(units),
               "calib": str(jax.tree.map(
                   lambda a: (tuple(a.shape), str(a.dtype)), calib))}
        journal = CalibJournal(workdir, sig)
        snap = journal.load()
        shutdown = GracefulShutdown()

    start_unit = 0
    v_all: dict[str, Array] = {}
    s_all: dict[str, Array] = {}
    stats: dict = {"units": [], "granularity": rc.granularity}
    stream_peak = 0
    mem_fp: Optional[Array] = None
    mem_q: Optional[Array] = None
    if snap is not None:
        # everything a restart cannot recompute comes from the journal;
        # qstates/Fisher/unit keys were rebuilt deterministically above
        start_unit = snap["next_unit"]
        x_fp, x_q = snap["x_fp"], snap["x_q"]
        mem_fp, mem_q = snap["mem_fp"], snap["mem_q"]
        v_all, s_all = snap["v_all"], snap["s_all"]
        stats["units"] = [_revive_unit_stat(u) for u in snap["unit_stats"]]
        stream_peak = snap["stream_peak"]
        stats["resumed_at_unit"] = start_unit
    else:
        # streams (stored in rc.stream_dtype; compute stays f32)
        x_fp = jax.jit(lambda b: walker.stem(params, b)[0].astype(sdtype))(calib)
        x_q = jax.jit(
            lambda b: walker.stem(params, b, q_stem_hook)[0].astype(sdtype))(calib)

    wd = StepWatchdog(label="unit")
    try:
        for ui in range(start_unit, len(units)):
            unit = units[ui]
            unit_key = jax.random.fold_in(base_key, ui)
            wd.start()
            # while a unit runs, the old and new stream generations coexist
            stream_peak = max(stream_peak, 2 * (_nbytes(x_fp) + _nbytes(x_q))
                              + _nbytes(mem_fp) + _nbytes(mem_q))
            if rc.granularity == "layer":
                x_fp, x_q, v_u, s_u, ustat = _reconstruct_layerwise(
                    model, walker, params, weights, calib, unit[0], x_fp, x_q,
                    mem_fp, mem_q, qstates, rc, unit_key)
            else:
                x_fp, x_q, v_u, s_u, ustat = _reconstruct_unit(
                    model, walker, params, weights, calib, unit, x_fp, x_q,
                    mem_fp, mem_q, fisher, qstates, rc, unit_key)
            v_all.update(v_u)
            s_all.update(s_u)
            stats["units"].append(ustat)
            # enc->dec boundary transition between units (computed in f32,
            # stored back in the stream dtype)
            if walker.encdec and max(unit) == walker.enc_n - 1:
                mem_fp, x_fp = walker.boundary_transition(
                    params, calib, x_fp.astype(jnp.float32))
                mem_q, x_q = walker.boundary_transition(
                    params, calib, x_q.astype(jnp.float32), q_stem_hook)
                mem_fp, x_fp = mem_fp.astype(sdtype), x_fp.astype(sdtype)
                mem_q, x_q = mem_q.astype(sdtype), x_q.astype(sdtype)
            wd.stop(ui)
            if journal is not None:
                # snapshot *after* the boundary transition so a resume
                # starts exactly where this loop iteration left off
                journal.save(ui + 1, x_fp, x_q, mem_fp, mem_q, v_all, s_all,
                             stats["units"], stream_peak)
                if shutdown.requested and ui + 1 < len(units):
                    raise CalibrationInterrupted(journal.workdir, ui + 1,
                                                 len(units))
    finally:
        if shutdown is not None:
            shutdown.restore()

    params_q = bake(model, params, qstates, v_all, embed_head)
    cache1 = calib_loop.cache_stats()
    opt_iters = sum(u.get("opt_iters", 0) for u in stats["units"])
    opt_wall = sum(u.get("opt_wall_s", 0.0) for u in stats["units"])
    fisher_bytes = fisher.peak_bytes if fisher is not None else 0
    stats.update(
        calib_wall_s=time.time() - t0, n_units=len(units),
        n_weights=len(qstates), loop_impl=rc.loop_impl,
        stream_dtype=str(sdtype), fisher_mode=rc.fisher_mode,
        fisher_wall_s=fisher.wall_s if fisher is not None else 0.0,
        calib_peak_bytes=stream_peak + fisher_bytes,
        calib_peak_bytes_detail={"streams": stream_peak, "fisher": fisher_bytes},
        calib_iters_per_s=opt_iters / max(opt_wall, 1e-9),
        unit_cache={"hits": cache1["unit_hits"] - cache0["unit_hits"],
                    "misses": cache1["unit_misses"] - cache0["unit_misses"]},
        probe_cache={"hits": cache1["probe_hits"] - cache0["probe_hits"],
                     "misses": cache1["probe_misses"] - cache0["probe_misses"]},
        stragglers=wd.stragglers,
        unit_retries=sum(int(u.get("retries", 0)) for u in stats["units"]),
        unit_fallbacks=sum(1 for u in stats["units"] if u.get("fallback")),
        unit_oom_halvings=sum(int(u.get("oom_halvings", 0))
                              for u in stats["units"]))
    if rc.granularity == "layer":
        stats["layer_cache"] = {
            "hits": cache1["layer_hits"] - cache0["layer_hits"],
            "misses": cache1["layer_misses"] - cache0["layer_misses"]}
        stats["cap_cache"] = {
            "hits": cache1["cap_hits"] - cache0["cap_hits"],
            "misses": cache1["cap_misses"] - cache0["cap_misses"]}
    all_states = dict(qstates)
    all_states.update(embed_head)
    # deployment telemetry: what an export of this result will pack
    hist: dict[str, int] = {}
    for _p, (_st, qcfg) in all_states.items():
        hist[str(qcfg.bits)] = hist.get(str(qcfg.bits), 0) + 1
    stats.update(w_bits=rc.w_bits, a_bits=rc.a_bits, w_group=rc.w_group,
                 bits_histogram=hist)
    return PTQResult(params_q=params_q, act_scales=s_all, qstates=all_states,
                     v=v_all, stats=stats)


def _revive_unit_stat(u: dict) -> dict:
    """Journal round-trip: loss traces are JSON lists on disk, ndarrays
    in live stats."""
    u = dict(u)
    if isinstance(u.get("loss_trace"), list):
        u["loss_trace"] = np.asarray(u["loss_trace"])
    return u


def _apply_unit(walker, params, unit, hook, x, batch, memory):
    """Run the unit's contiguous blocks under ``hook``."""
    ctx = walker.ctx_for(batch, min(unit), memory)
    for bi in sorted(unit):
        x = walker.apply_block(params, bi, x, ctx, hook)
    return x


# ---------------------------------------------------------------------------
# block / stage / net units
# ---------------------------------------------------------------------------


def _unit_canon(walker, unit: list[int]):
    """Canonical naming for a unit: block ``j`` runs under scope ``u{j}``
    regardless of its absolute index, so structurally identical units
    trace to the same jaxpr and share one compiled program."""
    prefixes = [(j, walker.block_path(bi) + "/") for j, bi in enumerate(unit)]

    def canon(p: str) -> str:
        for j, pref in prefixes:
            if p.startswith(pref):
                return f"u{j}/" + p[len(pref):]
        raise KeyError(f"path {p} not inside unit {unit}")

    return canon


def _unit_uncanon(walker, unit: list[int]):
    """Inverse of :func:`_unit_canon`: ``u{j}/rest`` -> real block path."""

    def uncanon(cp: str) -> str:
        j, rest = cp.split("/", 1)
        return walker.block_path(unit[int(j[1:])]) + "/" + rest

    return uncanon


def _unit_pieces(walker, params, unit: list[int]):
    """(bparams, stackdefs, is_dec) — the traced/static per-unit inputs."""
    bparams = []
    stackdefs = []
    for bi in unit:
        stack, ri = walker.blocks()[bi]
        bparams.append(jax.tree.map(lambda a: a[ri], params[stack.name]))
        stackdefs.append(stack)
    is_dec = bool(walker.encdec and min(unit) >= walker.enc_n)
    return tuple(bparams), tuple(stackdefs), is_dec


def _reconstruct_unit(model, walker, params, weights, calib, unit, x_fp, x_q,
                      mem_fp, mem_q, fisher: Optional[FisherStream], qstates,
                      rc: ReconConfig, unit_key):
    t0 = time.time()
    N = calib["tokens"].shape[0]
    unit = sorted(unit)

    canon = _unit_canon(walker, unit)
    uncanon = _unit_uncanon(walker, unit)
    bparams, stackdefs, is_dec = _unit_pieces(walker, params, unit)

    # which paths does this unit touch? (structure-cached probe; weight
    # paths come from an abstract trace, no per-unit eager forward)
    b1 = _slice_batch(calib, jnp.arange(1))
    m1 = _m1(mem_q, jnp.arange(1))
    probe = calib_loop.get_unit_probe(model, walker, stackdefs, is_dec,
                                      bparams, x_q[:1], b1, m1)
    wpaths = [p for p in map(uncanon, probe.wpaths) if p in qstates]

    c_of = {p: canon(p) for p in wpaths}
    cfgs = {c_of[p]: qstates[p][1] for p in wpaths}
    states_c = {c_of[p]: qstates[p][0] for p in wpaths}
    bs = min(rc.calib_bs, N)

    if not wpaths:  # nothing to optimize: only the forward programs run
        misses0 = calib_loop.cache_stats()["unit_misses"]
        progs = calib_loop.get_unit_programs(
            model, walker, stackdefs, is_dec, {}, rc, bs, N,
            bparams, {}, {"v": {}, "s": {}}, (x_q, x_fp, None, calib, mem_q))
        cache_hit = calib_loop.cache_stats()["unit_misses"] == misses0
        z_fp = progs.fwd(bparams, x_fp, calib, mem_fp)
        x_q2 = progs.fwd(bparams, x_q, calib, mem_q)
        return z_fp, x_q2, {}, {}, {"unit": list(unit), "skipped": True,
                                    "cache_hit": cache_hit,
                                    "wall_s": time.time() - t0}

    # diagonal Fisher at the unit's output block, computed on demand
    # (streamed mode) — freed with g2 when this unit finishes; skipped
    # units above never pay for it
    g2 = fisher.for_block(max(unit)) if fisher is not None else None

    v0 = {c_of[p]: adaround.init_v(weights[p], *qstates[p]) for p in wpaths}
    s0 = {}
    act_of = {}
    if rc.a_bits is not None:
        # activation capture runs only when scales are needed, through the
        # same structure-cached jitted probe
        for cp, a in probe.acts(bparams, x_q[:1], b1, m1).items():
            act_of[uncanon(cp)] = cp
            s0[cp] = lsq.init_act_scale(a, rc.a_bits, symmetric=True)
    opt = {"v": v0, "s": s0}

    opt0 = opt  # initial logits/scales: the RTN start point, kept undonated

    misses0 = calib_loop.cache_stats()["unit_misses"]
    progs = calib_loop.get_unit_programs(
        model, walker, stackdefs, is_dec, cfgs, rc, bs, N,
        bparams, states_c, opt0, (x_q, x_fp, g2, calib, mem_q))
    cache_hit = calib_loop.cache_stats()["unit_misses"] == misses0

    z_fp = progs.fwd(bparams, x_fp, calib, mem_fp)

    def mse_vs_fp(x):
        return float(jnp.mean((x - z_fp).astype(jnp.float32) ** 2))

    rtn_mse = None
    x_rtn = None
    if rc.unit_guard:
        # RTN baseline through the same hard program: hard_quant at the
        # *initial* logits is exactly round-to-nearest, so one extra
        # forward yields both the guard threshold and the degradation
        # target (no re-trace — same compiled program).
        x_rtn = progs.hard(bparams, states_c, opt0, x_q, calib, mem_q)
        rtn_mse = mse_vs_fp(x_rtn)

    opt_wall = 0.0
    retries = 0
    oom_halvings = 0
    fallback = False
    lr_scale = 1.0
    opt = losses = x_q2 = mse = None
    while True:
        opt_try = jax.tree.map(jnp.copy, opt0)  # survives buffer donation
        t_opt = time.time()
        try:
            opt_try, losses = calib_loop.run_unit_loop(
                progs, rc, bparams, states_c, opt_try, adam.init(opt_try),
                unit_key, x_q, x_fp, z_fp, g2, calib, mem_q,
                lr_scale=lr_scale)
        except jax.errors.JaxRuntimeError as e:
            opt_wall += time.time() - t_opt
            if (not rc.unit_guard or not _is_oom(e) or bs <= 1
                    or oom_halvings >= 3):
                raise
            # device OOM: halve the calibration minibatch and recompile
            oom_halvings += 1
            bs = max(1, bs // 2)
            progs = calib_loop.get_unit_programs(
                model, walker, stackdefs, is_dec, cfgs, rc, bs, N,
                bparams, states_c, opt0, (x_q, x_fp, g2, calib, mem_q))
            continue
        opt_wall += time.time() - t_opt
        opt = opt_try
        x_q2 = progs.hard(bparams, states_c, opt, x_q, calib, mem_q)
        mse = mse_vs_fp(x_q2)
        if not rc.unit_guard:
            break
        healthy = (bool(np.all(np.isfinite(losses))) and np.isfinite(mse)
                   and mse <= rtn_mse * rc.mse_guard_ratio)
        if healthy:
            break
        if retries >= rc.unit_retries:
            fallback = True
            break
        retries += 1
        lr_scale *= rc.retry_lr_decay  # runtime scalar: no re-trace

    if fallback:
        # degrade to the RTN baseline: omit this unit's logits so bake()
        # rounds-to-nearest, keep the *initial* act scales (x_rtn was
        # produced with exactly those)
        x_q2, mse = x_rtn, rtn_mse
        v_real = {}
        s_real = {p: opt0["s"][c] for p, c in act_of.items()}
    else:
        v_real = {p: opt["v"][c_of[p]] for p in wpaths}
        s_real = {p: opt["s"][c] for p, c in act_of.items()}

    n_iters = rc.iters * (retries + 1)
    stat = {"unit": list(unit), "paths": len(wpaths), "iters": rc.iters,
            "loss_first": float(losses[0]), "loss_last": float(losses[-1]),
            "loss_trace": losses,
            "final_recon_mse": mse,
            "opt_iters": n_iters, "opt_wall_s": opt_wall,
            "calib_iters_per_s": n_iters / max(opt_wall, 1e-9),
            "cache_hit": cache_hit,
            "retries": retries, "fallback": fallback,
            "oom_halvings": oom_halvings, "calib_bs": bs,
            "wall_s": time.time() - t0}
    if rtn_mse is not None:
        stat["rtn_recon_mse"] = rtn_mse
    return z_fp, x_q2, v_real, s_real, stat


def _is_oom(e: Exception) -> bool:
    """Device allocation failures surface as JaxRuntimeError with a
    RESOURCE_EXHAUSTED / out-of-memory message."""
    msg = str(e).upper()
    return ("RESOURCE_EXHAUSTED" in msg or "OUT OF MEMORY" in msg
            or "OOM" in msg)


def _m1(mem, idx=None):
    if mem is None:
        return None
    return mem[idx] if idx is not None else mem


# ---------------------------------------------------------------------------
# layer-wise units (AdaRound baseline: per-linear MSE, no Fisher)
# ---------------------------------------------------------------------------


def _reconstruct_layerwise(model, walker, params, weights, calib, bi, x_fp, x_q,
                           mem_fp, mem_q, qstates, rc: ReconConfig, unit_key):
    """AdaRound-style: each linear reconstructs its own output z = x W.

    The per-linear inner loop runs through the cached scan program
    (:mod:`calib_loop`), so every same-shape linear in the model shares
    one compiled step. The block forward/harden passes reuse the unit
    program cache."""
    t0 = time.time()
    unit = [bi]
    canon = _unit_canon(walker, unit)
    uncanon = _unit_uncanon(walker, unit)
    bparams, stackdefs, is_dec = _unit_pieces(walker, params, unit)
    probe = calib_loop.get_unit_probe(
        model, walker, stackdefs, is_dec, bparams, x_q[:1],
        _slice_batch(calib, jnp.arange(1)), _m1(mem_q, jnp.arange(1)))
    wpaths = [p for p in map(uncanon, probe.wpaths) if p in qstates]
    c_of = {p: canon(p) for p in wpaths}
    cfgs = {c_of[p]: qstates[p][1] for p in wpaths}
    states_c = {c_of[p]: qstates[p][0] for p in wpaths}
    s_paths = tuple(sorted(c_of.values())) if rc.a_bits is not None else ()
    # structure-only signature of the opt tree the hard pass will receive
    hard_opt_sig = {
        "v": {c_of[p]: jax.ShapeDtypeStruct(weights[p].shape, jnp.float32)
              for p in wpaths},
        "s": {c: jax.ShapeDtypeStruct((), jnp.float32) for c in s_paths}}

    misses0 = calib_loop.cache_stats()["unit_misses"]
    uprogs = calib_loop.get_unit_programs(
        model, walker, stackdefs, is_dec, cfgs, rc,
        min(rc.calib_bs, calib["tokens"].shape[0]), calib["tokens"].shape[0],
        bparams, states_c, hard_opt_sig, (x_q, x_fp, None, calib, mem_q))
    cache_hit = calib_loop.cache_stats()["unit_misses"] == misses0

    z_fp = uprogs.fwd(bparams, x_fp, calib, mem_fp)

    v_done: dict[str, Array] = {}
    s_done: dict[str, Array] = {}
    opt_wall = 0.0
    for pi, path in enumerate(wpaths):
        W = weights[path]
        st, qc = qstates[path]

        # capture this linear's inputs on both streams through the cached
        # canonical capture programs: block k's j-th linear reuses the
        # program traced for block 0 instead of building a fresh jit
        states_done = {c_of[p]: qstates[p][0] for p in v_done}
        cv_done = {c_of[p]: v for p, v in v_done.items()}
        cs_done = {c_of[p]: s for p, s in s_done.items()}
        cfg_items = tuple(sorted((c_of[p], qstates[p][1]) for p in v_done))
        data_q = (bparams, states_done, cv_done, cs_done, x_q, calib, mem_q)
        xin_q = calib_loop.get_capture_program(
            model, walker, stackdefs, is_dec, c_of[path], cfg_items,
            rc.a_bits, rc, data_q).run(*data_q)
        data_fp = (bparams, {}, {}, {}, x_fp, calib, mem_fp)
        xin_fp = calib_loop.get_capture_program(
            model, walker, stackdefs, is_dec, c_of[path], (), None,
            rc, data_fp).run(*data_fp)
        zt = jnp.matmul(xin_fp.astype(jnp.float32),
                        W.astype(jnp.float32)).astype(xin_fp.dtype)
        opt = {"v": adaround.init_v(W, st, qc)}
        if rc.a_bits is not None:
            opt["s"] = lsq.init_act_scale(xin_q, rc.a_bits, symmetric=True)
        lead = xin_q.shape[0]
        bs = min(rc.calib_bs, lead)
        progs = calib_loop.get_layer_programs(qc, rc, bs, lead, W, st, opt,
                                              xin_q, zt)
        t_opt = time.time()
        opt, _losses = calib_loop.run_layer_loop(
            progs, rc, W, st, opt, adam.init(opt),
            jax.random.fold_in(unit_key, pi), xin_q, zt)
        opt_wall += time.time() - t_opt
        v_done[path] = opt["v"]
        if rc.a_bits is not None:
            s_done[path] = opt["s"]

    hard_opt = {"v": {c_of[p]: v for p, v in v_done.items()},
                "s": {c_of[p]: s for p, s in s_done.items()}}
    x_q2 = uprogs.hard(bparams, states_c, hard_opt, x_q, calib, mem_q)
    n_iters = len(wpaths) * rc.iters
    stat = {"unit": [bi], "paths": len(wpaths), "iters": rc.iters,
            "final_recon_mse": float(jnp.mean((x_q2 - z_fp).astype(jnp.float32) ** 2)),
            "opt_iters": n_iters, "opt_wall_s": opt_wall,
            "calib_iters_per_s": n_iters / max(opt_wall, 1e-9),
            "cache_hit": cache_hit,
            "wall_s": time.time() - t0}
    return z_fp, x_q2, v_done, s_done, stat


def _cap(walker, params, bi, qstates, v_done, s_done, rc, path, x, calib, mem):
    hook = _LayerHook(qstates, v_done, path, s_done, rc.a_bits)
    _apply_unit(walker, params, [bi], hook, x, calib, mem)
    return hook.captured


# ---------------------------------------------------------------------------
# baking
# ---------------------------------------------------------------------------


def bake(model, params, qstates, v_all, embed_head) -> Params:
    """Write hard-quantized weights back into a params copy."""
    params_q = jax.tree.map(lambda x: x, params)

    def set_leaf(path: str, fn):
        parts = path.split("/")
        if "." in parts[0]:
            sname, ri = parts[0].rsplit(".", 1)
            ri = int(ri)
            keys = parts[1:] + ["w"]
            node = params_q[sname]
            for k in keys[:-1]:
                node = node[k]
            leaf = node[keys[-1]]
            node[keys[-1]] = leaf.at[ri].set(fn(leaf[ri]))
        else:
            node = params_q
            for k in parts[:-1]:
                node = node[k]
            node[parts[-1]] = fn(node[parts[-1]])

    for path, (st, cfg) in qstates.items():
        if path in v_all:
            v = v_all[path]
            set_leaf(path, lambda w, v=v, st=st, cfg=cfg: adaround.hard_quant(w, v, st, cfg))
        else:
            set_leaf(path, lambda w, st=st, cfg=cfg: quantize_dequant(w, st, cfg))
    for path, (st, cfg) in embed_head.items():
        set_leaf(path, lambda w, st=st, cfg=cfg: quantize_dequant(w, st, cfg))
    return params_q
