"""Genetic-algorithm mixed precision under a hardware constraint
(paper Sec. 3.4 + Algorithm 2), with a TPU-v5e analytic cost model
replacing the paper's cycle-accurate FPGA simulator (DESIGN.md §2).

Search space c in {2,4,8}^n. Fitness = sum of diagonal sensitivities at
the assigned bits + intra-block pairwise interaction for layers assigned
2-bit. Constraint H(c) <= delta where H is model bytes or estimated
serving latency.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import numpy as np

from .sensitivity import SensTable

BIT_CHOICES = (2, 4, 8)


# ---------------------------------------------------------------------------
# TPU cost model
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TPUCostModel:
    """Analytic v5e roofline for per-layer serving cost.

    Weight-only quantization leaves MXU FLOPs unchanged (dequant to bf16
    before the matmul); the win is the weight-streaming memory term,
    which scales linearly with bits — exactly the behaviour of the
    qmatmul kernel. int8 activations double MXU throughput.

    ``layer_cost_fn`` swaps the roofline for an injected per-layer cost
    ``(path, shape, w_bits) -> seconds`` — e.g. measured kernel timings
    (``repro.deploy.budget.cost.measure_cost_table``) — so the same
    search can run under analytic or measured constraints; fig2 reports
    both, and BENCH_serve.json shows why the difference matters
    (the roofline's decode-tier assumption loses on CPU).
    """

    peak_flops_bf16: float = 197e12
    hbm_bw: float = 819e9
    tokens_per_step: int = 1024  # batch x seq of the serving shape
    layer_cost_fn: Optional[Callable[[str, tuple, int], float]] = None

    def layer_latency_s(self, shape: tuple, w_bits: int, a_bits: int = 16) -> float:
        *lead, k, n = shape
        e = int(np.prod(lead)) if lead else 1  # stacked experts
        flops = 2.0 * self.tokens_per_step * k * n  # per expert-equivalent
        peak = self.peak_flops_bf16 * (2.0 if a_bits <= 8 else 1.0)
        compute_t = e * flops / peak
        w_bytes = e * k * n * w_bits / 8.0
        act_bytes = self.tokens_per_step * (k + n) * (a_bits / 8.0)
        mem_t = (w_bytes + act_bytes) / self.hbm_bw
        return max(compute_t, mem_t)

    def model_latency_s(self, shapes: dict[str, tuple], bits: dict[str, int],
                        a_bits: int = 16) -> float:
        if self.layer_cost_fn is not None:
            return sum(self.layer_cost_fn(p, shapes[p], bits[p])
                       for p in shapes)
        return sum(self.layer_latency_s(shapes[p], bits[p], a_bits) for p in shapes)


def model_bytes(shapes: dict[str, tuple], bits: dict[str, int]) -> float:
    return sum(np.prod(s) * bits[p] / 8.0 for p, s in shapes.items())


# ---------------------------------------------------------------------------
# fitness from the sensitivity lookup table
# ---------------------------------------------------------------------------


def fitness(sens: SensTable, assign: dict[str, int]) -> float:
    total = 0.0
    for p, b in assign.items():
        total += sens.diag.get((p, b), 0.0)
    for (p1, p2), inter in sens.offdiag.items():
        if assign.get(p1) == 2 and assign.get(p2) == 2:
            total += inter
    return total


# ---------------------------------------------------------------------------
# genetic algorithm (paper Algorithm 2)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class GAConfig:
    pop_size: int = 50
    iters: int = 100
    p_mutation: float = 0.1
    top_k: int = 10
    seed: int = 0
    max_tries: int = 200  # per half-population fill


def genetic_search(sens: SensTable, cost_fn: Callable[[dict[str, int]], float],
                   delta: float, ga: GAConfig = GAConfig()) -> tuple[dict[str, int], dict]:
    """Search argmin fitness s.t. cost_fn(assign) <= delta.

    ``cost_fn`` is a whole-assignment cost; a per-layer
    ``deploy.budget.CostTable`` may be passed directly (its
    ``assign_cost`` is used), so the GA and the exact solver run under
    identical constraints when cross-checked. With a per-layer table the
    infeasibility fallback is the true cheapest assignment — measured
    cost tables are not monotone in bits (on CPU 2-bit unpack overhead
    makes W2 *slower* than W8), so the historical all-2-bit fallback can
    be infeasible when cheaper points exist."""
    per_layer = cost_fn if hasattr(cost_fn, "assign_cost") else None
    cost_fn = getattr(cost_fn, "assign_cost", cost_fn)
    paths = sorted(sens.shapes.keys())
    n = len(paths)
    rng = np.random.default_rng(ga.seed)
    if per_layer is None:
        cheapest = np.zeros(n, np.int64)  # all 2-bit
    else:
        cheapest = np.array([min(range(len(BIT_CHOICES)), key=lambda i:
                                 per_layer.cost(p, BIT_CHOICES[i]))
                             for p in paths], np.int64)

    def to_assign(vec: np.ndarray) -> dict[str, int]:
        return {p: BIT_CHOICES[v] for p, v in zip(paths, vec)}

    def feasible(vec) -> bool:
        return cost_fn(to_assign(vec)) <= delta

    def random_vec() -> np.ndarray:
        # gaussian around mid-precision, rounded into {0,1,2} (paper init)
        v = np.clip(np.round(rng.normal(1.0, 0.8, n)), 0, 2).astype(np.int64)
        return v

    # initial feasible population (bias toward low bits if delta is tight)
    pop: list[np.ndarray] = []
    tries = 0
    while len(pop) < ga.pop_size and tries < ga.max_tries * ga.pop_size:
        v = random_vec()
        if not feasible(v):
            v = cheapest.copy()
            if not feasible(v):
                raise ValueError("delta infeasible even at the cheapest "
                                 "assignment")
        pop.append(v)
        tries += 1

    def score(v) -> float:
        return fitness(sens, to_assign(v))

    topk: list[tuple[float, np.ndarray]] = []
    history = []
    for t in range(ga.iters):
        scored = sorted(((score(v), v) for v in pop), key=lambda x: x[0])
        pool = scored[: ga.top_k] + topk
        pool = sorted(pool, key=lambda x: x[0])[: ga.top_k]
        topk = [(s, v.copy()) for s, v in pool]
        history.append(topk[0][0])

        def crossover() -> np.ndarray:
            a = topk[rng.integers(len(topk))][1]
            b = topk[rng.integers(len(topk))][1]
            mask = rng.random(n) < 0.5
            return np.where(mask, a, b)

        def mutate() -> np.ndarray:
            v = topk[rng.integers(len(topk))][1].copy()
            mask = rng.random(n) < ga.p_mutation
            v[mask] = rng.integers(0, 3, mask.sum())
            return v

        new_pop: list[np.ndarray] = []
        for gen in (crossover, mutate):
            half: list[np.ndarray] = []
            tries = 0
            while len(half) < ga.pop_size // 2 and tries < ga.max_tries:
                c = gen()
                tries += 1
                if feasible(c):
                    half.append(c)
            while len(half) < ga.pop_size // 2:  # fall back to known-feasible
                half.append(topk[rng.integers(len(topk))][1].copy())
            new_pop += half
        pop = new_pop

    best_s, best_v = topk[0]
    assign = to_assign(best_v)
    return assign, {"fitness": best_s, "history": history,
                    "cost": cost_fn(assign)}


def pareto_sweep(sens: SensTable, cost_fn, deltas, ga: GAConfig = GAConfig()):
    """One GA run per threshold -> (delta, assignment, fitness) Pareto set."""
    out = []
    for d in deltas:
        assign, info = genetic_search(sens, cost_fn, d, ga)
        out.append({"delta": d, "assign": assign, **info})
    return out
