"""Distribution plane: mesh sharding plans for train / serve steps.

Public API (see :mod:`repro.dist.sharding`):
  * :class:`Plan` — path-based partition rules over a named mesh for one
    ``{dp, tp, fsdp, zero3}`` strategy, covering fp *and* packed-int
    (`repro.deploy`) param trees, optimizer state, batches and KV caches.
  * :func:`pick_strategy` — default strategy for an (arch, step-kind).
  * :func:`estimate_params` — exact param count from an ArchConfig.
"""
from .sharding import Plan, estimate_params, pick_strategy  # noqa: F401
