"""Mesh sharding plans: path-based partition rules for every step input.

A :class:`Plan` binds a named mesh (axes drawn from ``pod``/``data``/
``model``), one parallelism strategy and an :class:`ArchConfig`, and
answers one question for the step builders in ``launch/steps.py``: *how
is this leaf laid out over the mesh?* Everything is expressed as
``PartitionSpec`` rules keyed on the leaf's **tree path** and shape — no
model cooperation needed beyond the repo-wide param conventions
(``{'w': (K, N)}`` linears, stacked ``(L, ...)`` scan leaves, stacked
``(E, K, N)`` MoE experts).

Strategies
----------
``dp``
    Params/opt replicated; the batch shards over every mesh axis that
    divides it (including ``model``, so a pure-DP mesh is fully used).
``tp``
    Megatron tensor parallelism over ``model``: column-parallel
    in-projections (``wq``/``wk``/``wv``/``w_gate``/``w_up``/
    ``in_proj``/...) shard N, row-parallel out-projections
    (``wo``/``w_down``/``out_proj``) shard K, norms/biases/gains
    replicate, the embedding table is vocab-parallel, MoE expert stacks
    shard E over ``model``. Batch shards over ``pod``+``data``.
``fsdp``
    ``tp`` rules plus weight-sharding over ``fsdp_axis`` (default
    ``data``) on the other matrix dim — 2-D sharded params, gathered by
    GSPMD where the compute needs them. Expert stacks keep E over
    ``model`` and put their role dim over ``fsdp_axis``.
``zero3``
    No tensor parallelism: every matrix-like leaf shards its largest
    divisible dim over the *joint* axes tuple (all mesh axes), i.e.
    ZeRO-3 weight sharding at maximum width.

Packed-int leaves (`repro.deploy` artifact format)
--------------------------------------------------
``params_sharding`` recognizes packed nodes (``qscale`` /
``table_qscale`` sibling) produced by ``deploy.quantize_tree`` — also
under ``jax.eval_shape``, which is how ``launch/steps.py`` derives
abstract serving params. Rules:

  * int8 codes ``w: (..., K*cbits/8, N)`` shard along **N only** (plus E
    for expert stacks). The packed row dim is never sharded: sub-byte
    unpacking reshapes rows (values interleave across a byte), so a row
    split is only legal at container granularity — N stays elementwise
    through dequant and is always safe.
  * ``qscale`` siblings are small ``(..., G, N)`` f32 — replicated.
  * the int8 embedding ``table`` keeps the fp vocab-parallel rule (the
    8-bit container has one row per vocab entry, so gather + per-channel
    dequant are unchanged); ``table_qscale`` replicates.
  * int8-container fallbacks (ragged K, widths not dividing 8) change
    only the row count, which is never sharded — the rules stay legal.

Every axis assignment is guarded by divisibility; a dim that does not
divide the axis size falls back to replication instead of failing, so
reduced configs lower on any placeholder mesh.
"""
from __future__ import annotations

import dataclasses
import math
from functools import lru_cache
from typing import Any, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ArchConfig

Params = Any

STRATEGIES = ("dp", "tp", "fsdp", "zero3")

# parent-node names classifying a {'w': ...} leaf's matmul role.
COL_PARENTS = frozenset({
    "wq", "wk", "wv",            # attention / mlstm in-projections
    "w_gate", "w_up",            # (shared-)MLP in-projections
    "in_proj", "w_in", "w_if",   # ssm / xlstm fused in-projections
    "w_dt", "wB", "wC",          # mamba coefficient projections
    "head",                      # lm head: (d, V), vocab is the out dim
})
ROW_PARENTS = frozenset({"wo", "w_down", "out_proj"})
EXPERT_PARENTS = frozenset({"w_gate", "w_up", "w_down"})

_SEQ_TILE = 128  # minimum per-shard seq chunk for sequence parallelism


def _keys(path: Sequence[Any]) -> tuple[str, ...]:
    """Key path -> plain strings (accepts jax DictKeys or any object
    with a ``.key`` attribute, e.g. the step builders' fake keys)."""
    return tuple(str(getattr(k, "key", k)) for k in path)


@dataclasses.dataclass
class Plan:
    """Sharding plan: (mesh, strategy, arch) -> per-leaf PartitionSpecs.

    Args:
      mesh: named device mesh; axes from ``("pod", "data", "model")``.
      strategy: one of :data:`STRATEGIES`.
      cfg: the architecture the plan serves (used by :func:`pick_strategy`
        callers and kept for provenance in dry-run artifacts).
      fsdp_axis: axis weight-sharding uses under ``fsdp``.
      shard_experts: shard stacked MoE expert dims over ``model``.
      seq_parallel: sequence-shard block-boundary activations over
        ``model`` for tp/fsdp when the seq length tiles (see
        ``launch/steps.act_shard_fn``).
    """

    mesh: Mesh
    strategy: str
    cfg: ArchConfig
    fsdp_axis: str = "data"
    shard_experts: bool = True
    seq_parallel: bool = True

    def __post_init__(self):
        if self.strategy not in STRATEGIES:
            raise ValueError(f"unknown strategy {self.strategy!r}; "
                             f"expected one of {STRATEGIES}")
        if self.strategy == "fsdp" and self.fsdp_axis not in self.mesh.shape:
            raise ValueError(f"fsdp_axis {self.fsdp_axis!r} not a mesh axis "
                             f"{tuple(self.mesh.shape)}")

    # -- mesh helpers --------------------------------------------------------

    def _axis_size(self, axes) -> int:
        if axes is None:
            return 1
        if isinstance(axes, str):
            axes = (axes,)
        size = 1
        for a in axes:
            if a not in self.mesh.shape:
                return 0
            size *= self.mesh.shape[a]
        return size

    @property
    def _model_size(self) -> int:
        return self.mesh.shape.get("model", 0)

    def _replicated(self, ndim: int) -> P:
        return P(*([None] * ndim))

    # -- batch ---------------------------------------------------------------

    def batch_axes(self, global_batch: int) -> tuple[str, ...]:
        """Mesh axes the batch dim shards over, in mesh order, greedily
        keeping the joint product a divisor of ``global_batch``."""
        if self.strategy == "dp":
            cand = self.mesh.axis_names
        else:
            cand = tuple(a for a in self.mesh.axis_names if a in ("pod", "data"))
        axes: list[str] = []
        prod = 1
        for a in cand:
            n = self.mesh.shape[a]
            if n > 1 and global_batch % (prod * n) == 0:
                axes.append(a)
                prod *= n
        return tuple(axes)

    def _seq_shard_ok(self, seq_len: int, baxes: tuple[str, ...]) -> bool:
        return (self.seq_parallel and self.strategy in ("tp", "fsdp")
                and self._model_size > 1 and "model" not in baxes
                and seq_len > 0
                and seq_len % (self._model_size * _SEQ_TILE) == 0)

    def batch_spec(self, global_batch: int, ndim: int,
                   seq_axis: int = 1, seq_len: int = 0) -> P:
        """Spec for one batch leaf: dim 0 over :meth:`batch_axes`;
        optionally dim ``seq_axis`` over ``model`` (sequence parallelism)
        when ``seq_len`` tiles over the model axis."""
        baxes = self.batch_axes(global_batch)
        spec: list[Any] = [None] * ndim
        spec[0] = baxes if baxes else None
        if 0 < seq_axis < ndim and self._seq_shard_ok(seq_len, baxes):
            spec[seq_axis] = "model"
        return P(*spec)

    def batch_sharding(self, batch: Params, global_batch: int,
                       shard_seq: bool = True) -> Params:
        """NamedShardings for a batch pytree (tokens / patches / frames):
        batch dim over the data axes, seq dim over ``model`` when
        ``shard_seq`` and the length tiles."""

        def leaf(x):
            ndim = len(x.shape)
            seq_len = x.shape[1] if (shard_seq and ndim >= 2) else 0
            return NamedSharding(
                self.mesh, self.batch_spec(global_batch, ndim, 1, seq_len))

        return jax.tree.map(leaf, batch)

    # -- params --------------------------------------------------------------

    def param_spec(self, path: Sequence[Any], shape: Sequence[int]) -> P:
        """PartitionSpec for one fp param leaf, from its tree path.

        ``path`` is a key path (jax ``DictKey``-likes); ``shape`` the
        global leaf shape, leading scan-stack dim included.
        """
        return self._param_spec(_keys(path), tuple(shape))

    def _param_spec(self, keys: tuple[str, ...], shape: tuple[int, ...]) -> P:
        ndim = len(shape)
        spec: list[Any] = [None] * ndim
        leaf = keys[-1] if keys else ""
        parent = keys[-2] if len(keys) > 1 else ""
        gparent = keys[-3] if len(keys) > 2 else ""
        if self.strategy == "dp" or ndim < 2:
            return P(*spec)
        if leaf in ("qscale", "table_qscale") or parent == "router":
            return P(*spec)  # scales replicate; the MoE router stays FP+small

        if leaf == "table":  # embedding: vocab-parallel (megatron)
            return self._matrix_spec(spec, shape, role_dim=ndim - 2,
                                     other_dim=ndim - 1)
        if leaf != "w":
            return P(*spec)  # norms, biases, gates, convs, pos tables

        if (parent in EXPERT_PARENTS and gparent == "moe" and ndim >= 3):
            return self._expert_spec(spec, shape, parent)
        if parent in COL_PARENTS:
            return self._matrix_spec(spec, shape, role_dim=ndim - 1,
                                     other_dim=ndim - 2)
        if parent in ROW_PARENTS:
            return self._matrix_spec(spec, shape, role_dim=ndim - 2,
                                     other_dim=ndim - 1)
        return P(*spec)  # unknown weight: replicate rather than guess

    def _zero3_spec(self, spec: list, shape: tuple[int, ...]) -> P:
        joint = tuple(self.mesh.axis_names)
        size = self._axis_size(joint)
        for dim in sorted(range(len(shape)), key=lambda d: -shape[d]):
            if size > 1 and shape[dim] % size == 0:
                spec[dim] = joint
                break
        return P(*spec)

    def _matrix_spec(self, spec: list, shape: tuple[int, ...],
                     role_dim: int, other_dim: int) -> P:
        """tp: role dim over ``model``; fsdp: + other dim over
        ``fsdp_axis``; zero3: largest divisible dim over the joint axes."""
        if self.strategy == "zero3":
            return self._zero3_spec(spec, shape)
        if self._model_size > 1 and shape[role_dim] % self._model_size == 0:
            spec[role_dim] = "model"
        if self.strategy == "fsdp" and self.fsdp_axis != "model":
            fs = self._axis_size(self.fsdp_axis)
            if fs > 1 and shape[other_dim] % fs == 0:
                spec[other_dim] = self.fsdp_axis
        return P(*spec)

    def _expert_spec(self, spec: list, shape: tuple[int, ...],
                     parent: str) -> P:
        """Stacked experts ``(..., E, K, N)``: E over ``model`` (EP);
        fsdp additionally shards the role dim over ``fsdp_axis``."""
        ndim = len(shape)
        if self.strategy == "zero3":
            return self._zero3_spec(spec, shape)
        e_dim = ndim - 3
        role_dim = ndim - 2 if parent in ROW_PARENTS else ndim - 1
        other_dim = ndim - 1 if role_dim == ndim - 2 else ndim - 2
        e_sharded = (self.shard_experts and self._model_size > 1
                     and shape[e_dim] % self._model_size == 0)
        if e_sharded:
            spec[e_dim] = "model"
        elif self._model_size > 1 and shape[role_dim] % self._model_size == 0:
            spec[role_dim] = "model"  # EP off/impossible: plain tp rule
        if self.strategy == "fsdp" and self.fsdp_axis != "model":
            fs = self._axis_size(self.fsdp_axis)
            dim = role_dim if e_sharded else other_dim
            if fs > 1 and shape[dim] % fs == 0 and spec[dim] is None:
                spec[dim] = self.fsdp_axis
        return P(*spec)

    def _packed_spec(self, keys: tuple[str, ...], shape: tuple[int, ...]) -> P:
        """Spec for packed int8 codes: N (and E) only — see module doc."""
        ndim = len(shape)
        spec: list[Any] = [None] * ndim
        parent = keys[-2] if len(keys) > 1 else ""
        gparent = keys[-3] if len(keys) > 2 else ""
        if self.strategy == "dp" or ndim < 2:
            return P(*spec)
        if self.strategy == "zero3":
            joint = tuple(self.mesh.axis_names)
            size = self._axis_size(joint)
            if size > 1 and shape[-1] % size == 0:
                spec[-1] = joint
            return P(*spec)
        n_axis = "model"
        if (parent in EXPERT_PARENTS and gparent == "moe" and ndim >= 3
                and self.shard_experts and self._model_size > 1
                and shape[ndim - 3] % self._model_size == 0):
            spec[ndim - 3] = "model"
            n_axis = self.fsdp_axis if self.strategy == "fsdp" else None
        if (n_axis and self._axis_size(n_axis) > 1
                and shape[-1] % self._axis_size(n_axis) == 0):
            spec[-1] = n_axis
        return P(*spec)

    def params_sharding(self, params: Params) -> Params:
        """NamedShardings for a whole param tree — fp or packed.

        Accepts concrete arrays or ``ShapeDtypeStruct`` trees (e.g.
        ``jax.eval_shape(deploy.quantize_tree)`` output from the serving
        path). Packed nodes are detected by their ``qscale`` /
        ``table_qscale`` sibling and get the packed-leaf rules.
        """

        def walk(node, keypath):
            if not isinstance(node, dict):
                return NamedSharding(
                    self.mesh, self._param_spec(keypath, tuple(node.shape)))
            if "qscale" in node or "table_qscale" in node:
                out = {}
                for k, v in node.items():
                    if k == "w":
                        spec = self._packed_spec(keypath + (k,), tuple(v.shape))
                    elif k == "table":
                        spec = self._param_spec(keypath + (k,), tuple(v.shape))
                    else:  # qscale / table_qscale / bias: small, replicated
                        spec = self._replicated(len(v.shape))
                    out[k] = NamedSharding(self.mesh, spec)
                return out
            return {k: walk(v, keypath + (k,)) for k, v in node.items()}

        return walk(params, ())

    def opt_sharding(self, opt_tree: Params) -> Params:
        """Optimizer-moment trees mirror the param tree layout."""
        return self.params_sharding(opt_tree)

    # -- caches --------------------------------------------------------------

    def cache_spec(self, path: Sequence[Any], shape: Sequence[int],
                   global_batch: int) -> P:
        """Spec for one stacked cache leaf ``(L, B, ...)``: batch dim over
        the data axes; the largest trailing dim (seq slots for KV caches,
        the inner dim for recurrent states) over ``model`` when free and
        divisible. ``path`` is accepted for rule-engine symmetry."""
        del path  # shape-driven; kept for API symmetry with param_spec
        return self._cache_spec(tuple(shape), global_batch)

    def _cache_spec(self, shape: tuple[int, ...], global_batch: int) -> P:
        ndim = len(shape)
        spec: list[Any] = [None] * ndim
        if ndim < 2:
            return P(*spec)
        baxes = self.batch_axes(shape[1] if shape[1] else global_batch)
        spec[1] = baxes if baxes else None
        if ndim >= 3 and self._model_size > 1 and "model" not in baxes:
            j = max(range(2, ndim), key=lambda d: shape[d])
            if shape[j] % self._model_size == 0:
                spec[j] = "model"
        return P(*spec)

    def cache_sharding(self, cache: Params, global_batch: int) -> Params:
        """NamedShardings for a KV/state cache pytree."""
        return jax.tree.map(
            lambda x: NamedSharding(
                self.mesh, self._cache_spec(tuple(x.shape), global_batch)),
            cache)


# ---------------------------------------------------------------------------
# strategy selection + param counting
# ---------------------------------------------------------------------------


def pick_strategy(cfg: ArchConfig, kind: str) -> str:
    """Default strategy for an (arch, step-kind) cell.

    Serving (prefill/decode) always runs tensor-parallel: weights stay
    resident over ``model`` and the small per-step batch shards over the
    data axes. Training is data-parallel for models that fit replicated
    and fsdp for MoE / multi-billion-param models.
    """
    if kind in ("prefill", "decode"):
        return "tp"
    if cfg.moe is not None or estimate_params(cfg) > 2e9:
        return "fsdp"
    return "dp"


@lru_cache(maxsize=None)
def _count_params(cfg: ArchConfig) -> float:
    from ..models.registry import build_model

    model = build_model(cfg)
    sds = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    return float(sum(math.prod(leaf.shape) for leaf in jax.tree.leaves(sds)))


def estimate_params(cfg: ArchConfig) -> float:
    """Exact parameter count for a config: the model's own ``init`` traced
    under ``jax.eval_shape`` (shapes only — no allocation), cached per
    config. Consumed by the roofline's MODEL_FLOPS and strategy picking."""
    return _count_params(cfg)
