"""Roofline terms per (arch x shape x mesh) from the compiled dry-run.

Hardware constants (TPU v5e, per assignment):
  197 TFLOP/s bf16 per chip, 819 GB/s HBM, ~50 GB/s/link ICI.

Terms (seconds, per step, per chip):
  compute    = HLO_FLOPs_per_chip / peak_FLOPs
  memory     = HLO_bytes_per_chip / HBM_bw
  collective = collective_bytes_per_chip / link_bw

HLO_FLOPs / bytes / collective_bytes come from the while-aware HLO parser
(analysis/hlo.py) applied to the compiled module — on a GSPMD module the
shapes are already the per-chip shards, so the totals are per-chip.
MODEL_FLOPS = 6*N*D (train) / 2*N*D (inference) with N = active params.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from ..configs.base import ArchConfig, ShapeSpec
from ..dist.sharding import estimate_params
from .hlo import HLOSummary, analyze_module

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # B/s per chip
ICI_BW = 50e9  # B/s per link


@dataclasses.dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    model_flops_per_chip: float
    hlo_flops_per_chip: float
    useful_ratio: float  # MODEL_FLOPS / HLO_FLOPs
    roofline_frac: float  # useful compute time / dominant term
    mem_frac: float = 0.0  # decode: ideal (params+cache once) / HLO bytes

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


def ideal_serve_bytes(cfg: ArchConfig, shape: ShapeSpec, n_chips: int,
                      w_bits: int = 16) -> float:
    """Per-chip lower bound for one decode step: stream active weights
    once + read the live cache once (both already sharded over chips)."""
    param_bytes = active_params(cfg) * w_bits / 8.0
    B, S = shape.global_batch, shape.seq_len
    hd, K = cfg.hd, cfg.n_kv_heads
    cache = 0.0
    for _ in range(1):
        if cfg.family == "ssm":
            di = int(cfg.d_model * cfg.xlstm_expansion)
            H = cfg.n_heads
            cache = cfg.n_layers * B * (H * (di // max(H, 1)) ** 2) * 4.0
        else:
            slots = S
            win = cfg.window or (cfg.hymba_window if cfg.family == "hybrid" else None)
            if cfg.local_global:
                nl, ng = cfg.local_global
                per_group = nl * min(S, cfg.local_window) + ng * S
                slots_total = per_group * (cfg.n_layers // (nl + ng))
                cache = B * slots_total * K * hd * 2 * 2.0
                slots = None
            elif win:
                slots = min(S, win)
            if slots is not None:
                cache = cfg.n_layers * B * slots * K * hd * 2 * 2.0
            if cfg.family == "hybrid":
                di = int(cfg.d_model * cfg.ssm_expansion)
                cache += cfg.n_layers * B * di * cfg.ssm_state * 4.0
    return (param_bytes + cache) / n_chips


def active_params(cfg: ArchConfig) -> float:
    """Parameters touched per token (MoE: top-k + shared experts only)."""
    total = estimate_params(cfg)
    if cfg.moe:
        d = cfg.d_model
        expert = 3 * d * cfg.moe.d_ff_expert
        inactive = (cfg.moe.n_experts - cfg.moe.top_k) * expert
        n_moe_layers = cfg.n_layers - cfg.moe.first_k_dense
        total -= n_moe_layers * inactive
    return total


def model_flops(cfg: ArchConfig, shape: ShapeSpec, n_chips: int) -> float:
    """6*N_active*D for train; 2*N_active*D for inference, per chip.

    decode shapes process global_batch tokens per step (D = batch)."""
    n = active_params(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens / n_chips
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens / n_chips
    tokens = shape.global_batch  # one token per sequence per step
    return 2.0 * n * tokens / n_chips


def from_hlo(hlo_text: str, cfg: ArchConfig, shape: ShapeSpec,
             n_chips: int, w_bits: int = 16) -> tuple[Roofline, HLOSummary]:
    summ = analyze_module(hlo_text)
    compute_s = summ.flops / PEAK_FLOPS
    memory_s = summ.bytes / HBM_BW
    collective_s = summ.collective_bytes / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    bottleneck = max(terms, key=terms.get)
    mf = model_flops(cfg, shape, n_chips)
    useful = mf / summ.flops if summ.flops else 0.0
    # fraction of the dominant term that is useful model compute:
    frac = (mf / PEAK_FLOPS) / max(terms.values()) if max(terms.values()) else 0.0
    mem_frac = 0.0
    if shape.kind == "decode" and summ.bytes:
        mem_frac = ideal_serve_bytes(cfg, shape, n_chips, w_bits) / summ.bytes
    rl = Roofline(compute_s=compute_s, memory_s=memory_s,
                  collective_s=collective_s, bottleneck=bottleneck,
                  model_flops_per_chip=mf, hlo_flops_per_chip=summ.flops,
                  useful_ratio=useful, roofline_frac=frac, mem_frac=mem_frac)
    return rl, summ
