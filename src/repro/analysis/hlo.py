"""Optimized-HLO text analysis: FLOPs / bytes / collective bytes with
while-loop (scan) trip-count multiplicity.

Why not ``compiled.cost_analysis()`` alone: XLA's HloCostAnalysis visits a
while body ONCE, so anything inside ``lax.scan`` (i.e. every transformer
layer here) is undercounted by the trip count. This parser:

  1. splits the HLO module into computations and builds a per-computation
     symbol table (op name -> shape),
  2. per computation, sums
       * dot FLOPs: 2 * prod(out_shape) * prod(contracting dims),
       * buffer bytes: in+out bytes of every materialized op (fusion
         boundary granularity - the same definition XLA uses),
       * collective bytes: operand bytes of all-gather / all-reduce /
         reduce-scatter / all-to-all / collective-permute,
  3. recovers each while's trip count from the integer constant in its
     condition computation and accumulates everything with multiplicity
     (nested whiles recurse).

Elementwise FLOPs are ignored (dots dominate for transformer workloads);
the delta vs cost_analysis is reported so the approximation is visible.
"""
from __future__ import annotations

import dataclasses
import math
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(\([^=]*?\)|[\w\[\],\{\} ]+?)\s+"
    r"([\w\-]+)\((.*?)\)(.*)$")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
# Perfect-fusion HBM model: only ops that force a materialized buffer on
# TPU count toward memory traffic. Elementwise/broadcast/convert chains are
# assumed fused into their consumers (XLA:CPU leaves them unfused, which
# would otherwise overstate the memory term by >100x vs a TPU build).
_MEM_OPS = {"dot", "convolution", "dynamic-update-slice", "dynamic-slice",
            "gather", "scatter", "reduce", "reduce-window", "sort",
            "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
            "collective-permute"}


def _shape_bytes(shape_str: str) -> int:
    """Total bytes of a (possibly tuple) shape string."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _shape_dims(shape_str: str) -> list[int]:
    m = _SHAPE_RE.search(shape_str)
    if not m:
        return []
    dims = m.group(2)
    return [int(d) for d in dims.split(",") if d] if dims else []


@dataclasses.dataclass
class CompStats:
    dot_flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: float = 0.0
    collective_counts: dict = dataclasses.field(default_factory=dict)
    whiles: list = dataclasses.field(default_factory=list)  # (body, cond)
    calls: list = dataclasses.field(default_factory=list)


def split_computations(hlo: str) -> tuple[dict[str, list[str]], str | None]:
    """(name -> op lines, entry computation name)."""
    comps: dict[str, list[str]] = {}
    cur = None
    entry = None
    for line in hlo.splitlines():
        s = line.strip()
        # computation header: `[ENTRY] %name (args...) -> shape {`
        if s.endswith("{") and "->" in s and "=" not in s.split("(")[0]:
            m = re.match(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(", s)
            if m:
                cur = m.group(2)
                comps[cur] = []
                if m.group(1):
                    entry = cur
                continue
        if s.startswith("}"):
            cur = None
            continue
        if cur is not None and "=" in s:
            # strip /*index=N*/ style comments: they contain '=' and break
            # the tuple-shape grammar
            comps[cur].append(re.sub(r"/\*.*?\*/", "", s))
    return comps, entry


def analyze_computation(lines: list[str]) -> CompStats:
    st = CompStats()
    shapes: dict[str, str] = {}
    for ln in lines:
        m = _OP_RE.match(ln)
        if not m:
            continue
        name, shape_str, op, operands_str, tail = m.groups()
        shapes[name] = shape_str
        operands = [o.strip().lstrip("%") for o in _split_operands(operands_str)]
        if op == "while":
            bm = re.search(r"body=%?([\w\.\-]+)", tail)
            cm = re.search(r"condition=%?([\w\.\-]+)", tail)
            if bm and cm:
                st.whiles.append((bm.group(1), cm.group(1)))
            continue
        if op in ("call", "conditional"):
            for cm in re.finditer(r"(?:to_apply|branch_computations=\{|calls)=?%?([\w\.\-]+)", tail):
                st.calls.append(cm.group(1))
        if op.startswith(tuple(_COLLECTIVES)):
            b = sum(_shape_bytes(shapes.get(o, "")) for o in operands)
            if b == 0:  # operand shapes unknown: fall back to output
                b = _shape_bytes(shape_str)
            st.collective_bytes += b
            kind = next(c for c in _COLLECTIVES if op.startswith(c))
            st.collective_counts[kind] = st.collective_counts.get(kind, 0) + 1
        if op == "dot":
            out_dims = _shape_dims(shape_str)
            lhs_shape = _shape_dims(shapes.get(operands[0], "")) if operands else []
            cm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", tail)
            contract = 1
            if cm and lhs_shape:
                for d in cm.group(1).split(","):
                    if d:
                        contract *= lhs_shape[int(d)]
            st.dot_flops += 2.0 * math.prod(out_dims or [0]) * contract
        if op == "fusion":
            fm = re.search(r"calls=%?([\w\.\-]+)", tail)
            if fm:
                st.calls.append("__fusion__" + fm.group(1))
        if op in _MEM_OPS:
            if op == "dynamic-update-slice":
                # in-place on TPU: only the updated slice moves
                upd = _shape_bytes(shapes.get(operands[1], "")) if len(operands) > 1 else 0
                b = 2 * upd
            elif op in ("dynamic-slice", "gather"):
                b = 2 * _shape_bytes(shape_str)  # read slice + write out
            elif op == "scatter":
                upd = _shape_bytes(shapes.get(operands[2], "")) if len(operands) > 2 else 0
                b = 3 * upd  # read-modify-write of touched region
            else:
                b = _shape_bytes(shape_str)
                b += sum(_shape_bytes(shapes.get(o, "")) for o in operands)
            st.bytes += b
    return st


def _split_operands(s: str) -> list[str]:
    """Split top-level comma-separated operand names."""
    out, depth, cur = [], 0, []
    for ch in s:
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
        if ch == "," and depth == 0:
            out.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
    if cur:
        out.append("".join(cur))
    return [o.split(" ")[-1] for o in (x.strip() for x in out) if o]


def trip_count(cond_lines: list[str]) -> int:
    """Largest integer constant in the while condition (scan bound)."""
    best = 1
    for ln in cond_lines:
        for m in re.finditer(r"constant\((\d+)\)", ln):
            best = max(best, int(m.group(1)))
    return best


@dataclasses.dataclass
class HLOSummary:
    flops: float
    bytes: float
    collective_bytes: float
    collective_counts: dict
    fusion_dot_flops: float  # dots found inside fusion computations


def analyze_module(hlo: str, entry_hint: str | None = None) -> HLOSummary:
    comps, entry = split_computations(hlo)
    stats = {name: analyze_computation(lines) for name, lines in comps.items()}

    if entry is None:
        # fallback: a computation not referenced by any other
        referenced = set()
        for st in stats.values():
            for b, c in st.whiles:
                referenced.add(b)
                referenced.add(c)
            for c in st.calls:
                referenced.add(c.replace("__fusion__", ""))
        entries = [n for n in comps if n not in referenced]
        for n in entries:
            if n.startswith("main") or (entry_hint and entry_hint in n):
                entry = n
        if entry is None and entries:
            entry = max(entries, key=lambda n: len(comps[n]))
        if entry is None:
            entry = next(iter(comps))

    total = HLOSummary(0.0, 0.0, 0.0, defaultdict(int), 0.0)
    seen: set[tuple[str, float]] = set()

    def visit(name: str, mult: float):
        st = stats.get(name)
        if st is None:
            return
        total.flops += mult * st.dot_flops
        total.bytes += mult * st.bytes
        total.collective_bytes += mult * st.collective_bytes
        for k, v in st.collective_counts.items():
            total.collective_counts[k] += mult * v
        for body, cond in st.whiles:
            n = trip_count(comps.get(cond, []))
            visit(body, mult * n)
        for c in st.calls:
            if c.startswith("__fusion__"):
                fst = stats.get(c.replace("__fusion__", ""))
                if fst:
                    total.fusion_dot_flops += mult * fst.dot_flops
                    total.flops += mult * fst.dot_flops
            else:
                visit(c, mult)

    visit(entry, 1.0)
    total.collective_counts = dict(total.collective_counts)
    return total
