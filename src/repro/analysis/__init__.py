from .hlo import analyze_module  # noqa: F401
from .roofline import Roofline, from_hlo, model_flops  # noqa: F401
