"""Builders of :class:`AuditProgram` descriptors for the repo's real
compiled programs.

Every builder returns descriptors for programs the repo actually ships —
the qmm dispatch tiers, serving decode/prefill (launch and engine
paths), budget-packed mixed-precision decode, and the calibration scan
step captured live from a micro ``quantize()`` run — each annotated with
the invariants past PRs established for it:

* decode-path programs carry ``forbidden_f32`` — the full-dequant shapes
  of their stacked packed leaves (the grouped tier's (E, K, N) and the
  scan stacks' (n, K, N) must never re-materialize in f32);
* programs the repo runs with buffer donation carry ``donate_argnums``
  (the launch decode loop's KV cache, the calibration scan's opt state);
* steady-state programs carry ``repeat_args`` so a retrace on a
  same-structure second call is caught.

Prefill programs deliberately do *not* carry ``forbidden_f32``: the
grouped-dense XLA reference materializes (E, K, N) per layer by design
at prefill arithmetic intensity (see ``kernels/qmatmul/ref.py``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .rules import AuditProgram, Violation

QUICK_ARCHS = ("brecq_lm_100m", "deepseek_moe_16b")
# Decode-capable archs beyond the quick set, exercised by --configs all.
EXTRA_ARCHS = ("tinyllama_1_1b", "gemma3_12b", "hymba_1_5b")


def forbidden_f32_shapes(params) -> frozenset:
    """Full-dequant f32 shapes for every *stacked* packed leaf in a
    params tree.

    A packed node ``{"w": int8 (..., rows, N), "qscale": ...}`` packs
    ``per`` codes per container row (per in {1, 2, 4} — int8/int4/int2);
    the leaf alone does not reveal ``per``, so every candidate logical
    K = rows * per is forbidden. Only stacked shapes (ndim >= 3) are
    returned: the 2-D per-layer (K, N) unscaled-code materialization is
    a legitimate XLA decode-reference step (``qgemv_ref``), while a full
    (E, K, N) / (n, K, N) f32 stack is exactly the residency blowup the
    grouped tier and scan layout exist to prevent.
    """
    shapes: set = set()

    def walk(node):
        if not isinstance(node, dict):
            return
        w = node.get("w")
        if w is not None and "qscale" in node and getattr(w, "ndim", 0) >= 3:
            rows, n = w.shape[-2], w.shape[-1]
            for per in (1, 2, 4):
                shapes.add(tuple(w.shape[:-2]) + (rows * per, n))
                if w.ndim >= 4:  # (n_layers, E, rows, N): per-layer slice too
                    shapes.add(tuple(w.shape[1:-2]) + (rows * per, n))
        for v in node.values():
            walk(v)

    walk(params)
    return frozenset(shapes)


# ---------------------------------------------------------------------------
# qmm dispatch tiers
# ---------------------------------------------------------------------------


def qmm_programs(key=None) -> list[AuditProgram]:
    """One program per qmm dispatch tier (decode gemv / prefill matmul /
    grouped experts), over real packed nodes."""
    from ...deploy import rtn_pack_leaf
    from ...kernels.qmatmul.ops import from_node, qmm

    key = key if key is not None else jax.random.PRNGKey(0)
    k1, k2 = jax.random.split(key)
    K, N, E = 64, 128, 4
    node2 = dict(zip(("w", "qscale"), rtn_pack_leaf(
        jax.random.normal(k1, (K, N), jnp.float32), 4, None)))
    node3 = dict(zip(("w", "qscale"), rtn_pack_leaf(
        jax.random.normal(k2, (E, K, N), jnp.float32), 4, None)))

    def tier2(x, w, qs):
        return qmm(x, from_node({"w": w, "qscale": qs}, K))

    def tier3(x, w, qs):
        return qmm(x, from_node({"w": w, "qscale": qs}, K))

    def prog(name, fn, node, x):
        return AuditProgram(
            name=name, fn=fn, args=(x, node["w"], node["qscale"]),
            repeat_args=(x + 1.0, node["w"], node["qscale"]),
            forbidden_f32=forbidden_f32_shapes({"n": node}))

    return [
        prog("qmm_decode", tier2, node2, jnp.ones((4, K), jnp.float32)),
        prog("qmm_prefill", tier2, node2, jnp.ones((32, K), jnp.float32)),
        prog("qmm_grouped_decode", tier3, node3,
             jnp.ones((E, 2, K), jnp.float32)),
    ]


# ---------------------------------------------------------------------------
# serving: launch-style decode/prefill per arch
# ---------------------------------------------------------------------------


def serve_programs(arch: str) -> list[AuditProgram]:
    """Decode step (with the KV-cache donation ``launch/serve.py``
    declares) and a prefill program for one reduced arch served from a
    packed RTN artifact."""
    from ...deploy import rtn_artifact
    from ...models import get_model

    cfg, model = get_model(arch, reduced=True)
    params = model.init(jax.random.PRNGKey(0))
    art = rtn_artifact(params, 4, cfg=cfg)
    B, T = 2, 16
    cache = model.init_cache(B, T, jnp.float32)
    tok = jnp.zeros((B, 1), jnp.int32)
    pos = jnp.full((B,), 4, jnp.int32)

    def decode(p, t, c, q):
        return model.decode_step(p, t, c, q)

    def prefill(p, toks, c):
        return model.prefill(p, {"tokens": toks}, c, remat="none")

    toks = jnp.zeros((B, 8), jnp.int32)
    return [
        AuditProgram(
            name=f"serve_decode[{arch}]", fn=decode,
            args=(art.params, tok, cache, pos),
            # launch/serve.py run_prefill_decode jits decode with
            # donate_argnums=(2,): the KV cache is consumed each step
            donate_argnums=(2,),
            forbidden_f32=forbidden_f32_shapes(art.params),
            repeat_args=(art.params, tok + 1, jax.tree.map(jnp.copy, cache),
                         pos + 1)),
        AuditProgram(
            name=f"serve_prefill[{arch}]", fn=prefill,
            args=(art.params, toks, jax.tree.map(jnp.copy, cache))),
    ]


# ---------------------------------------------------------------------------
# serving: the continuous-batching engine's two compiled programs
# ---------------------------------------------------------------------------


def engine_programs(arch: str = "brecq_lm_100m") -> list[AuditProgram]:
    """The ServeEngine's (num_slots, 1) decode and (1, prefill_chunk)
    chunked-prefill programs, exactly as ``ServeEngine.compile()`` builds
    them (un-jitted fns recovered from the engine's own jit wrappers)."""
    from ...deploy import rtn_artifact
    from ...models import get_model
    from ...serve_engine import EngineConfig, ServeEngine

    cfg, model = get_model(arch, reduced=True)
    params = model.init(jax.random.PRNGKey(0))
    art = rtn_artifact(params, 4, cfg=cfg)
    ecfg = EngineConfig(num_slots=2, page_size=8, num_pages=9, max_len=32,
                        prefill_chunk=8)
    eng = ServeEngine(model, art.params, ecfg, quant=art.hook())
    bt = jnp.asarray(eng.block_tables)
    tok = jnp.zeros((ecfg.num_slots, 1), jnp.int32)
    pos = jnp.zeros((ecfg.num_slots,), jnp.int32)
    tokc = jnp.zeros((1, ecfg.prefill_chunk), jnp.int32)
    forbidden = forbidden_f32_shapes(art.params)
    return [
        AuditProgram(
            name=f"engine_decode[{arch}]", fn=eng._decode_jit.__wrapped__,
            args=(eng.params, tok, eng.cache, pos, bt),
            forbidden_f32=forbidden,
            repeat_args=(eng.params, tok + 1, jax.tree.map(jnp.copy, eng.cache),
                         pos + 1, bt)),
        AuditProgram(
            name=f"engine_prefill_chunk[{arch}]",
            fn=eng._chunk_jit.__wrapped__,
            args=(eng.params, tokc, jax.tree.map(jnp.copy, eng.cache),
                  pos[:1], bt[:1])),
    ]


# ---------------------------------------------------------------------------
# budget-packed mixed-precision artifact
# ---------------------------------------------------------------------------


def budget_programs(arch: str = "brecq_lm_100m") -> list[AuditProgram]:
    """Decode over a budget-style mixed-precision artifact (alternating
    2/4-bit per-layer assignment, container promotion within stacks) —
    the deployment class ``deploy.budget`` produces."""
    from ...deploy import rtn_mixed_artifact
    from ...deploy.budget import weight_shapes
    from ...models import get_model

    cfg, model = get_model(arch, reduced=True)
    params = model.init(jax.random.PRNGKey(0))
    assign = {p: (4 if i % 2 else 2)
              for i, p in enumerate(sorted(weight_shapes(params)))}
    art = rtn_mixed_artifact(params, assign, cfg=cfg)
    B, T = 2, 16
    cache = model.init_cache(B, T, jnp.float32)
    tok = jnp.zeros((B, 1), jnp.int32)
    pos = jnp.full((B,), 4, jnp.int32)

    def decode(p, t, c, q):
        return model.decode_step(p, t, c, q)

    return [AuditProgram(
        name=f"budget_decode[{arch}]", fn=decode,
        args=(art.params, tok, cache, pos),
        donate_argnums=(2,),
        forbidden_f32=forbidden_f32_shapes(art.params),
        repeat_args=(art.params, tok + 1, jax.tree.map(jnp.copy, cache),
                     pos + 1))]


# ---------------------------------------------------------------------------
# calibration: the scan step, captured from a live micro-quantize
# ---------------------------------------------------------------------------


def calib_audit(n_layers: int = 2, iters: int = 2
                ) -> tuple[list[AuditProgram], list[Violation]]:
    """Run a micro ``quantize()`` with ``calib_loop.AUDIT_CAPTURE``
    installed and return

    * AuditPrograms for the captured scan programs (re-declared with the
      donation argnums ``calib_loop`` specifies — ``_donate()`` strips
      them on CPU, so the auditor re-lowers with the declared set), and
    * compiled-unit-cache violations: with ``n_layers`` identical
      transformer blocks the unit program must be traced once and reused
      (``unit_hits >= n_layers - 1``); zero hits means the cache key
      broke and every block of a real run would recompile.
    """
    import dataclasses as _dc

    from ...core import ReconConfig, calib_loop, quantize
    from ...data import Corpus, CorpusConfig, make_batches
    from ...models import build_model, get_config

    cfg = _dc.replace(get_config("brecq_lm_100m", reduced=True),
                      n_layers=n_layers)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    corpus = Corpus(CorpusConfig(vocab=cfg.vocab))
    calib = make_batches(corpus, 2, 4, 32, seed=1)

    calib_loop.clear_cache()
    captured: list = []
    calib_loop.AUDIT_CAPTURE = captured
    try:
        quantize(model, params, calib,
                 ReconConfig(w_bits=4, iters=iters, calib_bs=4, seed=0))
    finally:
        calib_loop.AUDIT_CAPTURE = None
    stats = calib_loop.cache_stats()

    donate = {"unit_scan": calib_loop.UNIT_DONATE,
              "layer_scan": calib_loop.LAYER_DONATE}
    programs, seen = [], set()
    for tag, jitted, args in captured:
        if tag in seen:
            continue
        seen.add(tag)
        programs.append(AuditProgram(
            name=f"calib_{tag}", fn=jitted.__wrapped__, args=args,
            donate_argnums=donate[tag]))

    violations = []
    if not captured:
        violations.append(Violation(
            "stable_compile_cache", "calib_unit_scan",
            "micro-quantize captured no scan programs (AUDIT_CAPTURE hook "
            "broken or unit loop bypassed)"))
    elif stats["unit_hits"] < n_layers - 1:
        violations.append(Violation(
            "stable_compile_cache", "calib_unit_scan",
            f"{n_layers} identical blocks produced only "
            f"{stats['unit_hits']} compiled-unit cache hit(s) "
            f"(misses={stats['unit_misses']}): the unit program cache key "
            f"no longer keys on structure and real runs would retrace "
            f"per block"))
    return programs, violations


# ---------------------------------------------------------------------------
# the default program set
# ---------------------------------------------------------------------------


def build_programs(configs: str = "quick", *, with_calib: bool = True
                   ) -> tuple[list[AuditProgram], list[Violation]]:
    """All audited programs for a config scope plus any violations the
    builders detect directly (calibration cache-hit accounting)."""
    archs = QUICK_ARCHS if configs == "quick" else QUICK_ARCHS + EXTRA_ARCHS
    programs: list[AuditProgram] = []
    violations: list[Violation] = []
    programs += qmm_programs()
    for arch in archs:
        programs += serve_programs(arch)
    programs += engine_programs()
    programs += budget_programs()
    if with_calib:
        calib_progs, calib_viol = calib_audit()
        programs += calib_progs
        violations += calib_viol
    return programs, violations
