"""Declarative rule engine for compiled-program audits.

The engine separates *what is audited* from *what is checked*:

* an :class:`AuditProgram` describes one real program — a plain callable
  plus example arguments, with declared expectations (donated argnums,
  forbidden f32 shapes, same-structure repeat arguments);
* a :class:`Rule` is a named check ``(AuditProgram) -> [Violation]``,
  registered with the :func:`rule` decorator so the catalog stays
  introspectable (``scripts/run_audit.py --list-rules``, the docs
  table);
* :func:`run_program_rules` applies every applicable rule to every
  program and returns the flat violation list.

The jaxpr walker that two serve-fastpath tests used to hand-roll lives
here (:func:`iter_jaxprs`) — one implementation, shared by rules and
tests. HLO-text rules reuse ``repro.analysis.hlo``'s parser.
"""
from __future__ import annotations

import dataclasses
import re
import warnings
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from ..hlo import _OP_RE, split_computations

__all__ = ["Violation", "AuditProgram", "Rule", "rule", "registered_rules",
           "iter_jaxprs", "run_program_rules"]


@dataclasses.dataclass(frozen=True)
class Violation:
    """One audited invariant broken at one place."""

    rule: str
    subject: str  # program name / kernel launch / file:line
    message: str

    def __str__(self) -> str:
        return f"[{self.rule}] {self.subject}: {self.message}"


@dataclasses.dataclass
class AuditProgram:
    """One real program under audit.

    ``fn`` is the *un-jitted* callable; the engine jits/lowers it as
    each rule requires. ``args`` are example arguments (arrays or
    ``ShapeDtypeStruct``). Expectations:

    * ``donate_argnums`` — argnums the repo declares donated for this
      program (``donation_respected`` re-lowers with them and checks);
    * ``forbidden_f32`` — shapes (tuples) that must never appear as an
      f32 equation output anywhere in the jaxpr
      (``no_materialized_f32_weight``); typically the full dequantized
      shapes of stacked packed weight nodes;
    * ``repeat_args`` — a second, freshly-built argument set with the
      identical structure; ``stable_compile_cache`` calls the jitted
      program with both and fails on a retrace.

    ``suppress`` maps rule name -> reason; suppressed rules are skipped
    for this program but the reason is surfaced in ``--verbose`` runs so
    suppressions stay visible.
    """

    name: str
    fn: Callable
    args: tuple
    donate_argnums: tuple = ()
    forbidden_f32: frozenset = frozenset()
    repeat_args: Optional[tuple] = None
    suppress: dict = dataclasses.field(default_factory=dict)
    jaxpr: Any = None  # memoized by the engine

    def get_jaxpr(self):
        if self.jaxpr is None:
            self.jaxpr = jax.make_jaxpr(self.fn)(*self.args)
        return self.jaxpr


@dataclasses.dataclass(frozen=True)
class Rule:
    name: str
    family: str  # 'program' | 'kernel' | 'ast'
    doc: str
    check: Optional[Callable] = None  # program rules: (AuditProgram) -> [Violation]


_RULES: dict[str, Rule] = {}


def rule(name: str, family: str = "program"):
    """Register a rule. Program-family rules are callables applied by
    :func:`run_program_rules`; kernel/ast rules register here for the
    catalog only (their modules drive the checks)."""

    def deco(fn: Callable) -> Callable:
        _RULES[name] = Rule(name, family, (fn.__doc__ or "").strip(), fn)
        return fn

    return deco


def register_catalog_rule(name: str, family: str, doc: str) -> None:
    """Catalog entry for a rule implemented outside the program engine."""
    _RULES[name] = Rule(name, family, doc, None)


def registered_rules(family: Optional[str] = None) -> list[Rule]:
    rules = list(_RULES.values())
    if family is not None:
        rules = [r for r in rules if r.family == family]
    return sorted(rules, key=lambda r: (r.family, r.name))


# ---------------------------------------------------------------------------
# the one jaxpr walker
# ---------------------------------------------------------------------------


def iter_jaxprs(jaxpr):
    """Yield ``jaxpr`` and every sub-jaxpr reachable through equation
    params (scan/while/cond bodies, pjit calls, custom derivatives).

    This is the single jaxpr-walking implementation in the repo — the
    serve-fastpath residency tests and the ``no_materialized_f32_weight``
    rule both build on it.
    """
    yield jaxpr
    for eqn in jaxpr.eqns:
        for v in eqn.params.values():
            for u in v if isinstance(v, (list, tuple)) else (v,):
                if hasattr(u, "jaxpr"):  # ClosedJaxpr
                    yield from iter_jaxprs(u.jaxpr)
                elif hasattr(u, "eqns"):
                    yield from iter_jaxprs(u)


def f32_outvars_matching(jaxpr, shapes) -> list[tuple[str, tuple]]:
    """(primitive name, shape) for every f32 equation output whose shape
    is in ``shapes``, anywhere in the (nested) jaxpr."""
    shapes = set(shapes)
    return [
        (eqn.primitive.name, v.aval.shape)
        for jx in iter_jaxprs(jaxpr) for eqn in jx.eqns
        for v in eqn.outvars
        if getattr(v.aval, "shape", None) in shapes
        and getattr(v.aval, "dtype", None) == jnp.float32]


# ---------------------------------------------------------------------------
# lowering helpers
# ---------------------------------------------------------------------------


def _abstract(args):
    """Concrete arrays -> ShapeDtypeStructs, so lowering can never be
    broken by donated/deleted buffers captured earlier."""
    return jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype)
        if hasattr(a, "shape") and hasattr(a, "dtype") else a, args)


def lower_program(prog: AuditProgram, donate: tuple = ()):
    """Lower ``prog.fn`` (suppressing the CPU donation warnings the
    audit deliberately triggers)."""
    jf = jax.jit(prog.fn, donate_argnums=donate)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        return jf.lower(*_abstract(prog.args))


def compiled_hlo(prog: AuditProgram, donate: tuple = ()) -> str:
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        return lower_program(prog, donate).compile().as_text()


def count_io_aliases(hlo: str) -> int:
    """Number of parameter buffers aliased to outputs in the module
    header's ``input_output_alias`` map (brace-balanced scan: entries
    nest braces, e.g. ``{ {0}: (2, {}, may-alias) }``)."""
    start = hlo.find("input_output_alias={")
    if start < 0:
        return 0
    i = start + len("input_output_alias=")
    depth = 0
    for j in range(i, len(hlo)):
        if hlo[j] == "{":
            depth += 1
        elif hlo[j] == "}":
            depth -= 1
            if depth == 0:
                return len(re.findall(r"\(\s*\d+\s*,", hlo[i:j]))
    return 0


# ---------------------------------------------------------------------------
# program rules
# ---------------------------------------------------------------------------


@rule("no_materialized_f32_weight")
def check_no_materialized_f32_weight(prog: AuditProgram) -> list[Violation]:
    """No f32 equation output anywhere in the program's jaxpr may have a
    forbidden full-dequant shape — e.g. a stacked MoE expert node's
    (E, K, N): serving must consume packed codes tile-/expert-wise, the
    transient full dequant the grouped qmm tier removed must not come
    back."""
    if not prog.forbidden_f32:
        return []
    offenders = f32_outvars_matching(prog.get_jaxpr().jaxpr,
                                     prog.forbidden_f32)
    return [Violation(
        "no_materialized_f32_weight", prog.name,
        f"f32 {shape} materialized by primitive {prim!r} (full dequantized "
        f"weight resident in the trace)") for prim, shape in offenders]


@rule("donation_respected")
def check_donation_respected(prog: AuditProgram) -> list[Violation]:
    """Programs that declare donated argnums must still lower with every
    leaf of those arguments marked donated, and the compiled module must
    alias at least as many input buffers to outputs as the donation
    promises (a dropped donation doubles peak residency of the
    calibration optimizer state / the serving KV cache)."""
    if not prog.donate_argnums:
        return []
    out = []
    lo = lower_program(prog, donate=prog.donate_argnums)
    info = lo.args_info[0] if isinstance(lo.args_info, tuple) else lo.args_info
    donated_leaves = 0
    for argnum in prog.donate_argnums:
        leaves = jax.tree.leaves(info[argnum],
                                 is_leaf=lambda x: hasattr(x, "donated"))
        bad = [l for l in leaves if not getattr(l, "donated", False)]
        donated_leaves += len(leaves) - len(bad)
        if bad:
            out.append(Violation(
                "donation_respected", prog.name,
                f"argnum {argnum} declares donation but {len(bad)}/"
                f"{len(leaves)} of its buffers lower undonated"))
    if donated_leaves:
        with warnings.catch_warnings():
            warnings.simplefilter("ignore")
            hlo = lo.compile().as_text()
        aliased = count_io_aliases(hlo)
        if aliased < donated_leaves:
            out.append(Violation(
                "donation_respected", prog.name,
                f"{donated_leaves} buffers donated at lowering but the "
                f"compiled module aliases only {aliased} input(s) to "
                f"outputs (donation dropped by the compiler — shape or "
                f"dtype mismatch between the donated buffer and every "
                f"output?)"))
    return out


# Hot programs must not round-trip through the host: infeed/outfeed and
# host send/recv serialize the device stream, and host-offload
# custom-calls hide a PCIe copy inside a "compiled" program.
_HOST_OPS = {"infeed", "outfeed", "send", "recv", "send-done", "recv-done"}
_HOST_CALL_RE = re.compile(
    r'custom_call_target="(MoveToHost|MoveToDevice|'
    r'annotate_device_placement|xla_ffi_python_cpu_callback|'
    r'xla_python_cpu_callback|xla_python_gpu_callback|CallbackCustomCall)"')


@rule("no_host_transfer")
def check_no_host_transfer(prog: AuditProgram) -> list[Violation]:
    """The optimized HLO of a hot program must contain no host
    transfers: no infeed/outfeed, no send/recv, no host-offload or
    python-callback custom-calls. Parsed with ``analysis/hlo.py``'s
    computation splitter so nested computations are covered."""
    hlo = compiled_hlo(prog)
    out = []
    comps, _ = split_computations(hlo)
    for cname, lines in comps.items():
        for ln in lines:
            m = _OP_RE.match(ln)
            op = m.group(3) if m else None
            if op in _HOST_OPS:
                out.append(Violation(
                    "no_host_transfer", prog.name,
                    f"host-transfer op {op!r} in computation {cname!r}"))
            hm = _HOST_CALL_RE.search(ln)
            if hm:
                out.append(Violation(
                    "no_host_transfer", prog.name,
                    f"host callback/offload custom-call "
                    f"{hm.group(1)!r} in computation {cname!r}"))
    return out


@rule("stable_compile_cache")
def check_stable_compile_cache(prog: AuditProgram) -> list[Violation]:
    """Two calls with identical argument structure must hit one compiled
    executable: a retrace on the second call means the program keys on
    object identity or mutable global state, and every serve/calib step
    would recompile in production."""
    if prog.repeat_args is None:
        return []
    jf = jax.jit(prog.fn)
    jf(*prog.args)
    n1 = jf._cache_size()
    jf(*prog.repeat_args)
    n2 = jf._cache_size()
    if n2 > n1:
        return [Violation(
            "stable_compile_cache", prog.name,
            f"second same-structure call retraced (compile cache grew "
            f"{n1} -> {n2})")]
    return []


PROGRAM_RULES = ("no_materialized_f32_weight", "donation_respected",
                 "no_host_transfer", "stable_compile_cache")


def run_program_rules(programs, rules: Optional[tuple] = None,
                      verbose: Callable[[str], None] = lambda s: None
                      ) -> list[Violation]:
    """Apply every (non-suppressed) program rule to every program."""
    names = rules if rules is not None else PROGRAM_RULES
    out: list[Violation] = []
    for prog in programs:
        for name in names:
            if name in prog.suppress:
                verbose(f"  suppressed {name} on {prog.name}: "
                        f"{prog.suppress[name]}")
                continue
            found = _RULES[name].check(prog)
            verbose(f"  {prog.name}: {name} -> "
                    + (f"{len(found)} violation(s)" if found else "ok"))
            out.extend(found)
    return out
