"""Program auditor: jaxpr/HLO invariant lints + Pallas kernel static
checks + AST-level repo lints.

Three rule families, one entry point (``scripts/run_audit.py``, CI job
``audit``):

* ``program`` — walks jaxprs and optimized-HLO text of the repo's
  *real* programs (qmm tiers, the calibration scan step, serve-engine
  decode/prefill-chunk, mixed-precision artifacts) and enforces the
  compiled-program invariants past PRs pinned one-off: no materialized
  f32 stacked-weight dequant, declared buffer donations still lower as
  donations, no host transfers in hot programs, no retraces across
  same-structure calls.
* ``kernel`` — static tile-math checks of every Pallas kernel via
  ``repro.kernels.spec``: grid/BlockSpec divisibility against the
  registered configs' shapes and estimated VMEM vs the declared budget.
* ``ast`` — stdlib-``ast`` lints over ``src/``: host syncs inside
  jitted bodies, mutable default args, bare asserts under ``kernels/``,
  ``interpret=True`` defaults.

See ``docs/static_analysis.md`` for the rule catalog and suppression
syntax.
"""
from .rules import (AuditProgram, Rule, Violation, iter_jaxprs,  # noqa: F401
                    registered_rules, rule, run_program_rules)
