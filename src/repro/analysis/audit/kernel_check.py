"""Static Pallas kernel audit: tile math + VMEM over registered configs.

No device and no weights: each arch's params tree comes from
``jax.eval_shape(model.init, ...)`` and the packed serving shapes from
``jax.eval_shape`` over ``deploy.pack.quantize_tree`` (shape-driven by
design), then every packed leaf is swept through the same
``kernels.spec.describe_*`` functions the kernel wrappers call — with
the same tile selection and ragged-N padding the ops layer applies
(``qmatmul/ops.py`` ``_qmm_2d``/``_qmm_grouped``, ``kvattn/ops.py``
``attend_int8``). A launch the runtime would attempt that fails its
tiling contract, or whose estimated VMEM exceeds
:data:`~repro.kernels.spec.VMEM_BUDGET_BYTES`, becomes a
:class:`~.rules.Violation`.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from ...kernels.spec import KernelSpecError
from .rules import Violation, register_catalog_rule

register_catalog_rule(
    "kernel_tile_divisibility", "kernel",
    "Every kernel launch the serving/calibration path would issue over a "
    "registered config's shapes must satisfy its grid/BlockSpec tiling "
    "contract (describe_* raises KernelSpecError naming the shapes).")
register_catalog_rule(
    "kernel_vmem_budget", "kernel",
    "The estimated VMEM footprint of one program instance (double-"
    "buffered input blocks + output/scratch) must stay under the "
    "declared per-core budget for every audited launch.")

# Decode batch rows and canonical KV cache length for the sweep; bs/bm/bn
# selection below mirrors the ops wrappers exactly.
DECODE_M = 4
PREFILL_M = 128
KV_SEQ = 512


def _bn(n: int) -> int:
    return 128 if n >= 128 else n


def _pad(n: int, b: int) -> int:
    return n + (-n) % b


def _iter_packed(fp, packed, path=()):
    """Yield (path, logical_K, wp_shape, qscale_shape) for every packed
    node, walking the FP shape tree (for K) and the packed tree in
    lockstep."""
    if isinstance(packed, dict):
        if "w" in packed and "qscale" in packed and hasattr(
                packed["w"], "shape"):
            fw = fp["w"] if isinstance(fp, dict) and "w" in fp else None
            if fw is not None and getattr(fw, "ndim", 0) >= 2:
                yield ("/".join(path), fw.shape[-2], packed["w"].shape,
                       packed["qscale"].shape)
            return
        for k in packed:
            yield from _iter_packed(
                fp.get(k) if isinstance(fp, dict) else None,
                packed[k], path + (str(k),))


def _sweep_leaf(arch: str, path: str, K: int, wp_shape, qs_shape,
                emit: Callable[[str, str, str], None]) -> None:
    """Audit every launch the qmm dispatch would issue for one packed
    leaf (decode + prefill tiers; grouped for stacked experts)."""
    from ...kernels.spec import (describe_qgemv, describe_qmatmul,
                                 describe_qmatmul_grouped)

    # strip the scan-stack dim: the runtime slices one layer per step
    if len(wp_shape) >= 3 and len(qs_shape) == len(wp_shape):
        wp_shape, qs_shape = wp_shape[1:], qs_shape[1:]
    rows, N = wp_shape[-2], wp_shape[-1]
    if rows == 0 or K % rows or K // rows not in (1, 2, 4):
        emit("kernel_tile_divisibility", f"{arch}:{path}",
             f"packed rows {rows} are not a 1/2/4-per-byte view of "
             f"K={K} (codes {tuple(wp_shape)})")
        return
    bits = 8 // (K // rows)
    bn = _bn(N)
    npad = _pad(N, bn)
    wp2 = (rows, npad)
    qs2 = (qs_shape[-2], npad)
    subject = f"{arch}:{path}"
    launches = []
    if len(wp_shape) == 2:
        launches = [
            ("decode", lambda: describe_qgemv(
                (DECODE_M, K), wp2, qs2, bits=bits, bn=bn)),
            ("prefill", lambda: describe_qmatmul(
                (PREFILL_M, K), wp2, qs2, bits=bits, bm=128, bn=bn)),
        ]
    elif len(wp_shape) == 3:
        E = wp_shape[0]
        wp3, qs3 = (E,) + wp2, (E,) + qs2
        launches = [
            ("grouped-decode", lambda: describe_qmatmul_grouped(
                (E, DECODE_M, K), wp3, qs3, bits=bits, bm=DECODE_M, bn=bn)),
            ("grouped-prefill", lambda: describe_qmatmul_grouped(
                (E, PREFILL_M, K), wp3, qs3, bits=bits, bm=128, bn=bn)),
        ]
    for tier, describe in launches:
        try:
            sp = describe()
        except KernelSpecError as e:
            emit("kernel_tile_divisibility", f"{subject}[{tier}]", str(e))
            continue
        try:
            sp.check_budget()
        except KernelSpecError as e:
            emit("kernel_vmem_budget", f"{subject}[{tier}]", str(e))


def _sweep_kv(arch: str, cfg, emit: Callable[[str, str, str], None]) -> None:
    from ...kernels.spec import describe_kv_decode

    S = KV_SEQ
    bs = 512 if S % 512 == 0 else (128 if S % 128 == 0 else S)
    q_shape = (DECODE_M, cfg.n_heads, cfg.hd)
    k8_shape = (DECODE_M, S, cfg.n_kv_heads, cfg.hd)
    try:
        sp = describe_kv_decode(q_shape, k8_shape, bs=bs)
    except KernelSpecError as e:
        emit("kernel_tile_divisibility", f"{arch}:kv_decode", str(e))
        return
    try:
        sp.check_budget()
    except KernelSpecError as e:
        emit("kernel_vmem_budget", f"{arch}:kv_decode", str(e))


def _sweep_fakequant(arch: str, path: str, K: int, N: int,
                     emit: Callable[[str, str, str], None]) -> None:
    from ...kernels.spec import describe_fakequant, largest_tile

    bk = largest_tile(K, 256)
    bn = largest_tile(N, 256)
    try:
        sp = describe_fakequant((K, N), (1, N), bk=bk, bn=bn)
    except KernelSpecError as e:
        emit("kernel_tile_divisibility", f"{arch}:{path}[fakequant]", str(e))
        return
    try:
        sp.check_budget()
    except KernelSpecError as e:
        emit("kernel_vmem_budget", f"{arch}:{path}[fakequant]", str(e))


def audit_arch(arch: str, *, bits: int = 4, reduced: bool = False
               ) -> list[Violation]:
    """Sweep one registered config's serving + calibration launches."""
    from ...deploy.pack import quantize_tree
    from ...models import get_model

    out: list[Violation] = []

    def emit(rule: str, subject: str, msg: str) -> None:
        out.append(Violation(rule, subject, msg))

    cfg, model = get_model(arch, reduced=reduced)
    fp = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    packed = jax.eval_shape(lambda p: quantize_tree(p, bits), fp)
    for path, K, wp_shape, qs_shape in _iter_packed(fp, packed):
        _sweep_leaf(arch, path, K, wp_shape, qs_shape, emit)
        # the AdaRound fused forward runs on the 2-D per-layer FP view
        N = wp_shape[-1]
        _sweep_fakequant(arch, path, K, N, emit)
    _sweep_kv(arch, cfg, emit)
    return out


def run_kernel_checks(archs, *, bits: int = 4, reduced: bool = False,
                      verbose: Callable[[str], None] = lambda s: None
                      ) -> list[Violation]:
    out: list[Violation] = []
    for arch in archs:
        found = audit_arch(arch, bits=bits, reduced=reduced)
        verbose(f"  {arch}: " + (f"{len(found)} violation(s)"
                                 if found else "ok"))
        out.extend(found)
    return out
