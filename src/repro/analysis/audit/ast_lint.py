"""AST-level repo lints (stdlib ``ast`` only — no imports of the code
under audit).

Rules (see ``docs/static_analysis.md`` for the catalog):

* ``no_host_sync_in_jit`` — no ``time.*`` calls, ``.item()`` /
  ``.block_until_ready()`` calls or ``np.asarray`` / ``jax.device_get``
  inside the body of a function that is jitted (``@jax.jit`` /
  ``@partial(jax.jit, ...)`` decorators, or ``jax.jit(name)`` applied
  anywhere in the same file). These force a device sync per call and
  have repeatedly snuck timing code into traced bodies.
* ``no_mutable_default_arg`` — no ``[]`` / ``{}`` / ``set()`` default
  argument values anywhere under ``src/``.
* ``no_bare_assert_in_kernels`` — ``kernels/`` raises typed
  ``KernelSpecError`` / ``PackedNodeError``; a bare ``assert`` there
  strips under ``python -O`` and reports no shapes.
* ``no_interpret_default_true`` — ``interpret=True`` as a *parameter
  default* outside ``tests``/CI guards silently pins the slow Pallas
  interpreter; call sites must opt in per-backend.

Suppression: a line comment ``# audit: ignore[rule_name]`` on the
offending line (or the ``def`` line for defaults) skips that finding;
``--verbose`` runs surface every suppression so they stay visible.
"""
from __future__ import annotations

import ast
import re
from pathlib import Path
from typing import Callable, Iterable, Optional

from .rules import Violation, register_catalog_rule

register_catalog_rule(
    "no_host_sync_in_jit", "ast",
    "No time.* / .item() / .block_until_ready() / np.asarray / "
    "jax.device_get calls inside jitted function bodies.")
register_catalog_rule(
    "no_mutable_default_arg", "ast",
    "No mutable default argument values ([] / {} / set()) under src/.")
register_catalog_rule(
    "no_bare_assert_in_kernels", "ast",
    "kernels/ must raise typed KernelSpecError/PackedNodeError instead "
    "of bare asserts (assert strips under -O and names no shapes).")
register_catalog_rule(
    "no_interpret_default_true", "ast",
    "No interpret=True parameter defaults outside tests/CI guards.")

_IGNORE_RE = re.compile(r"#\s*audit:\s*ignore\[([\w,\s]+)\]")

# calls that force a host round-trip when traced into a jitted body
_HOST_ATTR_CALLS = {"item", "block_until_ready"}
_HOST_MODULE_CALLS = {("time", None), ("np", "asarray"), ("numpy", "asarray"),
                      ("jax", "device_get")}


def _ignores(source: str) -> dict[int, set]:
    """line number -> rule names suppressed on that line."""
    out: dict[int, set] = {}
    for i, line in enumerate(source.splitlines(), 1):
        m = _IGNORE_RE.search(line)
        if m:
            out[i] = {r.strip() for r in m.group(1).split(",")}
    return out


def _call_root(node: ast.AST) -> tuple[Optional[str], Optional[str]]:
    """('time', 'perf_counter') for time.perf_counter(...), ('np',
    'asarray'), (None, 'item') for x.item(), etc."""
    if isinstance(node, ast.Attribute):
        if isinstance(node.value, ast.Name):
            return node.value.id, node.attr
        return None, node.attr
    if isinstance(node, ast.Name):
        return node.id, None
    return None, None


def _is_jit_decorator(dec: ast.AST) -> bool:
    """@jax.jit, @jit, @partial(jax.jit, ...), @functools.partial(jit, ...)."""
    if isinstance(dec, ast.Call):
        root, attr = _call_root(dec.func)
        if (root, attr) in (("jax", "jit"), ("jit", None)):
            return True
        if attr == "partial" or root == "partial":
            return any(_is_jit_decorator(a) for a in dec.args)
        return False
    root, attr = _call_root(dec)
    return (root, attr) in (("jax", "jit"), ("jit", None))


def _jitted_names(tree: ast.Module) -> set:
    """Names of functions the file jits anywhere: ``jax.jit(f)`` /
    ``jit(f, ...)`` call arguments plus @jit-decorated defs."""
    names: set = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Call):
            root, attr = _call_root(node.func)
            if (root, attr) in (("jax", "jit"), ("jit", None)):
                for a in node.args[:1]:
                    if isinstance(a, ast.Name):
                        names.add(a.id)
                    elif isinstance(a, ast.Attribute):
                        names.add(a.attr)
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            if any(_is_jit_decorator(d) for d in node.decorator_list):
                names.add(node.name)
    return names


def _check_host_sync(tree, path: str, ignores, emit) -> None:
    jitted = _jitted_names(tree)
    if not jitted:
        return

    for fn in ast.walk(tree):
        if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        if fn.name not in jitted:
            continue
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call):
                continue
            root, attr = _call_root(node.func)
            bad = None
            if root == "time":
                bad = f"time.{attr}()"
            elif (root, attr) in _HOST_MODULE_CALLS:
                bad = f"{root}.{attr}()"
            elif attr in _HOST_ATTR_CALLS and not node.args:
                bad = f".{attr}()"
            if bad is None:
                continue
            if "no_host_sync_in_jit" in ignores.get(node.lineno, ()):
                continue
            emit(Violation(
                "no_host_sync_in_jit", f"{path}:{node.lineno}",
                f"{bad} inside jitted function {fn.name!r} forces a host "
                f"sync every call (hoist it out of the traced body)"))


def _check_mutable_defaults(tree, path: str, ignores, emit) -> None:
    for fn in ast.walk(tree):
        if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        for default in list(fn.args.defaults) + [
                d for d in fn.args.kw_defaults if d is not None]:
            mutable = isinstance(default, (ast.List, ast.Dict, ast.Set)) or (
                isinstance(default, ast.Call)
                and isinstance(default.func, ast.Name)
                and default.func.id in ("list", "dict", "set"))
            if not mutable:
                continue
            if ("no_mutable_default_arg" in ignores.get(default.lineno, ())
                    or "no_mutable_default_arg" in ignores.get(fn.lineno, ())):
                continue
            emit(Violation(
                "no_mutable_default_arg", f"{path}:{default.lineno}",
                f"mutable default argument in {fn.name!r} (shared across "
                f"calls — default to None and build inside)"))


def _check_bare_asserts(tree, path: str, ignores, emit) -> None:
    for node in ast.walk(tree):
        if not isinstance(node, ast.Assert):
            continue
        if "no_bare_assert_in_kernels" in ignores.get(node.lineno, ()):
            continue
        emit(Violation(
            "no_bare_assert_in_kernels", f"{path}:{node.lineno}",
            "bare assert in kernels/ (strips under -O, names no shapes) — "
            "raise KernelSpecError via kernels.spec instead"))


def _check_interpret_defaults(tree, path: str, ignores, emit) -> None:
    for fn in ast.walk(tree):
        if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        args = fn.args
        named = args.posonlyargs + args.args + args.kwonlyargs
        defaults = ([None] * (len(args.posonlyargs) + len(args.args)
                              - len(args.defaults))
                    + list(args.defaults) + list(args.kw_defaults))
        for arg, default in zip(named, defaults):
            if (arg.arg == "interpret" and isinstance(default, ast.Constant)
                    and default.value is True):
                if "no_interpret_default_true" in ignores.get(fn.lineno, ()):
                    continue
                emit(Violation(
                    "no_interpret_default_true", f"{path}:{fn.lineno}",
                    f"{fn.name!r} defaults interpret=True — the Pallas "
                    f"interpreter must be an explicit per-backend opt-in"))


def lint_file(path: Path, root: Path,
              emit: Callable[[Violation], None],
              verbose: Callable[[str], None] = lambda s: None) -> None:
    rel = str(path.relative_to(root))
    source = path.read_text()
    try:
        tree = ast.parse(source, filename=rel)
    except SyntaxError as e:
        emit(Violation("ast_parse", rel, f"does not parse: {e}"))
        return
    ignores = _ignores(source)
    for line, rules in sorted(ignores.items()):
        verbose(f"  suppressed {sorted(rules)} at {rel}:{line}")
    _check_host_sync(tree, rel, ignores, emit)
    _check_mutable_defaults(tree, rel, ignores, emit)
    if "/kernels/" in str(path).replace("\\", "/"):
        _check_bare_asserts(tree, rel, ignores, emit)
    _check_interpret_defaults(tree, rel, ignores, emit)


def run_ast_lint(src_root, files: Optional[Iterable] = None,
                 verbose: Callable[[str], None] = lambda s: None
                 ) -> list[Violation]:
    """Lint every ``.py`` under ``src_root`` (or an explicit file list)."""
    root = Path(src_root)
    out: list[Violation] = []
    targets = ([Path(f) for f in files] if files is not None
               else sorted(root.rglob("*.py")))
    for path in targets:
        lint_file(path, root if root in path.parents or path == root
                  else path.parent, out.append, verbose)
    return out
