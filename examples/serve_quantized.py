"""Serve a quantized model with batched requests.

    PYTHONPATH=src python examples/serve_quantized.py --quant 4

Thin wrapper over launch/serve.py: packs the weights into a saved
`QuantizedArtifact` (int4/int8 codes + scales), re-loads it, prefills a
batch of prompts and decodes with the jitted step from packed codes —
the host-scale version of the decode_32k dry-run cells. Pass
``--artifact DIR`` instead of ``--quant`` to serve a calibrated BRECQ
export (see docs/deployment.md).
"""
import sys

from repro.launch import serve

if __name__ == "__main__":
    argv = sys.argv[1:] or ["--arch", "brecq_lm_100m", "--reduced",
                            "--quant", "4", "--batch", "8",
                            "--prompt-len", "64", "--gen-len", "32"]
    serve.main(argv)
