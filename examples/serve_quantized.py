"""Serve a quantized model with batched requests.

    PYTHONPATH=src python examples/serve_quantized.py --quant 4

Thin wrapper over launch/serve.py: builds (or loads) a model, packs the
weights to int4/int8, prefills a batch of prompts and decodes with the
jitted step — the host-scale version of the decode_32k dry-run cells.
"""
import sys

from repro.launch import serve

if __name__ == "__main__":
    argv = sys.argv[1:] or ["--arch", "brecq_lm_100m", "--reduced",
                            "--quant", "4", "--batch", "8",
                            "--prompt-len", "64", "--gen-len", "32"]
    serve.main(argv)
