"""Quickstart: quantize a small LM with BRECQ in ~2 minutes on CPU.

    PYTHONPATH=src python examples/quickstart.py

Trains a tiny LM on the synthetic corpus, then compares FP / RTN-W2 /
BRECQ-W2 perplexity — the paper's headline effect in miniature — and
finally exports the calibrated result to a packed-int
:class:`QuantizedArtifact`, saves/loads it, and evaluates the packed
model (what serving actually ships).

Set QUICKSTART_SMOKE=1 for a reduced run (fewer train steps, fewer
calibration iterations) — the docs CI job uses this to keep the README's
advertised flow from rotting without spending minutes of CI time.
"""
import os
import tempfile
import time

import jax

SMOKE = os.environ.get("QUICKSTART_SMOKE", "") not in ("", "0")
TRAIN_STEPS = 40 if SMOKE else 250
BRECQ_ITERS = 25 if SMOKE else 200
N_CALIB_BATCHES = 4 if SMOKE else 8

from repro.core import ReconConfig, quantize
from repro.core.baselines import quantize_rtn
from repro.core.evaluate import evaluate
from repro.data import Corpus, CorpusConfig, make_batches
from repro.deploy import QuantizedArtifact, export, tree_bytes
from repro.models import get_model
from repro.optim import adam


def main():
    cfg, model = get_model("brecq_lm_100m", reduced=True)
    corpus = Corpus(CorpusConfig(vocab=cfg.vocab))
    params = model.init(jax.random.PRNGKey(0))

    print("== training a tiny LM on the synthetic corpus ==")
    acfg = adam.AdamConfig(lr=3e-3, grad_clip=1.0)
    state = adam.init(params)
    step = jax.jit(lambda p, s, b: (
        *adam.update(acfg, jax.grad(lambda q: model.loss(q, b, remat='none'))(p), s, p),
        model.loss(p, b, remat='none')))
    for i in range(TRAIN_STEPS):
        batch = make_batches(corpus, 1, 16, 64, seed=0, start_step=i)[0]
        params, state, loss = step(params, state, batch)
        if i % 50 == 0:
            print(f"  step {i}: loss {float(loss):.3f}")

    calib = make_batches(corpus, N_CALIB_BATCHES, 8, 64, seed=1, start_step=1000)
    evalb = make_batches(corpus, 4, 16, 64, seed=2, start_step=2000)

    print("\n== post-training quantization ==")
    fp = evaluate(model, params, evalb)
    print(f"  FP32     : ppl {fp['ppl']:.2f}  top1 {fp['top1']:.3f}")

    pq, _ = quantize_rtn(model, params, calib, w_bits=2)
    rtn = evaluate(model, pq, evalb)
    print(f"  RTN  W2  : ppl {rtn['ppl']:.2f}  top1 {rtn['top1']:.3f}")

    t0 = time.time()
    res = quantize(model, params, calib, ReconConfig(w_bits=2, iters=BRECQ_ITERS))
    brecq = evaluate(model, res.params_q, evalb)
    print(f"  BRECQ W2 : ppl {brecq['ppl']:.2f}  top1 {brecq['top1']:.3f} "
          f"(calibrated in {time.time()-t0:.0f}s on "
          f"{sum(b['tokens'].shape[0] for b in calib)} sequences)")

    print("\n== packed-int deployment artifact ==")
    art = export(model, res)
    with tempfile.TemporaryDirectory(prefix="brecq_quickstart_art_") as art_dir:
        art.save(art_dir)
        loaded = QuantizedArtifact.load(art_dir)
        dep = evaluate(model, loaded, evalb)
    fp_bytes = tree_bytes(params)
    print(f"  packed W2: ppl {dep['ppl']:.2f}  "
          f"{fp_bytes/1e6:.1f}MB fp32 -> {loaded.nbytes()/1e6:.1f}MB packed, "
          f"packed in {art.stats['pack_wall_s']:.2f}s "
          f"(bits histogram {art.stats['bits_histogram']})")
    assert loaded.nbytes() < fp_bytes


if __name__ == "__main__":
    main()
