"""Mixed-precision search demo (paper Sec. 3.4 end to end).

    PYTHONPATH=src python examples/mixed_precision_search.py

Calibrates unified 2/4/8-bit models, measures diagonal + intra-block
sensitivities, runs the genetic algorithm under a model-size budget and
reports the chosen per-layer bit-widths.
"""
import jax

from repro.core import ReconConfig, quantize
from repro.core.evaluate import evaluate
from repro.core.mixed_precision import (GAConfig, genetic_search, model_bytes)
from repro.core.sensitivity import measure
from repro.data import Corpus, CorpusConfig, make_batches
from repro.models import get_model
from repro.optim import adam


def main():
    cfg, model = get_model("brecq_lm_100m", reduced=True)
    corpus = Corpus(CorpusConfig(vocab=cfg.vocab))
    params = model.init(jax.random.PRNGKey(0))
    acfg = adam.AdamConfig(lr=3e-3, grad_clip=1.0)
    state = adam.init(params)
    step = jax.jit(lambda p, s, b: adam.update(
        acfg, jax.grad(lambda q: model.loss(q, b, remat='none'))(p), s, p))
    for i in range(200):
        params, state = step(params, state,
                             make_batches(corpus, 1, 16, 64, seed=0, start_step=i)[0])

    calib = make_batches(corpus, 6, 8, 64, seed=1, start_step=1000)
    evalb = make_batches(corpus, 2, 16, 64, seed=2, start_step=2000)

    print("== unified-precision calibrations (2/4/8-bit) ==")
    results = {}
    for b in (2, 4, 8):
        results[b] = quantize(model, params, calib, ReconConfig(w_bits=b, iters=80))
        ev = evaluate(model, results[b].params_q, evalb)
        print(f"  W{b}: loss {ev['loss']:.4f}")

    print("== sensitivity lookup table ==")
    sens = measure(model, params, calib[:3], results, n_samples=16)
    print(f"  {len(sens.diag)} diagonal, {len(sens.offdiag)} intra-block entries")

    full8 = model_bytes(sens.shapes, {p: 8 for p in sens.shapes})
    for frac in (0.35, 0.5, 0.75):
        assign, info = genetic_search(
            sens, lambda a: model_bytes(sens.shapes, a), full8 * frac,
            GAConfig(pop_size=50, iters=100))
        res = quantize(model, params, calib,
                       ReconConfig(w_bits=4, iters=80, per_layer_bits=assign))
        ev = evaluate(model, res.params_q, evalb)
        hist = {b: sum(1 for v in assign.values() if v == b) for b in (2, 4, 8)}
        print(f"  budget {frac:.0%}: loss {ev['loss']:.4f}  bits histogram {hist}")


if __name__ == "__main__":
    main()
