"""End-to-end driver: train a ~100M-param LM, then BRECQ-quantize it.

    PYTHONPATH=src python examples/train_then_quantize.py [--steps 300]

This is the paper's full production pipeline on the framework's own
substrate: pretraining (fault-tolerant trainer with checkpoints) ->
block-reconstruction PTQ -> packed-int deployment artifact.
NOTE: the full 100M model takes a while per step on this CPU container;
use --small for the reduced config.
"""
import argparse
import pickle
import time
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.core import ReconConfig, quantize
from repro.core.baselines import quantize_rtn
from repro.core.evaluate import evaluate
from repro.data import Corpus, CorpusConfig, make_batches
from repro import deploy
from repro.launch import train as train_mod
from repro.models import get_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--small", action="store_true")
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--w-bits", type=int, default=4)
    ap.add_argument("--iters", type=int, default=300)
    ap.add_argument("--out", default="artifacts/example_e2e")
    args = ap.parse_args()
    out = Path(args.out)
    out.mkdir(parents=True, exist_ok=True)

    # 1) pretrain with the fault-tolerant driver (auto-resumes if re-run)
    train_args = ["--arch", "brecq_lm_100m", "--steps", str(args.steps),
                  "--batch", str(args.batch), "--seq", str(args.seq),
                  "--ckpt-dir", str(out / "ckpt"), "--ckpt-every", "100"]
    if args.small:
        train_args.append("--reduced")
    params = train_mod.main(train_args)

    # 2) calibrate with BRECQ (block granularity, Fisher-weighted)
    cfg, model = get_model("brecq_lm_100m", reduced=args.small)
    corpus = Corpus(CorpusConfig(vocab=cfg.vocab))
    calib = make_batches(corpus, 8, 8, args.seq, seed=1, start_step=50_000)
    evalb = make_batches(corpus, 4, 8, args.seq, seed=2, start_step=60_000)

    fp = evaluate(model, params, evalb)
    rtn = evaluate(model, quantize_rtn(model, params, calib, args.w_bits)[0], evalb)
    t0 = time.time()
    res = quantize(model, params, calib,
                   ReconConfig(w_bits=args.w_bits, iters=args.iters))
    brecq = evaluate(model, res.params_q, evalb)
    print(f"\nFP ppl {fp['ppl']:.2f} | RTN-W{args.w_bits} ppl {rtn['ppl']:.2f} "
          f"| BRECQ-W{args.w_bits} ppl {brecq['ppl']:.2f} "
          f"({time.time()-t0:.0f}s calibration)")

    # 3) emit the packed deployment artifact (what kernels/qmatmul serves)
    packed = deploy.quantize_tree(res.params_q, args.w_bits)
    nbytes = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(packed))
    fpbytes = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(params))
    with open(out / f"deploy_w{args.w_bits}.pkl", "wb") as f:
        pickle.dump(jax.device_get(packed), f)
    print(f"deployment artifact: {fpbytes/1e6:.1f}MB fp32 -> "
          f"{nbytes/1e6:.1f}MB packed W{args.w_bits} "
          f"({out}/deploy_w{args.w_bits}.pkl)")


if __name__ == "__main__":
    main()
