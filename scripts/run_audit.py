#!/usr/bin/env python
"""Static auditor: compiled-program lints, Pallas kernel checks, AST
repo lints — one entry point for the CI ``audit`` job.

    python scripts/run_audit.py                    # everything, quick configs
    python scripts/run_audit.py --family ast       # one rule family
    python scripts/run_audit.py --configs all      # wider arch coverage
    python scripts/run_audit.py --list-rules       # the rule catalog

Families (see docs/static_analysis.md for the full catalog):

  program   jaxpr + optimized-HLO rules over the repo's real programs
            (qmm tiers, serve decode/prefill, the serve engine's two
            compiled programs, budget-packed decode, the calibration
            scan step): no_materialized_f32_weight, donation_respected,
            no_host_transfer, stable_compile_cache.
  kernel    trace-free tile-math + VMEM sweep of every Pallas kernel
            over ALL registered full-scale configs (kernels/spec.py).
  ast       stdlib-ast lints over src/ (host syncs in jitted bodies,
            mutable defaults, bare asserts in kernels/, interpret=True
            defaults).

Exit 0 = no violations; exit 1 with every violation listed.
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT / "src"))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--family", choices=("program", "kernel", "ast", "all"),
                    default="all")
    ap.add_argument("--configs", choices=("quick", "all"), default="quick",
                    help="program-family arch scope: quick = the two "
                    "canonical serving archs; all adds more decode archs "
                    "(kernel checks always sweep every registered config)")
    ap.add_argument("--no-calib", action="store_true",
                    help="skip the micro-quantize calibration capture "
                    "(the slowest program-family step)")
    ap.add_argument("--src", default=None, metavar="PATH",
                    help="tree to AST-lint instead of src/ (tests use "
                    "this to drive the non-zero exit path)")
    ap.add_argument("--list-rules", action="store_true")
    ap.add_argument("-v", "--verbose", action="store_true")
    args = ap.parse_args(argv)

    from repro.analysis import audit  # registers program rules
    from repro.analysis.audit import ast_lint, kernel_check  # noqa: F401  (register catalogs)
    from repro.analysis.audit.rules import registered_rules

    if args.list_rules:
        for r in registered_rules():
            print(f"{r.family:8s} {r.name}")
            if args.verbose and r.doc:
                print(f"         {r.doc}")
        return 0

    verbose = print if args.verbose else (lambda s: None)
    violations = []

    if args.family in ("ast", "all"):
        src = Path(args.src) if args.src else ROOT / "src"
        print(f"== ast: linting {src} ==")
        violations += ast_lint.run_ast_lint(src, verbose=verbose)

    if args.family in ("kernel", "all"):
        print("== kernel: tile math + VMEM over registered configs ==")
        from repro.models.registry import ARCH_IDS
        violations += kernel_check.run_kernel_checks(ARCH_IDS,
                                                     verbose=verbose)

    if args.family in ("program", "all"):
        print(f"== program: jaxpr/HLO rules over real programs "
              f"({args.configs} configs) ==")
        from repro.analysis.audit.program_check import build_programs
        from repro.analysis.audit.rules import run_program_rules
        programs, builder_viol = build_programs(
            args.configs, with_calib=not args.no_calib)
        violations += builder_viol
        violations += run_program_rules(programs, verbose=verbose)

    if violations:
        print(f"\nAUDIT FAILED: {len(violations)} violation(s)")
        for v in violations:
            print(f"  {v}")
        return 1
    print("\naudit clean")
    return 0


if __name__ == "__main__":
    sys.exit(main())
