"""Regenerate the data-driven sections of EXPERIMENTS.md from artifacts.

Usage: PYTHONPATH=src python scripts/gen_experiments.py
Writes artifacts/experiments_sections.md with §Dry-run and §Roofline
tables; the narrative in EXPERIMENTS.md references/incorporates them.
"""
import json
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
ART = ROOT / "artifacts" / "dryrun"


def fmt_bytes(b):
    return f"{b/1e9:.1f}"


def main():
    rows = []
    for f in sorted(ART.glob("*.json")):
        d = json.loads(f.read_text())
        if d.get("tag"):
            continue
        rows.append(d)

    out = []
    out.append("### §Dry-run (generated)\n")
    out.append("| arch | shape | mesh | strategy | chips | GB/chip (tpu-corr) | fits 16GB | compile s | collectives (counts) |")
    out.append("|---|---|---|---|---|---|---|---|---|")
    for d in sorted(rows, key=lambda x: (x["arch"], x["shape"], x["mesh"])):
        cc = d["hlo"]["collective_counts"]
        cc_s = " ".join(f"{k.split('-')[-1]}:{int(v)}" for k, v in sorted(cc.items()))
        out.append(
            f"| {d['arch']} | {d['shape']} | {d['mesh']} | {d['strategy']} "
            f"| {d['n_chips']} | {fmt_bytes(d.get('per_chip_bytes_tpu_corrected', d['per_chip_bytes']))} "
            f"| {'Y' if d.get('fits_16gb') else 'N'} | {d['compile_s']} | {cc_s} |")

    out.append("\n### §Roofline (generated, single-pod 16x16 = 256 chips)\n")
    out.append("| arch | shape | strat | compute s | memory s | collective s | bound | MODEL_FLOPs/chip | HLO_FLOPs/chip | useful | roofline frac | mem frac | one-line fix |")
    out.append("|---|---|---|---|---|---|---|---|---|---|---|---|---|")
    fixes = {
        "compute": "raise intensity: larger per-chip batch / fuse small ops",
        "memory": "cut HBM bytes: W4/W2 weights + int8 KV (BRECQ deployment), leaner remat",
        "collective": "reshard: fewer TP psums / cheaper EP dispatch; overlap with compute",
    }
    for d in sorted(rows, key=lambda x: (x["arch"], x["shape"])):
        if d["mesh"] != "single":
            continue
        r = d["roofline"]
        out.append(
            f"| {d['arch']} | {d['shape']} | {d['strategy']} "
            f"| {r['compute_s']:.4f} | {r['memory_s']:.4f} | {r['collective_s']:.4f} "
            f"| **{r['bottleneck']}** | {r['model_flops_per_chip']:.3e} "
            f"| {r['hlo_flops_per_chip']:.3e} | {r['useful_ratio']:.3f} "
            f"| {r['roofline_frac']:.3f} | {r.get('mem_frac', 0):.3f} "
            f"| {fixes[r['bottleneck']]} |")

    (ROOT / "artifacts" / "experiments_sections.md").write_text("\n".join(out))
    print(f"wrote artifacts/experiments_sections.md ({len(rows)} cells)")


if __name__ == "__main__":
    main()
