#!/usr/bin/env python3
"""Markdown link checker for the docs CI job. Stdlib only.

    python scripts/check_links.py [FILE_OR_DIR ...]

Defaults to README.md + docs/. For every markdown link it verifies:

* relative file targets exist (resolved against the linking file's
  directory, with a repo-root fallback so `docs/foo.md` works from the
  README and vice versa);
* `#anchor` fragments match a heading in the target file (GitHub-style
  slugs: lowercase, punctuation stripped, spaces -> dashes);
* external (http/https/mailto) URLs are only syntax-checked — CI must
  not flake on the network.

Exits 1 and lists every broken link if any check fails.
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]

LINK_RE = re.compile(r"(?<!\!)\[([^\]]*)\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)
CODE_FENCE_RE = re.compile(r"```.*?```", re.DOTALL)


def slugify(heading: str) -> str:
    """GitHub-style anchor slug for a heading line."""
    h = re.sub(r"[`*_]", "", heading.strip().lower())
    h = re.sub(r"[^\w\- ]", "", h)
    return h.replace(" ", "-")


def anchors_of(md_path: Path) -> set[str]:
    # strip code fences first: '# comment' lines in fenced blocks are
    # not headings and must not mint phantom anchors
    text = CODE_FENCE_RE.sub("", md_path.read_text(encoding="utf-8"))
    return {slugify(m.group(1)) for m in HEADING_RE.finditer(text)}


def check_file(md_path: Path) -> list[str]:
    errors = []
    text = CODE_FENCE_RE.sub("", md_path.read_text(encoding="utf-8"))
    for m in LINK_RE.finditer(text):
        label, target = m.group(1), m.group(2)
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        target, _, frag = target.partition("#")
        if not target:  # same-file anchor
            if frag and slugify(frag) not in anchors_of(md_path):
                errors.append(f"{md_path}: missing anchor #{frag}")
            continue
        cand = (md_path.parent / target, ROOT / target)
        dest = next((c for c in cand if c.exists()), None)
        if dest is None:
            errors.append(f"{md_path}: broken link [{label}]({target})")
            continue
        if frag and dest.suffix == ".md" and slugify(frag) not in anchors_of(dest):
            errors.append(f"{md_path}: missing anchor #{frag} in {target}")
    return errors


def collect(args: list[str]) -> list[Path]:
    paths = [Path(a) for a in args] if args else [ROOT / "README.md", ROOT / "docs"]
    files = []
    for p in paths:
        files += sorted(p.rglob("*.md")) if p.is_dir() else [p]
    return files


def main(argv: list[str]) -> int:
    files = collect(argv)
    errors = []
    for f in files:
        errors += check_file(f)
    for e in errors:
        print(e, file=sys.stderr)
    print(f"checked {len(files)} file(s): "
          f"{'FAIL' if errors else 'ok'} ({len(errors)} broken)")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
