#!/usr/bin/env python
"""CI guard over the tracked budget-frontier benchmark (BENCH_budget.json).

Deterministic checks — these follow from the solver being exact, so a
failure means the solver, the cost accounting, or the bench harness
regressed (not runner noise):

  1. every swept byte budget: ``solver.artifact_bytes <= budget_bytes``
     (the bytes budget is a hard bound on what ships);
  2. every unified-precision point that fits the budget has predicted
     loss >= the solver's (the unified assignment is in the solver's
     feasible set, so the exact solver cannot lose to it) — together
     with (1) this means the solver Pareto-dominates every unified
     point of equal or larger size that fits the budget;
  3. the genetic cross-check never achieves a lower predicted loss than
     the exact solver under the same constraint (byte and latency rows).

One loose measured check (``--min-tok-ratio``, default 0.5): a solver
artifact must not decode slower than half the slowest unified point —
a tripwire for pathological dispatch/packing, wide enough for CI noise.

Exit 0 = pass. Run from the repo root:

    python scripts/check_budget_bench.py
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
MIN_TOK_RATIO = 0.5
EPS = 1e-9


def fail(msg: str) -> None:
    print(f"check_budget_bench: FAIL — {msg}", file=sys.stderr)
    sys.exit(1)


def check(path: Path, min_tok_ratio: float) -> None:
    doc = json.loads(path.read_text())
    for key in ("config", "unified", "rows", "latency_rows"):
        if key not in doc:
            fail(f"{path.name} is missing '{key}' — re-run "
                 f"benchmarks/table8_budget.py")
    unified = {u["bits"]: u for u in doc["unified"]}
    if not doc["rows"]:
        fail(f"{path.name} has no swept byte budgets")

    slowest_unified = min(u["decode_tok_s"] for u in unified.values())
    for row in doc["rows"]:
        budget, sol = row["budget_bytes"], row["solver"]
        if sol["artifact_bytes"] > budget:
            fail(f"budget {budget}: solver artifact is "
                 f"{sol['artifact_bytes']} bytes — exceeds the budget. "
                 f"Byte accounting (overhead/probe) has drifted.")
        for b, u in sorted(unified.items()):
            if u["artifact_bytes"] > budget:
                continue  # unified point does not fit this budget
            loss_eps = EPS * max(1.0, abs(u["predicted_loss"]))
            if sol["predicted_loss"] > u["predicted_loss"] + loss_eps:
                fail(f"budget {budget}: solver predicted loss "
                     f"{sol['predicted_loss']:.6g} is worse than unified "
                     f"W{b} ({u['predicted_loss']:.6g}) which fits the "
                     f"budget — the exact solver cannot legally lose; "
                     f"solver or fitness regression.")
        if sol["decode_tok_s"] < min_tok_ratio * slowest_unified:
            fail(f"budget {budget}: solver artifact decodes at "
                 f"{sol['decode_tok_s']} tok/s, under {min_tok_ratio}x the "
                 f"slowest unified point ({slowest_unified}) — dispatch or "
                 f"packing is pathological.")

    for row in doc["rows"] + doc["latency_rows"]:
        sol, ga = row["solver"], row["genetic"]
        loss_eps = EPS * max(1.0, abs(sol["predicted_loss"]))
        if ga["fitness"] + loss_eps < sol["predicted_loss"]:
            tag = row.get("budget_bytes", row.get("budget_decode_ms"))
            fail(f"budget {tag}: the genetic search found predicted loss "
                 f"{ga['fitness']:.6g}, beating the 'exact' solver "
                 f"({sol['predicted_loss']:.6g}) — the solver is not "
                 f"optimal; check component enumeration/groups.")
    print(f"check_budget_bench: OK — {len(doc['rows'])} byte budgets, "
          f"{len(doc['latency_rows'])} latency budgets; solver dominates "
          f"all in-budget unified points, GA never wins")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--budget", type=Path, default=ROOT / "BENCH_budget.json")
    ap.add_argument("--min-tok-ratio", type=float, default=MIN_TOK_RATIO)
    ap.add_argument("--require", action="store_true",
                    help="fail if the bench file is absent (CI smoke sets "
                         "this after regenerating it)")
    args = ap.parse_args()
    if not args.budget.exists():
        if args.require:
            fail(f"{args.budget} is missing — run "
                 f"benchmarks/table8_budget.py first")
        print(f"check_budget_bench: SKIP — {args.budget.name} not present")
        return
    check(args.budget, args.min_tok_ratio)


if __name__ == "__main__":
    main()
