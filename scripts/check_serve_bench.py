#!/usr/bin/env python
"""CI guard over the tracked serving benchmarks.

Two checks, selected by flags (default: both, skipping absent files):

  --serve PATH   BENCH_serve.json   — fail if the decode qmm tier loses
                 to the legacy path by more than the pinned CPU margin
                 (``decode_ratio_tier_vs_legacy < --min-tier-ratio``).
                 Until now that ratio was recorded but never enforced; a
                 regression sailed through CI silently.
  --mt PATH      BENCH_serve_mt.json — validate the multi-stream schema
                 and fail if the int8 paged KV cache stops delivering
                 ``--min-kv-ratio`` lower resident bytes/stream than the
                 fp16 reference, if any stream failed to complete, or if
                 any run leaked KV pages. The ``pressure`` section must
                 show overcommit beating worst-case reservation: mean
                 slot occupancy strictly higher on the same reduced
                 pool, at least one preemption actually exercised, and
                 preemption overhead (replayed prefill chunks per decode
                 tick) at most ``--max-preempt-overhead``.

Exit 0 = all present checks pass; exit 1 with a readable reason
otherwise. Run from the repo root:

    python scripts/check_serve_bench.py
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]

# Tier-vs-legacy on the CI CPU runner currently sits at ~0.95 (the gemv
# tier roughly ties legacy on CPU; it wins on accelerators). 0.85 flags
# a real regression without tripping on runner noise.
MIN_TIER_RATIO = 0.85
MIN_KV_RATIO = 1.8
# replayed prefill chunks per decode tick under overcommit: >10% means
# the scheduler is thrashing (preempting faster than streams progress)
MAX_PREEMPT_OVERHEAD = 0.10

MT_TOP_KEYS = ("config", "int8", "fp16", "pressure",
               "kv_bytes_ratio_fp16_over_int8", "sustained_tok_s_int8")
MT_RUN_KEYS = ("sustained_tok_s", "tokens_generated", "mean_slot_occupancy",
               "mean_resident_kv_bytes_per_stream", "bytes_per_page",
               "streams_completed", "leaked_pages", "preemptions")
MT_PRESSURE_KEYS = ("pool_frac", "num_pages", "none", "prompt",
                    "occupancy_gain", "preemption_overhead")


def fail(msg: str) -> None:
    print(f"check_serve_bench: FAIL — {msg}", file=sys.stderr)
    sys.exit(1)


def check_serve(path: Path, min_ratio: float) -> None:
    doc = json.loads(path.read_text())
    ratio = doc.get("decode_ratio_tier_vs_legacy")
    if ratio is None:
        fail(f"{path.name} is missing 'decode_ratio_tier_vs_legacy' — "
             "re-run benchmarks/table6_deploy.py --serve-only")
    if ratio < min_ratio:
        fail(
            f"{path.name}: decode gemv tier runs at {ratio:.3f}x the legacy "
            f"qmm path, below the pinned floor {min_ratio}. The decode "
            "tier has regressed; profile kernels/qmm decode_qmm (or bump "
            "the pin deliberately in scripts/check_serve_bench.py with a "
            "note in the PR)."
        )
    print(f"check_serve_bench: {path.name} ok "
          f"(tier/legacy {ratio:.3f} >= {min_ratio})")


def check_mt(path: Path, min_kv_ratio: float,
             max_preempt_overhead: float = MAX_PREEMPT_OVERHEAD) -> None:
    doc = json.loads(path.read_text())
    missing = [k for k in MT_TOP_KEYS if k not in doc]
    if missing:
        fail(f"{path.name} missing keys {missing} — re-run "
             "benchmarks/table7_serve_mt.py")
    press = doc["pressure"]
    press_missing = [k for k in MT_PRESSURE_KEYS if k not in press]
    if press_missing:
        fail(f"{path.name}[pressure] missing keys {press_missing} — re-run "
             "benchmarks/table7_serve_mt.py")
    runs = [("int8", doc["int8"]), ("fp16", doc["fp16"]),
            ("pressure.none", press["none"]),
            ("pressure.prompt", press["prompt"])]
    for mode, run in runs:
        run_missing = [k for k in MT_RUN_KEYS if k not in run]
        if run_missing:
            fail(f"{path.name}[{mode}] missing keys {run_missing}")
        want = doc["config"]["streams"]
        got = run["streams_completed"]
        if got != want:
            fail(f"{path.name}[{mode}]: only {got}/{want} streams completed")
        if run["leaked_pages"] != 0:
            fail(f"{path.name}[{mode}]: {run['leaked_pages']} KV pages "
                 "leaked — every terminal state must hand pages back "
                 "(serve_engine._release)")

    # overcommit must actually buy something on the reduced pool, and
    # must have been exercised (zero preemptions means the pool was not
    # actually under pressure — the section proves nothing)
    occ_oc = press["prompt"]["mean_slot_occupancy"]
    occ_wc = press["none"]["mean_slot_occupancy"]
    if not occ_oc > occ_wc:
        fail(f"{path.name}[pressure]: overcommit occupancy {occ_oc:.3f} "
             f"does not beat worst-case reservation {occ_wc:.3f} on the "
             f"same {press['num_pages']}-page pool — optimistic admission "
             "has stopped paying for its complexity")
    if press["prompt"]["preemptions"] < 1:
        fail(f"{path.name}[pressure]: overcommit run recorded no "
             "preemptions — shrink --pool-frac so the preemption path is "
             "actually exercised")
    if press["preemption_overhead"] > max_preempt_overhead:
        fail(f"{path.name}[pressure]: preemption overhead "
             f"{press['preemption_overhead']:.3f} replayed chunks/decode "
             f"tick exceeds {max_preempt_overhead} — the scheduler is "
             "thrashing (victim selection or admission headroom regressed)")

    ratio = doc["kv_bytes_ratio_fp16_over_int8"]
    if ratio < min_kv_ratio:
        fail(
            f"{path.name}: int8 paged KV holds only {ratio:.2f}x less "
            f"resident bytes/stream than fp16 (floor {min_kv_ratio}). "
            "Check scale storage in models/common.init_paged_kv — scales "
            "must stay float16."
        )
    print(f"check_serve_bench: {path.name} ok "
          f"(fp16/int8 KV bytes {ratio:.2f}x >= {min_kv_ratio}, "
          f"{doc['config']['streams']} streams completed; overcommit "
          f"occupancy {occ_oc:.2f} > {occ_wc:.2f} worst-case, "
          f"{press['prompt']['preemptions']} preemptions at "
          f"{press['preemption_overhead']:.3f} overhead, zero leaks)")


def main(argv=None) -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--serve", default=str(ROOT / "BENCH_serve.json"))
    p.add_argument("--mt", default=str(ROOT / "BENCH_serve_mt.json"))
    p.add_argument("--min-tier-ratio", type=float, default=MIN_TIER_RATIO)
    p.add_argument("--min-kv-ratio", type=float, default=MIN_KV_RATIO)
    p.add_argument("--max-preempt-overhead", type=float,
                   default=MAX_PREEMPT_OVERHEAD)
    p.add_argument("--require", choices=["serve", "mt", "both", "any"],
                   default="any",
                   help="which files must exist (default: check whatever "
                        "is present, but fail if neither is)")
    args = p.parse_args(argv)

    serve, mt = Path(args.serve), Path(args.mt)
    checked = 0
    if serve.exists():
        check_serve(serve, args.min_tier_ratio)
        checked += 1
    elif args.require in ("serve", "both"):
        fail(f"{serve} not found")
    if mt.exists():
        check_mt(mt, args.min_kv_ratio, args.max_preempt_overhead)
        checked += 1
    elif args.require in ("mt", "both"):
        fail(f"{mt} not found")
    if checked == 0:
        fail("no benchmark JSON found to check")


if __name__ == "__main__":
    main()
