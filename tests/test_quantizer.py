"""Unit tests for the uniform quantizer primitives."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.quantizer import (QConfig, fake_quant_ste, init_qstate,
                                  pack_int, quantize_dequant, quantize_int,
                                  unpack_int)


@pytest.mark.parametrize("bits", [2, 3, 4, 8])
@pytest.mark.parametrize("channel_axis", [None, -1])
def test_qdq_error_bound(rng, bits, channel_axis):
    w = jnp.asarray(rng.normal(size=(64, 32)), jnp.float32)
    cfg = QConfig(bits=bits, channel_axis=channel_axis)
    st = init_qstate(w, cfg)
    wq = quantize_dequant(w, st, cfg)
    # within the clip range error <= scale/2; minmax symmetric never clips
    # by more than one step at the negative extreme
    err = jnp.abs(wq - w)
    assert float(jnp.max(err)) <= float(jnp.max(st.scale)) * 1.01


def test_mse_beats_or_matches_minmax(rng):
    w = jnp.asarray(rng.standard_t(df=2, size=(128, 64)), jnp.float32)  # heavy tails
    for ca in (None, -1):
        mm = QConfig(bits=4, channel_axis=ca, scale_method="minmax")
        ms = QConfig(bits=4, channel_axis=ca, scale_method="mse")
        e_mm = float(jnp.sum((quantize_dequant(w, init_qstate(w, mm), mm) - w) ** 2))
        e_ms = float(jnp.sum((quantize_dequant(w, init_qstate(w, ms), ms) - w) ** 2))
        assert e_ms <= e_mm * 1.001


@pytest.mark.parametrize("bits", [2, 4, 8])
def test_group_scales_shapes(rng, bits):
    w = jnp.asarray(rng.normal(size=(256, 32)), jnp.float32)
    cfg = QConfig(bits=bits, group_size=64)
    st = init_qstate(w, cfg)
    assert st.scale.shape == (4, 1, 32)
    wq = quantize_dequant(w, st, cfg)
    assert wq.shape == w.shape
    # grouped quantization is at least as accurate as per-tensor
    cfg_t = QConfig(bits=bits)
    e_g = float(jnp.sum((wq - w) ** 2))
    e_t = float(jnp.sum((quantize_dequant(w, init_qstate(w, cfg_t), cfg_t) - w) ** 2))
    assert e_g <= e_t * 1.001


def test_group_scales_3d_experts(rng):
    w = jnp.asarray(rng.normal(size=(4, 64, 16)), jnp.float32)  # (E, K, N)
    cfg = QConfig(bits=4, group_size=32)
    st = init_qstate(w, cfg)
    assert st.scale.shape == (4, 2, 1, 16)
    assert quantize_dequant(w, st, cfg).shape == w.shape


@pytest.mark.parametrize("bits", [2, 4, 8])
@pytest.mark.parametrize("shape", [(64, 16), (4, 64, 16)])
def test_pack_unpack_roundtrip(rng, bits, shape):
    lo, hi = -(2 ** (bits - 1)), 2 ** (bits - 1) - 1
    q = jnp.asarray(rng.integers(lo, hi + 1, size=shape), jnp.int8)
    axis = len(shape) - 2
    p = pack_int(q, bits, axis=axis)
    per = 8 // bits
    assert p.shape[axis] == shape[axis] // per
    back = unpack_int(p, bits, shape[axis], axis=axis)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(q))


def test_ste_gradient_masks_clipped(rng):
    w = jnp.asarray([[-10.0, -0.5, 0.0, 0.5, 10.0]], jnp.float32)
    cfg = QConfig(bits=4)
    st = init_qstate(jnp.asarray([[1.0]]), cfg)  # scale for range ~[-1,1]
    g = jax.grad(lambda x: jnp.sum(fake_quant_ste(x, st, cfg)))(w)
    g = np.asarray(g)[0]
    assert g[0] == 0.0 and g[-1] == 0.0  # clipped
    assert g[1] == 1.0 and g[2] == 1.0 and g[3] == 1.0  # pass-through


def test_asymmetric_quantizer(rng):
    x = jnp.asarray(rng.uniform(0.0, 5.0, size=(32, 32)), jnp.float32)
    cfg = QConfig(bits=4, symmetric=False)
    st = init_qstate(x, cfg)
    xq = quantize_dequant(x, st, cfg)
    assert float(jnp.max(jnp.abs(xq - x))) <= float(st.scale.max()) * 0.51
    codes = quantize_int(x, st, cfg)
    assert int(codes.min()) >= 0
