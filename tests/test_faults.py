"""Fault-tolerance tests: journaled resume, per-unit guards, artifact
integrity. Injection lives in ``faults.py`` (also the CI smoke CLI)."""
import signal

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import faults
from repro.core import (CalibJournal, CalibJournalError,
                        CalibrationInterrupted, ReconConfig, quantize)
from repro.core.quantizer import quantize_dequant
from repro.deploy import (ArtifactCorruptionError, ArtifactSchemaError,
                          QuantizedArtifact, rtn_artifact)


@pytest.fixture(scope="module")
def tiny():
    """Untrained 2-block LM + 2 calibration batches (shared with the CI
    smoke CLI so both exercise the same shapes)."""
    return faults._tiny_setup()


def _rc(**kw):
    base = dict(w_bits=4, iters=6, calib_bs=4)
    base.update(kw)
    return ReconConfig(**base)


def _assert_bit_exact(a, b):
    fa = jax.tree_util.tree_flatten_with_path(a)[0]
    fb = jax.tree_util.tree_flatten_with_path(b)[0]
    assert len(fa) == len(fb)
    for (pa, xa), (_pb, xb) in zip(fa, fb):
        assert np.array_equal(np.asarray(xa), np.asarray(xb)), pa


# ---------------------------------------------------------------------------
# resumable calibration
# ---------------------------------------------------------------------------


def test_kill_and_resume_bit_exact(tiny, tmp_path):
    """SIGTERM after unit 0 -> journal snapshot + CalibrationInterrupted;
    re-running with the same workdir resumes at unit 1 and reproduces the
    uninterrupted run bit-for-bit."""
    cfg, model, params, calib = tiny
    rc = _rc()
    ref = quantize(model, params, calib, rc)

    d = str(tmp_path / "journal")
    with faults.kill_during_unit(0, sig=signal.SIGTERM):
        with pytest.raises(CalibrationInterrupted) as ei:
            quantize(model, params, calib, rc, workdir=d)
    assert ei.value.next_unit == 1
    assert ei.value.workdir == d

    res = quantize(model, params, calib, rc, workdir=d)
    assert res.stats["resumed_at_unit"] == 1
    assert res.stats["n_units"] == ref.stats["n_units"]
    _assert_bit_exact(ref.params_q, res.params_q)
    assert set(ref.v) == set(res.v)
    for p in ref.v:
        assert np.array_equal(np.asarray(ref.v[p]), np.asarray(res.v[p])), p
    # per-unit stats survive the journal round trip as arrays
    for u in res.stats["units"]:
        assert isinstance(u["loss_trace"], np.ndarray)


def test_journal_signature_mismatch(tmp_path):
    """A journal written by a different run must refuse to resume."""
    d = str(tmp_path)
    x = jnp.zeros((2, 4, 8), jnp.float32)
    j1 = CalibJournal(d, {"rc": "ReconConfig(A)", "n_units": 2})
    j1.save(1, x, x, None, None,
            {"blocks.0/attn/wq": jnp.zeros((3,), jnp.float32)}, {},
            [{"unit": 0}], 1234)
    assert j1.load()["next_unit"] == 1

    j2 = CalibJournal(d, {"rc": "ReconConfig(B)", "n_units": 2})
    with pytest.raises(CalibJournalError) as ei:
        j2.load()
    assert "rc" in str(ei.value)


def test_journal_truncation_is_typed(tmp_path):
    """A torn snapshot surfaces as CalibJournalError, not a zip traceback."""
    d = str(tmp_path)
    x = jnp.zeros((2, 4, 8), jnp.float32)
    j = CalibJournal(d, {"rc": "ReconConfig(A)"})
    j.save(1, x, x, None, None, {}, {}, [], 0)
    faults.truncate_arrays(d, drop_bytes=64)
    with pytest.raises(CalibJournalError, match="unreadable"):
        j.load()


# ---------------------------------------------------------------------------
# per-unit guards: NaN retry / RTN fallback / OOM minibatch halving
# ---------------------------------------------------------------------------


def test_nan_retry_recovers(tiny):
    """One poisoned attempt: the guard retries at reduced lr and the unit
    completes without falling back."""
    cfg, model, params, calib = tiny
    with faults.nan_unit_loop({0}):
        res = quantize(model, params, calib, _rc(unit_retries=2))
    assert res.stats["unit_retries"] == 1
    assert res.stats["unit_fallbacks"] == 0
    u0 = res.stats["units"][0]
    assert u0["retries"] == 1 and not u0["fallback"]
    assert np.isfinite(u0["final_recon_mse"])
    assert u0["final_recon_mse"] <= u0["rtn_recon_mse"] * 1.5
    for leaf in jax.tree.leaves(res.params_q):
        assert np.all(np.isfinite(np.asarray(leaf)))


def test_nan_fallback_to_rtn(tiny):
    """Every attempt poisoned: unit 0 degrades to RTN (its paths drop out
    of v, baked weights equal plain quantize_dequant) while unit 1 still
    reconstructs normally."""
    cfg, model, params, calib = tiny
    rc = _rc(unit_retries=1)  # 2 attempts per unit
    ref = quantize(model, params, calib, rc)
    with faults.nan_unit_loop({0, 1}):
        res = quantize(model, params, calib, rc)
    assert res.stats["unit_fallbacks"] == 1
    assert res.stats["unit_retries"] == 1
    u0 = res.stats["units"][0]
    assert u0["fallback"] and u0["retries"] == 1
    assert u0["final_recon_mse"] == u0["rtn_recon_mse"]
    assert not res.stats["units"][1]["fallback"]

    dropped = set(ref.v) - set(res.v)
    assert dropped, "fallback unit left its logits in v"
    prefixes = {p.split("/")[0] for p in dropped}
    assert len(prefixes) == 1  # exactly one unit degraded
    assert not any(p.split("/")[0] in prefixes for p in res.v)

    # baked weights of the degraded unit are exactly RTN
    path = sorted(dropped)[0]
    st, qcfg = res.qstates[path]
    sname, ri = path.split("/")[0].rsplit(".", 1)
    node_q, node_fp = res.params_q[sname], params[sname]
    for k in path.split("/")[1:]:
        node_q, node_fp = node_q[k], node_fp[k]
    w_q = np.asarray(node_q["w"][int(ri)])
    w_fp = node_fp["w"][int(ri)]
    np.testing.assert_array_equal(
        w_q, np.asarray(quantize_dequant(w_fp, st, qcfg)))
    for leaf in jax.tree.leaves(res.params_q):
        assert np.all(np.isfinite(np.asarray(leaf)))


def test_oom_halves_minibatch(tiny):
    """A device-OOM on the first attempt retries the unit with half the
    calibration minibatch instead of failing the job."""
    cfg, model, params, calib = tiny
    with faults.oom_unit_loop({0}):
        res = quantize(model, params, calib, _rc())
    assert res.stats["unit_oom_halvings"] == 1
    assert res.stats["unit_fallbacks"] == 0
    u0, u1 = res.stats["units"][:2]
    assert u0["oom_halvings"] == 1 and u0["calib_bs"] == 2
    assert u1["oom_halvings"] == 0 and u1["calib_bs"] == 4


def test_oom_reraised_when_guard_off(tiny):
    cfg, model, params, calib = tiny
    with faults.oom_unit_loop({0}):
        with pytest.raises(jax.errors.JaxRuntimeError,
                           match="RESOURCE_EXHAUSTED"):
            quantize(model, params, calib, _rc(unit_guard=False))


# ---------------------------------------------------------------------------
# artifact integrity
# ---------------------------------------------------------------------------


@pytest.fixture()
def saved_artifact(tiny, tmp_path):
    cfg, model, params, _ = tiny
    art = rtn_artifact(params, 4, cfg=cfg)
    d = str(tmp_path / "art")
    art.save(d)
    return d, art


def test_pristine_artifact_verifies(saved_artifact):
    d, art = saved_artifact
    loaded = QuantizedArtifact.load(d)
    assert loaded.manifest["schema_version"] == art.manifest["schema_version"]
    assert loaded.manifest["checksums"] == art.manifest["checksums"]


def test_bitflip_detected_names_leaf(saved_artifact):
    d, art = saved_artifact
    leaf = next(k for k in art.manifest["checksums"]
                if k.endswith("/w") or k.endswith("/table"))
    faults.flip_leaf_bit(d, leaf, byte_index=17, bit=3)
    with pytest.raises(ArtifactCorruptionError) as ei:
        QuantizedArtifact.load(d)
    assert ei.value.leaf == leaf
    assert leaf in str(ei.value)


def test_truncation_detected(saved_artifact):
    d, _ = saved_artifact
    faults.truncate_arrays(d)
    with pytest.raises(ArtifactCorruptionError, match="truncated or corrupt"):
        QuantizedArtifact.load(d)


def test_manifest_checksum_edit_detected(saved_artifact):
    d, art = saved_artifact
    leaf = next(iter(art.manifest["checksums"]))

    def bump(meta):
        meta["manifest"]["checksums"][leaf] ^= 1

    faults.edit_manifest(d, bump)
    with pytest.raises(ArtifactCorruptionError) as ei:
        QuantizedArtifact.load(d)
    assert ei.value.leaf == leaf


def test_manifest_digest_edit_detected(saved_artifact):
    d, _ = saved_artifact

    def forge(meta):
        meta["manifest"]["content_digest"] = "0" * 64

    faults.edit_manifest(d, forge)
    with pytest.raises(ArtifactCorruptionError, match="edited"):
        QuantizedArtifact.load(d)


def test_stale_schema_version_detected(saved_artifact):
    d, _ = saved_artifact

    def strip(meta):
        meta["manifest"].pop("schema_version")

    faults.edit_manifest(d, strip)
    with pytest.raises(ArtifactSchemaError, match="pre-v2"):
        QuantizedArtifact.load(d)
    # escape hatch still loads it
    assert QuantizedArtifact.load(d, verify=False) is not None

    def future(meta):
        meta["manifest"]["schema_version"] = 999

    faults.edit_manifest(d, future)
    with pytest.raises(ArtifactSchemaError):
        QuantizedArtifact.load(d)


def test_no_verify_loads_corrupt_artifact(saved_artifact):
    d, art = saved_artifact
    leaf = next(k for k in art.manifest["checksums"] if k.endswith("/w"))
    faults.flip_leaf_bit(d, leaf)
    loaded = QuantizedArtifact.load(d, verify=False)
    assert loaded.params is not None


# ---------------------------------------------------------------------------
# serving faults: mid-decode cancel, corrupt artifact at engine start
# ---------------------------------------------------------------------------


def test_cancel_mid_decode_reclaims_and_isolates():
    """Cancelling a decoding stream frees its pages immediately and
    leaves every other stream's output bit-identical to an uncancelled
    run (fp KV, same compiled programs -> exact)."""
    make = faults._serve_setup()
    ref = make()
    ref.run()
    eng = faults.cancel_mid_decode(make(), uid=1, after_tokens=3)
    assert eng.requests[1].state == "cancelled"
    assert eng.pool.refcount(1) == 0
    assert len(eng.requests[1].generated) < 12  # actually cut short
    for uid in (0, 2):
        assert eng.requests[uid].state == "done"
        assert eng.requests[uid].generated == ref.requests[uid].generated
    eng.assert_no_leaks()
    # cancel of an already-finished request is a no-op
    assert not eng.cancel(1)
    assert not eng.cancel(0)


def test_nan_decode_slot_fails_in_isolation():
    """A NaN logit row in one decode slot fails only that request; the
    other slots in the *same batched step* finish bit-identical to a
    fault-free run, and the failed stream's pages come back."""
    make = faults._serve_setup()
    ref = make()
    ref.run()
    eng = make()
    with faults.nan_decode_slot(eng, uid=1, after_tokens=3) as state:
        m = eng.run()
    assert state["fired"], "injection never triggered"
    assert eng.requests[1].state == "failed"
    assert eng.requests[1].error == "non-finite logits"
    assert len(eng.requests[1].generated) == 3  # cut at the poisoned step
    assert eng.pool.refcount(1) == 0
    assert m["failed"] == 1
    for uid in (0, 2):
        assert eng.requests[uid].state == "done"
        assert eng.requests[uid].generated == ref.requests[uid].generated
    eng.assert_no_leaks()


def test_nan_prefill_fails_in_isolation():
    """Same isolation for a fault landing on the *prefill* path (the
    first-token logits): only the poisoned stream dies."""
    import jax.numpy as jnp

    make = faults._serve_setup()
    ref = make()
    ref.run()
    eng = make()
    eng.compile()
    orig = eng._chunk_c
    state = {"fired": False}

    def patched(params, tokens, cache, pos, bt):
        logits, cache = orig(params, tokens, cache, pos, bt)
        req = eng.requests.get(2)
        if (not state["fired"] and req is not None
                and req.state == "prefill" and req.slot >= 0
                and int(bt[0, 0]) == eng.block_tables[req.slot, 0]):
            logits = jnp.full_like(logits, jnp.nan)
            state["fired"] = True
        return logits, cache

    eng._chunk_c = patched
    try:
        eng.run()
    finally:
        eng._chunk_c = orig
    assert state["fired"]
    assert eng.requests[2].state == "failed"
    assert eng.requests[2].error == "non-finite logits at prefill"
    assert eng.requests[2].generated == []
    for uid in (0, 1):
        assert eng.requests[uid].state == "done"
        assert eng.requests[uid].generated == ref.requests[uid].generated
    eng.assert_no_leaks()


def test_shutdown_flag_drains_mid_serving():
    """The GracefulShutdown flag (SIGTERM handler state, minus the raw
    signal — that lands in the faults.py CLI) stops admission, settles
    in-flight streams and rejects new submits."""
    from repro.launch.watchdog import GracefulShutdown
    from repro.serve_engine import RequestRejected

    make = faults._serve_setup()
    eng = make()
    for _ in range(4):
        eng.step()
    gs = GracefulShutdown(install=False)
    gs.requested = True
    m = eng.run(shutdown=gs)
    assert m["drained"] is True
    assert all(s in ("done", "waiting") for s in m["states"].values())
    eng.assert_no_leaks()
    with pytest.raises(RequestRejected, match="draining"):
        eng.submit(np.zeros(4, np.int32), 2)


def test_pool_pressure_storm_smoke():
    """Pressure-storm helper drives preemption and still finishes every
    stream (full bit-exactness pin lives in test_serve_pressure.py and
    the pool-pressure CLI)."""
    from repro.serve_engine import EngineConfig, ServeEngine

    make = faults._serve_setup()
    donor = make()  # borrow the module's compiled model/params
    model, params = donor.model, donor.params
    eng = ServeEngine(model, params, EngineConfig(
        num_slots=3, page_size=4, num_pages=8, max_len=32, prefill_chunk=8,
        kv_dtype="float32", backend="xla", overcommit="prompt"))
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, model.cfg.vocab, size=n).astype(np.int32)
               for n in (6, 9, 7, 11)]
    faults.pool_pressure_storm(eng, prompts, (12, 14, 12, 10))
    m = eng.metrics()
    assert m["preemptions"] >= 1
    assert all(r.state == "done" for r in eng.requests.values())
    eng.assert_no_leaks()


def test_corrupt_artifact_fails_before_admission(tmp_path):
    """A checksum failure at engine start raises the typed error from
    the verifying load — no engine exists, so no slot was admitted."""
    from repro.models import get_model
    from repro.serve_engine import ServeEngine

    cfg, model = get_model("brecq_lm_100m", reduced=True)
    params = model.init(jax.random.PRNGKey(0))
    art = rtn_artifact(params, 4, cfg=cfg)
    d = str(tmp_path / "art")
    art.save(d)
    # pristine artifact builds an engine with manifest KV defaults
    eng = ServeEngine.from_artifact(d, reduced=True)
    assert eng.cfg.kv_dtype == art.manifest["kv_dtype"]
    assert eng.cfg.page_size == art.manifest["kv_page_size"]
    leaf = next(k for k in art.manifest["checksums"] if k.endswith("/w"))
    faults.flip_leaf_bit(d, leaf)
    with pytest.raises(ArtifactCorruptionError) as ei:
        ServeEngine.from_artifact(d, reduced=True)
    assert ei.value.leaf == leaf
