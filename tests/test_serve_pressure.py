"""Serve-engine survival under pressure: overcommit + preemption,
deadlines, stall reporting, graceful drain.

The load-bearing claim is admission/eviction *correctness*, not speed:
a pool far below worst-case demand must still finish every stream with
tokens bit-identical to an unpressured solo run (greedy decode is
deterministic and re-prefill replays the exact KV), and every exit path
— done, cancelled, expired, failed, preempted, drained — must hand all
pages back. Companion chaos CLIs live in ``faults.py``.
"""
import numpy as np
import pytest

from repro.launch.watchdog import GracefulShutdown
from repro.models import get_model
from repro.serve_engine import (EngineConfig, EngineStalledError,
                                RequestRejected, ServeEngine)

BASE = dict(num_slots=3, page_size=4, max_len=32, prefill_chunk=8,
            kv_dtype="float32", backend="xla")


@pytest.fixture(scope="module")
def mk():
    """Engine factory with per-config donor caching: the first engine of
    each EngineConfig compiles, later ones reuse its programs."""
    import jax

    _, model = get_model("brecq_lm_100m", reduced=True)
    params = model.init(jax.random.PRNGKey(0))
    donors: dict = {}

    def make(**over):
        cfg = EngineConfig(**{**BASE, **over})
        key = cfg.program_shape
        eng = ServeEngine(model, params, cfg,
                          share_compiled=donors.get(key))
        donors.setdefault(key, eng)
        return eng

    return make


RNG = np.random.default_rng(3)
PROMPTS = [RNG.integers(0, 331, size=n).astype(np.int32)
           for n in (6, 9, 7, 11)]
MAX_NEWS = (12, 14, 12, 10)


def _submit_storm(eng):
    for uid, (p, mn) in enumerate(zip(PROMPTS, MAX_NEWS)):
        eng.submit(p, mn, uid=uid)
    return eng


@pytest.fixture(scope="module")
def solo_refs(mk):
    """Each stream run alone on an uncontended pool — ground truth."""
    refs = {}
    for uid, (p, mn) in enumerate(zip(PROMPTS, MAX_NEWS)):
        e = mk(num_pages=49)
        e.submit(p, mn, uid=uid)
        e.run()
        refs[uid] = list(e.requests[uid].generated)
    return refs


# ---------------------------------------------------------------------------
# tentpole: overcommit + preemption correctness
# ---------------------------------------------------------------------------


def test_preemption_resumes_bit_exact(mk, solo_refs):
    """7 usable pages vs 16 worst-case demand: the scheduler must
    preempt at least once, yet every stream finishes with tokens
    identical to its solo run and the pool comes back pristine."""
    eng = _submit_storm(mk(num_pages=8, overcommit="prompt"))
    m = eng.run()
    assert m["preemptions"] >= 1, "pressure never forced a preemption"
    assert m["replay_prefill_chunks"] >= 1
    preempted = {u for t, ev, u in eng.events if ev == "preempt"}
    readmitted = {u for t, ev, u in eng.events if ev == "readmit"}
    assert preempted and preempted == readmitted
    for uid, ref in solo_refs.items():
        req = eng.requests[uid]
        assert req.state == "done", (uid, req.state)
        assert list(req.generated) == ref, uid
        assert req.preemptions == sum(
            1 for _, ev, u in eng.events if ev == "preempt" and u == uid)
    eng.assert_no_leaks()


def test_overcommit_raises_occupancy_over_worst_case(mk, solo_refs):
    """Same tight pool, same streams: worst-case reservation serializes
    admission while 'prompt' packs slots — higher mean occupancy — and
    both policies produce identical tokens."""
    worst = _submit_storm(mk(num_pages=8, overcommit="none")).run()
    oc_eng = _submit_storm(mk(num_pages=8, overcommit="prompt"))
    oc = oc_eng.run()
    assert oc["mean_slot_occupancy"] > worst["mean_slot_occupancy"]
    for uid, ref in solo_refs.items():
        assert list(oc_eng.requests[uid].generated) == ref, uid


def test_victim_is_lowest_priority_then_newest(mk):
    """Victim selection: priority dominates, admission recency breaks
    ties, and the requester itself is never evicted."""
    eng = mk(num_pages=49, overcommit="prompt")
    eng.submit(PROMPTS[0], 4, uid=0, priority=1)
    eng.submit(PROMPTS[1], 4, uid=1, priority=0)
    eng.submit(PROMPTS[2], 4, uid=2, priority=1)
    eng.step()  # admits all three (pool is comfortable)
    assert all(r is not None for r in eng.slot_req)
    assert eng._preempt_for(eng.requests[0])
    assert eng.requests[1].state == "waiting"  # only priority-0 stream
    assert eng.requests[1].preemptions == 1
    # among the remaining equal-priority pair, the newest admission goes
    assert eng._preempt_for(eng.requests[0])
    assert eng.requests[2].state == "waiting"
    # requester is never a candidate: no victims left
    assert not eng._preempt_for(eng.requests[0])
    eng.run()
    assert all(eng.requests[u].state == "done" for u in (0, 1, 2))
    eng.assert_no_leaks()


# ---------------------------------------------------------------------------
# deadlines
# ---------------------------------------------------------------------------


def test_deadline_expires_and_reclaims(mk):
    """A request that cannot finish inside its deadline moves to the
    terminal 'expired' state with pages reclaimed; an undeadlined
    neighbour is untouched."""
    eng = mk(num_pages=49)
    eng.submit(PROMPTS[0], 12, uid=0, deadline_ticks=4)
    eng.submit(PROMPTS[1], 6, uid=1)
    m = eng.run()
    assert eng.requests[0].state == "expired"
    assert len(eng.requests[0].generated) < 12
    assert eng.requests[1].state == "done"
    assert m["expired"] == 1
    assert eng.pool.refcount(0) == 0
    assert ("expired", 0) in [(ev, u) for _, ev, u in eng.events]
    eng.assert_no_leaks()
    # terminal state: cancel is a no-op, uid is reusable
    assert not eng.cancel(0)
    eng.submit(PROMPTS[0], 2, uid=0)
    eng.run()
    assert eng.requests[0].state == "done"


def test_deadline_expires_while_waiting(mk):
    """Deadlines bind in the queue too: a stream that never got a slot
    still expires (it holds no pages, so nothing to reclaim)."""
    eng = mk(num_pages=8, overcommit="none")
    # worst-case reserve of stream 0 starves the queue
    eng.submit(PROMPTS[0], 12, uid=0)
    eng.submit(PROMPTS[1], 12, uid=1, deadline_ticks=2)
    eng.run()
    assert eng.requests[0].state == "done"
    assert eng.requests[1].state == "expired"
    assert eng.requests[1].generated == []
    eng.assert_no_leaks()


# ---------------------------------------------------------------------------
# typed rejection + stall reporting
# ---------------------------------------------------------------------------


def test_duplicate_uid_rejected_not_overwritten(mk):
    eng = mk(num_pages=49)
    eng.submit(PROMPTS[0], 4, uid=7)
    with pytest.raises(RequestRejected) as ei:
        eng.submit(PROMPTS[1], 4, uid=7)
    assert ei.value.reason == "duplicate_uid"
    assert ei.value.uid == 7
    assert (eng.tick, "reject:duplicate_uid", 7) in eng.events
    assert np.array_equal(eng.requests[7].prompt, PROMPTS[0])  # untouched
    eng.run()
    eng.submit(PROMPTS[1], 4, uid=7)  # terminal uid is reusable
    eng.run()
    assert eng.requests[7].state == "done"


def test_reject_reasons_are_typed(mk):
    eng = mk(num_pages=8)
    cases = [
        (dict(prompt=PROMPTS[0], max_new=0), "bad_max_new"),
        (dict(prompt=np.zeros(30, np.int32), max_new=20), "too_long"),
        (dict(prompt=np.zeros(20, np.int32), max_new=10), "exceeds_pool"),
        (dict(prompt=PROMPTS[0], max_new=4, deadline_ticks=0),
         "bad_deadline"),
    ]
    for kw, reason in cases:
        p = kw.pop("prompt")
        mn = kw.pop("max_new")
        with pytest.raises(RequestRejected) as ei:
            eng.submit(p, mn, **kw)
        assert ei.value.reason == reason
    assert not eng.pending()  # nothing was queued


def test_stall_raises_typed_error_with_completed_work(mk):
    eng = mk(num_pages=49)
    eng.submit(PROMPTS[0], 2, uid=0)
    eng.submit(PROMPTS[1], 30 - len(PROMPTS[1]) - 1, uid=1)
    with pytest.raises(EngineStalledError) as ei:
        eng.run(max_ticks=6)
    err = ei.value
    assert err.states[0] == "done"          # finished work is reported…
    assert err.states[1] in ("prefill", "decode")
    assert err.metrics["tokens_generated"] >= 2
    assert "max_ticks=6" in str(err)
    assert eng.requests[0].generated        # …and not destroyed
    eng.run()                               # the engine is still usable
    assert eng.requests[1].state == "done"
    eng.assert_no_leaks()


def test_stall_nonstrict_returns_metrics(mk):
    eng = mk(num_pages=49)
    eng.submit(PROMPTS[0], 12, uid=0)
    m = eng.run(max_ticks=3, strict=False)
    assert m["stalled"] is True
    assert m["states"][0] in ("prefill", "decode")
    eng.run()
    assert eng.requests[0].state == "done"


# ---------------------------------------------------------------------------
# graceful drain
# ---------------------------------------------------------------------------


def test_drain_finish_settles_all_in_flight(mk):
    eng = mk(num_pages=49)
    for uid in range(3):
        eng.submit(PROMPTS[uid], MAX_NEWS[uid], uid=uid)
    for _ in range(4):
        eng.step()
    statuses = eng.drain(finish=True)
    assert eng.draining
    assert all(s == "done" for s in statuses.values()), statuses
    eng.assert_no_leaks()
    with pytest.raises(RequestRejected) as ei:
        eng.submit(PROMPTS[0], 2)
    assert ei.value.reason == "draining"
    # idempotent
    assert eng.drain(finish=True) == statuses


def test_drain_preempt_frees_pages_and_keeps_work_resumable(mk):
    eng = mk(num_pages=49)
    for uid in range(3):
        eng.submit(PROMPTS[uid], MAX_NEWS[uid], uid=uid)
    for _ in range(6):
        eng.step()
    statuses = eng.drain(finish=False)
    assert set(statuses.values()) <= {"waiting", "done"}
    assert "waiting" in statuses.values()  # something was in flight
    eng.assert_no_leaks()  # preempted streams hold no pages


def test_run_with_shutdown_drains_gracefully(mk):
    """run(shutdown=...) notices the flag between ticks, drains, and
    reports — the SIGTERM path minus the raw signal (that is exercised
    by ``faults.py sigterm-drain``)."""
    eng = mk(num_pages=49)
    for uid in range(3):
        eng.submit(PROMPTS[uid], MAX_NEWS[uid], uid=uid)
    for _ in range(4):
        eng.step()
    gs = GracefulShutdown(install=False)
    gs.requested = True
    m = eng.run(shutdown=gs)
    assert m["drained"] is True
    assert all(s == "done" for s in m["states"].values())
    assert m["draining"] is True
    eng.assert_no_leaks()


# ---------------------------------------------------------------------------
# watchdog surfacing
# ---------------------------------------------------------------------------


def test_tick_watchdog_surfaces_in_metrics(mk):
    eng = mk(num_pages=49)
    eng.submit(PROMPTS[0], 6, uid=0)
    m = eng.run()
    assert m["stragglers"] == eng._watchdog.stragglers
    assert m["mean_tick_s"] > 0.0
    assert isinstance(eng.watchdog_notes, list)  # notes, not stdout
