"""BRECQ engine integration tests on a tiny trained LM."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ReconConfig, quantize
from repro.core import calib_loop
from repro.core.baselines import quantize_rtn
from repro.core.evaluate import evaluate
from repro.core.reconstruction import Walker, enumerate_weights


def test_walker_matches_scan_forward(tiny_trained):
    cfg, model, params, calib, evalb, _ = tiny_trained
    walker = Walker(model)
    batch = calib[0]
    logits_scan, _ = model.forward(params, batch, remat="none")
    logits_walk = walker.run(params, batch)
    np.testing.assert_allclose(np.asarray(logits_walk), np.asarray(logits_scan),
                               rtol=1e-4, atol=1e-4)


def test_enumerate_weights_paths(tiny_trained):
    cfg, model, params, calib, _, _ = tiny_trained
    weights = enumerate_weights(model, params, calib[0])
    assert "embed/table" in weights
    assert any(p.endswith("attn/wq") for p in weights)
    assert any(p.endswith("mlp/w_down") for p in weights)
    # all block weights carry the stack.index prefix
    blocked = [p for p in weights if "." in p.split("/")[0]]
    assert len(blocked) == 4 * 7  # 4 blocks x (4 attn + 3 mlp) linears


def test_brecq_w4_near_fp(tiny_trained):
    cfg, model, params, calib, evalb, _ = tiny_trained
    fp = evaluate(model, params, evalb)
    rc = ReconConfig(w_bits=4, iters=60, calib_bs=8)
    res = quantize(model, params, calib, rc)
    q = evaluate(model, res.params_q, evalb)
    assert q["loss"] <= fp["loss"] + 0.05, (fp, q)
    assert res.stats["n_units"] == 4
    # reconstruction loss decreased within units
    for u in res.stats["units"]:
        if "loss_first" in u and u["loss_first"]:
            assert u["loss_last"] <= u["loss_first"] * 1.5


def test_brecq_beats_rtn_at_w2(tiny_trained):
    cfg, model, params, calib, evalb, _ = tiny_trained
    pq_rtn, _ = quantize_rtn(model, params, calib, w_bits=2)
    rtn = evaluate(model, pq_rtn, evalb)
    rc = ReconConfig(w_bits=2, iters=120, calib_bs=8)
    res = quantize(model, params, calib, rc)
    brecq = evaluate(model, res.params_q, evalb)
    assert brecq["loss"] <= rtn["loss"] + 1e-3, (rtn, brecq)


@pytest.mark.parametrize("granularity", ["layer", "block", "stage", "net"])
def test_granularities_run(tiny_trained, granularity):
    cfg, model, params, calib, evalb, _ = tiny_trained
    rc = ReconConfig(w_bits=3, iters=15, calib_bs=4, granularity=granularity)
    res = quantize(model, params, calib[:2], rc)
    q = evaluate(model, res.params_q, evalb[:1])
    assert np.isfinite(q["loss"])
    expected_units = {"layer": 4, "block": 4, "stage": 4, "net": 1}[granularity]
    assert res.stats["n_units"] == expected_units


def test_activation_quant_path(tiny_trained):
    cfg, model, params, calib, evalb, _ = tiny_trained
    rc = ReconConfig(w_bits=4, a_bits=8, iters=30, calib_bs=4)
    res = quantize(model, params, calib[:3], rc)
    assert res.act_scales, "no activation scales learned"
    q = evaluate(model, res.params_q, evalb, res.act_scales, a_bits=8)
    fp = evaluate(model, params, evalb)
    assert q["loss"] <= fp["loss"] + 0.2


def test_bake_values_on_grid(tiny_trained):
    cfg, model, params, calib, _, _ = tiny_trained
    rc = ReconConfig(w_bits=4, iters=10, calib_bs=4)
    res = quantize(model, params, calib[:2], rc)
    # pick one baked block weight and verify it lies on its grid
    path = next(p for p in res.v if p.endswith("attn/wq"))
    st, qcfg = res.qstates[path]
    sname, ri = path.split("/")[0].rsplit(".", 1)
    node = res.params_q[sname]
    for k in path.split("/")[1:]:
        node = node[k]
    w = np.asarray(node["w"][int(ri)])
    codes = w / np.asarray(st.scale)
    np.testing.assert_allclose(codes, np.round(codes), atol=1e-3)


@pytest.fixture(scope="module")
def two_block():
    """Untrained 2-block LM: enough for loop-equivalence checks."""
    from repro.data import Corpus, CorpusConfig, make_batches
    from repro.models import build_model, get_config

    cfg = dataclasses.replace(get_config("brecq_lm_100m", reduced=True),
                              n_layers=2)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    corpus = Corpus(CorpusConfig(vocab=cfg.vocab))
    calib = make_batches(corpus, 3, 8, 64, seed=1, start_step=1000)
    return model, params, calib


@pytest.mark.parametrize("granularity", ["block", "layer"])
def test_scan_loop_matches_python_loop(two_block, granularity):
    """Fused lax.scan loop == per-iteration dispatch of the same step:
    same seed -> same loss trajectory and identical hardened v signs."""
    model, params, calib = two_block
    mk = lambda impl: ReconConfig(w_bits=3, iters=25, calib_bs=4,
                                  granularity=granularity,
                                  use_fisher=(granularity != "layer"),
                                  seed=7, loop_impl=impl)
    res_scan = quantize(model, params, calib, mk("scan"))
    res_py = quantize(model, params, calib, mk("python"))
    for us, up in zip(res_scan.stats["units"], res_py.stats["units"]):
        if "loss_trace" in us:
            np.testing.assert_allclose(us["loss_trace"], up["loss_trace"],
                                       rtol=1e-4, atol=1e-6)
    assert set(res_scan.v) == set(res_py.v)
    for p in res_scan.v:
        np.testing.assert_array_equal(np.asarray(res_scan.v[p]) >= 0,
                                      np.asarray(res_py.v[p]) >= 0,
                                      err_msg=f"hardened signs differ at {p}")


def test_unit_cache_reuses_compiled_step(tiny_trained):
    """Identical transformer blocks must share one compiled unit program:
    4 blocks -> 1 trace, 3 cache hits; a re-run traces nothing."""
    cfg, model, params, calib, _, _ = tiny_trained
    calib_loop.clear_cache()
    rc = ReconConfig(w_bits=4, iters=8, calib_bs=4)
    res = quantize(model, params, calib[:2], rc)
    assert res.stats["unit_cache"] == {"hits": 3, "misses": 1}, res.stats
    assert calib_loop.trace_log().count("unit_scan") == 1
    hits = [u["cache_hit"] for u in res.stats["units"]]
    assert hits == [False, True, True, True]
    # identical second run: every unit hits the cache, no new traces
    n_traces = len(calib_loop.trace_log())
    res2 = quantize(model, params, calib[:2], rc)
    assert res2.stats["unit_cache"] == {"hits": 4, "misses": 0}
    assert len(calib_loop.trace_log()) == n_traces


def test_loss_trace_single_fetch(tiny_trained):
    """The whole trajectory comes back as one array per unit."""
    cfg, model, params, calib, _, _ = tiny_trained
    rc = ReconConfig(w_bits=4, iters=12, calib_bs=4)
    res = quantize(model, params, calib[:2], rc)
    for u in res.stats["units"]:
        assert u["loss_trace"].shape == (12,)
        assert np.all(np.isfinite(u["loss_trace"]))
        assert u["calib_iters_per_s"] > 0
    assert res.stats["calib_wall_s"] > 0
    assert res.stats["calib_iters_per_s"] > 0


def test_fisher_weighting_changes_result(tiny_trained):
    cfg, model, params, calib, evalb, _ = tiny_trained
    r1 = quantize(model, params, calib[:2],
                  ReconConfig(w_bits=2, iters=25, use_fisher=True, seed=3))
    r2 = quantize(model, params, calib[:2],
                  ReconConfig(w_bits=2, iters=25, use_fisher=False, seed=3))
    d1 = jax.tree.leaves(r1.params_q)
    d2 = jax.tree.leaves(r2.params_q)
    diff = sum(float(jnp.sum(jnp.abs(a - b))) for a, b in zip(d1, d2))
    assert diff > 0, "Fisher weighting had no effect"
