"""Baseline PTQ methods (paper comparison set) on the tiny trained LM."""
import numpy as np

from repro.core.baselines import (quantize_adaquant, quantize_bias_correction,
                                  quantize_lapq, quantize_rtn)
from repro.core.evaluate import evaluate


def test_rtn_bits_ordering(tiny_trained):
    cfg, model, params, calib, evalb, _ = tiny_trained
    losses = {}
    for bits in (8, 4, 2):
        pq, _ = quantize_rtn(model, params, calib, w_bits=bits)
        losses[bits] = evaluate(model, pq, evalb)["loss"]
    fp = evaluate(model, params, evalb)["loss"]
    assert losses[8] <= fp + 0.02
    assert losses[8] <= losses[4] + 1e-3 <= losses[2] + 1e-2, losses


def test_bias_correction_runs_and_helps(tiny_trained):
    cfg, model, params, calib, evalb, _ = tiny_trained
    pq_rtn, _ = quantize_rtn(model, params, calib, w_bits=3, scale_method="minmax")
    rtn = evaluate(model, pq_rtn, evalb)["loss"]
    pq_bc, _ = quantize_bias_correction(model, params, calib, w_bits=3)
    bc = evaluate(model, pq_bc, evalb)["loss"]
    assert np.isfinite(bc)
    # bias correction should not be much worse than plain RTN
    assert bc <= rtn + 0.1, (rtn, bc)


def test_adaquant_runs(tiny_trained):
    cfg, model, params, calib, evalb, _ = tiny_trained
    pq, _ = quantize_adaquant(model, params, calib[:3], w_bits=4, iters=20)
    q = evaluate(model, pq, evalb)["loss"]
    fp = evaluate(model, params, evalb)["loss"]
    assert np.isfinite(q) and q <= fp + 0.3


def test_lapq_runs(tiny_trained):
    cfg, model, params, calib, evalb, _ = tiny_trained
    pq, _ = quantize_lapq(model, params, calib[:2], w_bits=4,
                          ratios=(0.7, 0.85, 1.0))
    q = evaluate(model, pq, evalb)["loss"]
    pq_mm, _ = quantize_rtn(model, params, calib, w_bits=4, scale_method="minmax")
    mm = evaluate(model, pq_mm, evalb)["loss"]
    assert np.isfinite(q)
    assert q <= mm + 0.05  # loss-aware search should not lose to minmax
