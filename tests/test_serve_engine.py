"""Scheduler test suite for the continuous-batching serve engine.

Pins the behaviors the multi-stream benchmark relies on: continuous
batching must be *invisible* to any single request (staggered admission
produces exactly the tokens sequential batch-1 serving produces — exact
for fp KV pools, greedy-argmax-identical with a pinned logit tolerance
for int8), slots are reused across requests, chunked prefill interleaves
with decode instead of stalling it, and every KV page is returned to the
pool when a request finishes.
"""
import numpy as np
import pytest

from repro.serve_engine import EngineConfig, ServeEngine

# small enough to keep compiles cheap, big enough to exercise paging:
# 2-page prompts, multi-chunk prefill, ragged tails
ECFG = dict(num_slots=3, page_size=4, num_pages=49, max_len=32,
            prefill_chunk=8, backend="xla", record_logits=True)

PROMPT_LENS = (5, 13, 9, 17, 6)
MAX_NEW = (6, 3, 9, 4, 5)
ARRIVALS = (0, 0, 2, 5, 9)


def _prompts(vocab, seed=11):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, vocab, size=n).astype(np.int32)
            for n in PROMPT_LENS]


def _run_staggered(model, params, kv_dtype, *, quant=None, cancel_uid=None,
                   cancel_at_len=2):
    """All requests in flight together, admitted on their arrival ticks."""
    from repro.models.common import NO_QUANT

    eng = ServeEngine(model, params, EngineConfig(kv_dtype=kv_dtype, **ECFG),
                      quant=quant or NO_QUANT)
    prompts = _prompts(model.cfg.vocab)
    nxt, slots_seen = 0, {}
    while nxt < len(prompts) or eng.pending():
        while nxt < len(prompts) and ARRIVALS[nxt] <= eng.tick:
            eng.submit(prompts[nxt], MAX_NEW[nxt], uid=nxt)
            nxt += 1
        eng.step()
        for s, req in enumerate(eng.slot_req):
            if req is not None:
                slots_seen.setdefault(req.uid, s)
        if (cancel_uid is not None and cancel_uid in eng.requests
                and len(eng.requests[cancel_uid].generated) >= cancel_at_len
                and eng.requests[cancel_uid].state == "decode"):
            eng.cancel(cancel_uid)
            cancel_uid = None
    return eng, slots_seen


def _run_sequential(model, params, kv_dtype, *, quant=None):
    """Same engine config, one request at a time: batch-1 serving."""
    from repro.models.common import NO_QUANT

    eng = ServeEngine(model, params, EngineConfig(kv_dtype=kv_dtype, **ECFG),
                      quant=quant or NO_QUANT)
    for uid, prompt in enumerate(_prompts(model.cfg.vocab)):
        eng.submit(prompt, MAX_NEW[uid], uid=uid)
        eng.run()
    return eng


def _tokens(eng):
    return {uid: list(req.generated) for uid, req in eng.requests.items()}


def test_continuous_matches_sequential_fp(tiny_trained):
    """fp KV: staggered continuous batching is EXACTLY sequential batch-1."""
    _, model, params, _, _, _ = tiny_trained
    stag, _ = _run_staggered(model, params, "float32")
    seq = _run_sequential(model, params, "float32")
    assert _tokens(stag) == _tokens(seq)
    for uid, req in stag.requests.items():
        assert req.state == "done" and len(req.generated) == MAX_NEW[uid]
    # exact: the two schedules run the same compiled programs over the
    # same per-stream rows, so even the logits are bit-identical
    for uid in stag.requests:
        np.testing.assert_array_equal(
            np.stack(stag.requests[uid].logits),
            np.stack(seq.requests[uid].logits))
    stag.assert_no_leaks()
    seq.assert_no_leaks()


def test_continuous_matches_vanilla_decode_fp(tiny_trained):
    """Engine fp serving argmax-matches the plain prefill+decode_step path
    (different attention grouping at prefill, so logits only agree to a
    tolerance — greedy tokens must agree exactly)."""
    import jax
    import jax.numpy as jnp

    _, model, params, _, _, _ = tiny_trained
    eng, _ = _run_staggered(model, params, "float32")
    for uid, prompt in enumerate(_prompts(model.cfg.vocab)):
        cache = model.init_cache(1, ECFG["max_len"], jnp.float32)
        logits, cache = model.prefill(params, {"tokens": jnp.asarray(prompt[None])}, cache)
        toks = [int(jnp.argmax(logits, -1)[0])]
        ref_logits = [np.asarray(logits[0])]
        pos = jnp.full((1,), len(prompt), jnp.int32)
        for _ in range(MAX_NEW[uid] - 1):
            logits, cache = model.decode_step(
                params, jnp.asarray([[toks[-1]]], jnp.int32), cache, pos)
            toks.append(int(jnp.argmax(logits, -1)[0]))
            ref_logits.append(np.asarray(logits[0]))
            pos = pos + 1
        assert eng.requests[uid].generated == toks, uid
        np.testing.assert_allclose(np.stack(eng.requests[uid].logits),
                                   np.stack(ref_logits), atol=1e-4)


def test_continuous_matches_sequential_int8(tiny_trained):
    """int8 KV: scheduling is still invisible (staggered == sequential,
    exact), and the int8 path tracks the fp reference within a pinned
    logit tolerance with identical greedy tokens."""
    _, model, params, _, _, _ = tiny_trained
    stag, _ = _run_staggered(model, params, "int8")
    seq = _run_sequential(model, params, "int8")
    assert _tokens(stag) == _tokens(seq)
    for uid in stag.requests:
        np.testing.assert_array_equal(
            np.stack(stag.requests[uid].logits),
            np.stack(seq.requests[uid].logits))
    # int8 vs fp reference mode: pinned tolerance + greedy-argmax-identical
    fp, _ = _run_staggered(model, params, "float32")
    assert _tokens(stag) == _tokens(fp)
    for uid in stag.requests:
        np.testing.assert_allclose(np.stack(stag.requests[uid].logits),
                                   np.stack(fp.requests[uid].logits),
                                   atol=0.5)
    stag.assert_no_leaks()


def test_slot_reuse(tiny_trained):
    """5 requests over 3 slots: some slot hosts at least two requests."""
    _, model, params, _, _, _ = tiny_trained
    eng, slots_seen = _run_staggered(model, params, "int8")
    assert all(r.state == "done" for r in eng.requests.values())
    by_slot: dict = {}
    for uid, s in slots_seen.items():
        by_slot.setdefault(s, []).append(uid)
    assert any(len(uids) >= 2 for uids in by_slot.values()), by_slot
    eng.assert_no_leaks()


def test_chunked_prefill_interleaves_decode(tiny_trained):
    """A long prompt prefills in chunks WHILE other streams decode: a
    decode step runs on a tick strictly between two of its chunks."""
    _, model, params, _, _, _ = tiny_trained
    eng, _ = _run_staggered(model, params, "int8")
    # uid 3: prompt 17 over chunk 8 -> 3 prefill_chunk events
    chunk_ticks = [t for t, ev, uid in eng.events
                   if ev == "prefill_chunk" and uid == 3]
    assert len(chunk_ticks) == 3
    assert chunk_ticks[0] < chunk_ticks[-1], "chunks all ran in one tick"
    between = [t for t in eng.decode_tick_log
               if chunk_ticks[0] <= t < chunk_ticks[-1]]
    assert between, (
        f"no decode step between prefill chunks {chunk_ticks} "
        f"(decode ticks: {eng.decode_tick_log})")


def test_no_page_leak_and_refcounts(tiny_trained):
    """Pool pristine after completion; pages were actually used."""
    _, model, params, _, _, _ = tiny_trained
    eng, _ = _run_staggered(model, params, "int8")
    assert eng.pool.pages_in_use == 0
    assert eng.pool.reserved_pages == 0
    assert (eng.block_tables == -1).all()
    assert eng.metrics()["peak_pages_in_use"] > 0
    eng.assert_no_leaks()


def test_admission_waits_for_pages(tiny_trained):
    """A pool too small for all requests at once admits in waves and
    still completes everything (reservation-based admission)."""
    _, model, params, _, _, _ = tiny_trained
    cfg = dict(ECFG)
    cfg["num_pages"] = 13  # 12 usable pages; each request needs <= 8
    eng = ServeEngine(model, params, EngineConfig(kv_dtype="int8", **cfg))
    for uid, prompt in enumerate(_prompts(model.cfg.vocab)):
        eng.submit(prompt, MAX_NEW[uid], uid=uid)
    eng.run(max_ticks=500)
    assert all(r.state == "done" for r in eng.requests.values())
    eng.assert_no_leaks()


def test_rejects_oversized_and_recurrent():
    """Requests beyond max_len are rejected at submit; non-attention
    archs are rejected at engine construction."""
    import jax

    from repro.models import get_model

    _, model = get_model("brecq_lm_100m", reduced=True)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServeEngine(model, params, EngineConfig(kv_dtype="int8", **ECFG))
    with pytest.raises(ValueError, match="max_len"):
        eng.submit(np.zeros(30, np.int32), 10)
    _, xl = get_model("xlstm_350m", reduced=True)
    xp = xl.init(jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="attention-only"):
        ServeEngine(xl, xp, EngineConfig(kv_dtype="int8", **ECFG))
