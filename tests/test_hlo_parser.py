"""analysis/hlo.py regex-parser coverage: synthetic HLO snippets (tuple
shapes, nested whiles, ROOT ops, collectives) + real lowered modules.

The audit's program rules (``no_host_transfer``, ``donation_respected``)
ride on this parser, so its grammar is pinned here rather than implied
by the end-to-end analysis tests.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.analysis.hlo import (_OP_RE, _shape_bytes, _split_operands,
                                analyze_computation, analyze_module,
                                split_computations, trip_count)

# ---------------------------------------------------------------------------
# op-line grammar
# ---------------------------------------------------------------------------

OP_LINES = [
    ("%add.1 = f32[8,16]{1,0} add(%p.0, %p.1)", "add.1", "add",
     ["%p.0", "%p.1"]),
    ("ROOT %tuple.5 = (f32[8]{0}, s32[]) tuple(%a, %b)", "tuple.5",
     "tuple", ["%a", "%b"]),
    ("d = f32[4,4]{1,0} dot(x, y), lhs_contracting_dims={1}, "
     "rhs_contracting_dims={0}", "d", "dot", ["x", "y"]),
    ("%ag = f32[32]{0} all-gather(%sh), replica_groups={{0,1}}", "ag",
     "all-gather", ["%sh"]),
    ("%w = (s32[], f32[2,3]{1,0}) while(%init), condition=%cond.2, "
     "body=%body.3", "w", "while", ["%init"]),
    ("%if.0 = f32[] infeed(%tok)", "if.0", "infeed", ["%tok"]),
]


@pytest.mark.parametrize("line,name,op,operands", OP_LINES,
                         ids=[l[2] for l in OP_LINES])
def test_op_re_grammar(line, name, op, operands):
    m = _OP_RE.match(line)
    assert m, line
    assert m.group(1) == name
    assert m.group(3) == op
    assert _split_operands(m.group(4)) == operands


def test_split_operands_nested():
    # commas inside brackets/braces do not split; shape prefixes drop
    assert _split_operands("%a, f32[2,3]{1,0} %b") == ["%a", "%b"]
    assert _split_operands("(f32[4]{0}, s32[]) %t, %u") == ["%t", "%u"]


def test_shape_bytes_tuple():
    # tuple shapes sum element buffers; unknown dtypes are skipped
    assert _shape_bytes("(f32[8]{0}, s8[16]{0})") == 8 * 4 + 16
    assert _shape_bytes("token[]") == 0


# ---------------------------------------------------------------------------
# module splitting / while trip counts
# ---------------------------------------------------------------------------

NESTED_WHILE_HLO = """
HloModule nested

%inner_cond (arg.0: (s32[], f32[4])) -> pred[] {
  %arg.0 = (s32[], f32[4]{0}) parameter(0)
  %i = s32[] get-tuple-element(%arg.0), index=0
  %n = s32[] constant(5)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

%inner_body (arg.1: (s32[], f32[4])) -> (s32[], f32[4]) {
  %arg.1 = (s32[], f32[4]{0}) parameter(0)
  %x = f32[4]{0} get-tuple-element(%arg.1), index=1
  %d = f32[4]{0} dot(%x, %x), lhs_contracting_dims={}, rhs_contracting_dims={}
  ROOT %t = (s32[], f32[4]{0}) tuple(%i2, %d)
}

%outer_cond (arg.2: (s32[], f32[4])) -> pred[] {
  %arg.2 = (s32[], f32[4]{0}) parameter(0)
  %j = s32[] get-tuple-element(%arg.2), index=0
  %m = s32[] constant(3)
  ROOT %lt2 = pred[] compare(%j, %m), direction=LT
}

%outer_body (arg.3: (s32[], f32[4])) -> (s32[], f32[4]) {
  %arg.3 = (s32[], f32[4]{0}) parameter(0)
  ROOT %w.in = (s32[], f32[4]{0}) while(%arg.3), condition=%inner_cond, body=%inner_body
}

ENTRY %main (p.0: (s32[], f32[4])) -> (s32[], f32[4]) {
  %p.0 = (s32[], f32[4]{0}) parameter(0)
  ROOT %w.out = (s32[], f32[4]{0}) while(%p.0), condition=%outer_cond, body=%outer_body
}
"""


def test_split_computations_and_entry():
    comps, entry = split_computations(NESTED_WHILE_HLO)
    assert entry == "main"
    assert set(comps) == {"inner_cond", "inner_body", "outer_cond",
                          "outer_body", "main"}
    # header and closing-brace lines are excluded, op lines kept
    assert all("parameter" in ln or "=" in ln
               for lines in comps.values() for ln in lines)


def test_trip_count_from_condition():
    comps, _ = split_computations(NESTED_WHILE_HLO)
    assert trip_count(comps["inner_cond"]) == 5
    assert trip_count(comps["outer_cond"]) == 3
    assert trip_count(["no constants here"]) == 1


def test_nested_while_multiplicity():
    """The inner dot is counted 3 x 5 times: nested whiles multiply."""
    s = analyze_module(NESTED_WHILE_HLO)
    inner = analyze_computation(
        split_computations(NESTED_WHILE_HLO)[0]["inner_body"])
    assert inner.dot_flops > 0
    assert s.flops == pytest.approx(15 * inner.dot_flops)


COLLECTIVE_HLO = """
HloModule coll

ENTRY %main (p.0: f32[16]) -> f32[32] {
  %p.0 = f32[16]{0} parameter(0)
  %ar = f32[16]{0} all-reduce(%p.0), to_apply=%sum
  ROOT %ag = f32[32]{0} all-gather(%ar), dimensions={0}
}
"""


def test_collectives_counted_with_bytes():
    s = analyze_module(COLLECTIVE_HLO)
    assert s.collective_counts == {"all-reduce": 1, "all-gather": 1}
    # both collectives move the 16-float operand (64 bytes each)
    assert s.collective_bytes == pytest.approx(128)


def test_while_scan_trip_count_real_module():
    """A real jax.lax.scan lowers to a while whose trip count the parser
    must recover: per-iteration dot FLOPs x n_steps."""
    n, d = 7, 8

    def f(x, w):
        def body(c, _):
            return c @ w, ()
        y, _ = jax.lax.scan(body, x, None, length=n)
        return y

    x = jnp.ones((d, d), jnp.float32)
    hlo = jax.jit(f).lower(x, x).compile().as_text()
    s = analyze_module(hlo)
    assert s.flops == pytest.approx(n * 2 * d * d * d)


def test_root_and_comment_stripping():
    comps, _ = split_computations(
        "ENTRY %e (p: f32[2]) -> f32[2] {\n"
        "  %p = f32[2]{0} parameter(0)\n"
        "  ROOT %r = f32[2]{0} add(%p /*index=0*/, %p)\n"
        "}\n")
    (line,) = [ln for ln in comps["e"] if "add" in ln]
    assert "/*" not in line
    m = _OP_RE.match(line)
    assert m and m.group(1) == "r" and m.group(3) == "add"
