"""Synthetic corpus: determinism, host sharding, learnable structure."""
import numpy as np

from repro.data import Corpus, CorpusConfig, make_batches


def test_deterministic():
    c = Corpus(CorpusConfig(vocab=512))
    a = c.sample(4, 64, seed=1, host=0, step=5)
    b = c.sample(4, 64, seed=1, host=0, step=5)
    np.testing.assert_array_equal(a, b)


def test_host_and_step_shards_differ():
    c = Corpus(CorpusConfig(vocab=512))
    a = c.sample(4, 64, seed=1, host=0, step=5)
    b = c.sample(4, 64, seed=1, host=1, step=5)
    d = c.sample(4, 64, seed=1, host=0, step=6)
    assert not np.array_equal(a, b)
    assert not np.array_equal(a, d)


def test_markov_structure_learnable():
    """Bigram predictability: the true successor set is small, so the
    empirical conditional entropy is far below uniform."""
    cfg = CorpusConfig(vocab=256, branching=8)
    c = Corpus(cfg)
    toks = c.sample(8, 512, seed=0)
    pairs = {}
    for row in toks:
        for a, b in zip(row[:-1], row[1:]):
            pairs.setdefault(int(a), set()).add(int(b))
    avg_successors = np.mean([len(v) for v in pairs.values()])
    assert avg_successors < cfg.branching * 2.5  # far below vocab=256


def test_make_batches_shapes():
    c = Corpus(CorpusConfig(vocab=128))
    bs = make_batches(c, 3, 4, 16, seed=0)
    assert len(bs) == 3
    assert bs[0]["tokens"].shape == (4, 16)
    assert int(bs[0]["tokens"].max()) < 128
