"""Budgeted mixed-precision deployment: solver optimality, cost tables,
group reduction, artifact packing, and the serve CLI budget flow.

The load-bearing claim is solver *exactness*: `solve_budget` must match
full enumeration on every problem it accepts, never exceed the budget,
and never lose to the genetic search on the same (group-reduced)
problem. Seeded random problems exercise that always; a hypothesis
variant widens the net when the optional dep is installed.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.mixed_precision import GAConfig, fitness, genetic_search
from repro.core.sensitivity import SensTable
from repro.deploy.budget import (BudgetInfeasibleError, CostTable,
                                 brute_force, budget_artifact,
                                 bytes_cost_table, ensure_cost_table,
                                 grouped_problem, install_dispatch,
                                 measure_cost_table, rtn_mixed_artifact,
                                 solve_budget, storage_groups,
                                 weight_sens_table, weight_shapes)

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # optional dep: keep the seeded fuzz, skip the rest
    HAVE_HYPOTHESIS = False

BITS = (2, 4, 8)


# ---------------------------------------------------------------------------
# random solver problems (shared by the seeded fuzz and hypothesis)
# ---------------------------------------------------------------------------


def _random_problem(rng, n_max=6):
    n = int(rng.integers(2, n_max + 1))
    paths = [f"l{i}" for i in range(n)]
    block_of = {p: int(rng.integers(0, 2)) for p in paths}
    diag = {}
    for p in paths:
        vals = sorted(rng.uniform(0.0, 10.0, len(BITS)), reverse=True)
        for b, v in zip(BITS, vals):  # loss decreasing in bits
            diag[(p, b)] = float(v)
    offdiag = {}
    for i in range(n):
        for j in range(i + 1, n):
            if block_of[paths[i]] == block_of[paths[j]] and rng.random() < 0.4:
                offdiag[(paths[i], paths[j])] = float(rng.uniform(-1.0, 2.0))
    sens = SensTable(diag=diag, offdiag=offdiag, block_of=block_of,
                     shapes={p: (8, 8) for p in paths})
    costs = {(p, b): float(rng.uniform(0.1, 1.0)) * b
             for p in paths for b in BITS}
    table = CostTable(kind="bytes", backend="test", costs=costs)
    groups = None
    if rng.random() < 0.5:  # tie a random subset into two groups
        groups = {p: f"g{int(rng.integers(0, 2))}" if rng.random() < 0.6
                  else p for p in paths}
    lo = sum(min(table.cost(p, b) for b in BITS) for p in paths)
    hi = sum(max(table.cost(p, b) for b in BITS) for p in paths)
    budget = float(lo + rng.uniform(0.0, 1.0) * (hi - lo))
    return sens, table, groups, budget


def _check_solver_invariants(sens, table, groups, budget):
    try:
        sol = solve_budget(sens, table, budget, groups=groups)
    except BudgetInfeasibleError:
        # the random budget fell below the *grouped* floor (ties can
        # raise the cheapest feasible cost) — enumeration must agree
        with pytest.raises(BudgetInfeasibleError):
            brute_force(sens, table, budget, groups=groups)
        return
    assert sol.cost <= budget + 1e-9
    assert sol.predicted_loss == pytest.approx(fitness(sens, sol.assign))
    # groups respected: tied paths carry identical bits
    if groups:
        by_g = {}
        for p, b in sol.assign.items():
            by_g.setdefault(groups.get(p, p), set()).add(b)
        assert all(len(s) == 1 for s in by_g.values())
    # exactness: full enumeration finds nothing better
    bf = brute_force(sens, table, budget, groups=groups)
    assert sol.predicted_loss == pytest.approx(bf.predicted_loss, abs=1e-9)
    # GA on the identical (group-reduced) problem never wins
    if groups:
        gsens, gtable, expand = grouped_problem(sens, table, groups)
    else:
        gsens, gtable, expand = sens, table, lambda a: dict(a)
    assign, info = genetic_search(gsens, gtable, budget,
                                  GAConfig(pop_size=16, iters=10, seed=0))
    assert info["fitness"] >= sol.predicted_loss - 1e-9
    assert fitness(sens, expand(assign)) == pytest.approx(info["fitness"])
    # lagrange approximation: feasible, never better than exact
    lag = solve_budget(sens, table, budget, groups=groups, method="lagrange")
    assert lag.cost <= budget + 1e-9
    assert lag.predicted_loss >= sol.predicted_loss - 1e-9


@pytest.mark.parametrize("seed", range(25))
def test_solver_invariants_seeded(seed):
    rng = np.random.default_rng(seed)
    _check_solver_invariants(*_random_problem(rng))


if HAVE_HYPOTHESIS:

    @given(seed=st.integers(0, 2**31 - 1))
    @settings(max_examples=50, deadline=None)
    def test_solver_invariants_hypothesis(seed):
        rng = np.random.default_rng(seed)
        _check_solver_invariants(*_random_problem(rng))


def test_infeasible_budget_raises():
    rng = np.random.default_rng(0)
    sens, table, groups, _ = _random_problem(rng)
    floor = sum(min(table.cost(p, b) for b in BITS) for p in sens.shapes)
    with pytest.raises(BudgetInfeasibleError):
        solve_budget(sens, table, floor * 0.5, groups=groups)


def test_solver_prefers_interactions():
    """Two coupled 2-bit layers must pay the offdiag term — with a large
    positive interaction the solver splits them even when the diagonal
    alone says all-2 is optimal."""
    paths = ["a", "b"]
    diag = {(p, b): {2: 1.0, 4: 1.1, 8: 1.2}[b] for p in paths for b in BITS}
    sens = SensTable(diag=diag, offdiag={("a", "b"): 50.0},
                     block_of={p: 0 for p in paths},
                     shapes={p: (4, 4) for p in paths})
    table = CostTable(kind="bytes", backend="test",
                      costs={(p, b): float(b) for p in paths for b in BITS})
    sol = solve_budget(sens, table, budget=6.0)
    assert sorted(sol.assign.values()) == [2, 4]
    assert sol.predicted_loss == pytest.approx(2.1)


# ---------------------------------------------------------------------------
# group reduction
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", range(10))
def test_grouped_problem_preserves_fitness_and_cost(seed):
    rng = np.random.default_rng(100 + seed)
    sens, table, _, _ = _random_problem(rng)
    paths = sorted(sens.shapes)
    groups = {p: f"g{i % 2}" for i, p in enumerate(paths)}
    gsens, gtable, expand = grouped_problem(sens, table, groups)
    assert gtable.kind == table.kind
    for _ in range(10):
        gassign = {g: int(rng.choice(BITS)) for g in gsens.shapes}
        full = expand(gassign)
        assert fitness(gsens, gassign) == pytest.approx(fitness(sens, full))
        assert gtable.assign_cost(gassign) == pytest.approx(
            table.assign_cost(full))


# ---------------------------------------------------------------------------
# sensitivity + cost table serialization
# ---------------------------------------------------------------------------


def test_senstable_json_roundtrip(tmp_path):
    rng = np.random.default_rng(7)
    sens, _, _, _ = _random_problem(rng)
    path = tmp_path / "sens.json"
    sens.save(path)
    back = SensTable.load(path)
    assert back.diag == sens.diag
    assert back.offdiag == sens.offdiag
    assert back.block_of == sens.block_of
    assert {p: tuple(s) for p, s in back.shapes.items()} == \
        {p: tuple(s) for p, s in sens.shapes.items()}


def test_costtable_json_roundtrip(tmp_path):
    table = CostTable(kind="decode_ms", backend="cpu",
                      costs={("a", 2): 0.5, ("a", 8): 0.25},
                      tiers={("a", 2): "decode"},
                      dispatch={"64,128,2": "prefill"},
                      meta={"m": 1})
    path = tmp_path / "cost.json"
    table.save(path)
    back = CostTable.load(path)
    assert back == table
    # and it survives a json.dumps embed (manifest caching path)
    assert CostTable.from_json(json.loads(json.dumps(table.to_json()))) == table


def test_bytes_cost_table_container_aware():
    """2-bit on a K that 4 does not divide ships in an int8 container —
    the bytes table must charge container bits, not nominal bits."""
    table = bytes_cost_table({"even": (64, 16), "ragged": (6, 16)})
    assert table.cost("even", 2) == 64 * 16 * 2 / 8
    assert table.cost("ragged", 2) == 6 * 16 * 8 / 8  # promoted to int8
    assert table.cost("even", 8) == 64 * 16
    # stacked experts multiply through the lead dims
    t3 = bytes_cost_table({"moe": (4, 64, 16)})
    assert t3.cost("moe", 4) == 4 * 64 * 16 * 4 / 8


# ---------------------------------------------------------------------------
# measured cost table + dispatch install
# ---------------------------------------------------------------------------


def test_measured_cost_table_and_dispatch(monkeypatch):
    import repro.kernels.qmatmul.ops as qmm_ops

    monkeypatch.delenv("REPRO_QMM_DISPATCH", raising=False)
    shapes = {"a": (64, 32), "b": (64, 32), "c": (2, 32, 16)}
    table = measure_cost_table(shapes, m=1, inner=2, reps=1)
    for p in shapes:
        for b in BITS:
            assert table.cost(p, b) > 0
    # identical (shape, container) rows share one measurement
    assert table.cost("a", 4) == table.cost("b", 4)
    # grouped stacks time the grouped tier only
    assert table.tiers[("c", 4)] == "grouped"
    assert table.meta["m"] == 1
    # dispatch winners install onto the qmm tier predicate
    try:
        install_dispatch(table)
        assert qmm_ops._DISPATCH_TABLE  # parsed "k,n,cbits" keys
        assert all(isinstance(k, tuple) and len(k) == 3
                   for k in qmm_ops._DISPATCH_TABLE)
        assert qmm_ops.dispatch_mode() == "measured"
    finally:
        qmm_ops.set_dispatch_table(None)


# ---------------------------------------------------------------------------
# artifact packing: proxy sensitivity, promotion, budget e2e
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def reduced_lm():
    from repro.models import get_model

    cfg, model = get_model("brecq_lm_100m", reduced=True)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def test_weight_sens_table_proxy(reduced_lm):
    cfg, model, params = reduced_lm
    sens = weight_sens_table(params, cfg.n_layers)
    shapes = weight_shapes(params, cfg.n_layers)
    assert set(sens.shapes) == set(shapes)
    assert len(shapes) % cfg.n_layers == 0 and len(shapes) > 0
    for p in sens.shapes:
        # RTN error shrinks with bits
        assert sens.diag[(p, 2)] > sens.diag[(p, 4)] > sens.diag[(p, 8)] >= 0
    # storage groups tie exactly the per-layer copies of each stack
    groups = storage_groups(sens.shapes)
    sizes = {}
    for g in groups.values():
        sizes[g] = sizes.get(g, 0) + 1
    assert set(sizes.values()) == {cfg.n_layers}


def test_rtn_mixed_artifact_promotion_and_manifest(reduced_lm, tmp_path):
    """Mixed bits inside one stack ship in the widest member's container;
    the manifest still records true per-layer widths and the histogram
    matches them after a save/load round trip."""
    from repro.deploy import QuantizedArtifact

    cfg, model, params = reduced_lm
    shapes = weight_shapes(params, cfg.n_layers)
    assign = {p: 2 for p in shapes}
    stack = sorted({p for p in shapes if "/attn/wq" in p})
    assign[stack[0]] = 8  # one 8-bit layer promotes the whole wq stack
    art = rtn_mixed_artifact(params, assign, cfg=cfg)
    man = art.manifest
    # every assigned layer recorded at its true width; embed stays pinned
    assert {p: man["bits_by_path"][p] for p in shapes} == assign
    assert man["bits_by_path"]["embed/table"] == 8
    hist = art.stats["bits_histogram"]
    assert hist["8"] >= 1 and hist["2"] == sum(
        1 for b in assign.values() if b == 2)
    # promoted container: the wq stack is int8-wide but a 2-bit-only
    # stack still packs sub-byte
    art2 = rtn_mixed_artifact(params, {p: 2 for p in shapes}, cfg=cfg)
    assert art.nbytes() > art2.nbytes()
    art.save(str(tmp_path / "mixed"))
    back = QuantizedArtifact.load(str(tmp_path / "mixed"))
    assert back.manifest["bits_by_path"] == man["bits_by_path"]
    assert back.stats["bits_histogram"] == hist
    for a, b in zip(jax.tree.leaves(art.params), jax.tree.leaves(back.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_budget_artifact_bytes_end_to_end(reduced_lm, tmp_path):
    """budget_artifact: artifact bytes <= budget exactly, the manifest
    records the solve, and the packed model still decodes."""
    cfg, model, params = reduced_lm
    sens = weight_sens_table(params, cfg.n_layers)
    lo = rtn_mixed_artifact(params, {p: 2 for p in sens.shapes},
                            cfg=cfg).nbytes()
    hi = rtn_mixed_artifact(params, {p: 8 for p in sens.shapes},
                            cfg=cfg).nbytes()
    budget = (lo + hi) // 2
    art, sol, table = budget_artifact(params, sens, budget, kind="bytes",
                                      cfg=cfg)
    assert art.nbytes() <= budget
    assert table.kind == "bytes"
    man = art.manifest["budget"]
    assert man["budget"] == budget and man["artifact_bytes"] == art.nbytes()
    assert man["kind"] == "bytes" and man["bits_histogram"]
    assert man["artifact_bytes"] - man["overhead_bytes"] == pytest.approx(
        sol.cost)
    # tighter budget than the 2-bit floor is infeasible with the
    # fixed overhead spelled out
    with pytest.raises(BudgetInfeasibleError, match="fixed bytes"):
        budget_artifact(params, sens, lo // 2, kind="bytes", cfg=cfg)
    # the artifact decodes
    toks = jnp.asarray(np.random.default_rng(0).integers(
        0, cfg.vocab, (2, 8)))
    logits, _ = model.prefill(art.params, {"tokens": toks},
                              model.init_cache(2, 16, jnp.float32),
                              art.hook(), remat="none")
    assert np.isfinite(np.asarray(logits)).all()


def test_ensure_cost_table_caches_in_manifest(reduced_lm):
    cfg, model, params = reduced_lm
    shapes = dict(list(weight_shapes(params, cfg.n_layers).items())[:2])
    art = rtn_mixed_artifact(params, {p: 4 for p in
                                      weight_shapes(params, cfg.n_layers)},
                             cfg=cfg)
    t1 = ensure_cost_table(art, shapes, m=1, inner=2, reps=1)
    backend = jax.default_backend()
    assert art.manifest["cost_tables"][backend]["meta"]["m"] == 1
    t2 = ensure_cost_table(art, shapes, m=1, inner=2, reps=1)
    assert t2 == t1  # served from the manifest cache, not re-measured
    # different decode rows invalidate the cache
    t3 = ensure_cost_table(art, shapes, m=4, inner=2, reps=1)
    assert t3.meta["m"] == 4


def test_serve_cli_budget_flow(tmp_path):
    """serve --budget-bytes B ships an artifact with nbytes <= B and a
    manifest that records the solve."""
    from repro.deploy import QuantizedArtifact
    from repro.launch import serve
    from repro.models import get_model

    cfg, model = get_model("brecq_lm_100m", reduced=True)
    params = model.init(jax.random.PRNGKey(0))
    sens = weight_sens_table(params, cfg.n_layers)
    lo = rtn_mixed_artifact(params, {p: 2 for p in sens.shapes},
                            cfg=cfg).nbytes()
    hi = rtn_mixed_artifact(params, {p: 8 for p in sens.shapes},
                            cfg=cfg).nbytes()
    budget = (lo + hi) // 2
    gen = serve.main(["--reduced", "--budget-bytes", str(budget),
                      "--batch", "2", "--prompt-len", "8", "--gen-len", "2",
                      "--save-artifact", str(tmp_path / "art")])
    assert gen.shape == (2, 2)
    art = QuantizedArtifact.load(str(tmp_path / "art"))
    assert art.nbytes() <= budget
    assert art.manifest["budget"]["kind"] == "bytes"
    assert art.manifest["budget"]["artifact_bytes"] == art.nbytes()
