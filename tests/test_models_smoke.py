"""Per-arch reduced-config smoke tests (deliverable f).

Each assigned architecture instantiates its reduced family config and
runs one forward / train-grad / prefill / decode step on CPU, asserting
output shapes and no NaNs. The FULL configs are exercised only by the
dry-run (ShapeDtypeStruct, no allocation).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import ARCH_IDS, get_model


def batch_for(cfg, B=2, S=32, seed=0):
    rng = np.random.default_rng(seed)
    b = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)))}
    if cfg.family == "vlm":
        b["patches"] = jnp.asarray(
            rng.normal(size=(B, cfg.n_patches, cfg.d_model)), jnp.float32)
    if cfg.enc_dec:
        b["frames"] = jnp.asarray(
            rng.normal(size=(B, S, cfg.d_model)), jnp.float32)
    return b


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_and_grad(arch):
    cfg, model = get_model(arch, reduced=True)
    params = model.init(jax.random.PRNGKey(0))
    batch = batch_for(cfg)
    logits, aux = model.forward(params, batch, remat="none")
    assert logits.shape == (2, 32, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits))), f"{arch}: non-finite logits"
    loss = model.loss(params, batch, remat="dots")
    assert jnp.isfinite(loss)
    g = jax.grad(lambda p: model.loss(p, batch, remat="dots"))(params)
    gn = sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(g))
    assert bool(jnp.isfinite(gn)) and float(gn) > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode(arch):
    cfg, model = get_model(arch, reduced=True)
    params = model.init(jax.random.PRNGKey(0))
    batch = batch_for(cfg)
    cache = model.init_cache(2, 64, jnp.float32)
    lp, cache = model.prefill(params, batch, cache, remat="none")
    assert lp.shape == (2, cfg.vocab) and bool(jnp.all(jnp.isfinite(lp)))
    tok = jnp.argmax(lp, -1)[:, None]
    ld, cache = model.decode_step(params, tok, cache, jnp.full((2,), 32))
    assert ld.shape == (2, cfg.vocab) and bool(jnp.all(jnp.isfinite(ld)))


@pytest.mark.parametrize("arch", ["tinyllama_1_1b", "xlstm_350m", "hymba_1_5b",
                                  "gemma3_12b", "h2o_danube3_4b"])
def test_decode_matches_forward(arch):
    """prefill(S) + decode(S..) must agree with the full forward at the
    same positions (cache-correctness invariant)."""
    cfg, model = get_model(arch, reduced=True)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 24
    full = batch_for(cfg, B=B, S=S)
    logits_full, _ = model.forward(params, full, remat="none")

    prompt = {k: (v[:, : S - 1] if v.ndim == 2 else v) for k, v in full.items()}
    cache = model.init_cache(B, 64, jnp.float32)
    lp, cache = model.prefill(params, prompt, cache, remat="none")
    np.testing.assert_allclose(np.asarray(lp), np.asarray(logits_full[:, S - 2]),
                               rtol=2e-2, atol=2e-2)
    # decode token S-1 and compare with forward position S-1
    tok = full["tokens"][:, S - 1 : S]
    ld, cache = model.decode_step(params, tok, cache, jnp.full((B,), S - 1))
    np.testing.assert_allclose(np.asarray(ld), np.asarray(logits_full[:, S - 1]),
                               rtol=3e-2, atol=3e-2)


def test_moe_dense_capacity_agree():
    from repro.models.common import Ctx
    from repro.models.moe import MoESpec, apply as moe_apply, init as moe_init

    key = jax.random.PRNGKey(0)
    spec_d = MoESpec(32, 64, 4, 2, n_shared=1, impl="dense")
    spec_c = MoESpec(32, 64, 4, 2, n_shared=1, impl="capacity", capacity_factor=4.0)
    p = moe_init(key, spec_d)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32))
    ctx = Ctx(cfg=None, positions=jnp.zeros((2, 16), jnp.int32))
    yd = moe_apply(ctx, p, spec_d, x)
    yc = moe_apply(ctx, p, spec_c, x)
    np.testing.assert_allclose(np.asarray(yd), np.asarray(yc), atol=2e-5)


def test_deploy_quant_tree_w8_close_to_fp():
    from repro import deploy

    cfg, model = get_model("tinyllama_1_1b", reduced=True)
    params = model.init(jax.random.PRNGKey(0))
    batch = batch_for(cfg)
    fp, _ = model.forward(params, batch, remat="none")
    q8 = deploy.quantize_tree(params, 8)
    l8, _ = model.forward(q8, batch, remat="none")
    # int8 weights: logits stay close; int2 diverge more
    assert float(jnp.mean(jnp.abs(fp - l8))) < 0.1
    q2 = deploy.quantize_tree(params, 2)
    l2, _ = model.forward(q2, batch, remat="none")
    assert bool(jnp.all(jnp.isfinite(l2)))
    e8 = float(jnp.mean((fp - l8) ** 2))
    e2 = float(jnp.mean((fp - l2) ** 2))
    assert e8 < e2
