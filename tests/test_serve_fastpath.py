"""Serving hot-path dispatch: qmm tiers, typed packed-node errors, MoE
grouped-expert residency, and the serve timing harness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.deploy.pack as pack_mod
import repro.kernels.qmatmul.ops as qmm_ops
from repro.deploy import rtn_artifact, rtn_pack_leaf
from repro.kernels.qmatmul.ops import (DECODE_M_MAX, PackedNodeError,
                                       from_node, qmm, reset_tier_counts,
                                       select_tier)


def _node(rng, K=64, N=128, bits=4, E=None):
    shape = (K, N) if E is None else (E, K, N)
    w = jnp.asarray(rng.normal(size=shape), jnp.float32)
    wp, qs = rtn_pack_leaf(w, bits, None)
    return {"w": wp, "qscale": qs}


# ---------------------------------------------------------------------------
# tier selection
# ---------------------------------------------------------------------------


def test_select_tier_by_shape(rng):
    qw2 = from_node(_node(rng), 64)
    qw3 = from_node(_node(rng, E=3), 64)
    for m in (1, 2, DECODE_M_MAX):
        assert select_tier(m, qw2) == "decode"
    for m in (DECODE_M_MAX + 1, 128, 4096):
        assert select_tier(m, qw2) == "prefill"
    assert select_tier(1, qw3) == "grouped"
    assert select_tier(512, qw3) == "grouped"


def test_qmm_traces_count_tiers(rng):
    """Each jit trace through qmm bumps exactly its shape's tier."""
    node2, node3 = _node(rng), _node(rng, E=3)
    reset_tier_counts()
    x_dec = jnp.ones((4, 64), jnp.float32)
    x_pre = jnp.ones((32, 64), jnp.float32)
    x_grp = jnp.ones((3, 4, 64), jnp.float32)
    jax.jit(lambda x: qmm(x, from_node(node2, 64)))(x_dec)
    jax.jit(lambda x: qmm(x, from_node(node2, 64)))(x_pre)
    jax.jit(lambda x: qmm(x, from_node(node3, 64)))(x_grp)
    assert qmm_ops.TIER_COUNTS == {"decode": 1, "prefill": 1, "grouped": 1}
    reset_tier_counts()


def test_decode_tier_override(rng, monkeypatch):
    """set_decode_tier(False) / REPRO_QMM_DECODE_TIER=0 force decode
    shapes onto the prefill tier (the gemv specialization loses on some
    backends); grouped dispatch is unaffected."""
    qw = from_node(_node(rng), 64)
    qw3 = from_node(_node(rng, E=3), 64)
    assert select_tier(2, qw) == "decode"
    try:
        qmm_ops.set_decode_tier(False)
        assert not qmm_ops.decode_tier_enabled()
        assert select_tier(2, qw) == "prefill"
        assert select_tier(128, qw) == "prefill"
        assert select_tier(2, qw3) == "grouped"
        reset_tier_counts()
        jax.jit(lambda x: qmm(x, qw))(jnp.ones((2, 64), jnp.float32))
        assert qmm_ops.TIER_COUNTS == {"decode": 0, "prefill": 1, "grouped": 0}
    finally:
        qmm_ops.set_decode_tier(None)
    reset_tier_counts()
    # env path, consulted only while no programmatic override is set
    monkeypatch.setenv("REPRO_QMM_DECODE_TIER", "off")
    assert not qmm_ops.decode_tier_enabled()
    assert select_tier(1, qw) == "prefill"
    monkeypatch.delenv("REPRO_QMM_DECODE_TIER")
    assert qmm_ops.decode_tier_enabled()
    assert select_tier(1, qw) == "decode"


def test_measured_dispatch_table(rng, monkeypatch):
    """An installed measured dispatch table reroutes decode-shaped calls
    per (K, N, container bits); REPRO_QMM_DISPATCH forces either mode,
    uncovered shapes keep the gemv guess, and the decode-tier kill
    switch still beats the table."""
    monkeypatch.delenv("REPRO_QMM_DISPATCH", raising=False)
    qw = from_node(_node(rng), 64)      # K=64, N=128, 4-bit container
    qw3 = from_node(_node(rng, E=3), 64)
    qw_other = from_node(_node(rng, N=32), 64)
    assert qmm_ops.dispatch_mode() == "heuristic"
    try:
        qmm_ops.set_dispatch_table({(64, 128, 4): "prefill"})
        assert qmm_ops.dispatch_mode() == "measured"
        assert select_tier(2, qw) == "prefill"    # measured winner
        assert select_tier(128, qw) == "prefill"  # big-M path unchanged
        assert select_tier(2, qw3) == "grouped"   # 3-D stacks unaffected
        assert select_tier(2, qw_other) == "decode"  # uncovered shape
        # env override: heuristic opts out of an installed table...
        monkeypatch.setenv("REPRO_QMM_DISPATCH", "heuristic")
        assert qmm_ops.dispatch_mode() == "heuristic"
        assert select_tier(2, qw) == "decode"
        # ...and measured re-enables it
        monkeypatch.setenv("REPRO_QMM_DISPATCH", "measured")
        assert select_tier(2, qw) == "prefill"
        monkeypatch.delenv("REPRO_QMM_DISPATCH")
        # the decode-tier kill switch wins over everything
        qmm_ops.set_decode_tier(False)
        assert select_tier(2, qw) == "prefill"
        assert select_tier(2, qw_other) == "prefill"
    finally:
        qmm_ops.set_decode_tier(None)
        qmm_ops.set_dispatch_table(None)
    assert select_tier(2, qw) == "decode"


# ---------------------------------------------------------------------------
# from_node typed errors
# ---------------------------------------------------------------------------


def test_from_node_rejects_bad_rank_with_path(rng):
    node = _node(rng)
    node1d = {"w": node["w"][:, 0], "qscale": node["qscale"][0]}
    with pytest.raises(PackedNodeError, match="body/sub0/attn/wq"):
        from_node(node1d, 64, path="body/sub0/attn/wq")
    node4d = {"w": node["w"][None, None], "qscale": node["qscale"][None, None]}
    with pytest.raises(PackedNodeError, match="2-D .* or .* 3-D"):
        from_node(node4d, 64)


def test_from_node_rejects_rank_mismatch_and_bad_rows(rng):
    node = _node(rng, E=3)
    with pytest.raises(PackedNodeError, match="rank"):
        from_node({"w": node["w"], "qscale": node["qscale"][0]}, 64)
    with pytest.raises(PackedNodeError, match="do not divide"):
        from_node(_node(rng, K=64), 100, path="mlp/w1")


def test_from_node_routes_stacked_to_grouped(rng):
    """A stacked node is a valid view (grouped tier), not a failure."""
    qw = from_node(_node(rng, E=5), 64, path="moe/w_gate")
    assert qw.packed.ndim == 3 and select_tier(8, qw) == "grouped"


def test_grouped_qmm_rejects_low_rank_activations(rng):
    """A stacked node fed rank-2 activations fails typed, not IndexError."""
    qw = from_node(_node(rng, E=5), 64)
    with pytest.raises(PackedNodeError, match="rank-2"):
        qmm(jnp.ones((4, 64), jnp.float32), qw)
    with pytest.raises(PackedNodeError, match="E=2"):  # E-axis mismatch
        qmm(jnp.ones((2, 4, 64), jnp.float32), qw)


# ---------------------------------------------------------------------------
# MoE decode: grouped tier, no transient full dequant
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def moe_packed():
    from repro.models import get_model

    cfg, model = get_model("deepseek_moe_16b", reduced=True)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, rtn_artifact(params, 4, cfg=cfg)


def test_moe_decode_skips_dequant_leaf(moe_packed, monkeypatch):
    """Serving decode must never route expert nodes through the
    transient dequant reference — the grouped qmm tier consumes the
    stacked codes directly."""
    cfg, model, art = moe_packed
    calls = []
    orig = pack_mod.dequant_leaf
    monkeypatch.setattr(pack_mod, "dequant_leaf",
                        lambda *a, **k: (calls.append(a), orig(*a, **k))[1])
    reset_tier_counts()
    cache = model.init_cache(2, 12, jnp.float32)
    tok = jnp.zeros((2, 1), jnp.int32)
    pos = jnp.full((2,), 8, jnp.int32)
    jax.make_jaxpr(lambda p, t, c, q: model.decode_step(p, t, c, q))(
        art.params, tok, cache, pos)
    assert not calls
    assert qmm_ops.TIER_COUNTS["grouped"] > 0
    reset_tier_counts()


def test_moe_decode_residency_no_full_expert_dequant(moe_packed):
    """The decode trace holds no f32 (E, K, N) intermediate: the XLA
    grouped tier scans one expert at a time and the Pallas tier unpacks
    per (expert, tile). Checked through the audit rule engine — the same
    ``no_materialized_f32_weight`` rule CI runs over every serve
    program."""
    from repro.analysis.audit.program_check import forbidden_f32_shapes
    from repro.analysis.audit.rules import AuditProgram, run_program_rules

    cfg, model, art = moe_packed
    E = cfg.moe.n_experts
    d, f = cfg.d_model, cfg.moe.d_ff_expert
    cache = model.init_cache(2, 12, jnp.float32)
    tok = jnp.zeros((2, 1), jnp.int32)
    pos = jnp.full((2,), 8, jnp.int32)
    forbidden = forbidden_f32_shapes(art.params)
    # the shape inference must cover the hand-derived expert shapes
    assert {(E, d, f), (E, f, d)} <= set(forbidden)
    prog = AuditProgram(
        name="moe_decode", fn=lambda p, t, c, q: model.decode_step(p, t, c, q),
        args=(art.params, tok, cache, pos), forbidden_f32=forbidden)
    violations = run_program_rules([prog],
                                   rules=("no_materialized_f32_weight",))
    assert not violations, [str(v) for v in violations]


def test_moe_packed_decode_matches_transient_dequant(moe_packed, rng):
    """Grouped-tier decode logits == the old transient-dequant path
    (numerics unchanged, only residency/scheduling)."""
    cfg, model, art = moe_packed
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (2, 8)))
    cache = model.init_cache(2, 12, jnp.float32)
    logits, cache = model.prefill(art.params, {"tokens": toks}, cache,
                                  remat="none")
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    pos = jnp.full((2,), 8, jnp.int32)
    got, _ = model.decode_step(art.params, tok,
                               jax.tree.map(jnp.copy, cache), pos)

    # reference: dequantize the expert stacks back to plain f32 {"w": ...}
    # (in the full tree they carry a leading scan-layer dim: (n, E, rows, N))
    def walk(node, key=None):
        if (isinstance(node, dict) and "qscale" in node
                and key in ("w_gate", "w_up", "w_down")
                and node["w"].ndim == 4):  # (n, E, rows, N) expert stacks;
            # dense stacks' swiglu MLPs reuse these key names at
            # (n, rows, N) and stay packed on both sides
            k = cfg.moe.d_ff_expert if key == "w_down" else cfg.d_model
            out = {kk: v for kk, v in node.items() if kk != "qscale"}
            out["w"] = pack_mod.dequant_leaf(node["w"], node["qscale"], k)
            return out
        if isinstance(node, dict):
            return {kk: walk(v, kk) for kk, v in node.items()}
        return node

    ref_params = walk(art.params)
    want, _ = model.decode_step(ref_params, tok,
                                jax.tree.map(jnp.copy, cache), pos)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-4, rtol=2e-4)


# ---------------------------------------------------------------------------
# serve harness
# ---------------------------------------------------------------------------


def test_run_prefill_decode_reports_tiers_and_throughput(rng):
    from repro.launch.serve import run_prefill_decode
    from repro.models import get_model

    cfg, model = get_model("brecq_lm_100m", reduced=True)
    params = model.init(jax.random.PRNGKey(0))
    art = rtn_artifact(params, 4, None, cfg=cfg)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (4, 16)))
    gen, stat = run_prefill_decode(model, art.params, {"tokens": toks},
                                   batch_size=4, prompt_len=16, gen_len=4,
                                   hook=art.hook(), quiet=True)
    assert gen.shape == (4, 4)
    assert stat["qmm_tiers"]["decode"] > 0  # decode steps hit the gemv tier
    assert stat["qmm_tiers"]["prefill"] > 0
    assert stat["tok_s"] > 0 and stat["prefill_tok_s"] > 0
    assert stat["t_compile"] > 0

    _, fp_stat = run_prefill_decode(model, params, {"tokens": toks},
                                    batch_size=4, prompt_len=16, gen_len=4,
                                    quiet=True)
    assert fp_stat["qmm_tiers"] == {"decode": 0, "prefill": 0, "grouped": 0}


def test_serve_records_decode_tier_override(rng):
    """With the decode tier disabled, serving routes decode steps through
    the prefill GEMM and the stats record the override."""
    from repro.launch.serve import run_prefill_decode
    from repro.models import get_model

    cfg, model = get_model("brecq_lm_100m", reduced=True)
    params = model.init(jax.random.PRNGKey(0))
    art = rtn_artifact(params, 4, None, cfg=cfg)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (4, 16)))
    try:
        qmm_ops.set_decode_tier(False)
        _, stat = run_prefill_decode(model, art.params, {"tokens": toks},
                                     batch_size=4, prompt_len=16, gen_len=4,
                                     hook=art.hook(), quiet=True)
    finally:
        qmm_ops.set_decode_tier(None)
    assert stat["decode_tier_enabled"] is False
    assert stat["qmm_tiers"]["decode"] == 0
    assert stat["qmm_tiers"]["prefill"] > 0
