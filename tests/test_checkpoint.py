"""Checkpoint manager: atomicity, gc, async, elastic restore."""
import json
import shutil
import threading
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt import CheckpointManager


def tree():
    return {"a": jnp.arange(12.0).reshape(3, 4),
            "b": {"c": jnp.ones((5,), jnp.int32), "d": jnp.zeros(())}}


def test_save_restore_roundtrip(tmp_path):
    cm = CheckpointManager(tmp_path)
    t = tree()
    cm.save(3, t, meta={"loss": 1.5})
    assert cm.all_steps() == [3]
    like = jax.tree.map(jnp.zeros_like, t)
    r = cm.restore(3, like)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(r)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert cm.manifest(3)["meta"]["loss"] == 1.5


def test_incomplete_checkpoint_ignored(tmp_path):
    cm = CheckpointManager(tmp_path)
    cm.save(1, tree())
    # simulate a preempted save: directory without manifest
    bad = tmp_path / "step_00000002"
    bad.mkdir()
    (bad / "arrays.npz").write_bytes(b"corrupt")
    assert cm.latest_step() == 1


def test_gc_keeps_latest(tmp_path):
    cm = CheckpointManager(tmp_path, keep=2)
    for s in (1, 2, 3, 4):
        cm.save(s, tree())
    assert cm.all_steps() == [3, 4]


def test_async_save(tmp_path):
    cm = CheckpointManager(tmp_path)
    cm.save_async(7, tree())
    cm.wait()
    assert cm.latest_step() == 7


def test_elastic_restore_with_sharding(tmp_path):
    """Restore onto explicit shardings of the current (1-device) mesh —
    the path a different-size mesh uses after preemption/rescale."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    cm = CheckpointManager(tmp_path)
    t = tree()
    cm.save(5, t)
    mesh = jax.make_mesh((1,), ("data",))
    sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), t)
    r = cm.restore(5, t, shardings=sh)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(r)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert all(x.sharding == NamedSharding(mesh, P())
               for x in jax.tree.leaves(r))


def test_train_resume_cli(tmp_path):
    """The train driver resumes exactly where it stopped."""
    from repro.launch.train import main

    args = ["--arch", "brecq_lm_100m", "--reduced", "--steps", "6",
            "--batch", "4", "--seq", "32", "--ckpt-dir", str(tmp_path),
            "--ckpt-every", "3", "--log-every", "100"]
    main(args)
    cm = CheckpointManager(tmp_path)
    assert cm.latest_step() == 6
    # extend the run: resumes from 6, trains to 8
    args8 = [a if a != "6" else "8" for a in args]
    main(args8)
    assert CheckpointManager(tmp_path).latest_step() == 8
    # resume at completion: start_step == steps, the loop never runs —
    # must exit cleanly (regression: NameError on the final save)
    main(args8)
    assert CheckpointManager(tmp_path).latest_step() == 8
