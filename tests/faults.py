"""Fault-injection harness for the robustness layer.

Three families of faults, used by ``test_faults.py`` and by the CI
``fault-smoke`` job (this file doubles as a CLI):

  * **process faults** — deliver a real SIGTERM/SIGINT to this process
    after a chosen reconstruction unit completes
    (:func:`kill_during_unit`), exercising the actual signal handler +
    journal path rather than a mocked one;
  * **loop faults** — corrupt selected ``run_unit_loop`` invocations
    with non-finite results (:func:`nan_unit_loop`) or a synthetic
    device-OOM (:func:`oom_unit_loop`), exercising the per-unit guard's
    retry / RTN-fallback / minibatch-halving paths;
  * **storage faults** — genuinely damage a saved artifact on disk:
    flip one bit inside a chosen leaf's bytes (:func:`flip_leaf_bit` —
    ``np.savez`` stores uncompressed, so the payload offset is exact),
    truncate ``arrays.npz`` (:func:`truncate_arrays`), or edit the
    manifest (:func:`edit_manifest`).

CLI (used by CI):

  PYTHONPATH=src python tests/faults.py kill-resume
  PYTHONPATH=src python tests/faults.py corruption
"""
from __future__ import annotations

import contextlib
import json
import os

os.environ.setdefault("JAX_PLATFORMS", "cpu")

import signal
import struct
import zipfile
from pathlib import Path

import numpy as np

# ---------------------------------------------------------------------------
# process faults
# ---------------------------------------------------------------------------


@contextlib.contextmanager
def kill_during_unit(unit_call: int, sig: int = signal.SIGTERM):
    """Deliver ``sig`` to this process while reconstruction unit number
    ``unit_call`` (0-based count of ``run_unit_loop`` invocations) is
    finishing. The handler installed by ``quantize(workdir=...)`` turns
    this into a checkpoint-at-unit-boundary + CalibrationInterrupted."""
    from repro.core import calib_loop

    orig = calib_loop.run_unit_loop
    calls = {"n": 0}

    def patched(*a, **k):
        out = orig(*a, **k)
        if calls["n"] == unit_call:
            os.kill(os.getpid(), sig)
        calls["n"] += 1
        return out

    calib_loop.run_unit_loop = patched
    try:
        yield calls
    finally:
        calib_loop.run_unit_loop = orig


# ---------------------------------------------------------------------------
# loop faults
# ---------------------------------------------------------------------------


@contextlib.contextmanager
def nan_unit_loop(bad_calls: set[int]):
    """Replace the result of selected ``run_unit_loop`` invocations with
    non-finite logits and losses (call index counts every invocation,
    including guard retries — injecting ``{0}`` fails only the first
    attempt, so the first retry recovers)."""
    import jax
    import jax.numpy as jnp

    from repro.core import calib_loop

    orig = calib_loop.run_unit_loop
    calls = {"n": 0}

    def patched(*a, **k):
        i = calls["n"]
        calls["n"] += 1
        opt, losses = orig(*a, **k)
        if i in bad_calls:
            opt = jax.tree.map(lambda x: jnp.full_like(x, jnp.nan), opt)
            losses = np.full_like(np.asarray(losses, np.float64), np.nan)
        return opt, losses

    calib_loop.run_unit_loop = patched
    try:
        yield calls
    finally:
        calib_loop.run_unit_loop = orig


@contextlib.contextmanager
def oom_unit_loop(bad_calls: set[int]):
    """Raise a synthetic device-OOM (``jax.errors.JaxRuntimeError`` with
    a RESOURCE_EXHAUSTED message, the type/format XLA allocation
    failures surface as) on selected ``run_unit_loop`` invocations."""
    import jax

    from repro.core import calib_loop

    orig = calib_loop.run_unit_loop
    calls = {"n": 0}

    def patched(*a, **k):
        i = calls["n"]
        calls["n"] += 1
        if i in bad_calls:
            raise jax.errors.JaxRuntimeError(
                "RESOURCE_EXHAUSTED: synthetic out-of-memory injected by "
                "tests/faults.py (Out of memory while trying to allocate)")
        return orig(*a, **k)

    calib_loop.run_unit_loop = patched
    try:
        yield calls
    finally:
        calib_loop.run_unit_loop = orig


# ---------------------------------------------------------------------------
# storage faults
# ---------------------------------------------------------------------------


def latest_step_dir(directory) -> Path:
    steps = sorted(p for p in Path(directory).glob("step_*") if p.is_dir())
    if not steps:
        raise FileNotFoundError(f"no step_* checkpoint under {directory}")
    return steps[-1]


def arrays_npz(directory) -> Path:
    return latest_step_dir(directory) / "arrays.npz"


def _payload_offsets(npz_path: Path) -> dict[str, tuple[int, int]]:
    """member name -> (absolute offset of the raw .npy payload, size).

    Valid because ``np.savez`` writes ZIP_STORED (no compression): the
    payload bytes sit directly after the local file header."""
    out = {}
    with zipfile.ZipFile(npz_path) as z:
        infos = z.infolist()
    with open(npz_path, "rb") as f:
        for info in infos:
            assert info.compress_type == zipfile.ZIP_STORED, info.filename
            f.seek(info.header_offset)
            hdr = f.read(30)  # local file header is 30 bytes fixed
            name_len, extra_len = struct.unpack("<HH", hdr[26:30])
            out[info.filename] = (
                info.header_offset + 30 + name_len + extra_len,
                info.file_size)
    return out


def _npy_data_offset(f, member_off: int) -> int:
    """Offset of the array *data* inside a .npy payload (skip the magic,
    version and header-dict so a flipped bit lands in array bytes, not
    in the parseable header)."""
    f.seek(member_off)
    magic = f.read(8)
    assert magic[:6] == b"\x93NUMPY", magic
    major = magic[6]
    if major == 1:
        (hlen,) = struct.unpack("<H", f.read(2))
        return member_off + 10 + hlen
    (hlen,) = struct.unpack("<I", f.read(4))
    return member_off + 12 + hlen


def flip_leaf_bit(directory, leaf: str, byte_index: int = 0,
                  bit: int = 0) -> None:
    """Flip one bit inside leaf ``leaf``'s stored array bytes in the
    latest checkpoint under ``directory`` (leaf names are the flat
    '/'-joined tree paths, e.g. ``params/body/0/attn/wq/w``)."""
    npz = arrays_npz(directory)
    offsets = _payload_offsets(npz)
    member = leaf + ".npy"
    if member not in offsets:
        raise KeyError(f"{leaf!r} not in {sorted(offsets)}")
    member_off, _size = offsets[member]
    with open(npz, "r+b") as f:
        data_off = _npy_data_offset(f, member_off)
        f.seek(data_off + byte_index)
        b = f.read(1)[0]
        f.seek(data_off + byte_index)
        f.write(bytes([b ^ (1 << bit)]))


def truncate_arrays(directory, drop_bytes: int = 4096) -> None:
    """Chop the tail off ``arrays.npz`` (simulates a partial copy /
    filled disk — the zip central directory is destroyed)."""
    npz = arrays_npz(directory)
    size = npz.stat().st_size
    with open(npz, "r+b") as f:
        f.truncate(max(0, size - drop_bytes))


def edit_manifest(directory, fn) -> None:
    """Load the latest checkpoint's ``manifest.json``, apply ``fn(meta)``
    (mutating the ``meta`` dict in place), write it back."""
    path = latest_step_dir(directory) / "manifest.json"
    doc = json.loads(path.read_text())
    fn(doc["meta"])
    path.write_text(json.dumps(doc))


# ---------------------------------------------------------------------------
# serving faults
# ---------------------------------------------------------------------------


def cancel_mid_decode(engine, uid: int, *, after_tokens: int = 2,
                      max_ticks: int = 10_000):
    """Drive ``engine`` until drained, cancelling request ``uid`` the
    moment it has decoded ``after_tokens`` tokens (it must be holding KV
    pages at that point — asserted). Requests must already be submitted.
    Returns the engine after every surviving request finished."""
    cancelled = False
    ticks = 0
    while engine.pending():
        engine.step()
        ticks += 1
        if ticks > max_ticks:
            raise RuntimeError("engine did not drain")
        req = engine.requests.get(uid)
        if (not cancelled and req is not None and req.state == "decode"
                and len(req.generated) >= after_tokens):
            assert engine.pool.refcount(uid) > 0, "no pages held mid-decode"
            engine.cancel(uid)
            cancelled = True
    if not cancelled:
        raise AssertionError(
            f"request {uid} never decoded {after_tokens} tokens")
    return engine


# ---------------------------------------------------------------------------
# CLI for the CI fault-smoke job
# ---------------------------------------------------------------------------


def _tiny_setup(n_layers: int = 2):
    import dataclasses

    import jax

    from repro.data import Corpus, CorpusConfig, make_batches
    from repro.models import build_model, get_config

    cfg = dataclasses.replace(get_config("brecq_lm_100m", reduced=True),
                              n_layers=n_layers)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    corpus = Corpus(CorpusConfig(vocab=cfg.vocab))
    calib = make_batches(corpus, 2, 4, 32, seed=1, start_step=1000)
    return cfg, model, params, calib


def _cli_kill_resume() -> None:
    """SIGTERM a journaled quantize mid-run, resume, assert bit-exact
    against an uninterrupted run."""
    import tempfile

    import jax

    from repro.core import CalibrationInterrupted, ReconConfig, quantize

    cfg, model, params, calib = _tiny_setup()
    rc = ReconConfig(w_bits=4, iters=6, calib_bs=4)
    ref = quantize(model, params, calib, rc)

    with tempfile.TemporaryDirectory() as d:
        interrupted = False
        with kill_during_unit(0):
            try:
                quantize(model, params, calib, rc, workdir=d)
            except CalibrationInterrupted as e:
                interrupted = True
                print(f"interrupted as designed: {e}")
        assert interrupted, "SIGTERM did not interrupt the journaled run"
        res = quantize(model, params, calib, rc, workdir=d)
        assert res.stats.get("resumed_at_unit") == 1, res.stats.get(
            "resumed_at_unit")

    ref_leaves = jax.tree_util.tree_flatten_with_path(ref.params_q)[0]
    res_leaves = jax.tree_util.tree_flatten_with_path(res.params_q)[0]
    for (pa, a), (_pb, b) in zip(ref_leaves, res_leaves):
        assert np.array_equal(np.asarray(a), np.asarray(b)), pa
    assert set(ref.v) == set(res.v)
    for p in ref.v:
        assert np.array_equal(np.asarray(ref.v[p]), np.asarray(res.v[p])), p
    print("kill-resume: resumed run is bit-exact "
          f"({len(res.stats['units'])} units, resumed at unit 1)")


def _cli_corruption() -> None:
    """Flip one bit in a saved artifact and assert the verifying load
    detects it and names the damaged leaf."""
    import tempfile

    from repro.deploy import (ArtifactCorruptionError, QuantizedArtifact,
                              rtn_artifact)

    cfg, model, params, _ = _tiny_setup()
    art = rtn_artifact(params, 4, cfg=cfg)
    with tempfile.TemporaryDirectory() as d:
        art.save(d)
        QuantizedArtifact.load(d)  # pristine artifact verifies
        leaf = next(k for k in art.manifest["checksums"]
                    if k.endswith("/w") or k.endswith("/table"))
        flip_leaf_bit(d, leaf)
        try:
            QuantizedArtifact.load(d)
        except ArtifactCorruptionError as e:
            assert e.leaf == leaf, (e.leaf, leaf)
            print(f"corruption: bit flip detected at leaf {e.leaf!r}")
        else:
            raise AssertionError("bit flip went undetected")


def _serve_setup():
    import jax
    import numpy as np

    from repro.models import get_model
    from repro.serve_engine import EngineConfig, ServeEngine

    _, model = get_model("brecq_lm_100m", reduced=True)
    params = model.init(jax.random.PRNGKey(0))
    ecfg = EngineConfig(num_slots=3, page_size=4, num_pages=49, max_len=32,
                        prefill_chunk=8, kv_dtype="float32", backend="xla")
    rng = np.random.default_rng(21)
    prompts = [rng.integers(0, model.cfg.vocab, size=n).astype(np.int32)
               for n in (6, 9, 7)]

    def make():
        eng = ServeEngine(model, params, ecfg)
        for uid, p in enumerate(prompts):
            eng.submit(p, (8, 12, 8)[uid], uid=uid)
        return eng

    return make


def _cli_serve_cancel() -> None:
    """Cancel a mid-decode stream; its pages must be reclaimed and the
    surviving streams' outputs must match an uncancelled run exactly."""
    make = _serve_setup()
    ref = make()
    ref.run()
    eng = cancel_mid_decode(make(), uid=1, after_tokens=3)
    assert eng.requests[1].state == "cancelled"
    assert eng.pool.refcount(1) == 0, "cancelled stream leaked pages"
    eng.assert_no_leaks()
    for uid in (0, 2):
        assert eng.requests[uid].generated == ref.requests[uid].generated, uid
    print("serve-cancel: pages reclaimed, surviving streams unchanged "
          f"({[len(eng.requests[u].generated) for u in (0, 2)]} tokens)")


def _cli_serve_corrupt() -> None:
    """Bit-flip a saved artifact; engine start must raise the typed
    ArtifactCorruptionError before any slot is admitted."""
    import tempfile

    import jax

    from repro.deploy import ArtifactCorruptionError, rtn_artifact
    from repro.models import get_model
    from repro.serve_engine import ServeEngine

    cfg, model = get_model("brecq_lm_100m", reduced=True)
    params = model.init(jax.random.PRNGKey(0))
    art = rtn_artifact(params, 4, cfg=cfg)
    with tempfile.TemporaryDirectory() as d:
        art.save(d)
        eng = ServeEngine.from_artifact(d, reduced=True)  # pristine: builds
        assert not eng.pending()
        leaf = next(k for k in art.manifest["checksums"] if k.endswith("/w"))
        flip_leaf_bit(d, leaf)
        try:
            ServeEngine.from_artifact(d, reduced=True)
        except ArtifactCorruptionError as e:
            print(f"serve-corrupt: engine start rejected damaged artifact "
                  f"(leaf {e.leaf!r}) before admitting any request")
        else:
            raise AssertionError("corrupt artifact started serving")


def main(argv=None) -> None:
    import argparse

    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("command", choices=["kill-resume", "corruption",
                                       "serve-cancel", "serve-corrupt"])
    args = p.parse_args(argv)
    if args.command == "kill-resume":
        _cli_kill_resume()
    elif args.command == "corruption":
        _cli_corruption()
    elif args.command == "serve-cancel":
        _cli_serve_cancel()
    else:
        _cli_serve_corrupt()


if __name__ == "__main__":
    import sys

    SRC = str(Path(__file__).resolve().parents[1] / "src")
    if SRC not in sys.path:
        sys.path.insert(0, SRC)
    main()
