"""Fault-injection harness for the robustness layer.

Three families of faults, used by ``test_faults.py`` and by the CI
``fault-smoke`` job (this file doubles as a CLI):

  * **process faults** — deliver a real SIGTERM/SIGINT to this process
    after a chosen reconstruction unit completes
    (:func:`kill_during_unit`), exercising the actual signal handler +
    journal path rather than a mocked one;
  * **loop faults** — corrupt selected ``run_unit_loop`` invocations
    with non-finite results (:func:`nan_unit_loop`) or a synthetic
    device-OOM (:func:`oom_unit_loop`), exercising the per-unit guard's
    retry / RTN-fallback / minibatch-halving paths;
  * **storage faults** — genuinely damage a saved artifact on disk:
    flip one bit inside a chosen leaf's bytes (:func:`flip_leaf_bit` —
    ``np.savez`` stores uncompressed, so the payload offset is exact),
    truncate ``arrays.npz`` (:func:`truncate_arrays`), or edit the
    manifest (:func:`edit_manifest`).

  * **engine faults** — chaos for the serve engine: cancel a stream
    mid-decode (:func:`cancel_mid_decode`), poison one slot's decode
    logits with NaN (:func:`nan_decode_slot`), or storm a pool too
    small for worst-case reservation (:func:`pool_pressure_storm`).

CLI (used by CI):

  PYTHONPATH=src python tests/faults.py kill-resume
  PYTHONPATH=src python tests/faults.py corruption
  PYTHONPATH=src python tests/faults.py serve-cancel
  PYTHONPATH=src python tests/faults.py serve-corrupt
  PYTHONPATH=src python tests/faults.py pool-pressure
  PYTHONPATH=src python tests/faults.py nan-decode-slot
  PYTHONPATH=src python tests/faults.py sigterm-drain
"""
from __future__ import annotations

import contextlib
import json
import os

os.environ.setdefault("JAX_PLATFORMS", "cpu")

import signal
import struct
import zipfile
from pathlib import Path

import numpy as np

# ---------------------------------------------------------------------------
# process faults
# ---------------------------------------------------------------------------


@contextlib.contextmanager
def kill_during_unit(unit_call: int, sig: int = signal.SIGTERM):
    """Deliver ``sig`` to this process while reconstruction unit number
    ``unit_call`` (0-based count of ``run_unit_loop`` invocations) is
    finishing. The handler installed by ``quantize(workdir=...)`` turns
    this into a checkpoint-at-unit-boundary + CalibrationInterrupted."""
    from repro.core import calib_loop

    orig = calib_loop.run_unit_loop
    calls = {"n": 0}

    def patched(*a, **k):
        out = orig(*a, **k)
        if calls["n"] == unit_call:
            os.kill(os.getpid(), sig)
        calls["n"] += 1
        return out

    calib_loop.run_unit_loop = patched
    try:
        yield calls
    finally:
        calib_loop.run_unit_loop = orig


# ---------------------------------------------------------------------------
# loop faults
# ---------------------------------------------------------------------------


@contextlib.contextmanager
def nan_unit_loop(bad_calls: set[int]):
    """Replace the result of selected ``run_unit_loop`` invocations with
    non-finite logits and losses (call index counts every invocation,
    including guard retries — injecting ``{0}`` fails only the first
    attempt, so the first retry recovers)."""
    import jax
    import jax.numpy as jnp

    from repro.core import calib_loop

    orig = calib_loop.run_unit_loop
    calls = {"n": 0}

    def patched(*a, **k):
        i = calls["n"]
        calls["n"] += 1
        opt, losses = orig(*a, **k)
        if i in bad_calls:
            opt = jax.tree.map(lambda x: jnp.full_like(x, jnp.nan), opt)
            losses = np.full_like(np.asarray(losses, np.float64), np.nan)
        return opt, losses

    calib_loop.run_unit_loop = patched
    try:
        yield calls
    finally:
        calib_loop.run_unit_loop = orig


@contextlib.contextmanager
def oom_unit_loop(bad_calls: set[int]):
    """Raise a synthetic device-OOM (``jax.errors.JaxRuntimeError`` with
    a RESOURCE_EXHAUSTED message, the type/format XLA allocation
    failures surface as) on selected ``run_unit_loop`` invocations."""
    import jax

    from repro.core import calib_loop

    orig = calib_loop.run_unit_loop
    calls = {"n": 0}

    def patched(*a, **k):
        i = calls["n"]
        calls["n"] += 1
        if i in bad_calls:
            raise jax.errors.JaxRuntimeError(
                "RESOURCE_EXHAUSTED: synthetic out-of-memory injected by "
                "tests/faults.py (Out of memory while trying to allocate)")
        return orig(*a, **k)

    calib_loop.run_unit_loop = patched
    try:
        yield calls
    finally:
        calib_loop.run_unit_loop = orig


# ---------------------------------------------------------------------------
# storage faults
# ---------------------------------------------------------------------------


def latest_step_dir(directory) -> Path:
    steps = sorted(p for p in Path(directory).glob("step_*") if p.is_dir())
    if not steps:
        raise FileNotFoundError(f"no step_* checkpoint under {directory}")
    return steps[-1]


def arrays_npz(directory) -> Path:
    return latest_step_dir(directory) / "arrays.npz"


def _payload_offsets(npz_path: Path) -> dict[str, tuple[int, int]]:
    """member name -> (absolute offset of the raw .npy payload, size).

    Valid because ``np.savez`` writes ZIP_STORED (no compression): the
    payload bytes sit directly after the local file header."""
    out = {}
    with zipfile.ZipFile(npz_path) as z:
        infos = z.infolist()
    with open(npz_path, "rb") as f:
        for info in infos:
            assert info.compress_type == zipfile.ZIP_STORED, info.filename
            f.seek(info.header_offset)
            hdr = f.read(30)  # local file header is 30 bytes fixed
            name_len, extra_len = struct.unpack("<HH", hdr[26:30])
            out[info.filename] = (
                info.header_offset + 30 + name_len + extra_len,
                info.file_size)
    return out


def _npy_data_offset(f, member_off: int) -> int:
    """Offset of the array *data* inside a .npy payload (skip the magic,
    version and header-dict so a flipped bit lands in array bytes, not
    in the parseable header)."""
    f.seek(member_off)
    magic = f.read(8)
    assert magic[:6] == b"\x93NUMPY", magic
    major = magic[6]
    if major == 1:
        (hlen,) = struct.unpack("<H", f.read(2))
        return member_off + 10 + hlen
    (hlen,) = struct.unpack("<I", f.read(4))
    return member_off + 12 + hlen


def flip_leaf_bit(directory, leaf: str, byte_index: int = 0,
                  bit: int = 0) -> None:
    """Flip one bit inside leaf ``leaf``'s stored array bytes in the
    latest checkpoint under ``directory`` (leaf names are the flat
    '/'-joined tree paths, e.g. ``params/body/0/attn/wq/w``)."""
    npz = arrays_npz(directory)
    offsets = _payload_offsets(npz)
    member = leaf + ".npy"
    if member not in offsets:
        raise KeyError(f"{leaf!r} not in {sorted(offsets)}")
    member_off, _size = offsets[member]
    with open(npz, "r+b") as f:
        data_off = _npy_data_offset(f, member_off)
        f.seek(data_off + byte_index)
        b = f.read(1)[0]
        f.seek(data_off + byte_index)
        f.write(bytes([b ^ (1 << bit)]))


def truncate_arrays(directory, drop_bytes: int = 4096) -> None:
    """Chop the tail off ``arrays.npz`` (simulates a partial copy /
    filled disk — the zip central directory is destroyed)."""
    npz = arrays_npz(directory)
    size = npz.stat().st_size
    with open(npz, "r+b") as f:
        f.truncate(max(0, size - drop_bytes))


def edit_manifest(directory, fn) -> None:
    """Load the latest checkpoint's ``manifest.json``, apply ``fn(meta)``
    (mutating the ``meta`` dict in place), write it back."""
    path = latest_step_dir(directory) / "manifest.json"
    doc = json.loads(path.read_text())
    fn(doc["meta"])
    path.write_text(json.dumps(doc))


# ---------------------------------------------------------------------------
# serving faults
# ---------------------------------------------------------------------------


@contextlib.contextmanager
def nan_decode_slot(engine, uid: int, *, after_tokens: int = 2):
    """Corrupt ONE decode step's logits for request ``uid``'s slot row
    (once it has ``after_tokens`` tokens) with NaN — the device-fault
    shape a bad kernel or poisoned weights would produce for a single
    stream. The engine must fail only that request; every other slot in
    the same batched step continues."""
    import jax.numpy as jnp

    engine.compile()
    orig = engine._decode_c
    state = {"fired": False}

    def patched(params, tokens, cache, pos, bt):
        logits, cache = orig(params, tokens, cache, pos, bt)
        req = engine.requests.get(uid)
        if (not state["fired"] and req is not None and req.state == "decode"
                and req.slot >= 0 and len(req.generated) >= after_tokens):
            logits = logits.at[req.slot].set(jnp.nan)
            state["fired"] = True
        return logits, cache

    engine._decode_c = patched
    try:
        yield state
    finally:
        engine._decode_c = orig


def pool_pressure_storm(engine, prompts, max_news, *, max_ticks: int = 10_000):
    """Submit every stream at tick 0 against an engine whose pool is too
    small for worst-case reservation, then drive to completion. Under
    ``overcommit='prompt'`` this manufactures a preemption storm; the
    caller asserts >= 1 preemption, bit-exact tokens and a clean pool."""
    for uid, (p, mn) in enumerate(zip(prompts, max_news)):
        engine.submit(p, mn, uid=uid)
    engine.run(max_ticks=max_ticks)
    return engine


def cancel_mid_decode(engine, uid: int, *, after_tokens: int = 2,
                      max_ticks: int = 10_000):
    """Drive ``engine`` until drained, cancelling request ``uid`` the
    moment it has decoded ``after_tokens`` tokens (it must be holding KV
    pages at that point — asserted). Requests must already be submitted.
    Returns the engine after every surviving request finished."""
    cancelled = False
    ticks = 0
    while engine.pending():
        engine.step()
        ticks += 1
        if ticks > max_ticks:
            raise RuntimeError("engine did not drain")
        req = engine.requests.get(uid)
        if (not cancelled and req is not None and req.state == "decode"
                and len(req.generated) >= after_tokens):
            assert engine.pool.refcount(uid) > 0, "no pages held mid-decode"
            engine.cancel(uid)
            cancelled = True
    if not cancelled:
        raise AssertionError(
            f"request {uid} never decoded {after_tokens} tokens")
    return engine


# ---------------------------------------------------------------------------
# CLI for the CI fault-smoke job
# ---------------------------------------------------------------------------


def _tiny_setup(n_layers: int = 2):
    import dataclasses

    import jax

    from repro.data import Corpus, CorpusConfig, make_batches
    from repro.models import build_model, get_config

    cfg = dataclasses.replace(get_config("brecq_lm_100m", reduced=True),
                              n_layers=n_layers)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    corpus = Corpus(CorpusConfig(vocab=cfg.vocab))
    calib = make_batches(corpus, 2, 4, 32, seed=1, start_step=1000)
    return cfg, model, params, calib


def _cli_kill_resume() -> None:
    """SIGTERM a journaled quantize mid-run, resume, assert bit-exact
    against an uninterrupted run."""
    import tempfile

    import jax

    from repro.core import CalibrationInterrupted, ReconConfig, quantize

    cfg, model, params, calib = _tiny_setup()
    rc = ReconConfig(w_bits=4, iters=6, calib_bs=4)
    ref = quantize(model, params, calib, rc)

    with tempfile.TemporaryDirectory() as d:
        interrupted = False
        with kill_during_unit(0):
            try:
                quantize(model, params, calib, rc, workdir=d)
            except CalibrationInterrupted as e:
                interrupted = True
                print(f"interrupted as designed: {e}")
        assert interrupted, "SIGTERM did not interrupt the journaled run"
        res = quantize(model, params, calib, rc, workdir=d)
        assert res.stats.get("resumed_at_unit") == 1, res.stats.get(
            "resumed_at_unit")

    ref_leaves = jax.tree_util.tree_flatten_with_path(ref.params_q)[0]
    res_leaves = jax.tree_util.tree_flatten_with_path(res.params_q)[0]
    for (pa, a), (_pb, b) in zip(ref_leaves, res_leaves):
        assert np.array_equal(np.asarray(a), np.asarray(b)), pa
    assert set(ref.v) == set(res.v)
    for p in ref.v:
        assert np.array_equal(np.asarray(ref.v[p]), np.asarray(res.v[p])), p
    print("kill-resume: resumed run is bit-exact "
          f"({len(res.stats['units'])} units, resumed at unit 1)")


def _cli_corruption() -> None:
    """Flip one bit in a saved artifact and assert the verifying load
    detects it and names the damaged leaf."""
    import tempfile

    from repro.deploy import (ArtifactCorruptionError, QuantizedArtifact,
                              rtn_artifact)

    cfg, model, params, _ = _tiny_setup()
    art = rtn_artifact(params, 4, cfg=cfg)
    with tempfile.TemporaryDirectory() as d:
        art.save(d)
        QuantizedArtifact.load(d)  # pristine artifact verifies
        leaf = next(k for k in art.manifest["checksums"]
                    if k.endswith("/w") or k.endswith("/table"))
        flip_leaf_bit(d, leaf)
        try:
            QuantizedArtifact.load(d)
        except ArtifactCorruptionError as e:
            assert e.leaf == leaf, (e.leaf, leaf)
            print(f"corruption: bit flip detected at leaf {e.leaf!r}")
        else:
            raise AssertionError("bit flip went undetected")


def _serve_setup(**cfg_overrides):
    import jax
    import numpy as np

    from repro.models import get_model
    from repro.serve_engine import EngineConfig, ServeEngine

    _, model = get_model("brecq_lm_100m", reduced=True)
    params = model.init(jax.random.PRNGKey(0))
    base = dict(num_slots=3, page_size=4, num_pages=49, max_len=32,
                prefill_chunk=8, kv_dtype="float32", backend="xla")
    base.update(cfg_overrides)
    ecfg = EngineConfig(**base)
    rng = np.random.default_rng(21)
    prompts = [rng.integers(0, model.cfg.vocab, size=n).astype(np.int32)
               for n in (6, 9, 7)]

    donor: list = []  # first engine compiles; later ones share its programs

    def make():
        eng = ServeEngine(model, params, ecfg,
                          share_compiled=donor[0] if donor else None)
        if not donor:
            donor.append(eng)
        for uid, p in enumerate(prompts):
            eng.submit(p, (8, 12, 8)[uid], uid=uid)
        return eng

    return make


def _cli_serve_cancel() -> None:
    """Cancel a mid-decode stream; its pages must be reclaimed and the
    surviving streams' outputs must match an uncancelled run exactly."""
    make = _serve_setup()
    ref = make()
    ref.run()
    eng = cancel_mid_decode(make(), uid=1, after_tokens=3)
    assert eng.requests[1].state == "cancelled"
    assert eng.pool.refcount(1) == 0, "cancelled stream leaked pages"
    eng.assert_no_leaks()
    for uid in (0, 2):
        assert eng.requests[uid].generated == ref.requests[uid].generated, uid
    print("serve-cancel: pages reclaimed, surviving streams unchanged "
          f"({[len(eng.requests[u].generated) for u in (0, 2)]} tokens)")


def _cli_serve_corrupt() -> None:
    """Bit-flip a saved artifact; engine start must raise the typed
    ArtifactCorruptionError before any slot is admitted."""
    import tempfile

    import jax

    from repro.deploy import ArtifactCorruptionError, rtn_artifact
    from repro.models import get_model
    from repro.serve_engine import ServeEngine

    cfg, model = get_model("brecq_lm_100m", reduced=True)
    params = model.init(jax.random.PRNGKey(0))
    art = rtn_artifact(params, 4, cfg=cfg)
    with tempfile.TemporaryDirectory() as d:
        art.save(d)
        eng = ServeEngine.from_artifact(d, reduced=True)  # pristine: builds
        assert not eng.pending()
        leaf = next(k for k in art.manifest["checksums"] if k.endswith("/w"))
        flip_leaf_bit(d, leaf)
        try:
            ServeEngine.from_artifact(d, reduced=True)
        except ArtifactCorruptionError as e:
            print(f"serve-corrupt: engine start rejected damaged artifact "
                  f"(leaf {e.leaf!r}) before admitting any request")
        else:
            raise AssertionError("corrupt artifact started serving")


def _cli_pool_pressure() -> None:
    """Preemption storm: a pool far below worst-case demand under
    overcommit='prompt' must preempt, finish every stream bit-identical
    to its solo run, and leave the pool pristine."""
    import jax
    import numpy as np

    from repro.models import get_model
    from repro.serve_engine import EngineConfig, ServeEngine

    _, model = get_model("brecq_lm_100m", reduced=True)
    params = model.init(jax.random.PRNGKey(0))
    base = dict(num_slots=3, page_size=4, max_len=32, prefill_chunk=8,
                kv_dtype="float32", backend="xla")
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, model.cfg.vocab, size=n).astype(np.int32)
               for n in (6, 9, 7, 11)]
    max_news = (12, 14, 12, 10)

    solo_cfg = EngineConfig(num_pages=49, **base)
    donor = ServeEngine(model, params, solo_cfg)
    donor.compile()
    refs = {}
    for uid, p in enumerate(prompts):
        e = ServeEngine(model, params, solo_cfg, share_compiled=donor)
        e.submit(p, max_news[uid], uid=uid)
        e.run()
        refs[uid] = list(e.requests[uid].generated)

    # 7 usable pages vs 16 worst-case demand: guaranteed mid-decode
    # exhaustion once several streams grow together
    eng = ServeEngine(model, params,
                      EngineConfig(num_pages=8, overcommit="prompt", **base))
    pool_pressure_storm(eng, prompts, max_news)
    m = eng.metrics()
    assert m["preemptions"] >= 1, "pressure storm produced no preemption"
    for uid, ref in refs.items():
        assert eng.requests[uid].state == "done", (uid, eng.requests[uid].state)
        assert list(eng.requests[uid].generated) == ref, uid
    eng.assert_no_leaks()
    print(f"pool-pressure: {m['preemptions']} preemptions "
          f"({m['replay_prefill_chunks']} replayed chunks over "
          f"{m['decode_ticks']} decode ticks), all {len(prompts)} streams "
          "bit-identical to solo runs, zero leaked pages")


def _cli_nan_decode_slot() -> None:
    """NaN logits in one slot's decode row: that request alone fails;
    the other slots in the same batched step finish unchanged."""
    make = _serve_setup()
    ref = make()
    ref.run()
    eng = make()
    with nan_decode_slot(eng, uid=1, after_tokens=3) as fired:
        eng.run()
    assert fired["fired"], "injection never triggered"
    assert eng.requests[1].state == "failed", eng.requests[1].state
    assert eng.requests[1].error == "non-finite logits"
    assert eng.pool.refcount(1) == 0, "failed stream leaked pages"
    for uid in (0, 2):
        assert eng.requests[uid].state == "done"
        assert eng.requests[uid].generated == ref.requests[uid].generated, uid
    assert eng.metrics()["failed"] == 1
    eng.assert_no_leaks()
    print("nan-decode-slot: stream 1 failed in isolation, streams 0/2 "
          f"unchanged ({[len(eng.requests[u].generated) for u in (0, 2)]} "
          "tokens), pages reclaimed")


def _cli_sigterm_drain() -> None:
    """Real SIGTERM mid-serving: the engine stops admission, finishes
    in-flight streams, reports statuses, and rejects new submits."""
    from repro.launch.watchdog import GracefulShutdown
    from repro.serve_engine import RequestRejected

    make = _serve_setup()
    eng = make()
    with GracefulShutdown(install=True) as gs:
        ticks = 0
        while eng.pending():
            eng.step()
            ticks += 1
            if ticks == 4:
                os.kill(os.getpid(), signal.SIGTERM)
            if gs.requested:
                statuses = eng.drain(finish=True)
                break
        else:
            raise AssertionError("engine drained before the signal landed")
    assert eng.draining
    in_flight = [s for s in statuses.values() if s in ("prefill", "decode")]
    assert not in_flight, f"drain left in-flight work: {statuses}"
    eng.assert_no_leaks()
    try:
        eng.submit(np.zeros(4, np.int32), 2)
    except RequestRejected as e:
        assert e.reason == "draining"
    else:
        raise AssertionError("draining engine accepted a new request")
    print(f"sigterm-drain: admission stopped at tick {eng.tick}, statuses "
          f"{ {u: s for u, s in sorted(statuses.items())} }, no leaked pages, "
          "new submits rejected")


def main(argv=None) -> None:
    import argparse

    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("command", choices=["kill-resume", "corruption",
                                       "serve-cancel", "serve-corrupt",
                                       "pool-pressure", "nan-decode-slot",
                                       "sigterm-drain"])
    args = p.parse_args(argv)
    dispatch = {
        "kill-resume": _cli_kill_resume,
        "corruption": _cli_corruption,
        "serve-cancel": _cli_serve_cancel,
        "serve-corrupt": _cli_serve_corrupt,
        "pool-pressure": _cli_pool_pressure,
        "nan-decode-slot": _cli_nan_decode_slot,
        "sigterm-drain": _cli_sigterm_drain,
    }
    dispatch[args.command]()


if __name__ == "__main__":
    import sys

    SRC = str(Path(__file__).resolve().parents[1] / "src")
    if SRC not in sys.path:
        sys.path.insert(0, SRC)
    main()
