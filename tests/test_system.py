"""End-to-end behaviour: the paper's headline claims hold on a tiny LM.

These mirror EXPERIMENTS.md at CI scale: W4 ~ FP; at W2 BRECQ recovers
accuracy RTN loses; quantized serving produces usable generations.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ReconConfig, quantize
from repro.core.baselines import quantize_rtn
from repro.core.evaluate import evaluate


def test_paper_claims_w4_w2(tiny_trained):
    cfg, model, params, calib, evalb, train_loss = tiny_trained
    fp = evaluate(model, params, evalb)
    assert fp["loss"] < 5.5  # model actually learned something

    # W4: BRECQ within a hair of FP (paper Table 2 behaviour)
    res4 = quantize(model, params, calib, ReconConfig(w_bits=4, iters=80))
    q4 = evaluate(model, res4.params_q, evalb)
    assert q4["loss"] <= fp["loss"] + 0.05

    # W2: RTN degrades; BRECQ recovers a meaningful part of the gap
    rtn2, _ = quantize_rtn(model, params, calib, w_bits=2)
    r2 = evaluate(model, rtn2, evalb)
    res2 = quantize(model, params, calib, ReconConfig(w_bits=2, iters=150))
    q2 = evaluate(model, res2.params_q, evalb)
    assert r2["loss"] > fp["loss"]  # damage exists
    assert q2["loss"] <= r2["loss"] + 1e-3  # BRECQ never worse than RTN
    assert q2["top1"] >= r2["top1"] - 0.01


def test_quantized_generation_runs(tiny_trained):
    cfg, model, params, calib, _, _ = tiny_trained
    from repro import deploy

    q = deploy.quantize_tree(params, 4)
    B, S = 2, 16
    toks = calib[0]["tokens"][:B, :S]
    cache = model.init_cache(B, 48, jnp.float32)
    logits, cache = model.prefill(params, {"tokens": toks}, cache, remat="none")
    lq, cacheq = model.prefill(q, {"tokens": toks},
                               model.init_cache(B, 48, jnp.float32), remat="none")
    # top-1 next-token agreement between FP and W4 serving
    agree = float(jnp.mean((jnp.argmax(logits, -1) == jnp.argmax(lq, -1)).astype(jnp.float32)))
    assert agree >= 0.5, agree
    # packed weights really are smaller than the FP tree they replace
    assert deploy.tree_bytes(q) < deploy.tree_bytes(params)


def test_input_source_variants(tiny_trained):
    """'quant' (paper), 'fp' and 'mix' (QDrop-ish, beyond paper) all work."""
    cfg, model, params, calib, evalb, _ = tiny_trained
    losses = {}
    for src in ("quant", "fp", "mix"):
        res = quantize(model, params, calib[:3],
                       ReconConfig(w_bits=2, iters=40, input_source=src, seed=5))
        losses[src] = evaluate(model, res.params_q, evalb[:1])["loss"]
    assert all(np.isfinite(v) for v in losses.values()), losses
