"""Hypothesis property tests on quantizer invariants."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dep: skip, don't break collection
from hypothesis import given, settings, strategies as st

from repro.core import adaround
from repro.core.lsq import lsq_quant
from repro.core.quantizer import (QConfig, init_qstate, pack_int,
                                  quantize_dequant, unpack_int)

floats = st.floats(min_value=-100.0, max_value=100.0,
                   allow_nan=False, allow_infinity=False, width=32)


@st.composite
def weight_matrix(draw, max_dim=16):
    r = draw(st.integers(2, max_dim))
    c = draw(st.integers(1, max_dim))
    data = draw(st.lists(floats, min_size=r * c, max_size=r * c))
    return np.asarray(data, np.float32).reshape(r, c)


@given(w=weight_matrix(), bits=st.sampled_from([2, 3, 4, 8]))
@settings(max_examples=40, deadline=None)
def test_qdq_idempotent(w, bits):
    """Quantizing an already-quantized tensor is the identity."""
    w = jnp.asarray(w)
    cfg = QConfig(bits=bits, channel_axis=-1)
    stq = init_qstate(w, cfg)
    wq = quantize_dequant(w, stq, cfg)
    wqq = quantize_dequant(wq, stq, cfg)
    np.testing.assert_allclose(np.asarray(wq), np.asarray(wqq), atol=1e-5, rtol=1e-5)


@given(w=weight_matrix(), bits=st.sampled_from([2, 4, 8]))
@settings(max_examples=40, deadline=None)
def test_qdq_on_grid(w, bits):
    """Every fake-quantized value lies on the scale grid."""
    w = jnp.asarray(w)
    cfg = QConfig(bits=bits)
    stq = init_qstate(w, cfg)
    wq = np.asarray(quantize_dequant(w, stq, cfg))
    scale = float(stq.scale.reshape(-1)[0])
    codes = wq / scale
    np.testing.assert_allclose(codes, np.round(codes), atol=1e-3)
    assert codes.min() >= cfg.qmin - 1e-3 and codes.max() <= cfg.qmax + 1e-3


@given(bits=st.sampled_from([2, 4, 8]),
       rows=st.integers(1, 8).map(lambda k: k * 8),
       cols=st.integers(1, 16),
       seed=st.integers(0, 2**16))
@settings(max_examples=30, deadline=None)
def test_pack_unpack_identity(bits, rows, cols, seed):
    rng = np.random.default_rng(seed)
    lo, hi = -(2 ** (bits - 1)), 2 ** (bits - 1) - 1
    q = jnp.asarray(rng.integers(lo, hi + 1, size=(rows, cols)), jnp.int8)
    back = unpack_int(pack_int(q, bits), bits, rows)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(q))


@given(bits=st.sampled_from([2, 3, 4, 8]),
       rows=st.integers(2, 96),
       cols=st.integers(1, 16),
       group=st.sampled_from([None, 4, 8, 32, 64]),
       seed=st.integers(0, 2**16))
@settings(max_examples=40, deadline=None)
def test_deploy_leaf_roundtrip_any_group(bits, rows, cols, group, seed):
    """rtn_pack_leaf/dequant_leaf round-trips for every (bits, K, group)
    combination — K not divisible by the group falls back to per-channel
    scales, K not divisible by the pack factor falls back to an int8
    container; both must stay value-exact vs the fake-quant reference."""
    from repro.deploy import dequant_leaf, rtn_pack_leaf

    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.normal(size=(rows, cols)), jnp.float32)
    packed, scales = rtn_pack_leaf(w, bits, group)
    got = dequant_leaf(packed, scales, rows)
    g = group if (group and rows % group == 0) else None
    cfg = QConfig(bits=bits, channel_axis=-1, group_size=g)
    ref = quantize_dequant(w, init_qstate(w, cfg), cfg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               atol=1e-5, rtol=1e-5)


@given(bits=st.sampled_from([2, 4]), cbits=st.sampled_from([4, 8]),
       rows=st.integers(1, 8).map(lambda k: k * 8), cols=st.integers(1, 16),
       seed=st.integers(0, 2**16))
@settings(max_examples=30, deadline=None)
def test_pack_container_promotion(bits, cbits, rows, cols, seed):
    """Codes survive storage in any container at least as wide — the
    invariant mixed-precision stacked leaves depend on."""
    if cbits < bits:
        return
    rng = np.random.default_rng(seed)
    lo, hi = -(2 ** (bits - 1)), 2 ** (bits - 1) - 1
    q = jnp.asarray(rng.integers(lo, hi + 1, size=(rows, cols)), jnp.int8)
    back = unpack_int(pack_int(q, cbits), cbits, rows)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(q))


@given(w=weight_matrix(), bits=st.sampled_from([2, 4]))
@settings(max_examples=30, deadline=None)
def test_adaround_init_invariants(w, bits):
    """AdaRound init (Nagel et al. Sec 3): h(v_init) = frac, so the SOFT
    forward reproduces the FP weight (within the clip range) and the
    HARDENED forward reproduces round-to-nearest."""
    w = jnp.asarray(w)
    cfg = QConfig(bits=bits, channel_axis=-1)
    stq = init_qstate(w, cfg)
    v = adaround.init_v(w, stq, cfg)
    soft = np.asarray(adaround.soft_quant(w, v, stq, cfg))
    hard = np.asarray(adaround.hard_quant(w, v, stq, cfg))
    rtn = np.asarray(quantize_dequant(w, stq, cfg))
    tol = float(stq.scale.max()) * 1e-2 + 1e-6
    # soft == identity inside the clip range
    lo = cfg.qmin * np.asarray(stq.scale)
    hi = cfg.qmax * np.asarray(stq.scale)
    inside = (np.asarray(w) >= lo) & (np.asarray(w) <= hi)
    np.testing.assert_allclose(soft[inside], np.asarray(w)[inside], atol=tol)
    # hard == RTN everywhere (up to exact .5 midpoints: round-half cases)
    frac = np.asarray(w / stq.scale - jnp.floor(w / stq.scale))
    not_midpoint = np.abs(frac - 0.5) > 1e-3
    np.testing.assert_allclose(hard[not_midpoint], rtn[not_midpoint], atol=tol)


@given(w=weight_matrix(), bits=st.sampled_from([2, 4]),
       seed=st.integers(0, 2**16))
@settings(max_examples=30, deadline=None)
def test_adaround_hard_on_grid(w, bits, seed):
    """Hardened AdaRound output is on the quantizer grid for any v."""
    w = jnp.asarray(w)
    rng = np.random.default_rng(seed)
    cfg = QConfig(bits=bits)
    stq = init_qstate(w, cfg)
    v = jnp.asarray(rng.normal(size=w.shape), jnp.float32)
    hard = np.asarray(adaround.hard_quant(w, v, stq, cfg))
    scale = float(stq.scale.reshape(-1)[0])
    codes = hard / scale
    np.testing.assert_allclose(codes, np.round(codes), atol=1e-3)


@given(x=weight_matrix(), bits=st.sampled_from([4, 8]),
       s=st.floats(min_value=1e-3, max_value=2.0))
@settings(max_examples=30, deadline=None)
def test_lsq_output_on_grid(x, bits, s):
    x = jnp.asarray(x)
    s = jnp.asarray(s, jnp.float32)
    out = np.asarray(lsq_quant(x, s, bits, True))
    codes = out / float(s)
    np.testing.assert_allclose(codes, np.round(codes), atol=1e-2)
