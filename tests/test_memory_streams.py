"""Calibration memory plane: streamed Fisher, bf16 streams, probe cache.

See docs/memory.md for the model these tests pin down.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ReconConfig, quantize
from repro.core import calib_loop
from repro.core.fisher import FisherStream
from repro.core.reconstruction import Walker


def _tiny(n_layers: int):
    from repro.data import Corpus, CorpusConfig, make_batches
    from repro.models import build_model, get_config

    cfg = dataclasses.replace(get_config("brecq_lm_100m", reduced=True),
                              n_layers=n_layers)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    corpus = Corpus(CorpusConfig(vocab=cfg.vocab))
    calib = make_batches(corpus, 3, 8, 64, seed=1, start_step=1000)
    return model, params, calib


@pytest.fixture(scope="module")
def two_block():
    return _tiny(2)


def test_streamed_fisher_matches_full(two_block):
    """Per-unit backward == the joint all-blocks eps-trick backward."""
    model, params, calib = two_block
    walker = Walker(model)
    full = FisherStream(walker, params, calib, mode="full")
    stream = FisherStream(walker, params, calib, mode="stream",
                          dtype=jnp.float32)
    for bi in range(len(walker.blocks())):
        np.testing.assert_allclose(np.asarray(stream.for_block(bi)),
                                   np.asarray(full.for_block(bi)),
                                   rtol=1e-4, atol=1e-6)
    # residency: full keeps every block, streamed keeps one
    assert full.peak_bytes == 2 * stream.peak_bytes


def test_streamed_fisher_end_to_end_parity(two_block):
    """quantize() under streamed Fisher reproduces the full-mode result
    (f32 streams isolate the Fisher path)."""
    model, params, calib = two_block
    mk = lambda fm: ReconConfig(w_bits=3, iters=20, calib_bs=4, seed=5,
                                stream_dtype="float32", fisher_mode=fm)
    r_stream = quantize(model, params, calib, mk("stream"))
    r_full = quantize(model, params, calib, mk("full"))
    for us, uf in zip(r_stream.stats["units"], r_full.stats["units"]):
        np.testing.assert_allclose(us["loss_trace"], uf["loss_trace"],
                                   rtol=1e-3, atol=1e-6)
    assert set(r_stream.v) == set(r_full.v)
    for p in r_stream.v:
        np.testing.assert_array_equal(np.asarray(r_stream.v[p]) >= 0,
                                      np.asarray(r_full.v[p]) >= 0,
                                      err_msg=f"hardened signs differ at {p}")


def test_bf16_stream_equivalence(two_block):
    """bf16 stream storage moves the final recon MSE by <1% and keeps the
    hardened rounding decisions stable."""
    model, params, calib = two_block
    mk = lambda dt: ReconConfig(w_bits=3, iters=30, calib_bs=4, seed=5,
                                stream_dtype=dt)
    r_bf16 = quantize(model, params, calib, mk("bfloat16"))
    r_f32 = quantize(model, params, calib, mk("float32"))
    for ub, uf in zip(r_bf16.stats["units"], r_f32.stats["units"]):
        rel = abs(ub["final_recon_mse"] - uf["final_recon_mse"]) / \
            max(uf["final_recon_mse"], 1e-12)
        assert rel < 0.01, (ub["final_recon_mse"], uf["final_recon_mse"])
    agree = []
    for p in r_f32.v:
        s_b = np.asarray(r_bf16.v[p]) >= 0
        s_f = np.asarray(r_f32.v[p]) >= 0
        agree.append(np.mean(s_b == s_f))
    assert np.mean(agree) >= 0.98, np.mean(agree)
    # streams were actually stored half-width
    det_b = r_bf16.stats["calib_peak_bytes_detail"]
    det_f = r_f32.stats["calib_peak_bytes_detail"]
    assert det_b["streams"] * 2 == det_f["streams"]
    assert det_b["fisher"] * 2 == det_f["fisher"]


def test_fisher_residency_independent_of_depth():
    """Streamed Fisher keeps one block's g2 resident whatever the depth;
    full mode scales with nb."""
    m2, p2, c2 = _tiny(2)
    m4, p4, c4 = _tiny(4)
    rc = ReconConfig(w_bits=4, iters=6, calib_bs=4, granularity="block")
    r2 = quantize(m2, p2, c2, rc)
    r4 = quantize(m4, p4, c4, rc)
    f2 = r2.stats["calib_peak_bytes_detail"]["fisher"]
    f4 = r4.stats["calib_peak_bytes_detail"]["fisher"]
    assert f2 == f4 > 0, (f2, f4)
    # stream residency is depth-independent too (same N, S, d)
    assert (r2.stats["calib_peak_bytes_detail"]["streams"]
            == r4.stats["calib_peak_bytes_detail"]["streams"])
    # reference mode: Fisher residency doubles with depth
    rc_full = dataclasses.replace(rc, fisher_mode="full")
    r2f = quantize(m2, p2, c2, rc_full)
    r4f = quantize(m4, p4, c4, rc_full)
    assert (2 * r2f.stats["calib_peak_bytes_detail"]["fisher"]
            == r4f.stats["calib_peak_bytes_detail"]["fisher"])


def test_probe_cache_trace_count(tiny_trained):
    """Identical blocks share one probe trace; a re-run traces nothing."""
    cfg, model, params, calib, _, _ = tiny_trained
    calib_loop.clear_cache()
    rc = ReconConfig(w_bits=4, iters=6, calib_bs=4)
    res = quantize(model, params, calib[:2], rc)
    assert res.stats["probe_cache"] == {"hits": 3, "misses": 1}
    assert calib_loop.trace_log().count("unit_probe") == 1
    n_traces = len(calib_loop.trace_log())
    res2 = quantize(model, params, calib[:2], rc)
    assert res2.stats["probe_cache"] == {"hits": 4, "misses": 0}
    assert calib_loop.trace_log().count("unit_probe") == 1
    assert len(calib_loop.trace_log()) == n_traces


def test_layer_capture_cache_shared_across_blocks(tiny_trained):
    """Layer-wise capture programs are keyed by structure: block k's
    captures reuse block 0's traces, so misses don't scale with depth."""
    cfg, model, params, calib, _, _ = tiny_trained
    calib_loop.clear_cache()
    rc = ReconConfig(w_bits=4, iters=4, calib_bs=4, granularity="layer")
    res = quantize(model, params, calib[:2], rc)
    cap = res.stats["cap_cache"]
    nb = res.stats["n_units"]
    L = len(res.v) // nb  # linears per block
    # block 0 traces 2L-1 capture programs (the first quant-stream capture
    # has an empty done-set and shares the FP capture's key); every later
    # block hits. Misses are depth-independent, total calls are 2L per block.
    assert cap["misses"] == 2 * L - 1, (cap, L)
    assert cap["misses"] + cap["hits"] == 2 * L * nb, (cap, L, nb)
    # identical second run: all captures hit, no new traces
    n_traces = calib_loop.trace_log().count("layer_cap")
    res2 = quantize(model, params, calib[:2], rc)
    assert res2.stats["cap_cache"]["misses"] == 0
    assert calib_loop.trace_log().count("layer_cap") == n_traces
