"""Pallas kernel allclose sweeps vs the pure-jnp oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.quantizer import QConfig, init_qstate, quantize_int
from repro.kernels.fakequant.kernel import fakequant
from repro.kernels.fakequant.ref import fakequant_ref
from repro.kernels.kvattn.kernel import kv_decode
from repro.kernels.kvattn.ops import attend_int8, quantize_kv
from repro.kernels.kvattn.ref import kv_decode_ref
from repro.kernels.qmatmul.kernel import qgemv, qmatmul, qmatmul_grouped
from repro.kernels.qmatmul.ops import QuantizedLinear, pack_weights, qmm
from repro.kernels.qmatmul.ref import (qgemv_ref, qmatmul_ref,
                                       qmm_grouped_dense_ref, qmm_grouped_ref)


@pytest.mark.parametrize("bits", [8, 4, 2])
@pytest.mark.parametrize("M,K,N,group", [
    (8, 256, 128, 128),
    (128, 512, 256, None),
    (16, 128, 128, 64),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_qmatmul_vs_ref(rng, bits, M, K, N, group, dtype):
    w = jnp.asarray(rng.normal(size=(K, N)), jnp.float32)
    cfg = QConfig(bits=bits, channel_axis=-1, group_size=group)
    st = init_qstate(w, cfg)
    codes = quantize_int(w, st, cfg)
    scales = st.scale.reshape(-1, N)
    x = jnp.asarray(rng.normal(size=(M, K)), dtype)
    packed = pack_weights(codes, scales, bits).packed
    ref = qmatmul_ref(x, packed, scales, bits)
    out = qmatmul(x, packed, scales, bits=bits,
                  bm=8 if M <= 16 else 128, interpret=True)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-3
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("bits", [8, 4, 2])
def test_unpack_tile_matches_unpack_int(rng, bits):
    """int8 shift/mask unpack == the pure-jnp widening oracle."""
    from repro.core.quantizer import pack_int, unpack_int
    from repro.kernels.qmatmul.kernel import _unpack_tile

    K, N = 64, 128
    codes = jnp.asarray(
        rng.integers(-(2 ** (bits - 1)), 2 ** (bits - 1), size=(K, N)), jnp.int8)
    packed = pack_int(codes, bits)
    got = _unpack_tile(packed, bits)
    ref = unpack_int(packed, bits, K).astype(jnp.float32)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(codes, np.float32))


@pytest.mark.parametrize("bits", [8, 4])
@pytest.mark.parametrize("M", [3, 13, 130])
def test_qmm_ragged_m_pads_to_tile(rng, bits, M):
    """M not a multiple of 8/128 pads up + slices instead of bm=1."""
    K, N = 256, 128
    w = jnp.asarray(rng.normal(size=(K, N)), jnp.float32)
    cfg = QConfig(bits=bits, channel_axis=-1)
    st = init_qstate(w, cfg)
    codes = quantize_int(w, st, cfg)
    qw = pack_weights(codes, st.scale.reshape(-1, N), bits)
    x = jnp.asarray(rng.normal(size=(M, K)), jnp.float32)
    out = qmm(x, qw, backend="pallas")
    ref = qmatmul_ref(x, qw.packed, qw.scales, bits)
    assert out.shape == (M, N)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-3, rtol=1e-3)


@pytest.mark.parametrize("bits", [8, 4])
@pytest.mark.parametrize("M", [5, 130])
@pytest.mark.parametrize("N", [150, 192])
def test_qmm_ragged_n_pads_lanes(rng, bits, M, N):
    """N not a multiple of the 128 lane tile (and not itself a valid bn)
    zero-pads the packed columns + scales and slices the output back —
    both decode (M=5) and prefill (M=130) tiers."""
    K = 256
    w = jnp.asarray(rng.normal(size=(K, N)), jnp.float32)
    cfg = QConfig(bits=bits, channel_axis=-1)
    st = init_qstate(w, cfg)
    codes = quantize_int(w, st, cfg)
    qw = pack_weights(codes, st.scale.reshape(-1, N), bits)
    x = jnp.asarray(rng.normal(size=(M, K)), jnp.float32)
    out = qmm(x, qw, backend="pallas")
    ref = qmatmul_ref(x, qw.packed, qw.scales, bits)
    assert out.shape == (M, N)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-3, rtol=1e-3)


# ---------------------------------------------------------------------------
# decode tier: qgemv
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("bits", [8, 4, 2])
@pytest.mark.parametrize("M", [1, 2, 5])
@pytest.mark.parametrize("group", [None, 64])
def test_qgemv_vs_qmatmul_ref(rng, bits, M, group):
    """Decode gemv (kernel + XLA ref) == the prefill oracle at small M."""
    K, N = 256, 128
    w = jnp.asarray(rng.normal(size=(K, N)), jnp.float32)
    cfg = QConfig(bits=bits, channel_axis=-1, group_size=group)
    st = init_qstate(w, cfg)
    codes = quantize_int(w, st, cfg)
    scales = st.scale.reshape(-1, N)
    packed = pack_weights(codes, scales, bits).packed
    x = jnp.asarray(rng.normal(size=(M, K)), jnp.float32)
    ref = qmatmul_ref(x, packed, scales, bits)
    out_ref = qgemv_ref(x, packed, scales, bits)
    out_kern = qgemv(x, packed, scales, bits=bits, interpret=True)
    np.testing.assert_allclose(np.asarray(out_ref), np.asarray(ref),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(out_kern), np.asarray(ref),
                               atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# grouped tier: stacked expert nodes
# ---------------------------------------------------------------------------


def _stacked_node(rng, E, K, N, bits, group=None):
    from repro.deploy import rtn_pack_leaf

    w = jnp.asarray(rng.normal(size=(E, K, N)), jnp.float32)
    wp, qs = rtn_pack_leaf(w, bits, group)
    return {"w": wp, "qscale": qs}


@pytest.mark.parametrize("bits,group", [(8, None), (4, 64), (2, None),
                                        (3, None)])  # 3: int8-container case
def test_qmm_grouped_vs_dequant_einsum(rng, bits, group):
    """Grouped kernel + ref == transient dequant + grouped einsum (the
    path they replaced), incl. a W3 code in an int8 container."""
    from repro.deploy import dequant_leaf
    from repro.kernels.qmatmul.ops import from_node

    E, C, K, N = 3, 5, 128, 256
    node = _stacked_node(rng, E, K, N, bits, group)
    x = jnp.asarray(rng.normal(size=(E, C, K)), jnp.float32)
    w = dequant_leaf(node["w"], node["qscale"], K)
    ref = jnp.einsum("eck,ekn->ecn", x, w)

    qw = from_node(node, K)
    out_scan = qmm_grouped_ref(x, qw.packed, qw.scales, qw.bits)
    out_dense = qmm_grouped_dense_ref(x, qw.packed, qw.scales, qw.bits)
    out_kern = qmatmul_grouped(x, qw.packed, qw.scales, bits=qw.bits, bm=C,
                               interpret=True)
    out_qmm = qmm(x, qw, backend="xla")
    for got in (out_scan, out_dense, out_kern, out_qmm):
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("C", [3, 16])  # scan (decode) / dense (prefill) refs
def test_qmm_grouped_batched_lead_dims(rng, C):
    """(B, E, C, K) activations keep the expert axis aligned to the
    stacked codes through the dispatcher (both backends)."""
    B, E, K, N = 2, 4, 64, 128
    node = _stacked_node(rng, E, K, N, 4)
    from repro.deploy import dequant_leaf
    from repro.kernels.qmatmul.ops import from_node

    x = jnp.asarray(rng.normal(size=(B, E, C, K)), jnp.float32)
    w = dequant_leaf(node["w"], node["qscale"], K)
    ref = jnp.einsum("beck,ekn->becn", x, w)
    qw = from_node(node, K)
    for backend in ("xla", "pallas"):
        out = qmm(x, qw, backend=backend)
        assert out.shape == (B, E, C, N)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=1e-4, rtol=1e-4)


def test_qmm_wrapper_matches_dense(rng):
    w = jnp.asarray(rng.normal(size=(256, 128)), jnp.float32)
    cfg = QConfig(bits=8, channel_axis=-1)
    st = init_qstate(w, cfg)
    codes = quantize_int(w, st, cfg)
    qw = pack_weights(codes, st.scale.reshape(-1, 128), 8)
    x = jnp.asarray(rng.normal(size=(4, 8, 256)), jnp.float32)
    out = qmm(x, qw, backend="pallas")
    dense = x @ (codes.astype(jnp.float32) * st.scale)
    np.testing.assert_allclose(np.asarray(out), np.asarray(dense), atol=1e-4)


@pytest.mark.parametrize("B,H,K,hd,S,bs", [
    (2, 8, 2, 64, 256, 128),
    (1, 4, 4, 32, 128, 128),
    (3, 4, 1, 128, 512, 256),  # MQA
])
@pytest.mark.parametrize("window", [None, 64])
def test_kvattn_vs_ref(rng, B, H, K, hd, S, bs, window):
    q = jnp.asarray(rng.normal(size=(B, H, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, K, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, K, hd)), jnp.float32)
    k8, v8, ks, vs = quantize_kv(k, v)
    kpos = jnp.broadcast_to(jnp.arange(S), (B, S))
    cur = jnp.asarray(rng.integers(S // 4, S, size=(B,)), jnp.int32)
    ref = kv_decode_ref(q, k8, v8, ks, vs, kpos, cur, window)
    out = kv_decode(q, k8, v8, ks, vs, kpos, cur, window=window, bs=bs,
                    interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-4, rtol=1e-4)


def test_kvattn_int8_vs_fp_reference(rng):
    """int8 KV quantization error stays small vs full-precision attention."""
    from repro.models.common import decode_attend

    B, H, K, hd, S = 2, 4, 2, 64, 128
    q = jnp.asarray(rng.normal(size=(B, 1, H, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, K, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, K, hd)), jnp.float32)
    kpos = jnp.broadcast_to(jnp.arange(S), (B, S))
    cur = jnp.full((B, 1), S - 1, jnp.int32)
    fp = decode_attend(q, k, v, kpos, cur)[:, 0]
    k8, v8, ks, vs = quantize_kv(k, v)
    q8out = attend_int8(q[:, 0], k8, v8, ks, vs, kpos, cur[:, 0], backend="xla")
    err = float(jnp.max(jnp.abs(fp - q8out)))
    assert err < 0.05, err


@pytest.mark.parametrize("hard", [False, True])
@pytest.mark.parametrize("K,N,per_row", [(256, 256, False), (64, 128, True)])
def test_fakequant_vs_ref(rng, hard, K, N, per_row):
    w = jnp.asarray(rng.normal(size=(K, N)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(K, N)), jnp.float32)
    srows = K if per_row else 1
    s = jnp.asarray(rng.uniform(0.01, 0.1, size=(srows, N)), jnp.float32)
    ref = fakequant_ref(w, v, s, -8, 7, hard)
    out = fakequant(w, v, s, qmin=-8, qmax=7, hard=hard, bk=64, bn=128,
                    interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-6)


def test_fakequant_matches_core_adaround(rng):
    """Kernel == core.adaround on the shared per-channel symmetric case."""
    from repro.core import adaround
    from repro.kernels.fakequant.ops import adaround_forward

    w = jnp.asarray(rng.normal(size=(64, 32)), jnp.float32)
    cfg = QConfig(bits=4, channel_axis=-1)
    st = init_qstate(w, cfg)
    v = adaround.init_v(w, st, cfg)
    for hard in (False, True):
        core = (adaround.hard_quant if hard else adaround.soft_quant)(w, v, st, cfg)
        kern = adaround_forward(w, v, st, cfg, hard=hard, backend="pallas")
        np.testing.assert_allclose(np.asarray(kern), np.asarray(core), atol=1e-5)


def test_fakequant_unsupported_config_raises_typed(rng):
    """Grouped or asymmetric configs the fused kernel does not cover
    raise KernelSpecError naming the config (used to be a bare assert
    with no message), and bad ranks name the offending shape."""
    import pytest

    from repro.kernels import KernelSpecError
    from repro.kernels.fakequant.ops import adaround_forward

    w = jnp.asarray(rng.normal(size=(64, 32)), jnp.float32)
    cfg = QConfig(bits=4, channel_axis=-1)
    st = init_qstate(w, cfg)
    v = jnp.zeros_like(w)

    grouped = QConfig(bits=4, channel_axis=-1, group_size=32)
    with pytest.raises(KernelSpecError, match="group_size=32"):
        adaround_forward(w, v, st, grouped)
    asym = QConfig(bits=4, channel_axis=-1, symmetric=False)
    with pytest.raises(KernelSpecError, match="symmetric=False"):
        adaround_forward(w, v, st, asym)
    with pytest.raises(KernelSpecError, match=r"\(64, 32, 1\)"):
        adaround_forward(w[..., None], v, st, cfg)
