"""Distribution layer: sharding rules (unit) + multi-device execution
(subprocess with 8 placeholder devices) + gradient compression."""
import json
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

SRC = str(Path(__file__).resolve().parents[1] / "src")


def run_subprocess(code: str) -> str:
    env = {"PYTHONPATH": SRC, "PATH": "/usr/bin:/bin:/usr/local/bin",
           "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
           "HOME": "/root"}
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=900, env=env)
    assert r.returncode == 0, r.stderr[-3000:]
    return r.stdout


def test_param_specs_unit():
    code = """
    import jax
    from jax.sharding import PartitionSpec as P
    from repro.dist.sharding import Plan, pick_strategy
    from repro.models import get_config

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    cfg = get_config("tinyllama_1_1b")
    plan = Plan(mesh=mesh, strategy="tp", cfg=cfg)

    class K:  # fake DictKey
        def __init__(self, key): self.key = key

    # column-parallel in-proj: N over model
    s = plan.param_spec((K("body"), K("sub0"), K("attn"), K("wq"), K("w")), (22, 2048, 2048))
    assert s[-1] == "model" and s[-2] is None, s
    # row-parallel out-proj: K over model
    s = plan.param_spec((K("body"), K("sub0"), K("attn"), K("wo"), K("w")), (22, 2048, 2048))
    assert s[-2] == "model" and s[-1] is None, s
    # norms replicate
    s = plan.param_spec((K("body"), K("sub0"), K("norm1"), K("g")), (22, 2048))
    assert all(x is None for x in s) or s == P()
    # MoE experts over model
    plan_moe = Plan(mesh=mesh, strategy="fsdp", cfg=get_config("qwen3_moe_235b_a22b"))
    s = plan_moe.param_spec((K("moe"), K("sub0"), K("moe"), K("w_gate"), K("w")), (94, 128, 4096, 1536))
    assert s[1] == "model" and s[-1] == "data", s
    # zero3: largest dim over joint axes
    plan_z = Plan(mesh=mesh, strategy="zero3", cfg=cfg)
    s = plan_z.param_spec((K("body"), K("sub0"), K("mlp"), K("w_up"), K("w")), (22, 2048, 5632))
    assert s[-1] == ("data", "model"), s
    print("unit ok")
    """
    assert "unit ok" in run_subprocess(code)


def test_packed_param_rules_unit():
    """Partition rules for packed-int (`repro.deploy`) leaves: codes shard
    along N (plus E for expert stacks), the packed row dim never shards,
    qscale siblings replicate, and eval_shape(quantize_tree) trees flow
    through params_sharding — incl. the int8-container (W3) fallback."""
    code = """
    import jax, jax.numpy as jnp
    from repro import deploy
    from repro.dist.sharding import Plan
    from repro.launch import specs as specs_mod
    from repro.models import get_model

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    cfg, model = get_model("tinyllama_1_1b", reduced=True)
    plan = Plan(mesh=mesh, strategy="tp", cfg=cfg)
    params = specs_mod.params_specs(model)
    packed = jax.eval_shape(lambda p: deploy.quantize_tree(p, 4, 64), params)

    sh = plan.params_sharding(packed)
    attn = packed["body"]["sub0"]["attn"]
    assert attn["wq"]["w"].dtype == jnp.int8
    ash = sh["body"]["sub0"]["attn"]
    # codes: N over model; packed K rows never shard (even row-parallel wo)
    assert ash["wq"]["w"].spec[-1] == "model", ash["wq"]["w"].spec
    assert ash["wo"]["w"].spec[-1] == "model" and ash["wo"]["w"].spec[-2] is None
    # qscale siblings replicate
    assert all(s is None for s in ash["wq"]["qscale"].spec)
    # int8 embedding table keeps the vocab-parallel rule, its scale replicates
    assert sh["embed"]["table"].spec[0] == "model", sh["embed"]["table"].spec
    assert all(s is None for s in sh["embed"]["table_qscale"].spec)

    # W3 falls back to an int8 container (rows == K) and stays shardable
    packed3 = jax.eval_shape(lambda p: deploy.quantize_tree(p, 3, None), params)
    w3 = packed3["body"]["sub0"]["attn"]["wq"]["w"]
    assert w3.shape[-2] == params["body"]["sub0"]["attn"]["wq"]["w"].shape[-2]
    sh3 = plan.params_sharding(packed3)
    assert sh3["body"]["sub0"]["attn"]["wq"]["w"].spec[-1] == "model"

    # packed MoE experts: E over model, N over the fsdp axis, router FP
    cfg2, model2 = get_model("qwen3_moe_235b_a22b", reduced=True)
    plan2 = Plan(mesh=mesh, strategy="fsdp", cfg=cfg2)
    p2 = specs_mod.params_specs(model2)
    pk2 = jax.eval_shape(lambda p: deploy.quantize_tree(p, 4, None), p2)
    moe = pk2["moe"]["sub0"]["moe"]
    assert "qscale" not in moe["router"] and "qscale" in moe["w_gate"]
    msh = plan2.params_sharding(pk2)["moe"]["sub0"]["moe"]
    wsh = msh["w_gate"]["w"].spec
    assert wsh[1] == "model" and wsh[-1] == "data", wsh
    assert all(s is None for s in msh["w_gate"]["qscale"].spec)
    print("packed rules ok")
    """
    assert "packed rules ok" in run_subprocess(code)


def test_dryrun_reduced_quant_decode_cell(tmp_path):
    """The dry-run CLI lowers + compiles a reduced --quant 4 decode cell
    (packed int codes through params_sharding) on an 8-device host mesh."""
    env = {"PYTHONPATH": SRC, "PATH": "/usr/bin:/bin:/usr/local/bin",
           "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
           "HOME": "/root"}
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--reduced",
         "--arch", "tinyllama_1_1b", "--shape", "decode_32k",
         "--mesh", "single", "--quant", "4", "--group", "64",
         "--tag", "w4", "--out", str(tmp_path)],
        capture_output=True, text=True, timeout=900, env=env)
    assert r.returncode == 0, r.stderr[-3000:]
    out = json.loads(
        (tmp_path / "tinyllama_1_1b_decode_32k_single_w4.json").read_text())
    assert out["reduced"] and out["quant"] == 4 and out["n_chips"] == 8
    assert out["memory_analysis"]  # compiled.memory_analysis() was real


def test_train_rejects_int8_compress_with_model_shard():
    """--grad-compress int8 runs a DP-only shard_map; combining it with a
    model axis must be rejected up front, not silently ignored."""
    from repro.launch.train import parse_args

    with pytest.raises(SystemExit):
        parse_args(["--grad-compress", "int8", "--model-shard", "2"])
    args = parse_args(["--grad-compress", "int8", "--model-shard", "1"])
    assert args.grad_compress == "int8"


def test_tp_train_step_executes():
    """One real train step on a (4,2) mesh: loss finite, params updated,
    shardings as planned."""
    code = """
    import jax, jax.numpy as jnp
    from repro.configs.base import ShapeSpec
    from repro.dist.sharding import Plan
    from repro.models import get_model
    from repro.launch import steps as steps_mod
    from repro.optim import adam

    cfg, model = get_model("tinyllama_1_1b", reduced=True)
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    plan = Plan(mesh=mesh, strategy="tp", cfg=cfg)
    shape = ShapeSpec("t", seq_len=32, global_batch=8, kind="train")
    low = steps_mod.make_train_step(model, plan, shape, remat="dots")
    fn = low.jit()
    params = model.init(jax.random.PRNGKey(0))
    opt = adam.init(params)
    import numpy as np
    batch = {"tokens": jnp.asarray(np.random.default_rng(0).integers(0, cfg.vocab, (8, 32)))}
    p2, o2, m = fn(params, opt, batch)
    assert jnp.isfinite(m["loss"]), m
    l2 = float(fn(p2, o2, batch)[2]["loss"])
    assert l2 < float(m["loss"]), (float(m["loss"]), l2)
    print("tp step ok", float(m["loss"]), l2)
    """
    assert "tp step ok" in run_subprocess(code)


def test_grad_compress_int8_matches_uncompressed():
    code = """
    import jax, jax.numpy as jnp, numpy as np
    from repro.models import get_model
    from repro.optim import adam
    from repro.optim.grad_compress import init_error, make_dp_train_step

    cfg, model = get_model("brecq_lm_100m", reduced=True)
    mesh = jax.make_mesh((8,), ("data",))
    acfg = adam.AdamConfig(lr=1e-3)
    params = model.init(jax.random.PRNGKey(0))
    opt = adam.init(params)
    err = init_error(params)
    step = make_dp_train_step(model, mesh, acfg, remat="none")
    batch = {"tokens": jnp.asarray(np.random.default_rng(0).integers(0, cfg.vocab, (16, 32)))}
    losses = []
    for i in range(8):
        params, opt, err, loss = step(params, opt, err, batch)
        losses.append(float(loss))
    assert losses[-1] < losses[0], losses  # training progresses under int8 AR
    enorm = sum(float(jnp.sum(jnp.abs(e))) for e in jax.tree.leaves(err))
    assert enorm > 0  # error feedback active
    print("compress ok", losses[0], losses[-1])
    """
    assert "compress ok" in run_subprocess(code)


def test_dryrun_reduced_multi_mesh():
    """A reduced arch lowers + compiles on a (2,2,2) pod,data,model mesh —
    the multi-pod path at toy scale."""
    code = """
    import jax, jax.numpy as jnp
    from repro.configs.base import ShapeSpec
    from repro.dist.sharding import Plan
    from repro.models import get_model
    from repro.launch import steps as steps_mod

    cfg, model = get_model("deepseek_moe_16b", reduced=True)
    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    plan = Plan(mesh=mesh, strategy="tp", cfg=cfg)
    shape = ShapeSpec("t", seq_len=32, global_batch=8, kind="train")
    compiled = steps_mod.make_train_step(model, plan, shape, remat="dots").lower().compile()
    assert compiled.memory_analysis() is not None
    shape2 = ShapeSpec("d", seq_len=64, global_batch=4, kind="decode")
    c2 = steps_mod.make_decode_step(model, plan, shape2).lower().compile()
    print("multi ok")
    """
    assert "multi ok" in run_subprocess(code)


def test_hlo_analyzer_counts_scan_flops():
    """The while-aware parser multiplies loop bodies by trip count."""
    code = """
    import jax, jax.numpy as jnp
    from repro.analysis.hlo import analyze_module

    def f(w, x):
        def body(x, wi):
            return jnp.tanh(x @ wi), None
        y, _ = jax.lax.scan(body, x, w)
        return y.sum()

    w = jax.ShapeDtypeStruct((6, 128, 128), jnp.float32)
    x = jax.ShapeDtypeStruct((32, 128), jnp.float32)
    txt = jax.jit(f).lower(w, x).compile().as_text()
    s = analyze_module(txt)
    expected = 2 * 32 * 128 * 128 * 6
    assert abs(s.flops - expected) / expected < 0.05, (s.flops, expected)
    print("hlo ok", s.flops)
    """
    assert "hlo ok" in run_subprocess(code)
