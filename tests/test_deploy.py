"""Packed-int deployment API: pack/unpack, qmm parity, artifact lifecycle.

Covers the `repro.deploy` contract end to end:
  * leaf round trips across bits/group (incl. K not divisible by the
    group or the packing factor — container fallback),
  * qmm-vs-fake-quant matmul parity on BRECQ-style quantized weights,
  * export -> save -> load -> evaluate bit-exactness, manifest round
    trip, mixed-precision (`per_layer_bits`) export,
  * packed prefill/decode logits vs the baked `params_q` forward,
  * `quantize_tree` traceability (the launch layer eval_shapes it).
"""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ReconConfig, quantize
from repro.core.evaluate import evaluate
from repro.core.quantizer import QConfig, init_qstate, quantize_dequant
from repro.deploy import (QuantizedArtifact, container_bits, dequant_leaf,
                          export, quantize_tree, rtn_artifact, rtn_pack_leaf,
                          tree_bytes)


# ---------------------------------------------------------------------------
# leaf round trips
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("bits", [2, 3, 4, 8])  # 3: int8-container fallback
@pytest.mark.parametrize("K,group", [
    (128, None),   # per-channel
    (128, 64),     # grouped
    (128, 128),    # one group == per-channel
    (96, 64),      # group does not divide K -> per-channel fallback
    (100, None),   # K not divisible by 8/bits -> int8 container fallback
    (6, 4),        # tiny ragged K
])
def test_rtn_pack_leaf_matches_fake_quant(rng, bits, K, group):
    """dequant(pack(w)) == quantize_dequant under the equivalent QConfig."""
    w = jnp.asarray(rng.normal(size=(K, 32)), jnp.float32)
    packed, scales = rtn_pack_leaf(w, bits, group)
    got = dequant_leaf(packed, scales, K)

    g = group if (group and K % group == 0) else None
    cfg = QConfig(bits=bits, channel_axis=-1, group_size=g)
    ref = quantize_dequant(w, init_qstate(w, cfg), cfg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=1e-6)
    # container accounting: sub-byte only when K divides the pack factor
    assert packed.dtype == jnp.int8
    per = 8 // container_bits(bits, K)
    assert packed.shape == (K // per, 32)


@pytest.mark.parametrize("bits", [2, 4])
def test_container_promotion_is_exact(rng, bits):
    """Narrow codes stored in a wider container dequantize unchanged —
    the mechanism mixed-precision stacked leaves rely on."""
    from repro.core.quantizer import pack_int, unpack_int

    K, N = 64, 16
    lo, hi = -(2 ** (bits - 1)), 2 ** (bits - 1) - 1
    codes = jnp.asarray(rng.integers(lo, hi + 1, size=(K, N)), jnp.int8)
    for cbits in (bits, 4, 8):
        if cbits < bits:
            continue
        back = unpack_int(pack_int(codes, cbits), cbits, K)
        np.testing.assert_array_equal(np.asarray(back), np.asarray(codes))


def test_stacked_leaf_roundtrip(rng):
    """(n, E, K, N) MoE-style stacked leaves pack along the K axis."""
    w = jnp.asarray(rng.normal(size=(3, 4, 64, 16)), jnp.float32)
    packed, scales = rtn_pack_leaf(w, 4, 32)
    assert packed.shape == (3, 4, 32, 16) and scales.shape == (3, 4, 2, 16)
    got = dequant_leaf(packed, scales, 64)
    err = jnp.abs(got - w)
    assert float(jnp.max(err)) < float(jnp.max(jnp.abs(w)))  # sane
    # idempotency: re-packing the dequantized values is exact
    p2, s2 = rtn_pack_leaf(got, 4, 32)
    np.testing.assert_allclose(np.asarray(dequant_leaf(p2, s2, 64)),
                               np.asarray(got), atol=1e-6)


# ---------------------------------------------------------------------------
# qmm parity on BRECQ-exported weights
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("bits,group", [(2, 64), (4, None), (4, 128), (8, 64)])
def test_qmm_matches_fake_quant_matmul(rng, bits, group):
    """x @ hard_quant(w) == qmm(x, packed hard codes) — the serving path
    reproduces the calibration-time fake-quant matmul."""
    from repro.core import adaround
    from repro.kernels.qmatmul.ops import from_node, qmm

    K, N, M = 256, 128, 16
    w = jnp.asarray(rng.normal(size=(K, N)), jnp.float32)
    cfg = QConfig(bits=bits, channel_axis=-1, group_size=group)
    st = init_qstate(w, cfg)
    v = jnp.asarray(rng.normal(size=(K, N)), jnp.float32)
    from repro.core.quantizer import pack_int

    codes = adaround.hard_int_codes(w, v, st, cfg)
    node = {"w": pack_int(codes, bits, axis=0),
            "qscale": st.scale.reshape(-1, N)}
    x = jnp.asarray(rng.normal(size=(M, K)), jnp.float32)
    ref = x @ adaround.hard_quant(w, v, st, cfg)
    for backend in ("xla", "pallas"):
        out = qmm(x, from_node(node, K), backend=backend)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=1e-3, rtol=1e-4)


# ---------------------------------------------------------------------------
# artifact lifecycle on a calibrated model
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def w4_export(tiny_trained):
    """One W4 calibration + export shared by the lifecycle tests."""
    cfg, model, params, calib, evalb, _ = tiny_trained
    res = quantize(model, params, calib[:3], ReconConfig(w_bits=4, iters=20))
    return model, params, res, export(model, res), evalb


def _assert_dequant_equals_baked(art_params, params_q, path=()):
    if not isinstance(art_params, dict):
        return
    if "table_qscale" in art_params:
        dq = (art_params["table"].astype(jnp.float32)
              * art_params["table_qscale"][0])
        np.testing.assert_allclose(np.asarray(dq),
                                   np.asarray(params_q["table"]), atol=0)
        return
    if "qscale" in art_params:
        k = params_q["w"].shape[-2]
        dq = dequant_leaf(art_params["w"], art_params["qscale"], k)
        np.testing.assert_allclose(np.asarray(dq), np.asarray(params_q["w"]),
                                   atol=1e-6, err_msg=str(path))
        return
    for key in art_params:
        _assert_dequant_equals_baked(art_params[key], params_q[key], path + (key,))


def test_export_is_exact_and_smaller(w4_export):
    model, params, res, art, evalb = w4_export
    _assert_dequant_equals_baked(art.params, res.params_q)
    assert art.nbytes() < tree_bytes(params)
    assert art.stats["artifact_bytes"] == art.nbytes()
    assert art.stats["pack_wall_s"] > 0
    # telemetry surfaced from quantize() matches the export
    assert res.stats["w_bits"] == 4
    assert res.stats["bits_histogram"] == art.stats["bits_histogram"]


def test_export_save_load_evaluate_bitexact(w4_export, tmp_path):
    model, params, res, art, evalb = w4_export
    art.save(str(tmp_path / "art"))
    loaded = QuantizedArtifact.load(str(tmp_path / "art"))
    # manifest round trip (bits map, group, arch)
    assert loaded.manifest == art.manifest
    assert loaded.manifest["arch"] == model.cfg.name
    assert set(loaded.manifest["bits_by_path"]) == set(res.qstates)
    # packed leaves round trip exactly (incl. int8 dtypes)
    for a, b in zip(jax.tree.leaves(art.params), jax.tree.leaves(loaded.params)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # evaluate: loaded artifact == in-memory artifact, ~= baked params_q
    e_art = evaluate(model, art, evalb[:1])
    e_load = evaluate(model, loaded, evalb[:1])
    e_ref = evaluate(model, res.params_q, evalb[:1])
    assert e_art["loss"] == e_load["loss"]
    assert abs(e_art["loss"] - e_ref["loss"]) < 1e-4


def test_packed_decode_matches_baked_forward(w4_export):
    """Acceptance: prefill + decode from packed codes tracks the baked
    fake-quant forward (same hard rounding, f32 accumulation)."""
    model, params, res, art, evalb = w4_export
    B, S, G = 2, 16, 4
    toks = evalb[0]["tokens"][:B, :S]

    def run(p, hook=None):
        from repro.models.common import NO_QUANT

        hook = hook or NO_QUANT
        cache = model.init_cache(B, S + G, jnp.float32)
        logits, cache = jax.jit(
            lambda p, b, c: model.prefill(p, b, c, hook, remat="none"))(
                p, {"tokens": toks}, cache)
        outs = [logits]
        tok = jnp.argmax(logits, -1)[:, None]
        step = jax.jit(lambda p, t, c, pos: model.decode_step(p, t, c, pos, hook))
        for i in range(G):
            pos = jnp.full((B,), S + i, jnp.int32)
            logits, cache = step(p, tok, cache, pos)
            outs.append(logits)
            tok = jnp.argmax(logits, -1)[:, None]
        return outs

    ref = run(res.params_q)
    got = run(art.params, art.hook())
    for r, g in zip(ref, got):
        np.testing.assert_allclose(np.asarray(g), np.asarray(r),
                                   atol=2e-4, rtol=1e-4)


def test_mixed_export_histogram_matches_bits_by_path(tiny_trained, tmp_path):
    """After container promotion — including W3, which always ships in an
    int8 container — the manifest's per-path bits and the stats histogram
    must agree, and both must survive a save/load round trip. The budget
    solver's accounting leans on bits_by_path recording *logical* widths,
    never the promoted container."""
    cfg, model, params, calib, evalb, _ = tiny_trained
    mixed = {"body.0/sub0/attn/wq": 3, "body.1/sub0/attn/wq": 8,
             "body.1/sub0/mlp/w_down": 2}
    res = quantize(model, params, calib[:2],
                   ReconConfig(w_bits=4, iters=5, per_layer_bits=mixed))
    art = export(model, res)
    for path, bits in mixed.items():
        assert art.manifest["bits_by_path"][path] == bits

    def hist_of(bits_by_path):
        h = {}
        for b in bits_by_path.values():
            h[str(b)] = h.get(str(b), 0) + 1
        return h

    assert art.stats["bits_histogram"] == hist_of(art.manifest["bits_by_path"])
    art.save(str(tmp_path / "w3mixed"))
    back = QuantizedArtifact.load(str(tmp_path / "w3mixed"))
    assert back.manifest["bits_by_path"] == art.manifest["bits_by_path"]
    assert back.stats["bits_histogram"] == art.stats["bits_histogram"]
    # the W3 layer's stack really is int8-promoted on disk, yet still
    # dequantizes exactly
    _assert_dequant_equals_baked(back.params, res.params_q)
    wq = back.params["body"]["sub0"]["attn"]["wq"]
    k = res.params_q["body"]["sub0"]["attn"]["wq"]["w"].shape[-2]
    assert wq["w"].shape[-2] == k  # one int8 row per code row


def test_mixed_precision_export(tiny_trained):
    """per_layer_bits exports exactly via container promotion and the
    manifest records the true per-path widths."""
    cfg, model, params, calib, evalb, _ = tiny_trained
    mixed = {"body.1/sub0/attn/wq": 2, "body.0/sub0/mlp/w_up": 8}
    res = quantize(model, params, calib[:2],
                   ReconConfig(w_bits=4, iters=5, w_group=64,
                               per_layer_bits=mixed))
    art = export(model, res)
    _assert_dequant_equals_baked(art.params, res.params_q)
    for path, bits in mixed.items():
        assert art.manifest["bits_by_path"][path] == bits
    assert art.manifest["w_group"] == 64
    hist = art.stats["bits_histogram"]
    assert hist.get("2") == 1 and hist.get("8", 0) >= 2  # 8: w_up + embed


# ---------------------------------------------------------------------------
# RTN fast path + launch-layer contracts
# ---------------------------------------------------------------------------


def test_quantize_tree_traceable_under_eval_shape(tiny_trained):
    """steps.py eval_shapes quantize_tree to build abstract serve params."""
    cfg, model, params, calib, _, _ = tiny_trained
    sds = jax.eval_shape(lambda p: quantize_tree(p, 4, 64), params)
    concrete = quantize_tree(params, 4, 64)
    flat_a = jax.tree_util.tree_flatten_with_path(sds)[0]
    flat_b = jax.tree_util.tree_flatten_with_path(concrete)[0]
    for (pa, a), (pb, b) in zip(flat_a, flat_b):
        assert pa == pb and a.shape == b.shape and a.dtype == b.dtype


def test_rtn_artifact_skips_router_and_norms(rng):
    """MoE router and 1-D leaves stay FP; expert weights pack."""
    from repro.models import get_model

    cfg, model = get_model("deepseek_moe_16b", reduced=True)
    params = model.init(jax.random.PRNGKey(0))
    art = rtn_artifact(params, 4, cfg=cfg)
    moe0 = art.params["moe"]["sub0"]["moe"]
    assert "qscale" not in moe0["router"]
    assert moe0["router"]["w"].dtype == jnp.float32
    assert moe0["w_gate"]["w"].dtype == jnp.int8 and "qscale" in moe0["w_gate"]
    # packed MoE forward runs
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (2, 8)))
    logits, _ = model.prefill(art.params, {"tokens": toks},
                              model.init_cache(2, 16, jnp.float32), remat="none")
    assert np.isfinite(np.asarray(logits)).all()


def test_quantize_tree_idempotent(rng):
    """Re-applying quantize_tree must not re-quantize packed nodes —
    incl. the embedding (codes would be re-scaled by a scale derived
    from the codes themselves)."""
    from repro.models import get_model

    cfg, model = get_model("brecq_lm_100m", reduced=True)
    params = model.init(jax.random.PRNGKey(0))
    once = quantize_tree(params, 4, 64)
    twice = quantize_tree(once, 4, 64)
    for a, b in zip(jax.tree.leaves(once), jax.tree.leaves(twice)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_steps_module_importable():
    """The launch step-builder must import (its deploy dependency is
    real now); only a concrete Plan — the still-phantom dist.sharding —
    is needed to *run* the builders."""
    import importlib

    steps = importlib.import_module("repro.launch.steps")
    assert hasattr(steps, "make_prefill_step")


def test_serve_rejects_mismatched_artifact(tmp_path):
    """--artifact for a different model shape fails with a clear error,
    not an opaque einsum crash."""
    from repro.launch import serve
    from repro.models import get_model

    cfg, model = get_model("tinyllama_1_1b", reduced=True)
    params = model.init(jax.random.PRNGKey(0))
    rtn_artifact(params, 4, cfg=cfg).save(str(tmp_path / "art"))
    from repro.deploy import ArtifactMismatchError
    with pytest.raises(ArtifactMismatchError, match="exported for"):
        serve.main(["--reduced", "--artifact", str(tmp_path / "art"),
                    "--batch", "2", "--prompt-len", "8", "--gen-len", "2"])


def test_restore_nested_roundtrip(tmp_path):
    """ckpt structure-free restore rebuilds dict trees incl. int8 leaves."""
    from repro.ckpt import CheckpointManager

    tree = {"a": {"b": jnp.arange(6, dtype=jnp.int8).reshape(2, 3),
                  "c": jnp.ones((4,), jnp.float32)},
            "d": jnp.zeros((2, 2), jnp.bfloat16)}
    mgr = CheckpointManager(str(tmp_path), keep=1)
    mgr.save(0, tree, meta={"manifest": {"x": 1}})
    back = mgr.restore_nested(0)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert mgr.manifest(0)["meta"]["manifest"] == {"x": 1}


def test_serve_cli_end_to_end(tmp_path):
    """The acceptance flow: serve --reduced --quant 4 from a saved
    artifact — packed bytes < fp bytes is asserted inside main()."""
    from repro.launch import serve

    gen = serve.main(["--reduced", "--quant", "4", "--batch", "2",
                      "--prompt-len", "16", "--gen-len", "4",
                      "--save-artifact", str(tmp_path / "art")])
    assert gen.shape == (2, 4)
    # the artifact really was shipped to disk and reloads standalone
    art = QuantizedArtifact.load(str(tmp_path / "art"))
    assert art.manifest["bits_by_path"]
