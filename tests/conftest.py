import os

# Tests run on the single real CPU device. The 512-device override is
# exclusively for launch/dryrun.py (per assignment).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import sys
from pathlib import Path

SRC = str(Path(__file__).resolve().parents[1] / "src")
if SRC not in sys.path:
    sys.path.insert(0, SRC)

import jax
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def tiny_trained():
    """A small trained LM shared by reconstruction/baseline/system tests."""
    import jax.numpy as jnp

    from repro.data import Corpus, CorpusConfig, make_batches
    from repro.models import get_model
    from repro.optim import adam

    cfg, model = get_model("brecq_lm_100m", reduced=True)
    corpus = Corpus(CorpusConfig(vocab=cfg.vocab))
    params = model.init(jax.random.PRNGKey(0))
    acfg = adam.AdamConfig(lr=3e-3, grad_clip=1.0)
    state = adam.init(params)

    @jax.jit
    def step(params, state, batch):
        loss, g = jax.value_and_grad(
            lambda p: model.loss(p, batch, remat="none"))(params)
        return (*adam.update(acfg, g, state, params), loss)

    for i in range(200):
        batch = make_batches(corpus, 1, 16, 64, seed=0, start_step=i)[0]
        params, state, loss = step(params, state, batch)
    calib = make_batches(corpus, 6, 8, 64, seed=1, start_step=1000)
    evalb = make_batches(corpus, 3, 16, 64, seed=2, start_step=2000)
    return cfg, model, params, calib, evalb, float(loss)
