"""The static auditor catches a seeded violation of each rule family —
and passes the repo's real programs at HEAD.

Program family: each rule is driven to fire by injecting its failure
mode into a real-shaped program (forced full f32 dequant on a decode
path, a donation the compiled module drops, a host callback, a retrace).
Kernel family: a mis-tiled BlockSpec and a VMEM blow-up through the same
describe_* specs the kernel wrappers call. AST family: an offending
source file through the same linter CI runs over src/.
"""
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.audit import ast_lint, kernel_check  # noqa: F401 (register catalog rules)
from repro.analysis.audit.program_check import (forbidden_f32_shapes,
                                                qmm_programs)
from repro.analysis.audit.rules import (AuditProgram, Violation,
                                        count_io_aliases, iter_jaxprs,
                                        registered_rules, run_program_rules)
from repro.deploy import dequant_leaf, rtn_pack_leaf
from repro.kernels.spec import (VMEM_BUDGET_BYTES, KernelSpecError,
                                describe_qgemv, describe_qmatmul,
                                describe_qmatmul_grouped, largest_tile)

ROOT = Path(__file__).resolve().parents[1]


def _packed(rng, shape, bits=4):
    w = jnp.asarray(rng.normal(size=shape), jnp.float32)
    wp, qs = rtn_pack_leaf(w, bits, None)
    return {"w": wp, "qscale": qs}


# ---------------------------------------------------------------------------
# program rules: seeded violations
# ---------------------------------------------------------------------------


def test_seeded_f32_dequant_fires(rng):
    """A decode-shaped program that routes a stacked expert node through
    the full dequant reference trips no_materialized_f32_weight."""
    E, K, N = 4, 64, 128
    node = _packed(rng, (E, K, N))
    forbidden = forbidden_f32_shapes({"moe": node})
    assert (E, K, N) in forbidden

    def bad_decode(x, w, qs):
        wf = dequant_leaf(w, qs, K)  # f32 (E, K, N) materialized
        return jnp.einsum("emk,ekn->emn", x, wf)

    prog = AuditProgram(
        name="seeded_dequant", fn=bad_decode,
        args=(jnp.ones((E, 2, K), jnp.float32), node["w"], node["qscale"]),
        forbidden_f32=forbidden)
    found = run_program_rules([prog], rules=("no_materialized_f32_weight",))
    assert found and all(v.rule == "no_materialized_f32_weight"
                         for v in found)
    assert f"{(E, K, N)}" in found[0].message


def test_seeded_dropped_donation_fires():
    """A declared donation the compiled module cannot honor (no output
    matches the donated buffer) trips donation_respected."""

    def f(x, c):
        return x + 1.0  # c: declared donated, aliased into nothing

    prog = AuditProgram(
        name="seeded_drop", fn=f,
        args=(jnp.ones((4,), jnp.float32), jnp.ones((8,), jnp.float32)),
        donate_argnums=(1,))
    found = run_program_rules([prog], rules=("donation_respected",))
    assert found and found[0].rule == "donation_respected"
    assert "aliases only 0" in found[0].message


def test_donation_respected_on_honored_donation():
    """Sanity: a donation the compiler keeps passes the same rule."""

    def f(x, c):
        return x + c

    prog = AuditProgram(
        name="honored", fn=f,
        args=(jnp.ones((4,), jnp.float32), jnp.ones((4,), jnp.float32)),
        donate_argnums=(1,))
    assert run_program_rules([prog], rules=("donation_respected",)) == []


def test_seeded_host_callback_fires():
    """A python callback smuggled into a 'hot' program trips
    no_host_transfer via its custom-call in the optimized HLO."""

    def f(x):
        y = jax.pure_callback(
            lambda a: np.asarray(a) * 2,
            jax.ShapeDtypeStruct(x.shape, x.dtype), x)
        return y + 1

    prog = AuditProgram(name="seeded_callback", fn=f,
                        args=(jnp.ones((4,), jnp.float32),))
    found = run_program_rules([prog], rules=("no_host_transfer",))
    assert found and found[0].rule == "no_host_transfer"
    assert "callback" in found[0].message


def test_seeded_retrace_fires():
    """repeat_args with a different structure force a second trace —
    stable_compile_cache reports the cache growth."""

    def f(x):
        return x * 2

    prog = AuditProgram(
        name="seeded_retrace", fn=f,
        args=(jnp.ones((4,), jnp.float32),),
        repeat_args=(jnp.ones((4,), jnp.bfloat16),))
    found = run_program_rules([prog], rules=("stable_compile_cache",))
    assert found and "retraced" in found[0].message


def test_suppression_skips_rule_and_is_surfaced():
    def f(x):
        return x * 2

    prog = AuditProgram(
        name="suppressed", fn=f, args=(jnp.ones((4,), jnp.float32),),
        repeat_args=(jnp.ones((4,), jnp.bfloat16),),
        suppress={"stable_compile_cache": "intentional dtype probe"})
    log = []
    assert run_program_rules([prog], rules=("stable_compile_cache",),
                             verbose=log.append) == []
    assert any("intentional dtype probe" in s for s in log)


def test_real_qmm_programs_clean(rng):
    """The actual dispatch-tier programs audit clean (HEAD must pass)."""
    assert run_program_rules(qmm_programs(jax.random.PRNGKey(7))) == []


def test_iter_jaxprs_covers_scan_body():
    def f(x):
        def body(c, _):
            return c * 2.0, ()
        y, _ = jax.lax.scan(body, x, None, length=3)
        return y

    jaxpr = jax.make_jaxpr(f)(jnp.ones((4,), jnp.float32))
    prims = {e.primitive.name for jx in iter_jaxprs(jaxpr.jaxpr)
             for e in jx.eqns}
    assert "scan" in prims and "mul" in prims  # outer + body both walked


def test_count_io_aliases_nested_braces():
    hlo = ('HloModule m, input_output_alias={ {}: (1, {}, may-alias), '
           '{0}: (2, {}, must-alias) }\n')
    assert count_io_aliases(hlo) == 2
    assert count_io_aliases("HloModule m\n") == 0


# ---------------------------------------------------------------------------
# kernel rules: seeded violations
# ---------------------------------------------------------------------------


def test_seeded_mistiled_blockspec_fires():
    # bm does not divide M
    with pytest.raises(KernelSpecError, match="M=100 is not a multiple"):
        describe_qmatmul((100, 64), (32, 128), (1, 128), bits=4, bm=128,
                         bn=128)
    # packed rows inconsistent with K/bits
    with pytest.raises(KernelSpecError, match="values/byte"):
        describe_qgemv((4, 64), (40, 128), (1, 128), bits=4, bn=128)
    # expert axes disagree
    with pytest.raises(KernelSpecError, match="expert axes"):
        describe_qmatmul_grouped((4, 8, 64), (3, 32, 128), (3, 1, 128),
                                 bits=4, bm=8, bn=128)


def test_seeded_vmem_blowup_fires():
    sp = describe_qmatmul((4096, 512), (256, 8192), (1, 8192), bits=4,
                          bm=4096, bn=8192)
    assert sp.vmem_bytes > VMEM_BUDGET_BYTES
    with pytest.raises(KernelSpecError, match="exceeds the declared budget"):
        sp.check_budget()


def test_kernel_sweep_flags_bad_leaf():
    """The audit sweep converts KernelSpecError into rule violations."""
    out = []
    kernel_check._sweep_leaf(
        "fake_arch", "body/w", 100, (40, 128), (1, 128),
        lambda r, s, m: out.append(Violation(r, s, m)))
    assert out and out[0].rule == "kernel_tile_divisibility"
    # the weight sweep mirrors the runtime tile caps, which bound VMEM
    # by construction — the budget rule is seeded through the KV sweep,
    # whose query-group block scales with the config's head layout
    import dataclasses

    @dataclasses.dataclass
    class FakeCfg:
        n_heads: int = 8
        n_kv_heads: int = 1
        hd: int = 1 << 19

    out2 = []
    kernel_check._sweep_kv("fake_arch", FakeCfg(),
                           lambda r, s, m: out2.append(Violation(r, s, m)))
    assert any(v.rule == "kernel_vmem_budget" for v in out2)


def test_registered_configs_sweep_clean():
    """Every registered full-scale config's launches pass the kernel
    rules (HEAD must pass; brecq + the two canonical serving archs keep
    this test fast, CI's audit job sweeps all archs)."""
    got = kernel_check.run_kernel_checks(
        ["brecq_lm_100m", "deepseek_moe_16b", "h2o_danube3_4b"])
    assert got == [], [str(v) for v in got]


def test_largest_tile_picks_divisors():
    assert largest_tile(3840, 512) == 480
    assert largest_tile(512, 512) == 512
    assert largest_tile(10944, 256) == 228
    assert largest_tile(3840, 512, 2) == 480
    assert largest_tile(7, 4) == 1


# ---------------------------------------------------------------------------
# AST rules: seeded violations
# ---------------------------------------------------------------------------

BAD_SOURCE = '''
import time, jax
import numpy as np

@jax.jit
def step(x):
    t0 = time.perf_counter()
    y = np.asarray(x)
    return x.item()

def helper(x):
    return jax.device_get(x)

jit_helper = jax.jit(helper)

def bad_default(xs=[]):
    return xs

def kern(x, interpret=True):
    assert x.ndim == 2
    return x

def fine(x, interpret=False):  # audit: ignore[no_interpret_default_true]
    return x
'''


def test_seeded_ast_offenders_fire(tmp_path):
    pkg = tmp_path / "kernels"
    pkg.mkdir()
    (pkg / "bad.py").write_text(BAD_SOURCE)
    rules = {v.rule for v in ast_lint.run_ast_lint(tmp_path)}
    assert rules == {"no_host_sync_in_jit", "no_mutable_default_arg",
                     "no_bare_assert_in_kernels",
                     "no_interpret_default_true"}


def test_ast_suppression_comment(tmp_path):
    (tmp_path / "s.py").write_text(
        "def f(xs=[]):  # audit: ignore[no_mutable_default_arg]\n"
        "    return xs\n")
    assert ast_lint.run_ast_lint(tmp_path) == []


def test_src_tree_lints_clean():
    """HEAD must pass its own AST lints."""
    got = ast_lint.run_ast_lint(ROOT / "src")
    assert got == [], [str(v) for v in got]


def test_bare_assert_only_checked_under_kernels(tmp_path):
    (tmp_path / "other.py").write_text("def f(x):\n    assert x\n    return x\n")
    assert ast_lint.run_ast_lint(tmp_path) == []


# ---------------------------------------------------------------------------
# entry point
# ---------------------------------------------------------------------------


def test_run_audit_cli_ast_family_clean():
    p = subprocess.run(
        [sys.executable, str(ROOT / "scripts" / "run_audit.py"),
         "--family", "ast"], capture_output=True, text=True)
    assert p.returncode == 0, p.stdout + p.stderr
    assert "audit clean" in p.stdout


def test_run_audit_cli_exits_nonzero_on_violation(tmp_path):
    """A seeded AST offender dropped into the linted tree flips the CLI
    to exit 1 and the violation is listed."""
    pkg = tmp_path / "kernels"
    pkg.mkdir()
    (pkg / "bad.py").write_text(BAD_SOURCE)
    p = subprocess.run(
        [sys.executable, str(ROOT / "scripts" / "run_audit.py"),
         "--family", "ast", "--src", str(tmp_path)],
        capture_output=True, text=True)
    assert p.returncode == 1, p.stdout + p.stderr
    assert "AUDIT FAILED" in p.stdout
    assert "no_bare_assert_in_kernels" in p.stdout


def test_run_audit_cli_lists_rules():
    p = subprocess.run(
        [sys.executable, str(ROOT / "scripts" / "run_audit.py"),
         "--list-rules"], capture_output=True, text=True)
    assert p.returncode == 0
    for name in ("no_materialized_f32_weight", "donation_respected",
                 "no_host_transfer", "stable_compile_cache",
                 "kernel_tile_divisibility", "kernel_vmem_budget",
                 "no_host_sync_in_jit", "no_mutable_default_arg",
                 "no_bare_assert_in_kernels", "no_interpret_default_true"):
        assert name in p.stdout, name
