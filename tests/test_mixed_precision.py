"""Sensitivity table + genetic-algorithm mixed precision (paper Sec 3.4)."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dep: skip, don't break collection
from hypothesis import given, settings, strategies as st

from repro.core import ReconConfig, quantize
from repro.core.mixed_precision import (GAConfig, TPUCostModel, fitness,
                                        genetic_search, model_bytes,
                                        pareto_sweep)
from repro.core.sensitivity import SensTable, measure


def toy_table(n_layers=6, seed=0):
    rng = np.random.default_rng(seed)
    shapes = {f"body.{i}/sub0/mlp/w_up": (64, 64) for i in range(n_layers)}
    diag = {}
    for i, p in enumerate(shapes):
        base = rng.uniform(0.5, 2.0) * (1 + i)  # deeper layers more sensitive
        diag[(p, 2)] = base
        diag[(p, 4)] = base * 0.1
        diag[(p, 8)] = base * 0.01
    offdiag = {}
    return SensTable(diag=diag, offdiag=offdiag,
                     block_of={p: i for i, p in enumerate(shapes)},
                     shapes=shapes)


def test_ga_respects_constraint():
    sens = toy_table()
    cost = lambda a: model_bytes(sens.shapes, a)
    full8 = model_bytes(sens.shapes, {p: 8 for p in sens.shapes})
    delta = full8 * 0.5
    assign, info = genetic_search(sens, cost, delta, GAConfig(iters=30))
    assert info["cost"] <= delta
    assert set(assign.values()) <= {2, 4, 8}


def test_ga_allocates_high_bits_to_sensitive_layers():
    sens = toy_table()
    cost = lambda a: model_bytes(sens.shapes, a)
    full8 = model_bytes(sens.shapes, {p: 8 for p in sens.shapes})
    assign, _ = genetic_search(sens, cost, full8 * 0.55, GAConfig(iters=60, seed=1))
    paths = sorted(sens.shapes, key=lambda p: sens.diag[(p, 2)])
    # least sensitive layer should get <= bits of the most sensitive
    assert assign[paths[0]] <= assign[paths[-1]]


def test_pareto_monotone():
    sens = toy_table()
    cost = lambda a: model_bytes(sens.shapes, a)
    full8 = model_bytes(sens.shapes, {p: 8 for p in sens.shapes})
    sweep = pareto_sweep(sens, cost, [full8 * f for f in (0.3, 0.6, 1.0)],
                         GAConfig(iters=40))
    fits = [s["fitness"] for s in sweep]
    assert fits[0] >= fits[1] >= fits[2], fits  # looser budget -> better fitness


def test_cost_model_monotone_in_bits():
    # decode-like regime (few tokens): weight streaming dominates, so
    # latency scales with bits; at high token counts compute dominates
    cm = TPUCostModel(tokens_per_step=32)
    shape = (4096, 4096)
    lat = [cm.layer_latency_s(shape, b) for b in (2, 4, 8)]
    assert lat[0] <= lat[1] <= lat[2]
    assert lat[2] / lat[0] > 2.0  # memory-bound: ~4x between W2 and W8
    cm_big = TPUCostModel(tokens_per_step=1 << 20)
    lat_big = [cm_big.layer_latency_s(shape, b) for b in (2, 4, 8)]
    assert abs(lat_big[2] / lat_big[0] - 1.0) < 0.2  # compute-bound: flat


@given(seed=st.integers(0, 1000))
@settings(max_examples=10, deadline=None)
def test_ga_fitness_history_non_increasing(seed):
    sens = toy_table(seed=seed)
    cost = lambda a: model_bytes(sens.shapes, a)
    full8 = model_bytes(sens.shapes, {p: 8 for p in sens.shapes})
    _, info = genetic_search(sens, cost, full8 * 0.6,
                             GAConfig(iters=25, seed=seed))
    h = info["history"]
    assert all(h[i + 1] <= h[i] + 1e-9 for i in range(len(h) - 1))


def test_sensitivity_measure_end_to_end(tiny_trained):
    cfg, model, params, calib, _, _ = tiny_trained
    results = {b: quantize(model, params, calib[:2],
                           ReconConfig(w_bits=b, iters=8, calib_bs=4))
               for b in (2, 4)}
    sens = measure(model, params, calib[:2], results, bits_options=(2, 4),
                   n_samples=8)
    assert len(sens.diag) > 0 and len(sens.shapes) > 0
    # 2-bit quantization hurts more than 4-bit for every layer
    for p in sens.shapes:
        assert sens.diag[(p, 2)] >= sens.diag[(p, 4)] - 1e-9
    assert len(sens.offdiag) > 0  # intra-block pairs exist
