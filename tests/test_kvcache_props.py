"""Property tests: paged KV cache handle + int8 kvattn vs dense reference.

The paged cache's one load-bearing claim is the *identity layout*: after
appending a stream's tokens through an arbitrarily permuted block table,
the gathered per-stream view holds token t at row t — so paged attention
over any physical page assignment equals dense attention over the same
values. Hypothesis drives that across page sizes, GQA group counts,
sliding windows, ragged per-stream lengths, and page reuse after free.
"""
import numpy as np
import pytest

hyp = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.models import common as cm
from repro.serve_engine import PagePool

SET = dict(max_examples=25, deadline=None)


@st.composite
def paged_case(draw):
    ps = draw(st.sampled_from([1, 2, 4, 8]))
    B = draw(st.integers(1, 3))
    K = draw(st.sampled_from([1, 2]))
    G = draw(st.sampled_from([1, 2, 4]))  # H = K * G (GQA groups)
    hd = draw(st.sampled_from([4, 8]))
    max_pages = draw(st.integers(2, 4))
    cap = max_pages * ps
    lens = [draw(st.integers(1, cap)) for _ in range(B)]
    window = draw(st.sampled_from([None, max(1, cap // 2)]))
    seed = draw(st.integers(0, 2**31 - 1))
    return ps, B, K, G, hd, max_pages, lens, window, seed


def _build(ps, B, K, hd, max_pages, lens, seed, kv_dtype):
    """Append each stream's tokens in random-size chunks through a
    PERMUTED block table; return (cache, bt, dense_k, dense_v)."""
    rng = np.random.default_rng(seed)
    num_pages = 1 + B * max_pages
    cache = cm.init_paged_kv(num_pages, ps, K, hd, kv_dtype)
    perm = rng.permutation(np.arange(1, num_pages))
    bt = np.full((B, max_pages), -1, np.int32)
    cap = max_pages * ps
    k = rng.normal(size=(B, cap, K, hd)).astype(np.float32)
    v = rng.normal(size=(B, cap, K, hd)).astype(np.float32)
    pi = 0
    for b in range(B):
        n_pages = -(-lens[b] // ps)
        bt[b, :n_pages] = perm[pi:pi + n_pages]
        pi += n_pages
    btj = jnp.asarray(bt)
    for b in range(B):
        t = 0
        while t < lens[b]:
            c = int(rng.integers(1, lens[b] - t + 1))
            # single-stream append: other rows write to the sink via -1
            bt1 = np.full_like(bt, -1)
            bt1[b] = bt[b]
            pos = np.zeros((B, c), np.int32)
            pos[b] = np.arange(t, t + c)
            cache = cm.paged_append(
                cache, jnp.asarray(np.broadcast_to(k[:, t:t + c], (B, c, K, hd))),
                jnp.asarray(np.broadcast_to(v[:, t:t + c], (B, c, K, hd))),
                jnp.asarray(bt1), jnp.asarray(pos), ps)
            t += c
    return cache, btj, k, v


@settings(**SET)
@given(paged_case())
def test_append_gather_roundtrip_fp(case):
    """fp32 pools: gathered view row t == appended token t, bit-exact,
    for any page permutation and ragged lengths; kpos marks exactly the
    allocated rows."""
    ps, B, K, G, hd, MP, lens, window, seed = case
    cache, bt, k, v = _build(ps, B, K, hd, MP, lens, seed, "float32")
    gather, kpos = cm.paged_view(cache, bt, ps)
    gk = np.asarray(gather(cache["k_pages"]))
    kp = np.asarray(kpos)
    for b in range(B):
        np.testing.assert_array_equal(gk[b, :lens[b]], k[b, :lens[b]])
        n_alloc = -(-lens[b] // ps) * ps
        assert (kp[b, :n_alloc] == np.arange(n_alloc)).all()
        assert (kp[b, n_alloc:] == -1).all()


@settings(**SET)
@given(paged_case())
def test_paged_attend_matches_dense_fp(case):
    """fp32 paged attention == dense decode_attend over the same values
    (windowed and global), at every stream's own ragged length."""
    ps, B, K, G, hd, MP, lens, window, seed = case
    cache, bt, k, v = _build(ps, B, K, hd, MP, lens, seed, "float32")
    rng = np.random.default_rng(seed + 1)
    H = K * G
    q = jnp.asarray(rng.normal(size=(B, 1, H, hd)), jnp.float32)
    pos = jnp.asarray([[l - 1] for l in lens], jnp.int32)
    out = cm.paged_attend(q, cache, bt, pos, ps, window=window, backend="xla")
    cap = MP * ps
    kpos = jnp.broadcast_to(jnp.arange(cap), (B, cap))
    ref = cm.decode_attend(q, jnp.asarray(k), jnp.asarray(v), kpos, pos,
                           window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-5, rtol=1e-5)


@settings(**SET)
@given(paged_case())
def test_paged_attend_int8_vs_dense_fp(case):
    """int8 paged attention through kvattn tracks the dense fp reference
    within quantization tolerance, and matches attend_int8 over the
    dense-quantized values exactly (identity layout)."""
    from repro.kernels.kvattn.ops import attend_int8, quantize_kv

    ps, B, K, G, hd, MP, lens, window, seed = case
    cache, bt, k, v = _build(ps, B, K, hd, MP, lens, seed, "int8")
    rng = np.random.default_rng(seed + 1)
    H = K * G
    q = jnp.asarray(rng.normal(size=(B, 1, H, hd)), jnp.float32)
    pos = jnp.asarray([[l - 1] for l in lens], jnp.int32)
    out = cm.paged_attend(q, cache, bt, pos, ps, window=window, backend="xla")

    cap = MP * ps
    kpos = jnp.broadcast_to(jnp.arange(cap), (B, cap))
    fp = cm.decode_attend(q, jnp.asarray(k), jnp.asarray(v), kpos, pos,
                          window=window)
    np.testing.assert_allclose(np.asarray(out[:, 0]), np.asarray(fp[:, 0]),
                               atol=0.12)

    # exactness vs the same kernel on densely-stored quantized KV: the
    # paged pool must be a pure relayout (f16 scale storage included)
    k8, v8, ks, vs = quantize_kv(jnp.asarray(k), jnp.asarray(v))
    ks = ks.astype(jnp.float16).astype(jnp.float32)
    vs = vs.astype(jnp.float16).astype(jnp.float32)
    # mask rows past each stream's length like the paged kpos does
    kp = np.asarray(jnp.broadcast_to(jnp.arange(cap), (B, cap))).copy()
    for b in range(B):
        n_alloc = -(-lens[b] // ps) * ps
        kp[b, n_alloc:] = -1
    ref8 = attend_int8(q[:, 0], k8, v8, ks, vs, jnp.asarray(kp), pos[:, 0],
                       window=window, backend="xla")
    np.testing.assert_array_equal(np.asarray(out[:, 0]), np.asarray(ref8))


@settings(**SET)
@given(paged_case())
def test_page_reuse_after_free(case):
    """Evict/reuse round-trip: stream A's pages freed and handed to
    stream B; B's view must equal B's data exactly (no stale rows)."""
    ps, B, K, G, hd, MP, lens, window, seed = case
    cache, bt, k, v = _build(ps, B, K, hd, MP, lens, seed, "float32")
    rng = np.random.default_rng(seed + 2)
    # stream 0 "freed": reuse its exact pages for new data, same slot
    n_pages = -(-lens[0] // ps)
    k2 = rng.normal(size=(1, lens[0], K, hd)).astype(np.float32)
    v2 = rng.normal(size=(1, lens[0], K, hd)).astype(np.float32)
    bt1 = np.full((B, MP), -1, np.int32)
    bt1[0] = np.asarray(bt)[0]
    pos = np.zeros((B, lens[0]), np.int32)
    pos[0] = np.arange(lens[0])
    cache = cm.paged_append(
        cache, jnp.asarray(np.broadcast_to(k2, (B, lens[0], K, hd))),
        jnp.asarray(np.broadcast_to(v2, (B, lens[0], K, hd))),
        jnp.asarray(bt1), jnp.asarray(pos), ps)
    gather, _ = cm.paged_view(cache, jnp.asarray(bt1), ps)
    gk = np.asarray(gather(cache["k_pages"]))
    gv = np.asarray(gather(cache["v_pages"]))
    np.testing.assert_array_equal(gk[0, :lens[0]], k2[0])
    np.testing.assert_array_equal(gv[0, :lens[0]], v2[0])


@settings(max_examples=50, deadline=None)
@given(st.integers(2, 40), st.lists(st.integers(0, 5), max_size=60),
       st.integers(0, 2**31 - 1))
def test_page_pool_conservation(num_pages, ops, seed):
    """Allocator invariants under random reserve/alloc/free sequences:
    pages conserved, never double-allocated, page 0 never handed out,
    and full teardown restores the pristine pool."""
    rng = np.random.default_rng(seed)
    pool = PagePool(num_pages)
    live: set = set()
    uid = 0
    for op in ops:
        if op <= 2:  # reserve a new owner
            n = int(rng.integers(1, 4))
            if pool.can_reserve(n):
                pool.reserve(uid, n)
                live.add(uid)
                uid += 1
        elif op == 3 and live:  # alloc against a random owner
            o = sorted(live)[int(rng.integers(len(live)))]
            if pool._reserved.get(o, 0) > 0:
                page = pool.alloc(o)
                assert page != 0
        elif op == 4 and live:  # free an owner
            o = sorted(live)[int(rng.integers(len(live)))]
            pool.free_owner(o)
            live.discard(o)
        # conservation + no double allocation, every step
        allocated = [p for o in live for p in pool.owned(o)]
        assert len(allocated) == len(set(allocated))
        assert pool.free_pages + pool.pages_in_use == num_pages - 1
        assert pool.reserved_pages <= pool.free_pages
    for o in list(live):
        pool.free_owner(o)
    pool.check_no_leaks()


# ---------------------------------------------------------------------------
# engine interleavings: pressure + lifecycle churn conserve the pool and
# never perturb surviving streams
# ---------------------------------------------------------------------------

# tight pool (7 usable pages, worst-case demand far above) so random
# interleavings also drive the overcommit/preemption machinery
_ENG_BASE = dict(num_slots=3, page_size=4, max_len=32, prefill_chunk=8,
                 kv_dtype="float32", backend="xla")
_ENG_CTX: dict = {}


def _eng_ctx():
    """Module-lazy model + one compiled donor per pool size + solo-run
    token cache — hypothesis examples then cost ticks, not compiles."""
    if not _ENG_CTX:
        import jax

        from repro.models import get_model
        from repro.serve_engine import EngineConfig, ServeEngine

        _, model = get_model("brecq_lm_100m", reduced=True)
        params = model.init(jax.random.PRNGKey(0))
        rng = np.random.default_rng(11)
        prompts = [rng.integers(0, model.cfg.vocab, size=n).astype(np.int32)
                   for n in (5, 7, 9, 11)]
        cfgs = {
            "pressure": EngineConfig(num_pages=8, overcommit="prompt",
                                     **_ENG_BASE),
            "solo": EngineConfig(num_pages=49, **_ENG_BASE),
        }
        donors = {k: ServeEngine(model, params, c) for k, c in cfgs.items()}

        def make(kind):
            return ServeEngine(model, params, cfgs[kind],
                               share_compiled=donors[kind])

        solo_cache: dict = {}

        def solo(pi, mn):
            if (pi, mn) not in solo_cache:
                e = make("solo")
                e.submit(prompts[pi], mn, uid=0)
                e.run()
                solo_cache[(pi, mn)] = list(e.requests[0].generated)
            return solo_cache[(pi, mn)]

        _ENG_CTX.update(make=make, solo=solo, prompts=prompts)
    return _ENG_CTX


@st.composite
def engine_schedule(draw):
    """2–4 streams with optional per-stream deadline / cancel tick and
    an optional mid-run drain. Lengths keep worst-case per-stream need
    within the pool so submit() admits everything."""
    n = draw(st.integers(2, 4))
    streams = []
    for _ in range(n):
        pi = draw(st.integers(0, 3))
        mn = draw(st.sampled_from([4, 8, 12]))
        deadline = draw(st.sampled_from([None, None, None, 6, 14]))
        cancel_at = draw(st.sampled_from([None, None, None, 3, 9]))
        streams.append((pi, mn, deadline, cancel_at))
    drain_at = draw(st.sampled_from([None, None, None, 12]))
    return streams, drain_at


@settings(max_examples=8, deadline=None)
@given(engine_schedule())
def test_engine_interleavings_conserve_pool_and_pin_survivors(schedule):
    """Any interleaving of submit/cancel/deadline-expiry/drain on a
    pool under preemption pressure (a) conserves pages at every tick,
    (b) releases everything by the end, and (c) leaves every stream
    that ran to 'done' bit-identical to its solo run — churn in
    neighbouring slots must never leak into a surviving stream's KV."""
    ctx = _eng_ctx()
    streams, drain_at = schedule
    eng = ctx["make"]("pressure")
    for uid, (pi, mn, deadline, _) in enumerate(streams):
        eng.submit(ctx["prompts"][pi], mn, uid=uid, deadline_ticks=deadline)
    n_usable = eng.cfg.num_pages - 1
    drained = False
    while eng.pending() and not drained:
        if drain_at is not None and eng.tick >= drain_at:
            eng.drain(finish=True)
            drained = True
        else:
            eng.step()
        for uid, (_pi, _mn, _dl, cancel_at) in enumerate(streams):
            if cancel_at is not None and eng.tick == cancel_at:
                eng.cancel(uid)  # False (no-op) once terminal — fine
        # page conservation + reservation sanity, every tick
        assert eng.pool.free_pages + eng.pool.pages_in_use == n_usable
        assert eng.pool.reserved_pages <= eng.pool.free_pages
        assert eng.tick < 2000, "engine failed to make progress"
    eng.assert_no_leaks()
    final = {u: r.state for u, r in eng.requests.items()}
    allowed = {"done", "cancelled", "expired"} | ({"waiting"} if drained
                                                  else set())
    assert set(final.values()) <= allowed, final
    for uid, (pi, mn, _dl, _ca) in enumerate(streams):
        req = eng.requests[uid]
        if req.state == "done":
            assert list(req.generated) == ctx["solo"](pi, mn), uid
        else:
            # partial output of an interrupted stream is still a prefix
            # of its solo run (determinism holds right up to the cut)
            solo_toks = ctx["solo"](pi, mn)
            assert list(req.generated) == solo_toks[:len(req.generated)], uid
