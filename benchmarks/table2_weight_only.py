"""Paper Table 2: weight-only PTQ at W4/W3/W2 vs the baseline set.

Methods: RTN(minmax), OMSE(= RTN with MSE scales), Bias-Correction,
AdaRound (layer-wise reconstruction), AdaQuant, BRECQ.
Claim: all are fine at W4; only BRECQ stays usable at W2.
"""
from __future__ import annotations

import time

from repro.core import ReconConfig
from repro.core.baselines import (quantize_adaquant, quantize_bias_correction,
                                  quantize_rtn)
from repro.core.evaluate import evaluate

from .common import RECON_ITERS, cached_brecq, emit, get_bench_model


def main() -> list[dict]:
    cfg, model, params, calib, evalb = get_bench_model()
    fp = evaluate(model, params, evalb)
    rows = [{"name": "fp32", "us_per_call": 0,
             "derived": f"loss={fp['loss']:.4f};top1={fp['top1']:.4f}"}]

    def add(name, fn):
        t0 = time.time()
        pq = fn()
        wall = time.time() - t0
        ev = evaluate(model, pq, evalb)
        rows.append({"name": name, "us_per_call": wall * 1e6,
                     "derived": f"loss={ev['loss']:.4f};top1={ev['top1']:.4f}",
                     "loss": ev["loss"], "top1": ev["top1"]})
        print(f"  [{name}] loss {ev['loss']:.4f} top1 {ev['top1']:.4f}")

    for bits in (4, 3, 2):
        add(f"rtn_minmax_w{bits}",
            lambda b=bits: quantize_rtn(model, params, calib, b, scale_method="minmax")[0])
        add(f"omse_w{bits}",
            lambda b=bits: quantize_rtn(model, params, calib, b, scale_method="mse")[0])
        add(f"biascorr_w{bits}",
            lambda b=bits: quantize_bias_correction(model, params, calib, b)[0])
        add(f"adaround_w{bits}",  # layer-wise reconstruction, no Fisher
            lambda b=bits: cached_brecq(
                model, params, calib,
                ReconConfig(w_bits=b, iters=RECON_ITERS, granularity="layer",
                            use_fisher=False), f"t2_adaround_w{b}")["params_q"])
        add(f"adaquant_w{bits}",
            lambda b=bits: quantize_adaquant(model, params, calib, b,
                                             iters=RECON_ITERS // 2)[0])
        add(f"brecq_w{bits}",
            lambda b=bits: cached_brecq(
                model, params, calib,
                ReconConfig(w_bits=b, iters=RECON_ITERS),
                f"t2_brecq_w{b}")["params_q"])
    emit(rows, "table2")
    return rows


if __name__ == "__main__":
    main()
