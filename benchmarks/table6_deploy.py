"""Table 6 (beyond paper): deployment cost of the packed-int artifact.

Three views of the `repro.deploy` path on the bench model:
  * pack sweep — wall time + artifact bytes vs ``w_bits`` / ``w_group``
    (RTN fast path; packing cost is calibration-independent),
  * BRECQ export — pack time/bytes for the calibrated W4 result and the
    packed-vs-baked eval parity (should be ~0: same hard rounding),
  * serving throughput — prefill wall + decode tokens/s, FP params vs
    the packed W4 artifact (weights resident as int codes).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import PTQResult, ReconConfig
from repro.core.evaluate import evaluate
from repro.deploy import export, rtn_artifact, tree_bytes
from repro.launch.serve import run_prefill_decode

from .common import RECON_ITERS, cached_brecq, emit, get_bench_model

W_BITS_SWEEP = (2, 4, 8)
GROUPS = (None, 64)
BATCH, PROMPT, GEN = 8, 64, 16


def _throughput(model, params, hook=None):
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, model.cfg.vocab, (BATCH, PROMPT)))
    _, stat = run_prefill_decode(model, params, {"tokens": toks},
                                 batch_size=BATCH, prompt_len=PROMPT,
                                 gen_len=GEN, hook=hook, quiet=True)
    return stat["t_prefill"], stat["tok_s"]


def main() -> list[dict]:
    cfg, model, params, calib, evalb = get_bench_model()
    fp_bytes = tree_bytes(params)
    rows = []

    # pack sweep: bytes + wall vs bits/group (RTN path)
    for bits in W_BITS_SWEEP:
        for group in GROUPS:
            art = rtn_artifact(params, bits, group, cfg=cfg)
            s = art.stats
            rows.append({
                "name": f"pack_w{bits}_g{group or 'chan'}",
                "us_per_call": s["pack_wall_s"] * 1e6,
                "derived": (f"bytes={s['artifact_bytes']};"
                            f"ratio={s['artifact_bytes']/fp_bytes:.3f};"
                            f"pack_wall_s={s['pack_wall_s']:.2f}")})

    # BRECQ W4 export: exactness + deployment stats for the calibrated run
    res_d = cached_brecq(model, params, calib,
                         ReconConfig(w_bits=4, iters=RECON_ITERS), "t2_brecq_w4")
    res = PTQResult(params_q=jax.tree.map(jnp.asarray, res_d["params_q"]),
                    act_scales=res_d["act_scales"], qstates=res_d["qstates"],
                    v=res_d["v"], stats=res_d["stats"])
    art = export(model, res)
    baked = evaluate(model, res.params_q, evalb)
    packed = evaluate(model, art, evalb)
    rows.append({
        "name": "export_brecq_w4",
        "us_per_call": art.stats["pack_wall_s"] * 1e6,
        "derived": (f"bytes={art.stats['artifact_bytes']};"
                    f"ratio={art.stats['artifact_bytes']/fp_bytes:.3f};"
                    f"loss_packed={packed['loss']:.4f};"
                    f"loss_baked={baked['loss']:.4f};"
                    f"bits_hist={art.stats['bits_histogram']}")})

    # serving throughput fp vs packed
    t_pre_fp, toks_fp = _throughput(model, params)
    t_pre_q, toks_q = _throughput(model, art.params, art.hook())
    rows.append({"name": "serve_fp", "us_per_call": t_pre_fp * 1e6,
                 "derived": f"decode_tok_s={toks_fp:.1f};bytes={fp_bytes}"})
    rows.append({"name": "serve_packed_w4", "us_per_call": t_pre_q * 1e6,
                 "derived": (f"decode_tok_s={toks_q:.1f};"
                             f"bytes={art.stats['artifact_bytes']};"
                             f"tok_s_ratio={toks_q/max(toks_fp,1e-9):.2f}")})
    emit(rows, "table6")
    return rows


if __name__ == "__main__":
    main()
