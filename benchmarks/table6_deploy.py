"""Table 6 (beyond paper): deployment cost of the packed-int artifact.

Four views of the `repro.deploy` path:
  * serve benchmark — the tracked one: prefill + decode tok/s on the
    reduced serve config (the CI smoke shape), fp vs packed W4, with the
    decode tier both on (``qgemv`` dispatch) and forced off (the old
    padded-GEMM path) so the fast-path win is recorded per run. Written
    to ``BENCH_serve.json`` at the repo root — tracked in git, so the
    serving-perf trajectory survives across PRs.
  * pack sweep — wall time + artifact bytes vs ``w_bits`` / ``w_group``
    (RTN fast path; packing cost is calibration-independent),
  * BRECQ export — pack time/bytes for the calibrated W4 result and the
    packed-vs-baked eval parity (should be ~0: same hard rounding),
  * serving throughput — prefill wall + decode tokens/s on the bench
    model, FP params vs the packed W4 artifact.

``python -m benchmarks.table6_deploy --serve-only`` runs just the first
view (no trained bench cache needed).
"""
from __future__ import annotations

import json
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import PTQResult, ReconConfig
from repro.core.evaluate import evaluate
from repro.deploy import export, rtn_artifact, tree_bytes
from repro.kernels.qmatmul import ops as qmm_ops
from repro.launch.serve import run_prefill_decode

from .common import RECON_ITERS, cached_brecq, emit, get_bench_model

W_BITS_SWEEP = (2, 4, 8)
GROUPS = (None, 64)
BATCH, PROMPT, GEN = 8, 64, 16

# the reduced serve config (mirrors CI's serve-smoke flags)
SERVE_ARCH, SERVE_BATCH, SERVE_PROMPT, SERVE_GEN = "brecq_lm_100m", 8, 64, 32
SERVE_JSON = Path(__file__).resolve().parents[1] / "BENCH_serve.json"


def _throughput(model, params, hook=None, *, batch=BATCH, prompt=PROMPT,
                gen=GEN, vocab=None):
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, vocab or model.cfg.vocab, (batch, prompt)))
    _, stat = run_prefill_decode(model, params, {"tokens": toks},
                                 batch_size=batch, prompt_len=prompt,
                                 gen_len=gen, hook=hook, quiet=True)
    return stat


def serve_bench() -> dict:
    """fp-vs-packed decode/prefill tok/s on the reduced serve config.

    Three passes: FP params, packed W4 through the shape dispatcher
    (decode steps hit the ``qgemv`` tier), and packed W4 with the decode
    tier disabled — the pre-dispatcher behavior (decode rows zero-padded
    into the prefill GEMM), kept as the before/after baseline.
    """
    from repro.models import get_model

    cfg, model = get_model(SERVE_ARCH, reduced=True)
    params = model.init(jax.random.PRNGKey(0))
    art = rtn_artifact(params, 4, None, cfg=cfg)
    kw = dict(batch=SERVE_BATCH, prompt=SERVE_PROMPT, gen=SERVE_GEN,
              vocab=cfg.vocab)

    def best_of(fn, reps=2):  # best decode wall of N reps (CI hosts are noisy)
        runs = [fn() for _ in range(reps)]
        return max(runs, key=lambda s: s["tok_s"])

    fp = best_of(lambda: _throughput(model, params, **kw))
    packed = best_of(lambda: _throughput(model, art.params, art.hook(), **kw))
    m_max = qmm_ops.DECODE_M_MAX
    try:
        qmm_ops.DECODE_M_MAX = 0  # decode shapes fall back to the prefill GEMM
        legacy = best_of(lambda: _throughput(model, art.params, art.hook(), **kw))
    finally:
        qmm_ops.DECODE_M_MAX = m_max

    def row(s):
        return {"decode_tok_s": round(s["tok_s"], 1),
                "prefill_tok_s": round(s["prefill_tok_s"], 1),
                "t_compile_s": round(s["t_compile"], 2),
                "qmm_tiers": s["qmm_tiers"]}

    out = {
        "config": {"arch": SERVE_ARCH, "reduced": True, "batch": SERVE_BATCH,
                   "prompt_len": SERVE_PROMPT, "gen_len": SERVE_GEN,
                   "w_bits": 4, "backend": jax.default_backend()},
        "fp": row(fp),
        "packed_w4": row(packed),
        "packed_w4_no_decode_tier": row(legacy),
        "decode_ratio_packed_vs_fp": round(
            packed["tok_s"] / max(fp["tok_s"], 1e-9), 3),
        "decode_ratio_tier_vs_legacy": round(
            packed["tok_s"] / max(legacy["tok_s"], 1e-9), 3),
    }
    SERVE_JSON.write_text(json.dumps(out, indent=1) + "\n")
    print(f"serve bench -> {SERVE_JSON.name}: packed {out['packed_w4']['decode_tok_s']}"
          f" vs fp {out['fp']['decode_tok_s']} tok/s decode "
          f"(x{out['decode_ratio_packed_vs_fp']}), tiers "
          f"{out['packed_w4']['qmm_tiers']}")
    return out


def main() -> list[dict]:
    serve = serve_bench()
    rows = [{"name": "serve_reduced_fp", "us_per_call": 0,
             "derived": f"decode_tok_s={serve['fp']['decode_tok_s']}"},
            {"name": "serve_reduced_packed_w4", "us_per_call": 0,
             "derived": (f"decode_tok_s={serve['packed_w4']['decode_tok_s']};"
                         f"ratio_vs_fp={serve['decode_ratio_packed_vs_fp']};"
                         f"ratio_vs_legacy={serve['decode_ratio_tier_vs_legacy']}")}]

    cfg, model, params, calib, evalb = get_bench_model()
    fp_bytes = tree_bytes(params)

    # pack sweep: bytes + wall vs bits/group (RTN path)
    for bits in W_BITS_SWEEP:
        for group in GROUPS:
            art = rtn_artifact(params, bits, group, cfg=cfg)
            s = art.stats
            rows.append({
                "name": f"pack_w{bits}_g{group or 'chan'}",
                "us_per_call": s["pack_wall_s"] * 1e6,
                "derived": (f"bytes={s['artifact_bytes']};"
                            f"ratio={s['artifact_bytes']/fp_bytes:.3f};"
                            f"pack_wall_s={s['pack_wall_s']:.2f}")})

    # BRECQ W4 export: exactness + deployment stats for the calibrated run
    res_d = cached_brecq(model, params, calib,
                         ReconConfig(w_bits=4, iters=RECON_ITERS), "t2_brecq_w4")
    res = PTQResult(params_q=jax.tree.map(jnp.asarray, res_d["params_q"]),
                    act_scales=res_d["act_scales"], qstates=res_d["qstates"],
                    v=res_d["v"], stats=res_d["stats"])
    art = export(model, res)
    baked = evaluate(model, res.params_q, evalb)
    packed = evaluate(model, art, evalb)
    rows.append({
        "name": "export_brecq_w4",
        "us_per_call": art.stats["pack_wall_s"] * 1e6,
        "derived": (f"bytes={art.stats['artifact_bytes']};"
                    f"ratio={art.stats['artifact_bytes']/fp_bytes:.3f};"
                    f"loss_packed={packed['loss']:.4f};"
                    f"loss_baked={baked['loss']:.4f};"
                    f"bits_hist={art.stats['bits_histogram']}")})

    # serving throughput fp vs packed on the bench model
    fstat = _throughput(model, params)
    qstat = _throughput(model, art.params, art.hook())
    rows.append({"name": "serve_fp", "us_per_call": fstat["t_prefill"] * 1e6,
                 "derived": (f"decode_tok_s={fstat['tok_s']:.1f};"
                             f"bytes={fp_bytes}")})
    rows.append({"name": "serve_packed_w4", "us_per_call": qstat["t_prefill"] * 1e6,
                 "derived": (f"decode_tok_s={qstat['tok_s']:.1f};"
                             f"bytes={art.stats['artifact_bytes']};"
                             f"tok_s_ratio={qstat['tok_s']/max(fstat['tok_s'],1e-9):.2f};"
                             f"tiers={qstat['qmm_tiers']}")})
    emit(rows, "table6")
    return rows


if __name__ == "__main__":
    if "--serve-only" in sys.argv:
        serve_bench()
    else:
        main()
