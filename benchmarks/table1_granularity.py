"""Paper Table 1: reconstruction granularity ablation at 2-bit weights.

Claim under test: block > layer and block > net at W2 (stage between)."""
from __future__ import annotations

import time

from repro.core import ReconConfig
from repro.core.evaluate import evaluate

from .common import RECON_ITERS, cached_brecq, emit, get_bench_model


def main() -> list[dict]:
    cfg, model, params, calib, evalb = get_bench_model()
    fp = evaluate(model, params, evalb)
    rows = [{"name": "fp32", "us_per_call": 0,
             "derived": f"loss={fp['loss']:.4f};top1={fp['top1']:.4f}"}]
    for gran in ("layer", "block", "stage", "net"):
        rc = ReconConfig(w_bits=2, iters=RECON_ITERS, granularity=gran,
                         use_fisher=(gran != "layer"))
        res = cached_brecq(model, params, calib, rc, f"t1_{gran}_w2")
        ev = evaluate(model, res["params_q"], evalb)
        rows.append({
            "name": f"{gran}_w2",
            "us_per_call": res["stats"]["calib_wall_s"] * 1e6,
            "derived": f"loss={ev['loss']:.4f};top1={ev['top1']:.4f}",
            "loss": ev["loss"], "top1": ev["top1"],
        })
    emit(rows, "table1")
    return rows


if __name__ == "__main__":
    main()
