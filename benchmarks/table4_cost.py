"""Paper Table 4: PTQ-vs-QAT cost/accuracy trade.

BRECQ calibrates with N_CALIB sequences in seconds-to-minutes; a
straight-through-estimator QAT run needs the full training stream and
many steps to match. We report wall time, data budget and final loss for
both at W4 (the paper's 240x production-speed claim, at bench scale).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import ReconConfig
from repro.core.evaluate import evaluate
from repro.core.hooks import RTNHook
from repro.core.quantizer import QConfig, fake_quant_ste, init_qstate
from repro.core.reconstruction import Walker, enumerate_weights, init_states
from repro.data import Corpus, CorpusConfig, make_batches
from repro.optim import adam

from .common import (BATCH, RECON_ITERS, SEQ, cached_brecq, emit,
                     get_bench_model)

QAT_STEPS = 150
W_BITS = 4


def qat_ste(model, params, cfg, steps=QAT_STEPS, lr=5e-4):
    """STE fake-quant QAT baseline (PACT/DSQ-class), trained on the full
    data stream."""
    corpus = Corpus(CorpusConfig(vocab=cfg.vocab))
    rc = ReconConfig(w_bits=W_BITS)
    weights = enumerate_weights(
        model, params, make_batches(corpus, 1, 1, 8, seed=9)[0])
    qstates, embed_head = init_states(model, weights, rc)
    walker = Walker(model)

    class QATHook(RTNHook):
        def weight(self, path, w):
            if path in qstates:
                return fake_quant_ste(w, *qstates[path])
            if path in embed_head:
                return fake_quant_ste(w, *embed_head[path])
            return w

    hook = QATHook({})
    acfg = adam.AdamConfig(lr=lr, grad_clip=1.0)
    state = adam.init(params)

    # device-resident QAT: pregenerate the training stream, then one
    # jitted lax.scan over all steps (one dispatch, one final sync) —
    # same treatment as the BRECQ calibration loop so the table 4
    # wall-time comparison is apples to apples.
    toks = jnp.stack([make_batches(corpus, 1, BATCH, SEQ, seed=3,
                                   start_step=i)[0]["tokens"]
                      for i in range(steps)])

    @jax.jit
    def run(params, state, toks):
        def step(carry, t):
            params, state = carry
            loss, g = jax.value_and_grad(
                lambda p: walker.loss(p, {"tokens": t}, hook))(params)
            params, state = adam.update(acfg, g, state, params)
            return (params, state), loss

        (params, state), losses = jax.lax.scan(step, (params, state), toks)
        return params, state, losses

    t0 = time.time()
    params, state, losses = run(params, state, toks)
    jax.block_until_ready(losses)
    wall = time.time() - t0
    tokens_seen = steps * BATCH * SEQ
    # evaluate with hardened RTN weights at the fine-tuned point
    from repro.core.reconstruction import bake

    weights2 = enumerate_weights(
        model, params, make_batches(corpus, 1, 1, 8, seed=9)[0])
    qstates2, embed_head2 = init_states(model, weights2, rc)
    params_q = bake(model, params, qstates2, {}, embed_head2)
    return params_q, wall, tokens_seen


def main() -> list[dict]:
    cfg, model, params, calib, evalb = get_bench_model()
    fp = evaluate(model, params, evalb)
    rows = [{"name": "fp32", "us_per_call": 0,
             "derived": f"loss={fp['loss']:.4f}"}]

    res = cached_brecq(model, params, calib,
                       ReconConfig(w_bits=W_BITS, iters=RECON_ITERS),
                       f"t2_brecq_w{W_BITS}")
    ev = evaluate(model, res["params_q"], evalb)
    calib_tokens = sum(int(b["tokens"].size) for b in calib)
    brecq_wall = res["stats"]["calib_wall_s"]
    # .get(): disk-cached runs may predate the memory-plane stats
    peak_mb = res["stats"].get("calib_peak_bytes", 0) / 1e6
    fisher_s = res["stats"].get("fisher_wall_s", 0.0)
    # robustness telemetry (.get(): cached runs may predate the guards)
    retries = res["stats"].get("unit_retries", 0)
    fallbacks = res["stats"].get("unit_fallbacks", 0)
    stragglers = res["stats"].get("stragglers", 0)
    rows.append({"name": f"brecq_w{W_BITS}", "us_per_call": brecq_wall * 1e6,
                 "derived": (f"loss={ev['loss']:.4f};wall_s={brecq_wall:.0f};"
                             f"fisher_wall_s={fisher_s:.0f};"
                             f"peak_mb={peak_mb:.1f};"
                             f"data_tokens={calib_tokens};"
                             f"retries={retries};fallbacks={fallbacks};"
                             f"stragglers={stragglers}")})

    # production cost includes packing the deployment artifact
    from repro.core import PTQResult
    from repro.deploy import export

    art = export(model, PTQResult(
        params_q=jax.tree.map(jnp.asarray, res["params_q"]),
        act_scales=res["act_scales"], qstates=res["qstates"], v=res["v"],
        stats=res["stats"]))
    rows.append({"name": f"deploy_w{W_BITS}",
                 "us_per_call": art.stats["pack_wall_s"] * 1e6,
                 "derived": (f"pack_wall_s={art.stats['pack_wall_s']:.2f};"
                             f"artifact_mb={art.stats['artifact_bytes']/1e6:.2f};"
                             f"fp_mb={art.stats['fp_bytes']/1e6:.2f};"
                             f"bits_hist={art.stats['bits_histogram']}")})

    pq, wall, tokens = qat_ste(model, params, cfg)
    evq = evaluate(model, pq, evalb)
    rows.append({"name": f"qat_ste_w{W_BITS}", "us_per_call": wall * 1e6,
                 "derived": (f"loss={evq['loss']:.4f};wall_s={wall:.0f};"
                             f"data_tokens={tokens}")})
    if brecq_wall > 0:
        rows.append({"name": "speedup", "us_per_call": 0,
                     "derived": f"qat_wall/brecq_wall={wall / brecq_wall:.1f}x;"
                                f"data_ratio={tokens / calib_tokens:.1f}x"})
    emit(rows, "table4")
    return rows


if __name__ == "__main__":
    main()
