"""Table 8: the budget frontier — exact solver vs unified precision vs GA.

The deployment claim behind `repro.deploy.budget`: give the solver any
model-bytes budget and the artifact it ships is at least as good as
every unified-precision artifact that fits the same budget — while the
genetic search (paper Algorithm 2) never beats it under the identical
constraint. Sweeps budgets anchored at the unified W2/W4/W8 artifact
sizes (plus midpoints, where unified precision has no point at all and
mixed precision is the only occupant), packs each chosen assignment into
a real artifact, and measures its decode throughput through the serving
harness; a decode-latency sweep against the *measured* per-layer cost
table rides along.

Writes ``BENCH_budget.json`` at the repo root — tracked in git, guarded
by ``scripts/check_budget_bench.py`` in the CI budget-smoke job:
  * every swept budget: ``solver.artifact_bytes <= budget``,
  * every unified point fitting the budget has predicted loss >= the
    solver's (so the solver Pareto-dominates each in-budget unified
    point of equal or larger size),
  * the GA cross-check — run on the group-reduced problem so both
    searchers face the storage-stack tie — never achieves a lower
    predicted loss.

Model: the reduced serve config (same as table6's serve bench) with the
calibration-free RTN weight-error sensitivity proxy by default, so the
bench runs from a clean checkout in seconds; ``--sens PATH`` swaps in a
measured ``SensTable`` JSON (``core.sensitivity.SensTable.save``) for
paper-grade predicted losses — the frontier logic is identical.
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.mixed_precision import GAConfig, fitness, genetic_search
from repro.core.sensitivity import SensTable
from repro.deploy.budget import (budget_artifact, bytes_cost_table,
                                 grouped_problem, measure_cost_table,
                                 rtn_mixed_artifact, storage_groups,
                                 weight_sens_table)
from repro.launch.serve import run_prefill_decode
from repro.models import get_model

BUDGET_JSON = Path(__file__).resolve().parents[1] / "BENCH_budget.json"

ARCH, BATCH, PROMPT, GEN = "brecq_lm_100m", 8, 64, 16


def _decode_tok_s(model, art, *, batch=BATCH, prompt=PROMPT, gen=GEN,
                  reps=2) -> dict:
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, model.cfg.vocab, (batch, prompt)))
    runs = []
    for _ in range(reps):
        _, s = run_prefill_decode(model, art.params, {"tokens": toks},
                                  batch_size=batch, prompt_len=prompt,
                                  gen_len=gen, hook=art.hook(), quiet=True)
        runs.append(s)
    best = max(runs, key=lambda s: s["tok_s"])
    return {"decode_tok_s": round(best["tok_s"], 1),
            "qmm_tiers": best["qmm_tiers"]}


def main(argv=None) -> dict:
    p = argparse.ArgumentParser()
    p.add_argument("--smoke", action="store_true",
                   help="3-point sweep, 1 serving rep, tiny GA — the CI "
                        "budget-smoke configuration")
    p.add_argument("--sens", default=None,
                   help="measured SensTable JSON; default: RTN weight-error "
                        "proxy (calibration-free)")
    p.add_argument("--out", default=str(BUDGET_JSON))
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args(argv)

    cfg, model = get_model(ARCH, reduced=True)
    params = model.init(jax.random.PRNGKey(args.seed))
    if args.sens:
        sens = SensTable.load(args.sens)
        sens_source = args.sens
    else:
        sens = weight_sens_table(params, cfg.n_layers)
        sens_source = "rtn_weight_proxy"
    groups = storage_groups(sens.shapes)
    table = bytes_cost_table(sens.shapes)
    # the GA has no group support — cross-check it on the group-reduced
    # problem so it searches the same space the artifact can ship
    # (an untied GA reports per-layer splits container promotion erases)
    gsens, gtable, _ = grouped_problem(sens, table, groups)
    reps = 1 if args.smoke else 2
    ga = GAConfig(pop_size=24, iters=8 if args.smoke else 40, seed=args.seed)

    # unified-precision reference points, through the same artifact path
    unified = {}
    for b in (2, 4, 8):
        art = rtn_mixed_artifact(params, {q: b for q in sens.shapes}, cfg=cfg)
        unified[b] = {
            "bits": b, "artifact_bytes": art.nbytes(),
            "predicted_loss": fitness(sens, {q: b for q in sens.shapes}),
            **_decode_tok_s(model, art, reps=reps),
        }
        print(f"[unified W{b}] {art.nbytes()} bytes, predicted-loss "
              f"{unified[b]['predicted_loss']:.4g}, "
              f"{unified[b]['decode_tok_s']} tok/s decode")

    u2, u4, u8 = (unified[b]["artifact_bytes"] for b in (2, 4, 8))
    budgets = ([u2, (u4 + u8) // 2, u8] if args.smoke
               else [u2, (u2 + u4) // 2, u4, (u4 + u8) // 2, u8])

    rows = []
    for budget in budgets:
        t0 = time.time()
        art, sol, _ = budget_artifact(params, sens, budget, kind="bytes",
                                      cfg=cfg)
        solve_s = time.time() - t0
        overhead = art.manifest["budget"]["overhead_bytes"]
        t0 = time.time()
        _, ga_info = genetic_search(gsens, gtable, budget - overhead, ga)
        ga_s = time.time() - t0
        row = {
            "budget_bytes": budget,
            "solver": {"predicted_loss": sol.predicted_loss,
                       "artifact_bytes": art.nbytes(),
                       "bits_histogram": art.manifest["budget"]["bits_histogram"],
                       "n_frontier": sol.n_frontier,
                       "solve_wall_s": round(solve_s, 3),
                       **_decode_tok_s(model, art, reps=reps)},
            "genetic": {"fitness": ga_info["fitness"],
                        "cost": ga_info["cost"],
                        "wall_s": round(ga_s, 3)},
            "dominates_unified": sorted(
                b for b, u in unified.items()
                if u["artifact_bytes"] <= budget
                and sol.predicted_loss <= u["predicted_loss"] + 1e-12
                and art.nbytes() <= u["artifact_bytes"]),
        }
        rows.append(row)
        print(f"[budget {budget}] solver loss {sol.predicted_loss:.4g} "
              f"({art.nbytes()} bytes, {row['solver']['decode_tok_s']} tok/s) "
              f"vs GA {ga_info['fitness']:.4g}; dominates unified "
              f"{row['dominates_unified']}")

    # decode-latency sweep against the measured per-layer tier costs —
    # the constraint the analytic roofline gets wrong on this backend
    mtable = measure_cost_table(sens.shapes, m=min(BATCH, 8),
                                inner=4 if args.smoke else 8, reps=reps)
    gsens_m, gmtable, _ = grouped_problem(sens, mtable, groups)
    ms_uniform = {b: mtable.assign_cost({q: b for q in sens.shapes})
                  for b in (2, 4, 8)}
    ms_min = sum(min(mtable.cost(q, b) for b in (2, 4, 8))
                 for q in sens.shapes)
    # sweep from the cheapest assignment to the slowest uniform point —
    # [ms_min, ms8] alone collapses on backends where 8-bit is fastest
    ms_max = max(ms_uniform.values())
    lat_rows = []
    for frac in ([0.5] if args.smoke else [0.25, 0.5, 1.0]):
        budget_ms = ms_min + frac * (ms_max - ms_min)
        art, sol, _ = budget_artifact(params, sens, budget_ms,
                                      kind="decode_ms", cfg=cfg,
                                      cost_table=mtable)
        _, ga_info = genetic_search(gsens_m, gmtable, budget_ms, ga)
        lat_rows.append({
            "budget_decode_ms": round(budget_ms, 4),
            "solver": {"predicted_loss": sol.predicted_loss,
                       "cost_ms": round(sol.cost, 4),
                       "artifact_bytes": art.nbytes(),
                       "bits_histogram": art.manifest["budget"]["bits_histogram"],
                       **_decode_tok_s(model, art, reps=reps)},
            "genetic": {"fitness": ga_info["fitness"],
                        "cost_ms": round(ga_info["cost"], 4)},
        })
        print(f"[budget {budget_ms:.4f}ms] solver loss "
              f"{sol.predicted_loss:.4g} ({sol.cost:.4f}ms) vs GA "
              f"{ga_info['fitness']:.4g} ({ga_info['cost']:.4f}ms)")

    out = {
        "config": {"arch": ARCH, "reduced": True, "batch": BATCH,
                   "prompt_len": PROMPT, "gen_len": GEN,
                   "sens_source": sens_source, "smoke": args.smoke,
                   "backend": jax.default_backend(),
                   "n_paths": len(sens.shapes),
                   "n_groups": len(set(groups.values()))},
        "unified": [unified[b] for b in (2, 4, 8)],
        "rows": rows,
        "latency_rows": lat_rows,
        "measured_cost_meta": mtable.meta,
    }
    Path(args.out).write_text(json.dumps(out, indent=1) + "\n")
    print(f"budget bench -> {Path(args.out).name}: {len(rows)} byte budgets, "
          f"{len(lat_rows)} latency budgets")
    return out


if __name__ == "__main__":
    main()
