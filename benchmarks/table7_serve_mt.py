"""Table 7 (beyond paper): multi-stream continuous-batching serve bench.

Drives the serve engine with N concurrent synthetic request streams —
staggered arrivals, mixed prompt/generation lengths — over a packed W4
artifact, once with the int8 paged KV cache (the ``kernels/kvattn``
decode path) and once with fp16 KV pools as the reference mode. Tracked
in ``BENCH_serve_mt.json`` at the repo root:

  * sustained tok/s (all generated tokens / serving wall, compile AOT'd
    out),
  * mean resident KV bytes per active stream (pages-in-use x bytes/page,
    sampled every decode tick),
  * mean decode-slot occupancy,
  * the headline ratio: fp16 resident KV bytes / int8 resident KV bytes
    (>= 1.8x is the acceptance bar; int8 codes + f16 scales vs f16
    values).

Both passes use identical arrivals and lengths (same seed, and page
consumption depends only on lengths), so the byte ratio is exact, not
sampled noise.

A third section, ``pressure``, replays the same arrivals on a pool cut
to ``--pool-frac`` of worst-case demand, once under worst-case
reservation (``overcommit='none'`` — admission serializes, slots idle)
and once under optimistic admission (``overcommit='prompt'`` — slots
pack, the scheduler preempts on exhaustion). Tracked: the occupancy
gain, the preemption/replay/expired/failed/cancelled counters, and the
preemption overhead (replayed prefill chunks per decode tick), all
guarded by ``scripts/check_serve_bench.py``. The CI ``serve-mt-smoke``
job runs a reduced 8-stream variant of this file and checks the same
schema + zero leaked pages.
"""
from __future__ import annotations

import argparse
import json
import tempfile
from pathlib import Path

import jax
import numpy as np

from repro.deploy import QuantizedArtifact, rtn_artifact
from repro.models import get_model
from repro.serve_engine import EngineConfig, ServeEngine

MT_JSON = Path(__file__).resolve().parents[1] / "BENCH_serve_mt.json"

SCHEMA_KEYS = ("config", "int8", "fp16", "kv_bytes_ratio_fp16_over_int8",
               "sustained_tok_s_int8", "pressure")
RUN_KEYS = ("sustained_tok_s", "tokens_generated", "mean_slot_occupancy",
            "mean_resident_kv_bytes_per_stream", "bytes_per_page",
            "peak_pages_in_use", "compile_s", "decode_ticks",
            "preemptions", "replay_prefill_chunks", "expired", "failed",
            "cancelled")


def run_streams(model, weights, hook, kv_dtype, *, streams, slots, prompt,
                gen, chunk, page_size, seed, overcommit="none",
                num_pages=None) -> dict:
    """One full engine run; returns engine metrics + completion proof."""
    max_len = prompt + gen
    pages_per = -(-max_len // page_size)
    ecfg = EngineConfig(num_slots=slots, page_size=page_size,
                        num_pages=num_pages or 1 + slots * pages_per,
                        max_len=max_len,
                        prefill_chunk=min(chunk, prompt),
                        kv_dtype=kv_dtype, overcommit=overcommit)
    eng = ServeEngine(model, weights, ecfg, quant=hook)
    eng.compile()

    rng = np.random.default_rng(seed)
    arrivals = np.sort(rng.integers(0, 2 * streams, streams))
    arrivals[0] = 0
    plens = rng.integers(max(prompt // 2, 1), prompt + 1, streams)
    gens = rng.integers(max(gen // 2, 1), gen + 1, streams)
    prompts = [rng.integers(0, model.cfg.vocab, size=int(plens[i]))
               for i in range(streams)]
    nxt = 0
    while nxt < streams or eng.pending():
        while nxt < streams and arrivals[nxt] <= eng.tick:
            eng.submit(prompts[nxt], int(gens[nxt]))
            nxt += 1
        eng.step()
    eng.assert_no_leaks()  # zero leaked pages is part of the bench contract
    done = sum(r.state == "done" for r in eng.requests.values())
    assert done == streams, f"only {done}/{streams} streams completed"
    m = eng.metrics()
    m["streams_completed"] = done
    m["leaked_pages"] = eng.pool.pages_in_use  # 0 — asserted above
    return m


def _round_run(m: dict) -> dict:
    return {k: (round(v, 3) if isinstance(v, float) else v)
            for k, v in m.items() if k in RUN_KEYS
            or k in ("streams_completed", "leaked_pages")}


def bench(streams=64, slots=16, prompt=48, gen=48, chunk=32, page_size=16,
          seed=0, pool_frac=0.35, arch="brecq_lm_100m", out=MT_JSON) -> dict:
    cfg, model = get_model(arch, reduced=True)
    params = model.init(jax.random.PRNGKey(seed))
    # serve what deployment ships: packed W4, saved + reloaded verified
    with tempfile.TemporaryDirectory(prefix="brecq_mt_") as d:
        rtn_artifact(params, 4, cfg=cfg).save(d)
        art = QuantizedArtifact.load(d)
    kw = dict(streams=streams, slots=slots, prompt=prompt, gen=gen,
              chunk=chunk, page_size=page_size, seed=seed)

    runs = {}
    for kv_dtype in ("int8", "float16"):
        m = run_streams(model, art.params, art.hook(), kv_dtype, **kw)
        key = "fp16" if kv_dtype == "float16" else kv_dtype
        runs[key] = _round_run(m)
        print(f"[{key}] {streams} streams/{slots} slots: "
              f"{m['tokens_generated']} tokens, "
              f"{m['sustained_tok_s']:.1f} tok/s sustained, occupancy "
              f"{m['mean_slot_occupancy']:.2f}, resident KV "
              f"{m['mean_resident_kv_bytes_per_stream']/1e3:.1f} KB/stream")

    # pressure: identical arrivals on a pool at pool_frac of worst-case
    # demand. Worst-case reservation serializes admission; optimistic
    # 'prompt' admission packs slots and preempts on exhaustion — every
    # stream must still complete with zero leaked pages.
    pages_per = -(-(prompt + gen) // page_size)
    press_pages = 1 + max(pages_per, int(pool_frac * slots * pages_per))
    pressure = {"pool_frac": pool_frac, "num_pages": press_pages}
    for oc in ("none", "prompt"):
        m = run_streams(model, art.params, art.hook(), "int8",
                        overcommit=oc, num_pages=press_pages, **kw)
        pressure[oc] = _round_run(m)
        print(f"[pressure/{oc}] occupancy {m['mean_slot_occupancy']:.2f}, "
              f"{m['sustained_tok_s']:.1f} tok/s, "
              f"{m['preemptions']} preemptions "
              f"({m['replay_prefill_chunks']} replayed chunks / "
              f"{m['decode_ticks']} decode ticks)")
    pressure["occupancy_gain"] = round(
        pressure["prompt"]["mean_slot_occupancy"]
        / max(pressure["none"]["mean_slot_occupancy"], 1e-9), 3)
    pressure["preemption_overhead"] = round(
        pressure["prompt"]["replay_prefill_chunks"]
        / max(pressure["prompt"]["decode_ticks"], 1), 3)

    ratio = (runs["fp16"]["mean_resident_kv_bytes_per_stream"]
             / max(runs["int8"]["mean_resident_kv_bytes_per_stream"], 1e-9))
    out_doc = {
        "config": {"arch": arch, "reduced": True, "streams": streams,
                   "slots": slots, "prompt_len": prompt, "gen_len": gen,
                   "prefill_chunk": chunk, "page_size": page_size,
                   "w_bits": 4, "seed": seed, "pool_frac": pool_frac,
                   "backend": jax.default_backend()},
        "int8": runs["int8"],
        "fp16": runs["fp16"],
        "pressure": pressure,
        "kv_bytes_ratio_fp16_over_int8": round(ratio, 3),
        "sustained_tok_s_int8": runs["int8"]["sustained_tok_s"],
    }
    Path(out).write_text(json.dumps(out_doc, indent=1) + "\n")
    print(f"serve-mt bench -> {Path(out).name}: int8 KV "
          f"{ratio:.2f}x lower resident bytes/stream than fp16 "
          f"({runs['int8']['sustained_tok_s']} tok/s sustained); overcommit "
          f"occupancy x{pressure['occupancy_gain']:.2f} over worst-case at "
          f"{pool_frac:.0%} pool ({pressure['prompt']['preemptions']} "
          "preemptions)")
    return out_doc


def main(argv=None):
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--streams", type=int, default=64)
    p.add_argument("--slots", type=int, default=16)
    p.add_argument("--prompt", type=int, default=48)
    p.add_argument("--gen", type=int, default=48)
    p.add_argument("--chunk", type=int, default=32)
    p.add_argument("--page-size", type=int, default=16)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--pool-frac", type=float, default=0.35,
                   help="pressure-section pool size as a fraction of "
                        "worst-case page demand")
    p.add_argument("--out", default=str(MT_JSON))
    args = p.parse_args(argv)
    return bench(streams=args.streams, slots=args.slots, prompt=args.prompt,
                 gen=args.gen, chunk=args.chunk, page_size=args.page_size,
                 seed=args.seed, pool_frac=args.pool_frac, out=args.out)


if __name__ == "__main__":
    main()
