"""Table 7 (beyond paper): multi-stream continuous-batching serve bench.

Drives the serve engine with N concurrent synthetic request streams —
staggered arrivals, mixed prompt/generation lengths — over a packed W4
artifact, once with the int8 paged KV cache (the ``kernels/kvattn``
decode path) and once with fp16 KV pools as the reference mode. Tracked
in ``BENCH_serve_mt.json`` at the repo root:

  * sustained tok/s (all generated tokens / serving wall, compile AOT'd
    out),
  * mean resident KV bytes per active stream (pages-in-use x bytes/page,
    sampled every decode tick),
  * mean decode-slot occupancy,
  * the headline ratio: fp16 resident KV bytes / int8 resident KV bytes
    (>= 1.8x is the acceptance bar; int8 codes + f16 scales vs f16
    values).

Both passes use identical arrivals and lengths (same seed, and page
consumption depends only on lengths), so the byte ratio is exact, not
sampled noise. The CI ``serve-mt-smoke`` job runs a reduced 8-stream
variant of this file and checks the same schema + zero leaked pages.
"""
from __future__ import annotations

import argparse
import json
import tempfile
from pathlib import Path

import jax
import numpy as np

from repro.deploy import QuantizedArtifact, rtn_artifact
from repro.models import get_model
from repro.serve_engine import EngineConfig, ServeEngine

MT_JSON = Path(__file__).resolve().parents[1] / "BENCH_serve_mt.json"

SCHEMA_KEYS = ("config", "int8", "fp16", "kv_bytes_ratio_fp16_over_int8",
               "sustained_tok_s_int8")
RUN_KEYS = ("sustained_tok_s", "tokens_generated", "mean_slot_occupancy",
            "mean_resident_kv_bytes_per_stream", "bytes_per_page",
            "peak_pages_in_use", "compile_s", "decode_ticks")


def run_streams(model, weights, hook, kv_dtype, *, streams, slots, prompt,
                gen, chunk, page_size, seed) -> dict:
    """One full engine run; returns engine metrics + completion proof."""
    max_len = prompt + gen
    pages_per = -(-max_len // page_size)
    ecfg = EngineConfig(num_slots=slots, page_size=page_size,
                        num_pages=1 + slots * pages_per, max_len=max_len,
                        prefill_chunk=min(chunk, prompt),
                        kv_dtype=kv_dtype)
    eng = ServeEngine(model, weights, ecfg, quant=hook)
    eng.compile()

    rng = np.random.default_rng(seed)
    arrivals = np.sort(rng.integers(0, 2 * streams, streams))
    arrivals[0] = 0
    plens = rng.integers(max(prompt // 2, 1), prompt + 1, streams)
    gens = rng.integers(max(gen // 2, 1), gen + 1, streams)
    prompts = [rng.integers(0, model.cfg.vocab, size=int(plens[i]))
               for i in range(streams)]
    nxt = 0
    while nxt < streams or eng.pending():
        while nxt < streams and arrivals[nxt] <= eng.tick:
            eng.submit(prompts[nxt], int(gens[nxt]))
            nxt += 1
        eng.step()
    eng.assert_no_leaks()  # zero leaked pages is part of the bench contract
    done = sum(r.state == "done" for r in eng.requests.values())
    assert done == streams, f"only {done}/{streams} streams completed"
    m = eng.metrics()
    m["streams_completed"] = done
    return m


def bench(streams=64, slots=16, prompt=64, gen=32, chunk=16, page_size=16,
          seed=0, arch="brecq_lm_100m", out=MT_JSON) -> dict:
    cfg, model = get_model(arch, reduced=True)
    params = model.init(jax.random.PRNGKey(seed))
    # serve what deployment ships: packed W4, saved + reloaded verified
    with tempfile.TemporaryDirectory(prefix="brecq_mt_") as d:
        rtn_artifact(params, 4, cfg=cfg).save(d)
        art = QuantizedArtifact.load(d)
    kw = dict(streams=streams, slots=slots, prompt=prompt, gen=gen,
              chunk=chunk, page_size=page_size, seed=seed)

    runs = {}
    for kv_dtype in ("int8", "float16"):
        m = run_streams(model, art.params, art.hook(), kv_dtype, **kw)
        key = "fp16" if kv_dtype == "float16" else kv_dtype
        runs[key] = {k: (round(v, 3) if isinstance(v, float) else v)
                     for k, v in m.items() if k in RUN_KEYS
                     or k == "streams_completed"}
        print(f"[{key}] {streams} streams/{slots} slots: "
              f"{m['tokens_generated']} tokens, "
              f"{m['sustained_tok_s']:.1f} tok/s sustained, occupancy "
              f"{m['mean_slot_occupancy']:.2f}, resident KV "
              f"{m['mean_resident_kv_bytes_per_stream']/1e3:.1f} KB/stream")

    ratio = (runs["fp16"]["mean_resident_kv_bytes_per_stream"]
             / max(runs["int8"]["mean_resident_kv_bytes_per_stream"], 1e-9))
    out_doc = {
        "config": {"arch": arch, "reduced": True, "streams": streams,
                   "slots": slots, "prompt_len": prompt, "gen_len": gen,
                   "prefill_chunk": chunk, "page_size": page_size,
                   "w_bits": 4, "seed": seed,
                   "backend": jax.default_backend()},
        "int8": runs["int8"],
        "fp16": runs["fp16"],
        "kv_bytes_ratio_fp16_over_int8": round(ratio, 3),
        "sustained_tok_s_int8": runs["int8"]["sustained_tok_s"],
    }
    Path(out).write_text(json.dumps(out_doc, indent=1) + "\n")
    print(f"serve-mt bench -> {Path(out).name}: int8 KV "
          f"{ratio:.2f}x lower resident bytes/stream than fp16 "
          f"({runs['int8']['sustained_tok_s']} tok/s sustained)")
    return out_doc


def main(argv=None):
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--streams", type=int, default=64)
    p.add_argument("--slots", type=int, default=16)
    p.add_argument("--prompt", type=int, default=64)
    p.add_argument("--gen", type=int, default=32)
    p.add_argument("--chunk", type=int, default=16)
    p.add_argument("--page-size", type=int, default=16)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--out", default=str(MT_JSON))
    args = p.parse_args(argv)
    return bench(streams=args.streams, slots=args.slots, prompt=args.prompt,
                 gen=args.gen, chunk=args.chunk, page_size=args.page_size,
                 seed=args.seed, out=args.out)


if __name__ == "__main__":
    main()
