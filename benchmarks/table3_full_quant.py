"""Paper Table 3: fully-quantized models (activations at 4-bit).

W4A4 and W2A4: RTN+calibrated act scales, LAPQ, AdaQuant, BRECQ (LSQ
learned act step sizes). Claim: BRECQ is the only usable W2A4."""
from __future__ import annotations

import time

from repro.core import ReconConfig
from repro.core.baselines import (quantize_adaquant, quantize_lapq,
                                  quantize_rtn)
from repro.core.evaluate import evaluate

from .common import RECON_ITERS, cached_brecq, emit, get_bench_model

A_BITS = 4


def main() -> list[dict]:
    cfg, model, params, calib, evalb = get_bench_model()
    fp = evaluate(model, params, evalb)
    rows = [{"name": "fp32", "us_per_call": 0,
             "derived": f"loss={fp['loss']:.4f};top1={fp['top1']:.4f}"}]

    def add(name, fn):
        t0 = time.time()
        pq, scales = fn()
        wall = time.time() - t0
        ev = evaluate(model, pq, evalb, scales, a_bits=A_BITS)
        rows.append({"name": name, "us_per_call": wall * 1e6,
                     "derived": f"loss={ev['loss']:.4f};top1={ev['top1']:.4f}",
                     "loss": ev["loss"], "top1": ev["top1"]})
        print(f"  [{name}] loss {ev['loss']:.4f} top1 {ev['top1']:.4f}")

    for bits in (4, 2):
        add(f"rtn_w{bits}a{A_BITS}",
            lambda b=bits: quantize_rtn(model, params, calib, b, a_bits=A_BITS))
        add(f"lapq_w{bits}a{A_BITS}",
            lambda b=bits: quantize_lapq(model, params, calib, b, a_bits=A_BITS))
        add(f"adaquant_w{bits}a{A_BITS}",
            lambda b=bits: quantize_adaquant(model, params, calib, b,
                                             a_bits=A_BITS, iters=RECON_ITERS // 2))
        def brecq(b=bits):
            res = cached_brecq(model, params, calib,
                               ReconConfig(w_bits=b, a_bits=A_BITS,
                                           iters=RECON_ITERS),
                               f"t3_brecq_w{b}a{A_BITS}")
            return res["params_q"], res["act_scales"]

        add(f"brecq_w{bits}a{A_BITS}", brecq)
    emit(rows, "table3")
    return rows


if __name__ == "__main__":
    main()
