"""Roofline table from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Reads artifacts/dryrun/*.json (written by launch/dryrun.py) and emits the
per-(arch x shape x mesh) three-term table with bottleneck + notes.
"""
from __future__ import annotations

import json
from pathlib import Path

ART = Path(__file__).resolve().parents[1] / "artifacts" / "dryrun"

FIX_HINTS = {
    "compute": "increase arithmetic intensity (fuse, larger per-chip batch)",
    "memory": "cut HBM traffic: quantized weights/KV, better remat policy",
    "collective": "reshard: fewer TP psums / EP all-to-alls, overlap with compute",
}


def load_rows():
    rows = []
    for f in sorted(ART.glob("*.json")):
        d = json.loads(f.read_text())
        if d.get("tag"):
            continue  # variants are reported in §Perf, not the baseline table
        r = d["roofline"]
        rows.append({
            "arch": d["arch"], "shape": d["shape"], "mesh": d["mesh"],
            "strategy": d["strategy"],
            "compute_s": r["compute_s"], "memory_s": r["memory_s"],
            "collective_s": r["collective_s"], "bottleneck": r["bottleneck"],
            "model_flops": r["model_flops_per_chip"],
            "hlo_flops": r["hlo_flops_per_chip"],
            "useful_ratio": r["useful_ratio"],
            "roofline_frac": r["roofline_frac"],
            "fits_16gb": d.get("fits_16gb"),
            "per_chip_gb": d.get("per_chip_bytes_tpu_corrected",
                                 d.get("per_chip_bytes", 0)) / 1e9,
            "fix": FIX_HINTS[r["bottleneck"]],
        })
    return rows


def main() -> list[dict]:
    rows = load_rows()
    if not rows:
        print("roofline/none,0,run `python -m repro.launch.dryrun --all` first")
        return []
    hdr = (f"{'arch':24s} {'shape':12s} {'mesh':6s} {'strat':6s} "
           f"{'compute':>9s} {'memory':>9s} {'collect':>9s} {'bound':>10s} "
           f"{'useful':>7s} {'frac':>6s} {'GB/chip':>8s} fit")
    print(hdr)
    for r in sorted(rows, key=lambda x: (x["arch"], x["shape"], x["mesh"])):
        print(f"{r['arch']:24s} {r['shape']:12s} {r['mesh']:6s} {r['strategy']:6s} "
              f"{r['compute_s']:9.4f} {r['memory_s']:9.4f} {r['collective_s']:9.4f} "
              f"{r['bottleneck']:>10s} {r['useful_ratio']:7.3f} "
              f"{r['roofline_frac']:6.3f} {r['per_chip_gb']:8.1f} "
              f"{'Y' if r['fits_16gb'] else 'N'}")
    for r in rows:
        print(f"roofline/{r['arch']}_{r['shape']}_{r['mesh']},"
              f"{max(r['compute_s'], r['memory_s'], r['collective_s']) * 1e6:.0f},"
              f"bound={r['bottleneck']};frac={r['roofline_frac']:.3f}")
    return rows


if __name__ == "__main__":
    main()
