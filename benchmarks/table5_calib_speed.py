"""Table 5 (repo extension): calibration-loop throughput before/after.

BRECQ's practical pitch is cheap calibration, so the loop's iterations
per second is the headline systems metric. For each reconstruction
granularity we run the same quantization twice:

  * ``python``  — the pre-optimization dispatch pattern (one jitted step
    per iteration, host-side loss sync every iteration);
  * ``scan``    — the fused device-resident loop (one dispatch + one
    sync per unit, compiled-unit cache across identical blocks).

Both run the identical traced step body, so the loss trajectories match
and the delta is pure dispatch/sync/retrace overhead.
"""
from __future__ import annotations

import time

from repro.core import ReconConfig, quantize
from repro.core import fisher
from repro.core.calib_loop import clear_cache

from .common import RECON_ITERS, emit, get_bench_model

W_BITS = 4
GRANULARITIES = ("layer", "block", "stage", "net")


def main() -> list[dict]:
    cfg, model, params, calib, _evalb = get_bench_model()
    rows = []
    for gran in GRANULARITIES:
        ips = {}
        for impl in ("python", "scan"):
            clear_cache()  # cold-start both impls: tracing cost counts
            fisher.clear_cache()  # incl. the per-block Fisher grad jits
            rc = ReconConfig(w_bits=W_BITS, iters=RECON_ITERS,
                             granularity=gran, use_fisher=(gran != "layer"),
                             loop_impl=impl)
            t0 = time.time()
            res = quantize(model, params, calib, rc)
            wall = time.time() - t0
            ips[impl] = res.stats["calib_iters_per_s"]
            cache = res.stats["unit_cache"]
            mem = res.stats["calib_peak_bytes_detail"]
            rows.append({
                "name": f"{gran}_{impl}",
                "us_per_call": wall * 1e6,
                "derived": (f"calib_iters_per_s={ips[impl]:.1f};"
                            f"wall_s={res.stats['calib_wall_s']:.1f};"
                            f"fisher_wall_s={res.stats['fisher_wall_s']:.1f};"
                            f"peak_mb={res.stats['calib_peak_bytes'] / 1e6:.1f};"
                            f"fisher_mb={mem['fisher'] / 1e6:.1f};"
                            f"cache_hits={cache['hits']};"
                            f"cache_misses={cache['misses']}"),
                "calib_iters_per_s": ips[impl],
                "calib_peak_bytes": res.stats["calib_peak_bytes"],
            })
        rows.append({
            "name": f"{gran}_speedup", "us_per_call": 0,
            "derived": f"scan/python={ips['scan'] / max(ips['python'], 1e-9):.1f}x",
        })
    emit(rows, "table5")
    return rows


if __name__ == "__main__":
    main()
