"""Paper Fig. 2: mixed precision Pareto vs unified precision.

Pipeline exactly as the paper: calibrate unified 2/4/8-bit models once,
tabulate diagonal + intra-block 2-bit pair sensitivities, then sweep
model-size budgets with the genetic algorithm (Algorithm 2) and run the
final block reconstruction at the chosen per-layer bits.

Claim: mixed precision Pareto-dominates the unified-precision points."""
from __future__ import annotations

import time

from repro.core import ReconConfig
from repro.core.evaluate import evaluate
from repro.core.mixed_precision import (GAConfig, TPUCostModel,
                                        genetic_search, model_bytes)
from repro.core.sensitivity import SensTable, measure
from repro.deploy.budget import measure_cost_table

from .common import ART, RECON_ITERS, cached_brecq, emit, get_bench_model


def main() -> list[dict]:
    cfg, model, params, calib, evalb = get_bench_model()
    rows = []

    # 1. unified-precision calibrations (reused from table2 cache)
    results = {}
    for b in (2, 4, 8):
        res = cached_brecq(model, params, calib,
                           ReconConfig(w_bits=b, iters=RECON_ITERS),
                           f"t2_brecq_w{b}" if b != 8 else "fig2_brecq_w8")
        from repro.core import PTQResult

        results[b] = PTQResult(params_q=res["params_q"],
                               act_scales=res["act_scales"], qstates=res["qstates"],
                               v=res["v"], stats=res["stats"])
        ev = evaluate(model, res["params_q"], evalb)
        rows.append({"name": f"unified_w{b}", "us_per_call": 0,
                     "derived": f"loss={ev['loss']:.4f};bits={b}",
                     "loss": ev["loss"], "bits": float(b)})

    # 2. sensitivity lookup table (diag for 2/4/8 + intra-block 2-bit
    # pairs) — tabulated once and cached as JSON; the budget solver
    # (`serve --budget-bytes --sens`) reloads the same file.
    sens_path = ART / "fig2_sens.json"
    if sens_path.exists():
        sens = SensTable.load(sens_path)
        print(f"[fig2] sensitivity table: reloaded {sens_path.name} "
              f"({len(sens.diag)} diag, {len(sens.offdiag)} offdiag)")
    else:
        t0 = time.time()
        sens = measure(model, params, calib[:3], results,
                       bits_options=(2, 4, 8), n_samples=16)
        sens.save(sens_path)
        print(f"[fig2] sensitivity table: {len(sens.diag)} diag, "
              f"{len(sens.offdiag)} offdiag entries in "
              f"{time.time() - t0:.0f}s -> {sens_path.name}")

    # 3. GA sweep over model-size budgets
    full8 = model_bytes(sens.shapes, {p: 8 for p in sens.shapes})
    cost_fn = lambda a: model_bytes(sens.shapes, a)
    for frac in (0.35, 0.5, 0.7):
        t0 = time.time()
        assign, info = genetic_search(sens, cost_fn, full8 * frac,
                                      GAConfig(pop_size=50, iters=100))
        ga_s = time.time() - t0
        rc = ReconConfig(w_bits=4, iters=RECON_ITERS, per_layer_bits=assign)
        res = cached_brecq(model, params, calib, rc, f"fig2_mixed_{int(frac*100)}")
        ev = evaluate(model, res["params_q"], evalb)
        avg_bits = 8 * info["cost"] / full8
        rows.append({"name": f"mixed_{int(frac*100)}pct", "us_per_call": ga_s * 1e6,
                     "derived": (f"loss={ev['loss']:.4f};avg_bits={avg_bits:.2f};"
                                 f"fitness={info['fitness']:.4g};ga_s={ga_s:.1f}"),
                     "loss": ev["loss"], "bits": avg_bits})
        print(f"  [mixed_{int(frac*100)}pct] loss {ev['loss']:.4f} "
              f"avg_bits {avg_bits:.2f}")
    # latency-constrained variants: the analytic TPU roofline vs the
    # measured per-layer qmm tier cost (same GA, injected cost fn).
    # Decode-like regime (few tokens/step): the roofline says weight
    # streaming dominates so latency scales with bits; the measured
    # table says what the kernels on *this* backend actually do (on CPU
    # 2-bit unpack overhead makes W2 slower than W8 — BENCH_serve's
    # decode-tier result). Reporting both makes the gap visible.
    mtable = measure_cost_table(sens.shapes, m=8, inner=4, reps=2)
    variants = [
        ("analytic", TPUCostModel(tokens_per_step=32)),
        ("measured", TPUCostModel(tokens_per_step=32,
                                  layer_cost_fn=lambda p, s, b:
                                  mtable.cost(p, b) / 1e3)),
    ]
    for tag, cm in variants:
        lat_fn = lambda a, cm=cm: cm.model_latency_s(sens.shapes, a)
        uni = {b: lat_fn({p: b for p in sens.shapes}) for b in (2, 4, 8)}
        # halfway between the cheapest and slowest uniform point — the
        # measured table is not monotone in bits, so 0.5*lat8 can be
        # infeasible outright
        budget = min(uni.values()) + 0.5 * (max(uni.values()) - min(uni.values()))
        assign, info = genetic_search(sens, lat_fn, budget, GAConfig(iters=100))
        hist = dict(sorted(
            {b: sum(1 for v in assign.values() if v == b)
             for b in set(assign.values())}.items()))
        rc = ReconConfig(w_bits=4, iters=RECON_ITERS, per_layer_bits=assign)
        res = cached_brecq(model, params, calib, rc, f"fig2_mixed_lat50_{tag}")
        ev = evaluate(model, res["params_q"], evalb)
        rows.append({"name": f"mixed_lat50_{tag}", "us_per_call": 0,
                     "derived": (f"loss={ev['loss']:.4f};lat_frac=0.5;"
                                 f"fitness={info['fitness']:.4g};"
                                 f"bits_hist={hist}"),
                     "loss": ev["loss"]})
        print(f"  [mixed_lat50_{tag}] loss {ev['loss']:.4f} bits {hist} "
              f"budget {budget:.3g}s cost {info['cost']:.3g}s")
    emit(rows, "fig2")
    return rows


if __name__ == "__main__":
    main()
