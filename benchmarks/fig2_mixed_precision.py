"""Paper Fig. 2: mixed precision Pareto vs unified precision.

Pipeline exactly as the paper: calibrate unified 2/4/8-bit models once,
tabulate diagonal + intra-block 2-bit pair sensitivities, then sweep
model-size budgets with the genetic algorithm (Algorithm 2) and run the
final block reconstruction at the chosen per-layer bits.

Claim: mixed precision Pareto-dominates the unified-precision points."""
from __future__ import annotations

import time

from repro.core import ReconConfig
from repro.core.evaluate import evaluate
from repro.core.mixed_precision import (GAConfig, TPUCostModel,
                                        genetic_search, model_bytes)
from repro.core.sensitivity import measure

from .common import RECON_ITERS, cached_brecq, emit, get_bench_model


def main() -> list[dict]:
    cfg, model, params, calib, evalb = get_bench_model()
    rows = []

    # 1. unified-precision calibrations (reused from table2 cache)
    results = {}
    for b in (2, 4, 8):
        res = cached_brecq(model, params, calib,
                           ReconConfig(w_bits=b, iters=RECON_ITERS),
                           f"t2_brecq_w{b}" if b != 8 else "fig2_brecq_w8")
        from repro.core import PTQResult

        results[b] = PTQResult(params_q=res["params_q"],
                               act_scales=res["act_scales"], qstates=res["qstates"],
                               v=res["v"], stats=res["stats"])
        ev = evaluate(model, res["params_q"], evalb)
        rows.append({"name": f"unified_w{b}", "us_per_call": 0,
                     "derived": f"loss={ev['loss']:.4f};bits={b}",
                     "loss": ev["loss"], "bits": float(b)})

    # 2. sensitivity lookup table (diag for 2/4/8 + intra-block 2-bit pairs)
    t0 = time.time()
    sens = measure(model, params, calib[:3], results, bits_options=(2, 4, 8),
                   n_samples=16)
    t_sens = time.time() - t0
    print(f"[fig2] sensitivity table: {len(sens.diag)} diag, "
          f"{len(sens.offdiag)} offdiag entries in {t_sens:.0f}s")

    # 3. GA sweep over model-size budgets
    full8 = model_bytes(sens.shapes, {p: 8 for p in sens.shapes})
    cost_fn = lambda a: model_bytes(sens.shapes, a)
    for frac in (0.35, 0.5, 0.7):
        t0 = time.time()
        assign, info = genetic_search(sens, cost_fn, full8 * frac,
                                      GAConfig(pop_size=50, iters=100))
        ga_s = time.time() - t0
        rc = ReconConfig(w_bits=4, iters=RECON_ITERS, per_layer_bits=assign)
        res = cached_brecq(model, params, calib, rc, f"fig2_mixed_{int(frac*100)}")
        ev = evaluate(model, res["params_q"], evalb)
        avg_bits = 8 * info["cost"] / full8
        rows.append({"name": f"mixed_{int(frac*100)}pct", "us_per_call": ga_s * 1e6,
                     "derived": (f"loss={ev['loss']:.4f};avg_bits={avg_bits:.2f};"
                                 f"fitness={info['fitness']:.4g};ga_s={ga_s:.1f}"),
                     "loss": ev["loss"], "bits": avg_bits})
        print(f"  [mixed_{int(frac*100)}pct] loss {ev['loss']:.4f} "
              f"avg_bits {avg_bits:.2f}")
    # latency-constrained variant (TPU cost model instead of bytes).
    # Decode-like regime (few tokens/step): weight streaming dominates so
    # latency actually scales with bits — at large token counts the model
    # is compute-bound and every bit-width costs the same (measured: the
    # 4096-token variant makes a 0.5x budget infeasible by construction).
    cm = TPUCostModel(tokens_per_step=32)
    lat_fn = lambda a: cm.model_latency_s(sens.shapes, a)
    lat8 = lat_fn({p: 8 for p in sens.shapes})
    assign, info = genetic_search(sens, lat_fn, lat8 * 0.5, GAConfig(iters=100))
    rc = ReconConfig(w_bits=4, iters=RECON_ITERS, per_layer_bits=assign)
    res = cached_brecq(model, params, calib, rc, "fig2_mixed_lat50")
    ev = evaluate(model, res["params_q"], evalb)
    rows.append({"name": "mixed_lat50pct", "us_per_call": 0,
                 "derived": f"loss={ev['loss']:.4f};lat_frac=0.5",
                 "loss": ev["loss"]})
    emit(rows, "fig2")
    return rows


if __name__ == "__main__":
    main()
