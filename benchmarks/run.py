"""Benchmark entry point: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows per table (scaffold
contract) and saves JSON artifacts under artifacts/bench/.
"""
from __future__ import annotations

import sys
import time


def main() -> None:
    from . import (fig2_mixed_precision, roofline_table, table1_granularity,
                   table2_weight_only, table3_full_quant, table4_cost,
                   table5_calib_speed, table6_deploy)

    tables = [
        ("roofline_table", roofline_table.main),  # instant: reads dry-run artifacts
        ("table1_granularity", table1_granularity.main),
        ("table2_weight_only", table2_weight_only.main),
        ("table3_full_quant", table3_full_quant.main),
        ("table4_cost", table4_cost.main),
        ("table5_calib_speed", table5_calib_speed.main),
        ("table6_deploy", table6_deploy.main),
        ("fig2_mixed_precision", fig2_mixed_precision.main),
    ]
    only = sys.argv[1:] if len(sys.argv) > 1 else None
    for name, fn in tables:
        if only and name not in only:
            continue
        print(f"\n===== {name} =====")
        t0 = time.time()
        try:
            fn()
            print(f"===== {name} done in {time.time()-t0:.0f}s =====")
        except Exception as e:  # one table must not sink the suite
            print(f"===== {name} FAILED after {time.time()-t0:.0f}s: "
                  f"{type(e).__name__}: {e} =====")


if __name__ == "__main__":
    main()
