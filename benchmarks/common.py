"""Shared benchmark substrate: one trained bench model + cached PTQ runs.

The bench model plays ResNet-18's role at CPU-benchmark scale: big enough
that 2-bit RTN visibly collapses, small enough to calibrate in minutes.
Everything is cached under artifacts/bench/ so tables compose.
"""
from __future__ import annotations

import json
import pickle
import time
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.core import ReconConfig, quantize
from repro.core.evaluate import evaluate
from repro.data import Corpus, CorpusConfig, make_batches
from repro.models import get_model
from repro.optim import adam

ART = Path(__file__).resolve().parents[1] / "artifacts" / "bench"
ART.mkdir(parents=True, exist_ok=True)

BENCH_ARCH = "brecq_lm_100m"
TRAIN_STEPS = 400
BATCH, SEQ = 16, 96
N_CALIB = 64  # sequences (paper: 1024 images; scaled to CPU budget)
RECON_ITERS = 80  # paper: 20k/block; scaled to the CPU budget


def bench_config():
    import dataclasses

    from repro.models import get_config

    cfg = get_config(BENCH_ARCH, reduced=False)
    # CPU-bench scale of the same family (full 100M is for examples/)
    return dataclasses.replace(cfg, n_layers=6, d_model=256, n_heads=8,
                               n_kv_heads=8, d_ff=704, vocab=2048)


def get_bench_model(train_steps: int = TRAIN_STEPS):
    """(cfg, model, params, calib_batches, eval_batches); cached on disk."""
    from repro.models import build_model

    cfg = bench_config()
    model = build_model(cfg)
    corpus = Corpus(CorpusConfig(vocab=cfg.vocab))
    cache = ART / "bench_params.pkl"
    if cache.exists():
        with open(cache, "rb") as f:
            params = jax.tree.map(jnp.asarray, pickle.load(f))
    else:
        params = model.init(jax.random.PRNGKey(0))
        acfg = adam.AdamConfig(lr=3e-3, grad_clip=1.0)
        state = adam.init(params)

        # device-resident training: pregenerate the token stream and scan
        # over step chunks — one dispatch + one loss sync per chunk
        # instead of one of each per step.
        @jax.jit
        def run_chunk(params, state, tokens):
            def step(carry, toks):
                params, state = carry
                loss, g = jax.value_and_grad(
                    lambda p: model.loss(p, {"tokens": toks}, remat="none"))(params)
                params, state = adam.update(acfg, g, state, params)
                return (params, state), loss

            (params, state), losses = jax.lax.scan(step, (params, state), tokens)
            return params, state, losses

        t0 = time.time()
        chunk = 100
        for c0 in range(0, train_steps, chunk):
            n = min(chunk, train_steps - c0)
            toks = jnp.stack([make_batches(corpus, 1, BATCH, SEQ, seed=0,
                                           start_step=c0 + i)[0]["tokens"]
                              for i in range(n)])
            params, state, losses = run_chunk(params, state, toks)
            print(f"[bench-train] step {c0 + n} loss {float(losses[-1]):.3f}")
        print(f"[bench-train] {train_steps} steps in {time.time()-t0:.0f}s, "
              f"final loss {float(losses[-1]):.3f}")
        with open(cache, "wb") as f:
            pickle.dump(jax.device_get(params), f)
    calib = make_batches(corpus, N_CALIB // 8, 8, SEQ, seed=1, start_step=10_000)
    evalb = make_batches(corpus, 4, 16, SEQ, seed=2, start_step=20_000)
    return cfg, model, params, calib, evalb


def cached_brecq(model, params, calib, rc: ReconConfig, tag: str):
    """BRECQ result cache keyed by tag (fig2 reuses table runs).

    Wall time comes from ``quantize()`` itself (stats['calib_wall_s']),
    so a cache-miss run can never report 0."""
    f = ART / f"brecq_{tag}.pkl"
    if f.exists():
        with open(f, "rb") as fh:
            return pickle.load(fh)
    res = quantize(model, params, calib, rc)
    with open(f, "wb") as fh:
        pickle.dump(jax.device_get(
            {"params_q": res.params_q, "act_scales": res.act_scales,
             "v": res.v, "qstates": res.qstates, "stats": res.stats}), fh)
    with open(f, "rb") as fh:
        return pickle.load(fh)


def emit(rows: list[dict], table: str):
    """Print the scaffold CSV (name,us_per_call,derived) + save JSON."""
    for r in rows:
        print(f"{table}/{r['name']},{r.get('us_per_call', 0):.0f},{r['derived']}")
    (ART / f"{table}.json").write_text(json.dumps(rows, indent=1, default=float))
