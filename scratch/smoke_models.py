"""Dev smoke: every reduced arch runs forward / loss / prefill / decode."""
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import ARCH_IDS, get_model


def batch_for(cfg, B=2, S=32):
    rng = np.random.default_rng(0)
    b = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)))}
    if cfg.family == "vlm":
        b["patches"] = jnp.asarray(rng.normal(size=(B, cfg.n_patches, cfg.d_model)), jnp.float32)
    if cfg.enc_dec:
        b["frames"] = jnp.asarray(rng.normal(size=(B, S, cfg.d_model)), jnp.float32)
    return b


def main():
    only = sys.argv[1:] or ARCH_IDS
    for name in only:
        cfg, model = get_model(name, reduced=True)
        key = jax.random.PRNGKey(0)
        params = model.init(key)
        n_params = sum(x.size for x in jax.tree.leaves(params))
        batch = batch_for(cfg)
        logits, aux = model.forward(params, batch, remat="none")
        assert logits.shape == (2, 32, cfg.vocab), logits.shape
        assert bool(jnp.all(jnp.isfinite(logits))), f"{name}: NaN logits"
        loss = model.loss(params, batch, remat="none")
        g = jax.grad(lambda p: model.loss(p, batch, remat="dots"))(params)
        gn = jnp.sqrt(sum(jnp.sum(x.astype(jnp.float32) ** 2) for x in jax.tree.leaves(g)))
        # serving path
        cache = model.init_cache(2, 64)
        lp, cache = model.prefill(params, batch, cache, remat="none")
        assert lp.shape == (2, cfg.vocab)
        tok = jnp.argmax(lp, -1)[:, None]
        ld, cache = model.decode_step(params, tok, cache, jnp.full((2,), 32))
        assert lp.shape == ld.shape and bool(jnp.all(jnp.isfinite(ld)))
        # decode consistency vs full forward: run prefill of S, decode token S
        print(f"[ok] {name:24s} params={n_params:>9,} loss={float(loss):.3f} "
              f"gnorm={float(gn):.3f}")


if __name__ == "__main__":
    main()
