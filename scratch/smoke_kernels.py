import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quantizer import QConfig, init_qstate, quantize_int
from repro.kernels.qmatmul.kernel import qmatmul
from repro.kernels.qmatmul.ops import pack_weights, qmm
from repro.kernels.qmatmul.ref import qmatmul_ref
from repro.kernels.kvattn.kernel import kv_decode
from repro.kernels.kvattn.ops import quantize_kv
from repro.kernels.kvattn.ref import kv_decode_ref
from repro.kernels.fakequant.kernel import fakequant
from repro.kernels.fakequant.ref import fakequant_ref

rng = np.random.default_rng(0)

# --- qmatmul ---
for bits in (8, 4, 2):
    for (M, K, N, G) in [(8, 256, 128, 128), (128, 512, 256, 1)]:
        w = jnp.asarray(rng.normal(size=(K, N)), jnp.float32)
        cfg = QConfig(bits=bits, channel_axis=-1,
                      group_size=(G if G > 1 else None))
        st = init_qstate(w, cfg)
        codes = quantize_int(w, st, cfg)
        scales = st.scale.reshape(-1, N)
        x = jnp.asarray(rng.normal(size=(M, K)), jnp.float32)
        ref = qmatmul_ref(x, pack_weights(codes, scales, bits).packed, scales, bits)
        out = qmatmul(x, pack_weights(codes, scales, bits).packed, scales,
                      bits=bits, bm=8 if M == 8 else 128, interpret=True)
        err = float(jnp.max(jnp.abs(out - ref)))
        print(f"qmatmul bits={bits} M{M} K{K} N{N} G{G}: maxerr {err:.2e}")
        assert err < 1e-3

# --- kvattn ---
for (B, H, Kh, hd, S, bs) in [(2, 8, 2, 64, 256, 128), (1, 4, 4, 32, 128, 128)]:
    q = jnp.asarray(rng.normal(size=(B, H, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, Kh, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, Kh, hd)), jnp.float32)
    k8, v8, ks, vs = quantize_kv(k, v)
    kpos = jnp.broadcast_to(jnp.arange(S), (B, S))
    cur = jnp.full((B,), S // 2, jnp.int32)
    ref = kv_decode_ref(q, k8, v8, ks, vs, kpos, cur)
    out = kv_decode(q, k8, v8, ks, vs, kpos, cur, bs=bs, interpret=True)
    err = float(jnp.max(jnp.abs(out - ref)))
    print(f"kvattn B{B} H{H} K{Kh} S{S}: maxerr {err:.2e}")
    assert err < 1e-4
    # windowed
    ref = kv_decode_ref(q, k8, v8, ks, vs, kpos, cur, window=64)
    out = kv_decode(q, k8, v8, ks, vs, kpos, cur, window=64, bs=bs, interpret=True)
    assert float(jnp.max(jnp.abs(out - ref))) < 1e-4

# --- fakequant ---
for hard in (False, True):
    K, N = 256, 256
    w = jnp.asarray(rng.normal(size=(K, N)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(K, N)), jnp.float32)
    s = jnp.asarray(rng.uniform(0.01, 0.1, size=(1, N)), jnp.float32)
    ref = fakequant_ref(w, v, s, -8, 7, hard)
    out = fakequant(w, v, s, qmin=-8, qmax=7, hard=hard, interpret=True)
    err = float(jnp.max(jnp.abs(out - ref)))
    print(f"fakequant hard={hard}: maxerr {err:.2e}")
    assert err < 1e-6

print("kernels ok")
