"""Dev smoke: BRECQ end-to-end on a tiny trained LM.

Expect: FP < BRECQ-W2 << RTN-W2 in loss; W4 near FP.
"""
import time

import jax
import jax.numpy as jnp

from repro.core import ReconConfig, quantize
from repro.core.baselines import quantize_rtn
from repro.core.evaluate import evaluate
from repro.data import Corpus, CorpusConfig, make_batches
from repro.models import get_model
from repro.optim import adam


def train_tiny(model, params, corpus, steps=300, B=16, S=64, lr=3e-3):
    acfg = adam.AdamConfig(lr=lr, grad_clip=1.0)
    state = adam.init(params)

    @jax.jit
    def step(params, state, batch):
        loss, g = jax.value_and_grad(lambda p: model.loss(p, batch, remat="none"))(params)
        params, state = adam.update(acfg, g, state, params)
        return params, state, loss

    for i in range(steps):
        batch = make_batches(corpus, 1, B, S, seed=0, start_step=i)[0]
        params, state, loss = step(params, state, batch)
        if i % 100 == 0:
            print(f"  step {i}: loss {float(loss):.3f}")
    return params


def main():
    cfg, model = get_model("brecq_lm_100m", reduced=True)
    corpus = Corpus(CorpusConfig(vocab=cfg.vocab))
    params = model.init(jax.random.PRNGKey(0))
    t0 = time.time()
    params = train_tiny(model, params, corpus, steps=300)
    print(f"trained in {time.time()-t0:.0f}s")

    calib = make_batches(corpus, 8, 8, 64, seed=1, start_step=1000)
    evalb = make_batches(corpus, 4, 16, 64, seed=2, start_step=2000)

    fp = evaluate(model, params, evalb)
    print("FP    :", fp)

    for bits in (4, 2):
        pq, _ = quantize_rtn(model, params, calib, w_bits=bits)
        r = evaluate(model, pq, evalb)
        print(f"RTN-W{bits}:", r)

        rc = ReconConfig(w_bits=bits, iters=150, calib_bs=8)
        t0 = time.time()
        res = quantize(model, params, calib, rc)
        br = evaluate(model, res.params_q, evalb)
        print(f"BRECQ-W{bits}: {br}  ({time.time()-t0:.0f}s, "
              f"unit0 mse {res.stats['units'][0]['final_recon_mse']:.4g})")


if __name__ == "__main__":
    main()
